"""Fig 8 analog: per-module transient power over PTI bins for one model."""
from __future__ import annotations

from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import resnet50
from repro.hw.chip import System
from repro.hw.presets import paper_skew
from repro.power.powerem import PowerEM

from .common import save_json


def run(pti_ns: float = 20_000.0) -> dict:
    cfg = paper_skew()
    ops = resnet50()
    cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
    sysm = System(cfg, n_tiles=2)
    rep = sysm.run_workload(cw.tasks)
    pem = PowerEM(cfg, n_tiles=2)
    prep = pem.analyze(sysm.tracer, pti_ns=pti_ns)
    out = {
        "pti_ns": pti_ns,
        "makespan_ms": rep.makespan_ns / 1e6,
        "series_w": prep.series,
        "peak_w": prep.peak_w,
        "avg_w": prep.avg_w,
        "energy_mj_per_inf": prep.energy_j() * 1e3,
    }
    save_json("power_profile.json", out)
    return out


def main(print_csv=True):
    out = run()
    if print_csv:
        print(f"# Fig-8 analog: transient power, PTI={out['pti_ns']/1e3:.0f}us"
              f"  (peak {out['peak_w']:.1f} W, avg {out['avg_w']:.1f} W,"
              f" {out['energy_mj_per_inf']:.2f} mJ/inf)")
        mods = sorted(out["series_w"])
        n = len(next(iter(out["series_w"].values())))
        head = "bin   " + " ".join(f"{m:>12s}" for m in mods)
        print(head)
        for b in range(min(n, 8)):
            print(f"{b:4d}  " + " ".join(
                f"{out['series_w'][m][b]:12.2f}" for m in mods))
    return out


if __name__ == "__main__":
    main()
