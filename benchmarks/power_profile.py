"""Fig 8 analog: per-module transient power over PTI bins for one model.

A single-point campaign with ``keep_series=True``: the runner's cache
makes the (relatively slow) full ResNet50 event run + Power-EM trace
incremental across benchmark invocations.
"""
from __future__ import annotations

from typing import Optional

from repro.sweep import RefineSpec, SweepSpec

from .common import run_and_save_campaign, save_json


def campaign_spec(pti_ns: float = 20_000.0) -> SweepSpec:
    return SweepSpec(
        name="power_profile",
        description="Fig 8: per-module transient power (PTI-resolved)",
        workloads=["resnet50"],
        preset="paper_skew",
        axes={},
        n_tiles=[2],
        refine=RefineSpec(mode="all", pti_ns=pti_ns, keep_series=True),
    )


def run(pti_ns: float = 20_000.0, workers: Optional[int] = 0) -> dict:
    res = run_and_save_campaign(campaign_spec(pti_ns), workers=workers)
    (rec,) = res.refined
    out = {
        "pti_ns": pti_ns,
        "makespan_ms": rec["time_ns"] / 1e6,
        "series_w": rec["series_w"],
        "peak_w": rec["peak_w"],
        "avg_w": rec["avg_w"],
        "energy_mj_per_inf": rec["energy_j"] * 1e3,
    }
    save_json("power_profile.json", out)
    return {**out, "campaign": res.summary}


def main(print_csv=True):
    out = run()
    if print_csv:
        print(f"# Fig-8 analog: transient power, PTI={out['pti_ns']/1e3:.0f}us"
              f"  (peak {out['peak_w']:.1f} W, avg {out['avg_w']:.1f} W,"
              f" {out['energy_mj_per_inf']:.2f} mJ/inf)")
        mods = sorted(out["series_w"])
        n = len(next(iter(out["series_w"].values())))
        head = "bin   " + " ".join(f"{m:>12s}" for m in mods)
        print(head)
        for b in range(min(n, 8)):
            print(f"{b:4d}  " + " ".join(
                f"{out['series_w'][m][b]:12.2f}" for m in mods))
    return out


if __name__ == "__main__":
    main()
