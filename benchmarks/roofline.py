"""Roofline analysis: three terms per (arch x shape x mesh) cell from the
dry-run artifacts (deliverable g).

  compute_s    = HLO dot FLOPs / (peak bf16 FLOP/s)          [per chip]
  memory_s     = HLO HBM bytes / HBM BW                      [per chip]
  collective_s = ring link-bytes: intra-pod / ICI BW + cross-pod / DCN BW

Sources: trip-count-aware HLO parsing (graph.hlo_parser) of the compiled
per-device modules saved by launch/dryrun. Also reports MODEL_FLOPS
(6*N*D analytic) over HLO FLOPs — the useful-compute ratio that exposes
remat/redundancy waste — and a rule-based "what moves the dominant term"
suggestion per cell.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI, 25 GB/s DCN.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
from typing import Dict, Optional

from repro.configs import get_config, get_shape
from repro.graph.hlo_parser import summarize

from .common import ART_DIR, save_json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS (global, per step) — 6ND / 2ND + attention."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    H, hd, L = cfg.n_heads, cfg.hd, cfg.n_layers
    w = cfg.sliding_window or S
    n_full = len(cfg.global_attn_layers) if cfg.global_attn_layers else L
    if cfg.family == "hybrid":
        n_swa = L - len(cfg.global_attn_layers)
    else:
        n_swa = L - n_full if cfg.sliding_window else 0
        n_full = L - n_swa
    if cfg.family == "ssm":
        n_full = n_swa = 0           # recurrent: attention term ~ 0

    def attn(seq_kv, layers, tokens):
        return 4.0 * tokens * min(seq_kv, S) * H * hd * layers

    if shape.kind == "train":
        D = B * S
        # causal halves the score work; x3 for backward
        a = 0.5 * (attn(S, n_full, D) + attn(w, n_swa, D)) * 3
        return 6.0 * N * D + a
    if shape.kind == "prefill":
        D = B * S
        a = 0.5 * (attn(S, n_full, D) + attn(w, n_swa, D))
        return 2.0 * N * D + a
    # decode: one token per sequence against a seq_len KV
    D = B
    a = attn(S, n_full, D) + attn(w, n_swa, D)
    return 2.0 * N * D + a


def _suggest(dom: str, cell: Dict) -> str:
    if dom == "memory":
        return ("fuse the attention score pipeline into VMEM (flash kernel) "
                "and keep bf16 end-to-end — score/convert HBM round-trips "
                "dominate the byte count")
    if dom == "collective":
        return ("reshard to cut the per-layer gathers (weight replication "
                "for serving, kv_seq sharding for decode) and overlap the "
                "remaining collectives with compute")
    return ("reduce recomputation (remat policy: save attention outputs) "
            "and raise arithmetic intensity per pass")


def analyze_cell(json_path: str) -> Optional[Dict]:
    cell = json.load(open(json_path))
    if cell.get("status") != "ok":
        return {"arch": cell["arch"], "shape": cell["shape"],
                "mesh": cell["mesh"], "status": cell["status"],
                "skip_reason": cell.get("skip_reason", "")}
    hlo_path = json_path.replace(".json", ".hlo.txt.gz")
    if not os.path.exists(hlo_path):
        return None
    n_dev = cell["devices"]
    pod_size = 256
    s = summarize(gzip.open(hlo_path, "rt").read(), pod_size=pod_size)
    compute_s = s.dot_flops / PEAK_FLOPS
    memory_s = s.hbm_bytes / HBM_BW
    coll_intra = s.link_bytes(cross_pod=False) / ICI_BW
    coll_cross = s.link_bytes(cross_pod=True) / DCN_BW
    collective_s = coll_intra + coll_cross
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dom = max(terms, key=terms.get)
    bound_s = max(terms.values())
    cfg = get_config(cell["arch"])
    shape = get_shape(cell["shape"])
    mf = model_flops(cfg, shape)
    mf_per_chip = mf / n_dev
    useful_ratio = mf_per_chip / max(s.dot_flops, 1.0)
    # roofline fraction: useful-FLOPs time over the bound (how close the
    # *useful* work runs to the hardware ceiling if perfectly overlapped)
    mfu_bound = (mf_per_chip / PEAK_FLOPS) / max(bound_s, 1e-12)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "status": "ok",
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "collective_cross_pod_s": coll_cross,
        "dominant": dom, "bound_s": bound_s,
        "hlo_flops": s.dot_flops, "hbm_bytes": s.hbm_bytes,
        "link_bytes": s.link_bytes(),
        "model_flops_global": mf,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": mfu_bound,
        "suggestion": _suggest(dom, cell),
        "memory_fits": cell.get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0) < 16 * 2**30,
    }


def run(pattern: str = "*") -> dict:
    rows = []
    for p in sorted(glob.glob(os.path.join(ART_DIR, "dryrun",
                                           f"{pattern}.json"))):
        r = analyze_cell(p)
        if r is not None:
            rows.append(r)
    save_json("roofline.json", rows)
    return {"rows": rows}


def main(print_csv=True, pattern: str = "*"):
    out = run(pattern)
    if print_csv:
        ok = [r for r in out["rows"] if r.get("status") == "ok"]
        print(f"# roofline terms per cell ({len(ok)} ok cells); "
              "seconds per step per chip")
        print(f"{'arch':>22s} {'shape':>11s} {'mesh':>10s} {'compute':>9s} "
              f"{'memory':>9s} {'collect':>9s} {'dom':>10s} {'MFUbound':>8s} "
              f"{'useful':>7s}")
        for r in ok:
            print(f"{r['arch']:>22s} {r['shape']:>11s} {r['mesh']:>10s} "
                  f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
                  f"{r['collective_s']:9.4f} {r['dominant']:>10s} "
                  f"{r['roofline_fraction']:8.3f} "
                  f"{r['useful_flops_ratio']:7.3f}")
    return out


if __name__ == "__main__":
    import sys

    main(pattern=sys.argv[1] if len(sys.argv) > 1 else "*")
