"""Batched cross-point refinement gate: sharing must actually pay.

Refines an ``lm_full_pod`` slice — the three full-model depths
(L16/L32/L64) of the ``s1024b8tp4pod8`` prefill point crossed with the
campaign's three DCN rates — twice: per point (``refine_point`` in a
loop, the pre-ISSUE-8 path) and as one batch job
(``refine_batch``). The slice exercises every sharing tier at once:
the DCN axis is *dead* at tp4/pod8 (rings stay inside the pod), so
each structural class collapses its three DCN variants into one
simulation, and the three classes share their reduced-twin event
replays through the batch-wide memo.

Gates:

* records bitwise identical between the two paths (the differential
  contract — also locked more broadly by ``tests/test_batchsim.py``);
* batched wall time at least ``--min-speedup`` (3x, the ISSUE 8
  acceptance floor; measured ~7x locally) better than per-point.

Run:  PYTHONPATH=src python benchmarks/bench_batch.py [--out PATH]
          [--repeats N] [--min-speedup X]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.hw.presets import resolve_preset, to_dict
from repro.sweep.refine import batch_payload, refine_payload, refine_point

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO, "BENCH_batch.json")

LAYERS = (16, 32, 64)
# lm_full_pod's dcn_gbps axis — dead for this pod shape, so each
# layer-class shares one simulation across all three rates
DCN_GBPS = (6.25, 25.0, 100.0)
PTI_NS = 1_000_000.0


def _payloads() -> list:
    hw = to_dict(resolve_preset("v5e"))
    out = []
    for layers in LAYERS:
        for dcn in DCN_GBPS:
            out.append(refine_payload(
                workload=f"lm/qwen3-32b/L{layers}/s1024b8tp4pod8",
                n_tiles=2, hw=dict(hw, dcn_gbps=dcn), compile_opts={},
                pti_ns=PTI_NS, temp_c=60.0, keep_series=False,
                engine="fast"))
    return out


def _time(fn, repeats: int) -> tuple:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.time()
        out = fn()
        best = min(best, time.time() - t0)
    return best, out


def run(out_path: str = DEFAULT_OUT, *, repeats: int = 2,
        min_speedup: float = 3.0) -> dict:
    items = _payloads()
    solo_s, solo = _time(lambda: [refine_point(p) for p in _payloads()],
                         repeats)
    batch_s, br = _time(lambda: refine_point(batch_payload(_payloads())),
                        repeats)
    identical = all(
        json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
        for a, b in zip(solo, br["records"]))
    speedup = solo_s / batch_s if batch_s > 0 else float("inf")
    out = {
        "points": len(items),
        "layers": list(LAYERS),
        "dcn_gbps": list(DCN_GBPS),
        "repeats": repeats,
        "per_point_wall_s": solo_s,
        "batched_wall_s": batch_s,
        "speedup": speedup,
        "min_speedup": min_speedup,
        "records_bitwise_identical": identical,
        "pass": identical and speedup >= min_speedup,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"{len(items)} points: per-point {solo_s:.2f}s  "
          f"batched {batch_s:.2f}s  speedup {speedup:.1f}x "
          f"(gate {min_speedup:.0f}x)  bitwise={identical}  -> {out_path}")
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--repeats", type=int, default=2,
                    help="min-of-N wall time per mode (damps CI noise)")
    ap.add_argument("--min-speedup", type=float, default=3.0,
                    help="fail below this batched-vs-per-point speedup")
    args = ap.parse_args()
    out = run(args.out, repeats=args.repeats, min_speedup=args.min_speedup)
    if not out["pass"]:
        print(f"FAIL: speedup {out['speedup']:.2f}x < "
              f"{args.min_speedup}x or records drifted "
              f"(bitwise={out['records_bitwise_identical']})")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
