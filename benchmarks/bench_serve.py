"""Serving-fleet simulation throughput: requests simulated per second.

Times one ``serve_fleet``-class campaign cell end to end — trace
generation, the ``ServeCostModel`` compiles (a handful of bucketed
prefill/decode steps), and the fleet event loop — then re-times the
event loop alone over a long trace with the compiled costs warm. The
warm number is the one that matters for campaign scaling: a 48-point
``serve_fleet`` run re-uses the same few step costs across every
traffic/policy/rate cell, so cost compiles amortize to ~zero and the
per-cell price is the event loop.

Emits ``BENCH_serve.json``. No threshold gate — 2-CPU CI runners are
noisy; CI archives the JSON as an artifact (next to ``BENCH_refine``)
so the trajectory is inspectable per commit.

Run:  PYTHONPATH=src python benchmarks/bench_serve.py [--out PATH]
                                                      [--requests N]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.hw.presets import resolve_preset, to_dict
from repro.serve.fleet import serve_payload, simulate_serve_point

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO, "BENCH_serve.json")


def _payload(n_requests: int, seed: int = 0) -> dict:
    """One serve_fleet campaign cell (tp4/dp2 continuous bursty)."""
    return serve_payload(
        workload="bench/serve", arch="qwen3-32b", layers=32, prompt=512,
        max_new=64, tp=4, ep=1, dp=2, pod=8, slots=16, kv_capacity=1024,
        policy="continuous",
        traffic={"kind": "bursty", "rate_rps": 16.0,
                 "n_requests": n_requests, "seed": seed},
        slo={"ttft_ms": 2000.0, "tpot_ms": 100.0}, n_tiles=2,
        hw=to_dict(resolve_preset("v5e")), temp_c=60.0)


def run(out_path: str = DEFAULT_OUT, n_requests: int = 100_000) -> dict:
    # cold: one realistic campaign cell, compiles included
    cold_n = 4000
    t0 = time.time()
    rec = simulate_serve_point(_payload(cold_n))
    cold_s = time.time() - t0

    # long: 100k-class trace; the compile cost is the same handful of
    # bucketed steps, so this isolates event-loop throughput
    t0 = time.time()
    rec_long = simulate_serve_point(_payload(n_requests, seed=1))
    warm_s = time.time() - t0

    out = {
        "cell_requests": cold_n,
        "cell_wall_s": cold_s,
        "cell_requests_per_s": cold_n / cold_s,
        "long_requests": n_requests,
        "long_wall_s": warm_s,
        "long_requests_per_s": n_requests / warm_s,
        "long_steps": rec_long["steps"],
        "long_goodput_rps": rec_long["goodput_rps"],
        "cell_goodput_rps": rec["goodput_rps"],
    }
    print(f"cold cell : {cold_n:7d} requests in {cold_s:6.2f}s  "
          f"({out['cell_requests_per_s']:9.0f} req/s simulated)")
    print(f"long trace: {n_requests:7d} requests in {warm_s:6.2f}s  "
          f"({out['long_requests_per_s']:9.0f} req/s simulated, "
          f"{rec_long['steps']} fleet steps)")
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {out_path}")
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--requests", type=int, default=100_000,
                    help="long-trace request count (default 100000)")
    args = ap.parse_args()
    run(args.out, args.requests)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
