"""Fig 7 analog: memory-BW scaling x compute-buffer capacity."""
from __future__ import annotations

from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import WORKLOADS
from repro.hw.chip import simulate
from repro.hw.presets import paper_skew

from .common import save_json


def run() -> dict:
    rows = []
    for wname, builder in WORKLOADS.items():
        ops = builder()
        for vmem_mb, tag in ((2, "small_CB"), (16, "large_CB")):
            for bw in (8.0, 17.0, 34.0, 68.0):
                cfg = paper_skew(hbm_gbps=bw, vmem_bytes=vmem_mb * 2**20)
                cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
                t = simulate(cw.tasks, cfg, n_tiles=2).makespan_ns
                rows.append({"model": wname, "cb": tag, "ddr_gbps": bw,
                             "inf_per_s": 1e9 / t,
                             "spilled_layers": cw.spilled_layers})
    save_json("membw_scaling.json", rows)
    # headline: BW sensitivity (8 -> 68 GB/s) per CB size
    sens = {}
    for tag in ("small_CB", "large_CB"):
        lo = [r["inf_per_s"] for r in rows if r["cb"] == tag
              and r["ddr_gbps"] == 8.0]
        hi = [r["inf_per_s"] for r in rows if r["cb"] == tag
              and r["ddr_gbps"] == 68.0]
        sens[tag] = sum(h / l for h, l in zip(hi, lo)) / len(lo)
    save_json("membw_scaling_summary.json", sens)
    return {"rows": rows, "summary": sens}


def main(print_csv=True):
    out = run()
    if print_csv:
        s = out["summary"]
        print("# Fig-7 analog: DDR-BW sensitivity (8->68 GB/s speedup)")
        print(f"small CB: x{s['small_CB']:.2f}   large CB: x{s['large_CB']:.2f}"
              f"   (paper: dense models + small CB are BW-bound)")
    return out


if __name__ == "__main__":
    main()
