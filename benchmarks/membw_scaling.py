"""Fig 7 analog: memory-BW scaling x compute-buffer capacity.

A thin sweep spec over the campaign runner: the bandwidth axis is
analytic (one XLA pre-screen per VMEM cell), the VMEM-capacity axis is
structural (it changes tiling/spill decisions), and every point is
event-refined for the figure.
"""
from __future__ import annotations

from typing import Optional

from repro.graph.workloads import WORKLOADS
from repro.sweep import RefineSpec, SweepSpec

from .common import run_and_save_campaign, save_json

BANDWIDTHS = [8.0, 17.0, 34.0, 68.0]
CB_SIZES = {2 * 2**20: "small_CB", 16 * 2**20: "large_CB"}


def campaign_spec() -> SweepSpec:
    return SweepSpec(
        name="membw_scaling",
        description="Fig 7: DDR/HBM bandwidth x CB capacity",
        workloads=list(WORKLOADS),
        preset="paper_skew",
        axes={"vmem_bytes": list(CB_SIZES), "hbm_gbps": BANDWIDTHS},
        n_tiles=[2],
        refine=RefineSpec(mode="all"),
    )


def run(workers: Optional[int] = None) -> dict:
    res = run_and_save_campaign(campaign_spec(), workers=workers)
    rows = [{"model": r["workload"],
             "cb": CB_SIZES[r["overrides"]["vmem_bytes"]],
             "ddr_gbps": r["overrides"]["hbm_gbps"],
             "inf_per_s": r["inf_per_s"],
             "spilled_layers": r["spilled_layers"]}
            for r in res.refined]
    save_json("membw_scaling.json", rows)
    # headline: BW sensitivity (8 -> 68 GB/s) per CB size
    sens = {}
    for tag in ("small_CB", "large_CB"):
        lo = [r["inf_per_s"] for r in rows if r["cb"] == tag
              and r["ddr_gbps"] == 8.0]
        hi = [r["inf_per_s"] for r in rows if r["cb"] == tag
              and r["ddr_gbps"] == 68.0]
        sens[tag] = sum(h / l for h, l in zip(hi, lo)) / len(lo)
    save_json("membw_scaling_summary.json", sens)
    return {"rows": rows, "summary": sens, "campaign": res.summary}


def main(print_csv=True):
    out = run()
    if print_csv:
        s = out["summary"]
        print("# Fig-7 analog: DDR-BW sensitivity (8->68 GB/s speedup)")
        print(f"small CB: x{s['small_CB']:.2f}   large CB: x{s['large_CB']:.2f}"
              f"   (paper: dense models + small CB are BW-bound)")
    return out


if __name__ == "__main__":
    main()
