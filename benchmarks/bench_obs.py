"""Metrics-overhead gate: telemetry must stay near-free when enabled.

Times ``refine_point`` on the ``bench_refine`` 64-layer full-model pod
point twice — registry disabled (the default) and collecting — and
gates the min-of-repeats wall-time ratio at ``--max-overhead`` (5% by
default, the ISSUE 7 contract). The instrumented run flows through
every hot-path hook at once: the event engine's stats run-loop variant
(the extrapolation replays layers through it), the fast engine's
extrapolation/fallback counters, and the System resource-contention
flush.

Also asserts the record itself is unchanged by instrumentation —
metrics are observers, never inputs.

Run:  PYTHONPATH=src python benchmarks/bench_obs.py [--out PATH]
          [--repeats N] [--max-overhead FRAC]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.hw.presets import resolve_preset, to_dict
from repro.obs.metrics import REGISTRY, collecting
from repro.sweep.refine import refine_payload, refine_point

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO, "BENCH_obs.json")

# the bench_refine "full" case: steady-state extrapolation replays a
# handful of layers on the (instrumented) event engine, synthesizes 64
WORKLOAD = "lm/qwen3-32b/L64/s1024b8tp4pod8"
PTI_NS = 1_000_000.0


def _payload() -> dict:
    return refine_payload(workload=WORKLOAD, n_tiles=2,
                          hw=to_dict(resolve_preset("v5e")),
                          compile_opts={}, pti_ns=PTI_NS, temp_c=60.0,
                          keep_series=False, engine="fast")


def _time_point(repeats: int) -> tuple:
    best = float("inf")
    rec = None
    for _ in range(repeats):
        payload = _payload()
        t0 = time.time()
        rec = refine_point(payload)
        best = min(best, time.time() - t0)
    return best, rec


def run(out_path: str = DEFAULT_OUT, *, repeats: int = 3,
        max_overhead: float = 0.05) -> dict:
    assert not REGISTRY.enabled, \
        "run this bench without REPRO_METRICS so the baseline is clean"
    off_s, off_rec = _time_point(repeats)
    with collecting() as reg:
        on_s, on_rec = _time_point(repeats)
        n_counters = len(reg.snapshot()["counters"])
    assert n_counters > 0, "instrumented run recorded no metrics"
    assert on_rec == off_rec, \
        "metrics collection changed the refinement record"
    overhead = on_s / off_s - 1.0
    out = {
        "workload": WORKLOAD,
        "repeats": repeats,
        "off_wall_s": off_s,
        "on_wall_s": on_s,
        "overhead_frac": overhead,
        "max_overhead_frac": max_overhead,
        "counters_recorded": n_counters,
        "pass": overhead <= max_overhead,
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"metrics off {off_s:.3f}s  on {on_s:.3f}s  "
          f"overhead {overhead * 100:+.2f}% "
          f"(gate {max_overhead * 100:.0f}%)  -> {out_path}")
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--repeats", type=int, default=3,
                    help="min-of-N wall time per mode (damps CI noise)")
    ap.add_argument("--max-overhead", type=float, default=0.05,
                    help="fail above this on/off wall-time overhead")
    args = ap.parse_args()
    out = run(args.out, repeats=args.repeats,
              max_overhead=args.max_overhead)
    if not out["pass"]:
        print(f"FAIL: metrics overhead {out['overhead_frac'] * 100:.2f}% "
              f"exceeds {args.max_overhead * 100:.0f}%")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
