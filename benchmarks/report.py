"""Render EXPERIMENTS.md from the benchmark/dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS.md
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .common import ART_DIR

PERF_LOG = __name__  # placeholder to keep file self-contained; body below


def _load(name):
    p = os.path.join(ART_DIR, name)
    if os.path.exists(p):
        return json.load(open(p))
    return None


def _cells(subdir="dryrun") -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(ART_DIR, subdir, "*.json"))):
        out.append(json.load(open(p)))
    return out


def dryrun_section() -> str:
    cells = _cells()
    ok = [c for c in cells if c["status"] == "ok"]
    skip = [c for c in cells if c["status"] == "skip"]
    fail = [c for c in cells if c["status"] == "fail"]
    lines = ["## §Dry-run", ""]
    lines.append(
        f"All {len(cells)} cells = 10 architectures x 4 input shapes x "
        f"2 production meshes (16x16 = 256 chips single-pod; 2x16x16 = 512 "
        f"chips across two pods). **{len(ok)} compile OK, {len(skip)} "
        f"structural skips, {len(fail)} failures.** Every cell lowers with "
        "`jax.jit(...).lower(**ShapeDtypeStructs).compile()` — no array "
        "allocation; `memory_analysis()`/`cost_analysis()` and the gzip'd "
        "optimized HLO are archived in `benchmarks/artifacts/dryrun/`.")
    lines.append("")
    lines.append("| arch | shape | mesh | program | compile_s | "
                 "args GiB/chip | XLA flops/chip | status |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for c in cells:
        if c["status"] == "ok":
            args = c["memory_analysis"].get("argument_size_in_bytes", 0)
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                f"{c['program']} | {c.get('compile_s', 0):.1f} | "
                f"{args/2**30:.2f} | "
                f"{c['cost_analysis'].get('flops', 0):.2e} | ok |")
        else:
            reason = c.get("skip_reason", c.get("error", ""))[:60]
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                         f"{c['program']} | — | — | — | "
                         f"{c['status']}: {reason} |")
    lines.append("")
    lines.append(
        "Structural skips (9 logical cells x 2 meshes): `long_500k` for the "
        "8 pure full-attention archs (512k dense-attention decode is "
        "quadratic/KV-infeasible by design; the shape targets sub-quadratic "
        "archs and runs for xlstm-125m + hymba-1.5b), and `decode_*` for "
        "hubert-xlarge (encoder-only: no autoregressive step; its "
        "`prefill_32k` is a 32k-frame encoder forward). "
        "Note: XLA `cost_analysis()` flops under-count scanned layers "
        "(while bodies visited once) — the §Roofline numbers use the "
        "trip-count-aware parser instead.")
    return "\n".join(lines)


def roofline_section() -> str:
    rows = (_load("roofline.json") or [])
    ok = [r for r in rows if r.get("status") == "ok"]
    lines = ["## §Roofline", ""]
    lines.append(
        "Per-chip roofline terms from the compiled per-device HLO "
        "(trip-count-aware parse; TPU v5e constants: 197 TFLOP/s bf16, "
        "819 GB/s HBM, 50 GB/s/link ICI, 25 GB/s DCN). "
        "`MFU-bound` = (MODEL_FLOPS/chips/peak) / max(term) — how close the "
        "*useful* math runs to the hardware ceiling with perfect overlap; "
        "`useful` = MODEL_FLOPS / HLO FLOPs (remat/redundancy waste). "
        "Single-pod rows are the baseline table; multi-pod rows prove the "
        "pod axis (cross-pod DCN bytes shown).")
    lines.append("")
    lines.append("| arch | shape | mesh | compute_s | memory_s | "
                 "collective_s (xpod) | dominant | MFU-bound | useful | "
                 "what moves the dominant term |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in ok:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} ({r['collective_cross_pod_s']:.4f}) | "
            f"**{r['dominant']}** | {r['roofline_fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {r['suggestion'][:70]}... |")
    lines.append("")
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    lines.append(f"Bottleneck census: {doms}. The CPU backend materializes "
                 "f32 copies around bf16 dots and fuses less than the TPU "
                 "backend, so memory terms are upper bounds (bf16-native "
                 "collectives/buffers halve the affected payloads on real "
                 "hardware); dtype converts are already counted free "
                 "(TPU fuses them) — see DESIGN.md §assumption-changes.")
    return "\n".join(lines)


def paper_validation_section() -> str:
    lines = ["## Validation against the paper's own claims", ""]
    cs = _load("computation_scaling_summary.json")
    if cs:
        lines.append(
            f"* **Fig 5 (computation scaling)** — paper: ~1.9x for 1->2 "
            f"tiles, ~1.47x for 2->4, +25-45% for 2K->4K MACs. Ours: "
            f"**{cs['avg_scaling_1_to_2_tiles']:.2f}x**, "
            f"**{cs['avg_scaling_2_to_4_tiles']:.2f}x**, "
            f"**+{100*(cs['avg_gain_2K_to_4K_macs']-1):.0f}%** "
            f"(same qualitative structure: tile scaling saturates on the "
            f"shared DDR/DMA; bigger arrays alone underutilize).")
    fs = _load("frequency_scaling_summary.json")
    if fs:
        lines.append(
            f"* **Fig 6 (frequency scaling)** — paper: perf linear in F, "
            f"power super-linear, best efficiency at low F. Ours: F x"
            f"{fs['freq_ratio']:.1f} -> perf x{fs['perf_ratio']:.2f}, power "
            f"x{fs['power_ratio']:.2f}; best inf/J at "
            f"{fs['efficiency_best_at_ghz']} GHz.")
    ms = _load("membw_scaling_summary.json")
    if ms:
        lines.append(
            f"* **Fig 7 (memory-BW scaling)** — paper: DDR BW matters most "
            f"for dense models + limited CB. Ours: 8->68 GB/s gives x"
            f"{ms['small_CB']:.2f} with a small CB vs x{ms['large_CB']:.2f} "
            f"with a large CB.")
    ac = _load("accuracy_characterization.json")
    if ac:
        dense = [abs(r["em_vs_ref_pct"]) for r in ac if "_S" not in r["model"]]
        sparse = [abs(r["nn_vs_ref_pct"]) for r in ac if "_S" in r["model"]]
        lines.append(
            f"* **Table 1 (accuracy characterization)** — paper: EM within "
            f"5-10% of RTL on dense models; the learned cost model (VPUNN) "
            f"degrades badly on sparse variants. Ours (REF = detailed event "
            f"sim): EM-fast |err| = **{sum(dense)/len(dense):.1f}%** avg on "
            f"dense variants; TPU-NN |err| on sparse variants = "
            f"**{sum(sparse)/max(len(sparse),1):.1f}%** (same failure "
            f"structure: per-op models miss concurrency).")
    ss = _load("sim_speed.json")
    if ss:
        rn = next((r for r in ss if r["workload"] == "resnet50"), None)
        if rn:
            lines.append(
                f"* **§2.3 speed objective** — paper: ResNet50-class full "
                f"model within minutes. Ours: **{rn['wall_s']:.2f} s** "
                f"({rn['tasks_per_s']:.0f} tasks/s); pod-scale LM replay of "
                f"a compiled decode step also simulates in seconds.")
    pp = _load("power_profile.json")
    if pp:
        lines.append(
            f"* **Fig 8 (power profiling)** — per-module transient power "
            f"over {pp['pti_ns']/1e3:.0f}us PTIs: peak {pp['peak_w']:.1f} W "
            f"vs avg {pp['avg_w']:.1f} W on ResNet50 "
            f"({pp['energy_mj_per_inf']:.2f} mJ/inf).")
    dv = _load("dvfs_sweep.json")
    if dv:
        picks = ", ".join(f"{k}: {v['freq_ghz']} GHz"
                          for k, v in dv["picks"].items())
        lines.append(
            f"* **Fig 9 (joint perf/power DVFS)** — 100 MHz sweep per "
            f"model; lowest-energy points meeting a 50%-of-peak floor: "
            f"{picks}.")
    return "\n".join(lines)


def phase_roofline_section() -> str:
    """Prefill-vs-decode roofline (benchmarks/phase_roofline.py)."""
    d = _load("phase_roofline.json")
    if not d:
        return ""
    lines = ["## §Prefill vs decode roofline", ""]
    lines.append(
        f"One-transformer-layer op lists (`graph.workloads.lm_layer_ops`) "
        f"compiled for the `{d['preset']}` preset, placed on the chip "
        f"roofline. The ridge point is "
        f"**{d['ridge_flops_per_byte']:.0f} flops/byte**: prefill cells "
        "sit right of it (compute-bound GEMMs, weights amortized over "
        "`seq x batch` tokens); decode cells — `m=batch` GEMVs plus an "
        "HBM-streamed KV cache sized by `kv_len` — collapse far left of "
        "it. This is the phase flip the `lm_decode_kv` campaign sweeps "
        "at full grid scale.")
    lines.append("")
    lines.append("| arch | ctx | batch | phase | flops/byte | compute_ns | "
                 "memory_ns | bound |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in d["rows"]:
        lines.append(
            f"| {r['arch']} | {r['ctx']} | {r['batch']} | {r['phase']} | "
            f"{r['flops_per_byte']:.1f} | {r['compute_ns']:.3g} | "
            f"{r['memory_ns']:.3g} | **{r['bound']}** |")
    lines.append("")
    dec = [r for r in d["rows"] if r["phase"] == "decode"]
    mem = sum(r["bound"] == "memory" for r in dec)
    lines.append(
        f"{mem}/{len(dec)} decode cells are memory-bound; every decode "
        "cell's intensity is below its matching prefill cell's. The "
        "`lm_decode_kv` campaign records carry per-point "
        "`flops_per_byte` so the same comparison can be made across "
        "its full grid; `tests/test_phase_workloads.py` asserts it.")
    return "\n".join(lines)


def campaign_section() -> str:
    """Render every archived sweep campaign (repro.sweep records)."""
    paths = sorted(glob.glob(os.path.join(ART_DIR, "campaigns", "*.json")))
    if not paths:
        return ""
    lines = ["## §Sweep campaigns", ""]
    lines.append(
        "Design-space campaigns run by the `repro.sweep` subsystem: the "
        "full grid is pre-screened analytically in one batched XLA call "
        "per structural cell (`core.vectorized.schedule_many_stats`), the "
        "Pareto-interesting points are refined on the ground-truth event "
        "engine in parallel workers, and refinements are content-hash "
        "cached so re-runs are incremental.")
    lines.append("")
    lines.append("| campaign | grid | cells | refined | cache hits | "
                 "prescreen_s | refine_s | event/analytic | "
                 "best point (min time) |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for p in paths:
        with open(p) as f:
            d = json.load(f)
        s = d["summary"]
        dev = "—"
        if s.get("deviation_max") is not None:
            dev = f"{s['deviation_min']:.2f}–{s['deviation_max']:.2f}"
        best = "—"
        if "best_time_point" in s:
            b = s["best_time_point"]
            ov = ",".join(f"{k}={v:g}" if isinstance(v, float)
                          else f"{k}={v}" for k, v in b["overrides"].items())
            best = f"{b['workload']} {ov or 'base'}"
        lines.append(
            f"| {d['spec']['name']} | {s['grid_points']} | {s['cells']} | "
            f"{s['refined']} | {s['cache_hits']} | {s['prescreen_s']:.2f} | "
            f"{s['refine_s']:.2f} | {dev} | {best} |")
    lines.append("")
    lines.append(
        "The event/analytic column bounds the pre-screen's fidelity on the "
        "refined points (the `core/vectorized` deviation-bound tests "
        "assert the same corridor). Run any campaign with "
        "`PYTHONPATH=src python -m repro.sweep run <spec>`.")
    return "\n".join(lines)


def pod_pareto_section() -> str:
    """Pod-shape Pareto fronts from the lm_full_pod campaign: for each
    (phase, layer count), which DP x TP shapes are on the chips-vs-time
    frontier — the 'what pod shape serves this model fastest' answer."""
    p = os.path.join(ART_DIR, "campaigns", "lm_full_pod.json")
    if not os.path.exists(p):
        return ""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src"))
    from repro.graph.workloads import parse_lm_name

    with open(p) as f:
        d = json.load(f)
    # frontier on ANALYTIC times only: every grid point has one, so
    # shapes compare like-with-like (mixing in event-refined times —
    # deviation ~0.91-1.03 — could bold a shape for its model source
    # rather than its speed); the refined event time of the winning
    # point is shown as a fidelity column when it exists
    best: Dict[tuple, Dict] = {}
    for r in d["records"]:
        info = parse_lm_name(r["workload"])
        t = r["analytic_time_ns"]
        key = (info["phase"], info["layers"], info["dp"], info["tp"])
        if key not in best or t < best[key]["t"]:
            best[key] = {"t": t, "chips": info["dp"] * info["tp"]
                         * info["ep"],
                         "event_t": r.get("time_ns")
                         if r.get("refined") else None,
                         "pod": info["pod"], "batch": info["batch"]}
    lines = ["## §Pod-shape Pareto (lm_full_pod)", ""]
    lines.append(
        "Full-model workloads (`graph.workloads.lm_model_ops`): the whole "
        "layer stack per step, weights re-streamed from HBM each layer, "
        "placed DP x TP on "
        f"{best and next(iter(best.values()))['pod'] or '?'}-chip pods — "
        "TP rings wider than a pod run at DCN speed. Per (phase, layers), "
        "the chips-vs-step-time frontier over the analytic pre-screen "
        "(best batch/DVFS point per shape; **bold** = Pareto-optimal, "
        "i.e. no cheaper shape is faster; the event column is the "
        "ground-truth simulation of that point where refined):")
    lines.append("")
    lines.append("| phase | layers | dp x tp | chips | best step (ms) | "
                 "event (ms) |")
    lines.append("|---|---|---|---|---|---|")
    for (phase, layers) in sorted({(k[0], k[1]) for k in best}):
        shapes = sorted((v["chips"], v["t"], k[2], k[3], v)
                        for k, v in best.items()
                        if (k[0], k[1]) == (phase, layers))
        front_t = float("inf")
        for chips, t, dp, tp, v in shapes:
            on_front = t < front_t
            front_t = min(front_t, t)
            cell = f"{dp}x{tp}"
            if on_front:
                cell = f"**{cell}**"
            ev = f"{v['event_t']/1e6:.3f}" if v["event_t"] else "—"
            lines.append(
                f"| {phase} | {layers} | {cell} | {chips} | "
                f"{t/1e6:.3f} | {ev} |")
    lines.append("")
    lines.append(
        "Reading: within a phase/layer row-group, each added shape is "
        "bold only when it beats every smaller shape — decode steps "
        "(HBM-streamed KV + per-layer weight re-reads) keep buying "
        "latency from TP until the ring leaves the pod, while prefill "
        "saturates earlier. Records: `benchmarks/artifacts/campaigns/"
        "lm_full_pod.json` (`python -m repro.sweep run lm_full_pod "
        "--backend pool`).")
    return "\n".join(lines)


def serve_fleet_section() -> str:
    """Serving-fleet SLO answer from the serve_fleet campaign: per
    offered load and traffic pattern, the cheapest fleet (fewest chips,
    then lowest J/request) whose TTFT/TPOT percentiles meet the SLO."""
    p = os.path.join(ART_DIR, "campaigns", "serve_fleet.json")
    if not os.path.exists(p):
        return ""
    with open(p) as f:
        d = json.load(f)
    recs = [r for r in d["records"] if r.get("serve") and r.get("refined")]
    if not recs:
        return ""
    slo = d["spec"]["serve_grid"]["slo"]
    lines = ["## §Serving-fleet SLO campaign (serve_fleet)", ""]
    lines.append(
        "Trace-driven fleet simulation (`repro.serve.fleet`): open-loop "
        "Poisson and bursty (MMPP-2) request arrivals into a continuous- "
        "or static-batching scheduler over analytic per-step costs, per- "
        "request TTFT/TPOT percentiles rolled up per cell. The question "
        f"each row answers: **what is the cheapest fleet that serves the "
        f"offered load within SLO** (TTFT p95 <= {slo['ttft_ms']:g} ms, "
        f"TPOT p95 <= {slo['tpot_ms']:g} ms, >=99% of completed requests "
        "in-SLO, nothing rejected)?")
    lines.append("")
    lines.append("| offered (req/s) | traffic | cheapest in-SLO fleet | "
                 "chips | policy | goodput (req/s) | ttft p99 (ms) | "
                 "tpot p99 (ms) | J/req |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    loads = sorted({(r["overrides"]["rate_rps"], r["overrides"]["traffic"])
                    for r in recs})
    for rate, traffic in loads:
        cell = [r for r in recs
                if r["overrides"]["rate_rps"] == rate
                and r["overrides"]["traffic"] == traffic]
        ok = [r for r in cell if r["slo_attainment"] >= 0.99
              and r["rejected"] == 0 and r["evicted"] == 0]
        if not ok:
            lines.append(f"| {rate:g} | {traffic} | *none in grid meets "
                         f"SLO* | — | — | — | — | — | — |")
            continue
        b = min(ok, key=lambda r: (r["chips"], r["energy_per_req_j"]))
        lines.append(
            f"| {rate:g} | {traffic} | `{b['workload']}` | {b['chips']} | "
            f"{b['overrides']['policy']} | {b['goodput_rps']:.2f} | "
            f"{b['ttft_p99_ms']:.0f} | {b['tpot_p99_ms']:.1f} | "
            f"{b['energy_per_req_j']:.0f} |")
    lines.append("")
    lines.append(
        "Reading: the chips column is the provisioning answer — rows "
        "where only the larger TP or DP shapes qualify show the load "
        "level at which the smaller fleet falls out of SLO (queueing "
        "pushes TTFT tails past the bound before raw throughput "
        "saturates, and bursty arrivals need headroom Poisson does not). "
        "Records: `benchmarks/artifacts/campaigns/serve_fleet.json` "
        "(`python -m repro.sweep run serve_fleet --backend pool`).")
    return "\n".join(lines)


def perf_delta_section() -> str:
    rows = _load("perf_delta.json")
    if not rows:
        return ""
    import numpy as np

    ratios = [r["dominant_term_ratio"] for r in rows]
    geo = float(np.exp(np.mean(np.log(ratios))))
    improved = [r for r in rows if r["dominant_term_ratio"] < 0.95]
    regressed = sorted((r for r in rows if r["dominant_term_ratio"] > 1.05),
                       key=lambda r: -r["dominant_term_ratio"])
    best = sorted(rows, key=lambda r: r["dominant_term_ratio"])[:6]
    lines = ["### Framework-wide before/after (all 62 cells)", ""]
    lines.append(
        f"Re-lowering every cell under the optimized defaults vs the "
        f"preserved paper-faithful baseline artifacts: **geomean "
        f"dominant-term ratio {geo:.3f}** ({len(improved)} cells improved "
        f">5%, {len(regressed)} regressed >5%). Biggest wins (all decode "
        f"cells, collective-bound at baseline):")
    lines.append("")
    for r in best:
        lines.append(f"* {r['cell']}: {r['dominant_baseline']} x"
                     f"{r['dominant_term_ratio']:.3f}")
    lines.append("")
    lines.append(
        "The 'regressions' are the other side of the serving trade: weight "
        "replication makes each chip read the full weight set from its own "
        "HBM instead of all-gathering shards over ICI — decode memory terms "
        "rise x1.1-1.7 (tiny absolute values) while collective terms drop "
        "10-1000x; every regressed cell's max term still shrinks or stays "
        "within noise of its baseline bound.")
    return "\n".join(lines)


def training_section() -> str:
    p = os.path.join(ART_DIR, "train_lm_e2e.txt")
    if not os.path.exists(p):
        return ""
    body = open(p).read().strip()
    return ("## End-to-end training run (examples/train_lm.py)\n\n"
            "Full (non-reduced) SmolLM-135M, synthetic tokens, AdamW+cosine,"
            " checkpoints every ~20 steps (restart-safe; the run below "
            "includes the post-fan_in-fix loss descent):\n\n```\n"
            + body + "\n```")


def main():
    print("# EXPERIMENTS — TPU-EM reproduction of VPU-EM (Qi et al., 2023)")
    print()
    print("All numbers generated by `PYTHONPATH=src python -m "
          "benchmarks.run` (+ the dry-run sweep); artifacts under "
          "`benchmarks/artifacts/`. This file is rendered by "
          "`python -m benchmarks.report`.")
    print()
    print(paper_validation_section())
    print()
    print(dryrun_section())
    print()
    cs = campaign_section()
    if cs:
        print(cs)
        print()
    pp = pod_pareto_section()
    if pp:
        print(pp)
        print()
    sv = serve_fleet_section()
    if sv:
        print(sv)
        print()
    pr = phase_roofline_section()
    if pr:
        print(pr)
        print()
    print(roofline_section())
    print()
    print(PERF_BODY)
    pd = perf_delta_section()
    if pd:
        print()
        print(pd)
    ts = training_section()
    if ts:
        print()
        print(ts)


PERF_BODY = r"""## §Perf — hillclimbing log (hypothesis -> change -> measure)

Method per the paper's own spirit: form a napkin-math hypothesis from the
compiled artifact, change one thing, re-lower, re-measure the three terms.
The **paper-faithful baseline** is the initial framework (chunked jnp
attention, full `nothing_saveable` remat, one sharding ruleset for train
and serve); artifacts preserved in `benchmarks/artifacts/dryrun_baseline/`.
The **optimized framework** (current defaults) is what the final
`benchmarks/artifacts/dryrun/` sweep measures. Three cells were hillclimbed;
all other cells are baseline-only (re-lowered under the final defaults).

### Cell A — qwen3-32b / decode_32k / 2x16x16 (most representative: pod serving)

| iter | hypothesis | change | collective payload B/chip | replayed step (TPU-EM) | verdict |
|---|---|---|---|---|---|
| base | — | paper-faithful | 8.04e9 | 496 ms | baseline |
| A1 | GSPMD all-gathers the (d x V) head in f32 every step because logits are unconstrained | constrain logits to vocab-sharded (`model.py::_logits`) | 8.0e9 (head gather gone) | 403 ms | confirmed (small term) |
| A2 | 33 MB/layer FSDP weight gathers dominate a decode step; serving should replicate weights over `data` | serving memory planner: `fsdp=False` for serve programs under 8 GB/chip (`launch/programs.py`) | **3.58e7 (-224x)** | 270 ms | **confirmed** |
| A3 | remaining vector time is CPU-backend f32<->bf16 round-trips that a TPU build fuses | `free_converts` TPU semantics in the parser (validated: the convert chains wrap in-place cache updates) | 3.58e7 | **82 ms** | confirmed (accounting fix, applied to all cells) |
| A4 | q constrained to heads-TP conflicts with the kv_seq-sharded cache (cache all-gather per layer) | replicate q heads in decode (`blocks.py::_attn_decode`) | kept at 3.6e7 under head-TP archs | — | confirmed (required for A2 to hold on head-TP archs) |

Net: collective term 8.04e9 -> 3.58e7 bytes (flash-decoding small
all-reduces only); TPU-EM replayed step 496 -> 82 ms (6x, matched replay
settings at measurement time). The replay is latency-bound (dependency
chain), matching real decode behavior. The final replay benchmark
(`benchmarks/lm_replay.py`) uses stricter HBM-streaming semantics (large
compute IO charged through DMA) and reports the optimized cell at ~96 ms,
inside its [hard-bound, memory-upper-bound] corridor.

### Cell B — smollm-135m / train_4k / 16x16 (worst memory-bound fraction)

| iter | hypothesis | change | HLO flops/chip | HBM B/chip | memory term | verdict |
|---|---|---|---|---|---|---|
| base | — | paper-faithful | 8.60e12 | 1.477e12 | 1.80 s | baseline |
| B1 | the q-chunk `lax.map` stacks per-chunk f32 scores + pred masks as backward residuals (~70% of traffic) | `jax.checkpoint` around each attention chunk (`attention.py::remat_chunk`, now default) | 9.18e12 (+7% recompute) | 1.387e12 | 1.69 s | partially confirmed — stacked buffers gone, but the softmax chain recompute keeps most traffic; understanding refined |
| B2 | backward re-runs the whole O(S^2) score pipeline; saving the [B,S,H,hd] attention outputs (2.3 GB for this arch) skips it | named-checkpoint policy `save-attn` (`model.py::remat_policy`) | 8.02e12 | 1.113e12 | 1.36 s | **confirmed** (-25% HBM, -7% flops) |
| B3 | 8 q-chunks re-read K/V 8x; fewer, larger chunks amortize | `q_chunk` 512 -> 2048 | 8.02e12 | 9.60e11 | 1.17 s | **confirmed** (-35% total) |
| B4 | single chunk (no map) removes the last stacking copies | `q_chunk` 4096 | 8.02e12 | 9.35e11 | 1.14 s | confirmed, marginal (-2.7%) — stop rule hit |
| B5 | the remaining 25% of HBM traffic is score-pipeline tiles; the flash-attention Pallas kernel keeps them in VMEM | measured score-shaped traffic in the final artifact: 2.36e11 B | — | (9.35-2.36)e11 | **0.85 s** kernel-adjusted | kernel validated vs oracle in interpret mode (`tests/test_kernels.py`); effect quantified from the artifact, not compilable on the CPU dry-run |

Net (measured): memory term 1.80 -> 1.14 s (-37%); kernel-adjusted
projection 0.85 s (-53%). Dominant term remains memory: the rest is
parameter/activation streaming (inherent at 135M params x 1M tokens/step
on 256 chips).

### Cell C — llama-3.2-vision-90b / train_4k / 16x16 (most collective-bound)

| iter | hypothesis | change | HBM B/chip | link B/chip | verdict |
|---|---|---|---|---|---|
| base | — | paper-faithful | 6.10e13 | 3.90e12 | baseline |
| C1 | B1's stacked-score fix transfers | re-lower with `remat_chunk` | 5.87e13 (-4%) | 3.90e12 | confirmed, small (this cell's scores are head-TP-sharded already) |
| C2 | CE's reshape+swapaxes materializes a transposed f32 copy of the hidden stream (~10% of bytes) | chunked CE reads `dynamic_slice` windows (`layers.py`) | 5.87e13 | 3.90e12 | **refuted** — XLA had already sunk the transpose; the f32[65536,8192] traffic is the loss-gradient stream, not the CE input |
| C3 | Megatron-SP residual (seq-sharded stream) turns backward dgrad all-reduces into reduce-scatters | `sp_residual` rules flag | 7.35e13 | 1.88e13 (**5x worse**) | **refuted** — GSPMD re-shards seq<->heads around every attention; flag kept but off |
| C4 | the 2.1 GB f32 [16,4096,8192] activation all-reduces (540x) are the Megatron heads-TP tax, doubled by the CPU backend's f32 promotion | analysis: on a TPU build these are bf16 -> collective term ~39 s, memory ~35-40 s | — | — | documented correction; the honest fix at this scale is more chips (90B x 1M tokens/step on 256 v5e is under-provisioned) plus the flash kernel for the 1.1e13 B score pipeline |

Net: this cell is the fleet-sizing lesson the roofline is for — after B1
and dtype corrections the step is bound at ~39 s/step collective /
~35 s memory vs 13.7 s of useful compute (MFU-bound ~0.35 at perfect
overlap). Two refuted hypotheses recorded per the methodology.

### Cross-cutting wins applied framework-wide (beyond the paper)

* serving-vs-training sharding split (A2) — all decode/prefill cells.
* decode q-replication under head-TP (A4) — all decode cells.
* logits vocab-sharding (A1) — all serve cells.
* attention chunk remat (B1) + `save-attn` policy available per-arch (B2).
* MoE one-hot GSPMD dispatch for un-splittable token dims (decode) with the
  sort-based shard_map EP path for bulk tokens — both validated against the
  dense oracle.
* int8 error-feedback gradient compression for the cross-pod axis
  (validated numerically; modeled in TPU-EM as 4x DCN byte reduction).
* Pallas kernels (flash attention / fused RMSNorm / SSM scan) validated
  against jnp oracles in interpret mode — the TPU-side answer to the
  dominant memory terms above.

### Found by the end-to-end run (examples/train_lm.py)

The full-config 135M training run surfaced an init bug the reduced-config
smoke tests could not: `PT.fan_in` defaulted to `shape[-2]`, which for
`[d, H, hd]` projection layouts picks the HEAD COUNT (9 for smollm) instead
of `d` (576) — QKV weights ~14x too large, gradients exploding at depth 30
(global grad norm ~1e12). Fixed by explicit `fan_in` in every 3D+ template;
post-fix global grad norm ~20 and the loss actually descends (artifact:
`benchmarks/artifacts/train_lm_e2e.txt`). Depth-dependent bugs need
full-depth runs — exactly why the e2e example is a deliverable.

### Paper §6.2 future work, implemented

* **Stack-EM** (`graph/stackem.py`): multi-context use-case scheduling —
  per-context submission queues, priority dispatch, per-request e2e
  latency; tests show co-running contexts inflating a camera stream's
  latency (the software-stack effect the mode exists to expose).
* **Active power-state management** (`power/powerem.py::analyze(power_gating=True)`):
  modules idle for N consecutive PTIs drop to a gated state (retention
  leakage only, wake charged at full idle power); energy savings asserted
  in tests on bursty traces.
"""


if __name__ == "__main__":
    main()
