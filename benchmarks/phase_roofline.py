"""Prefill-vs-decode roofline comparison (consumed by the report).

For each (arch, context length L, batch) cell, compile the prefill
(``seq=L``) and decode (``kv_len=L``) op lists of one transformer layer
and place both phases on the chip roofline:

  intensity     = compiled FLOPs / compiled HBM bytes   [flops/byte]
  compute_ns    = FLOPs / peak bf16 FLOP/s
  memory_ns     = HBM bytes / HBM BW
  bound         = whichever term dominates; the ridge point
                  (peak_flops / hbm_bw) separates the regimes

Decode op lists stream the KV cache from HBM (``Op.stream``), so their
intensity collapses from O(seq) to O(batch): the same layer that sits
far right of the ridge in prefill lands deep in the memory-bound region
in decode — the phase-flip that drives latency/energy conclusions in
serving studies. The artifact (``phase_roofline.json``) is rendered by
``benchmarks.report``.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs import get_config
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import lm_layer_ops
from repro.hw.presets import resolve_preset

from .common import csv_row, save_json

PRESET = "v5e"
ARCHS = ("qwen3-32b", "qwen3-moe-30b-a3b")
CTX = (512, 2048, 8192)
BATCH = (1, 8)
N_TILES = 2


def _cell(cfg, hw, *, phase: str, ctx: int, batch: int) -> Dict:
    kw = dict(phase=phase, batch=batch, tp_shards=1)
    if phase == "decode":
        kw["kv_len"] = ctx
    else:
        kw["seq"] = ctx
    ops = lm_layer_ops(cfg, **kw)
    cw = compile_ops(ops, hw, CompileOptions(n_tiles=N_TILES, dtype_bytes=1))
    peak = hw.peak_tflops * 1e12
    compute_ns = cw.total_flops / peak * 1e9
    memory_ns = cw.hbm_bytes / hw.hbm_bytes_per_ns
    intensity = cw.total_flops / cw.hbm_bytes if cw.hbm_bytes else 0.0
    return {
        "arch": cfg.name, "phase": phase, "ctx": ctx, "batch": batch,
        "flops": cw.total_flops, "hbm_bytes": cw.hbm_bytes,
        "flops_per_byte": intensity,
        "compute_ns": compute_ns, "memory_ns": memory_ns,
        "bound": "compute" if compute_ns >= memory_ns else "memory",
        "spilled_layers": cw.spilled_layers,
    }


def run() -> dict:
    hw = resolve_preset(PRESET)
    ridge = hw.peak_tflops * 1e12 / (hw.hbm_bytes_per_ns * 1e9)
    rows: List[Dict] = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for ctx in CTX:
            for b in BATCH:
                for phase in ("prefill", "decode"):
                    rows.append(_cell(cfg, hw, phase=phase, ctx=ctx,
                                      batch=b))
    out = {"preset": PRESET, "ridge_flops_per_byte": ridge, "rows": rows}
    save_json("phase_roofline.json", out)
    return out


def main(print_csv: bool = True) -> dict:
    out = run()
    rows = out["rows"]
    dec = [r for r in rows if r["phase"] == "decode"]
    pre = [r for r in rows if r["phase"] == "prefill"]
    mem_bound_dec = sum(r["bound"] == "memory" for r in dec)
    if print_csv:
        print(csv_row("phase_ridge_flops_per_byte",
                      out["ridge_flops_per_byte"]))
        print(csv_row("decode_cells_memory_bound",
                      mem_bound_dec, f"of {len(dec)}"))
        worst = min(dec, key=lambda r: r["flops_per_byte"])
        print(csv_row("decode_min_flops_per_byte", worst["flops_per_byte"],
                      f"{worst['arch']} kv{worst['ctx']} b{worst['batch']}"))
        best = max(pre, key=lambda r: r["flops_per_byte"])
        print(csv_row("prefill_max_flops_per_byte", best["flops_per_byte"],
                      f"{best['arch']} s{best['ctx']} b{best['batch']}"))
    return out


if __name__ == "__main__":
    main()
