"""Fig 6 analog: performance + power vs clock frequency (joint analysis)."""
from __future__ import annotations

import numpy as np

from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import resnet50
from repro.hw.presets import paper_skew
from repro.power.dvfs import sweep

from .common import save_json


def run() -> dict:
    cfg = paper_skew()
    ops = resnet50()

    def builder(c):
        return compile_ops(ops, c, CompileOptions(n_tiles=2)).tasks

    freqs = [round(f, 2) for f in np.arange(0.3, 1.25, 0.1)]
    pts = sweep(builder, cfg, freqs, n_tiles=2)
    rows = [p.__dict__ for p in pts]
    save_json("frequency_scaling.json", rows)
    # paper claims: perf ~linear in F; power superlinear (V^2)
    perf_ratio = pts[-1].inf_per_s / pts[0].inf_per_s
    power_ratio = pts[-1].avg_w / pts[0].avg_w
    freq_ratio = pts[-1].freq_ghz / pts[0].freq_ghz
    summary = {"freq_ratio": freq_ratio, "perf_ratio": perf_ratio,
               "power_ratio": power_ratio,
               "efficiency_best_at_ghz": min(
                   pts, key=lambda p: 1.0 / max(p.inf_per_j, 1e-9)).freq_ghz}
    save_json("frequency_scaling_summary.json", summary)
    return {"rows": rows, "summary": summary}


def main(print_csv=True):
    out = run()
    if print_csv:
        s = out["summary"]
        print("# Fig-6 analog: perf ~linear, power superlinear in F")
        print(f"F x{s['freq_ratio']:.1f} -> perf x{s['perf_ratio']:.2f}, "
              f"power x{s['power_ratio']:.2f}; best inf/J at "
              f"{s['efficiency_best_at_ghz']} GHz")
    return out


if __name__ == "__main__":
    main()
