"""Fig 6 analog: performance + power vs clock frequency (joint analysis).

A thin sweep spec over the campaign runner: one frequency axis on
ResNet50, fully event-refined (shares cached points with the dvfs_sweep
campaign — same workload, tiles and operating points).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.power.dvfs import DvfsPoint
from repro.sweep import RefineSpec, SweepSpec

from .common import run_and_save_campaign, save_json

FREQS = [round(f, 2) for f in np.arange(0.3, 1.25, 0.1)]


def campaign_spec() -> SweepSpec:
    return SweepSpec(
        name="frequency_scaling",
        description="Fig 6: perf ~linear, power superlinear in F",
        workloads=["resnet50"],
        preset="paper_skew",
        axes={"clock_ghz": FREQS},
        n_tiles=[2],
        refine=RefineSpec(mode="all"),
    )


def run(workers: Optional[int] = None) -> dict:
    res = run_and_save_campaign(campaign_spec(), workers=workers)
    recs = sorted(res.refined, key=lambda r: r["overrides"]["clock_ghz"])
    rows = [DvfsPoint.from_record(r).__dict__ for r in recs]
    save_json("frequency_scaling.json", rows)
    # paper claims: perf ~linear in F; power superlinear (V^2)
    perf_ratio = rows[-1]["inf_per_s"] / rows[0]["inf_per_s"]
    power_ratio = rows[-1]["avg_w"] / rows[0]["avg_w"]
    freq_ratio = rows[-1]["freq_ghz"] / rows[0]["freq_ghz"]
    summary = {"freq_ratio": freq_ratio, "perf_ratio": perf_ratio,
               "power_ratio": power_ratio,
               "efficiency_best_at_ghz": max(
                   rows, key=lambda r: r["inf_per_j"])["freq_ghz"]}
    save_json("frequency_scaling_summary.json", summary)
    return {"rows": rows, "summary": summary, "campaign": res.summary}


def main(print_csv=True):
    out = run()
    if print_csv:
        s = out["summary"]
        print("# Fig-6 analog: perf ~linear, power superlinear in F")
        print(f"F x{s['freq_ratio']:.1f} -> perf x{s['perf_ratio']:.2f}, "
              f"power x{s['power_ratio']:.2f}; best inf/J at "
              f"{s['efficiency_best_at_ghz']} GHz")
    return out


if __name__ == "__main__":
    main()
