"""Captured-HLO ingestion throughput + one crosscheck cell.

Times the three stages a campaign pays when an ``hlo/<fixture>``
workload is first touched — gzip load + parse (``extract_tasks``),
lowering into the ``Op`` contract (``lower_tasks``), and the compile to
a barrier-synchronized task graph — then refines one ingested point and
its hand-built twin on the fast engine and reports the deviation ratio.
Emits ``BENCH_ingest.json``.

No threshold gate — 2-CPU CI runners are noisy; CI archives the JSON as
an artifact so the trajectory is inspectable per commit.

Run:  PYTHONPATH=src python benchmarks/bench_ingest.py [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.graph import ingest
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.hlo_parser import extract_tasks
from repro.hw.presets import resolve_preset, to_dict
from repro.sweep.refine import refine_payload, refine_point

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO, "BENCH_ingest.json")

CROSSCHECK_FIXTURE = "qwen2_1_5b_prefill"


def bench_fixture(fixture: str, cfg) -> dict:
    t0 = time.time()
    text = ingest.load_fixture(fixture)
    meta = ingest.fixture_meta(fixture)
    tasks = extract_tasks(text, pod_size=int(meta.get("pod_size", 0)))
    parse_s = time.time() - t0

    t0 = time.time()
    ops, rep = ingest.lower_tasks(tasks)
    lower_s = time.time() - t0

    t0 = time.time()
    cw = compile_ops(ops, cfg, CompileOptions(n_tiles=2))
    compile_s = time.time() - t0
    return {
        "hlo_kb": len(text) / 1024.0,
        "tasks": rep.n_tasks, "ops": rep.n_ops,
        "compiled_tasks": len(cw.tasks), "layers": rep.n_layers,
        "parse_s": parse_s, "lower_s": lower_s, "compile_s": compile_s,
        "tasks_per_s": rep.n_tasks / max(parse_s + lower_s, 1e-9),
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    cfg = resolve_preset("v5e")
    hw = to_dict(cfg)
    out = {"fixtures": {}, "crosscheck": {}}
    for fx in ingest.fixture_names():
        out["fixtures"][fx] = bench_fixture(fx, cfg)
        r = out["fixtures"][fx]
        print(f"{fx}: {r['hlo_kb']:.0f} KB -> {r['tasks']} tasks "
              f"in {r['parse_s'] + r['lower_s']:.3f}s "
              f"({r['tasks_per_s']:.0f} tasks/s), compile "
              f"{r['compile_s']:.3f}s")

    # one crosscheck cell: ingested vs hand-built, fast engine
    cell = {}
    for tag, wl in [("ingested", f"hlo/{CROSSCHECK_FIXTURE}"),
                    ("hand_built", ingest.twin_name(CROSSCHECK_FIXTURE))]:
        t0 = time.time()
        rec = refine_point(refine_payload(
            workload=wl, n_tiles=2, hw=hw, compile_opts={},
            pti_ns=50_000.0, temp_c=60.0, keep_series=False,
            engine="fast"))
        cell[tag] = {"workload": wl, "wall_s": time.time() - t0,
                     "time_ns": rec["time_ns"],
                     "energy_j": rec["energy_j"]}
    cell["deviation_ratio"] = (cell["ingested"]["time_ns"] /
                               cell["hand_built"]["time_ns"])
    band = ingest.fixture_meta(CROSSCHECK_FIXTURE)["band"]
    cell["band"] = band
    out["crosscheck"] = cell
    print(f"crosscheck {CROSSCHECK_FIXTURE}: refined deviation "
          f"{cell['deviation_ratio']:.2f}x (documented analytic band "
          f"{band})")

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
