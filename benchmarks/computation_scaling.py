"""Fig 5 analog: computation scaling — tiles 1/2/4 x MAC array 2K/4K.

A thin sweep spec over the campaign runner: the MXU-count axis is
analytic, the tile-count axis is structural (one pre-screen per tile
topology), and every point is event-refined for the figure.
"""
from __future__ import annotations

from typing import Optional

from repro.graph.workloads import WORKLOADS
from repro.sweep import RefineSpec, SweepSpec

from .common import run_and_save_campaign, save_json

MACS_TAG = {1: "2K", 2: "4K"}


def campaign_spec() -> SweepSpec:
    return SweepSpec(
        name="computation_scaling",
        description="Fig 5: tile count x MAC-array size scaling",
        workloads=list(WORKLOADS),
        preset="paper_skew",
        axes={"n_mxu": list(MACS_TAG)},
        n_tiles=[1, 2, 4],
        refine=RefineSpec(mode="all"),
    )


def run(workers: Optional[int] = None) -> dict:
    res = run_and_save_campaign(campaign_spec(), workers=workers)
    by_key = {(r["workload"], r["n_tiles"], r["overrides"]["n_mxu"]): r
              for r in res.refined}
    rows = []
    for wname in WORKLOADS:
        base = by_key[(wname, 1, 1)]["inf_per_s"]
        for n_mxu, macs_tag in MACS_TAG.items():
            for nt in (1, 2, 4):
                fps = by_key[(wname, nt, n_mxu)]["inf_per_s"]
                rows.append({"model": wname, "tiles": nt, "macs": macs_tag,
                             "inf_per_s": fps, "speedup_vs_1t2K": fps / base})
    save_json("computation_scaling.json", rows)
    # paper headline factors
    f12, f24, fmac = [], [], []
    for wname in WORKLOADS:
        r = {(x["tiles"], x["macs"]): x["inf_per_s"] for x in rows
             if x["model"] == wname}
        f12.append(r[(2, "2K")] / r[(1, "2K")])
        f24.append(r[(4, "2K")] / r[(2, "2K")])
        fmac.append(r[(1, "4K")] / r[(1, "2K")])
    summary = {
        "avg_scaling_1_to_2_tiles": sum(f12) / len(f12),
        "avg_scaling_2_to_4_tiles": sum(f24) / len(f24),
        "avg_gain_2K_to_4K_macs": sum(fmac) / len(fmac),
    }
    save_json("computation_scaling_summary.json", summary)
    return {"rows": rows, "summary": summary, "campaign": res.summary}


def main(print_csv=True):
    out = run()
    if print_csv:
        s = out["summary"]
        print("# Fig-5 analog (paper: 1.9x, 1.47x, +25-45%)")
        print(f"tiles 1->2: {s['avg_scaling_1_to_2_tiles']:.2f}x   "
              f"2->4: {s['avg_scaling_2_to_4_tiles']:.2f}x   "
              f"2K->4K MACs: +{100*(s['avg_gain_2K_to_4K_macs']-1):.0f}%")
    return out


if __name__ == "__main__":
    main()
