"""Fig 5 analog: computation scaling — tiles 1/2/4 x MAC array 2K/4K."""
from __future__ import annotations

from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import WORKLOADS
from repro.hw.chip import simulate
from repro.hw.presets import paper_skew

from .common import save_json


def run() -> dict:
    rows = []
    for wname, builder in WORKLOADS.items():
        ops = builder()
        base = None
        for n_mxu, macs_tag in ((1, "2K"), (2, "4K")):
            for nt in (1, 2, 4):
                cfg = paper_skew(n_mxu=n_mxu)
                cw = compile_ops(ops, cfg, CompileOptions(n_tiles=nt))
                t = simulate(cw.tasks, cfg, n_tiles=nt).makespan_ns
                fps = 1e9 / t
                if base is None:
                    base = fps
                rows.append({"model": wname, "tiles": nt, "macs": macs_tag,
                             "inf_per_s": fps, "speedup_vs_1t2K": fps / base})
    save_json("computation_scaling.json", rows)
    # paper headline factors
    f12, f24, fmac = [], [], []
    for wname in WORKLOADS:
        r = {(x["tiles"], x["macs"]): x["inf_per_s"] for x in rows
             if x["model"] == wname}
        f12.append(r[(2, "2K")] / r[(1, "2K")])
        f24.append(r[(4, "2K")] / r[(2, "2K")])
        fmac.append(r[(1, "4K")] / r[(1, "2K")])
    summary = {
        "avg_scaling_1_to_2_tiles": sum(f12) / len(f12),
        "avg_scaling_2_to_4_tiles": sum(f24) / len(f24),
        "avg_gain_2K_to_4K_macs": sum(fmac) / len(fmac),
    }
    save_json("computation_scaling_summary.json", summary)
    return {"rows": rows, "summary": summary}


def main(print_csv=True):
    out = run()
    if print_csv:
        s = out["summary"]
        print(f"# Fig-5 analog (paper: 1.9x, 1.47x, +25-45%)")
        print(f"tiles 1->2: {s['avg_scaling_1_to_2_tiles']:.2f}x   "
              f"2->4: {s['avg_scaling_2_to_4_tiles']:.2f}x   "
              f"2K->4K MACs: +{100*(s['avg_gain_2K_to_4K_macs']-1):.0f}%")
    return out


if __name__ == "__main__":
    main()
