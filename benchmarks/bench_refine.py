"""Refinement-throughput trajectory: event engine vs fast engine.

Times ``refine_point`` (compile + simulate + Power-EM, the per-point
campaign refinement unit) on three workload classes and emits
``BENCH_refine.json``:

* **small**  — a single-layer LM point (fast engine == exact replay,
  so the speedup here is the vectorized Power-EM alone),
* **medium** — a 16-layer full-model pod point,
* **full**   — ``lm_full_pod``-class 64-layer points (prefill and
  decode), where steady-state layer extrapolation replays ~4-6 layers
  and synthesizes the rest.

Each row reports wall seconds, points/sec, the fast/event speedup, and
the relative ``time_ns`` disagreement (0 when the fast engine replayed;
float-rounding noise when it extrapolated). No threshold gate — 2-CPU
CI runners are noisy; CI archives the JSON as an artifact so the
trajectory is inspectable per commit.

Run:  PYTHONPATH=src python benchmarks/bench_refine.py [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.hw.presets import resolve_preset, to_dict
from repro.sweep.refine import refine_payload, refine_point

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO, "BENCH_refine.json")

CASES = [
    ("small:lm_layer", "lm/qwen3-32b/s512b1tp1", 50_000.0),
    ("medium:lm_full_pod_L16", "lm/qwen3-32b/L16/s1024b8tp4pod8",
     1_000_000.0),
    ("full:lm_full_pod_L64_prefill", "lm/qwen3-32b/L64/s1024b8tp4pod8",
     1_000_000.0),
    ("full:lm_full_pod_L64_decode",
     "lm/qwen3-32b/L64/decode/kv4096b16tp4pod8", 1_000_000.0),
]


def bench_point(workload: str, pti_ns: float, engine: str, hw: dict,
                repeats: int = 1) -> dict:
    payload = refine_payload(workload=workload, n_tiles=2, hw=hw,
                             compile_opts={}, pti_ns=pti_ns, temp_c=60.0,
                             keep_series=False, engine=engine)
    best = float("inf")
    rec = None
    for _ in range(repeats):
        t0 = time.time()
        rec = refine_point(payload)
        best = min(best, time.time() - t0)
    return {"wall_s": best, "points_per_s": 1.0 / best,
            "time_ns": rec["time_ns"], "energy_j": rec["energy_j"]}


def run(out_path: str = DEFAULT_OUT) -> dict:
    hw = to_dict(resolve_preset("v5e"))
    rows = []
    for label, workload, pti in CASES:
        ev = bench_point(workload, pti, "event", hw)
        fa = bench_point(workload, pti, "fast", hw)
        rows.append({
            "case": label,
            "workload": workload,
            "event_wall_s": ev["wall_s"],
            "event_points_per_s": ev["points_per_s"],
            "fast_wall_s": fa["wall_s"],
            "fast_points_per_s": fa["points_per_s"],
            "speedup": ev["wall_s"] / fa["wall_s"],
            "time_ns_rel_diff": abs(fa["time_ns"] / ev["time_ns"] - 1.0)
            if ev["time_ns"] else 0.0,
            "energy_rel_diff": abs(fa["energy_j"] / ev["energy_j"] - 1.0)
            if ev["energy_j"] else 0.0,
        })
        r = rows[-1]
        print(f"{label:>30s}: event {r['event_wall_s']:6.2f}s  fast "
              f"{r['fast_wall_s']:6.2f}s  speedup {r['speedup']:5.1f}x  "
              f"time_ns rel diff {r['time_ns_rel_diff']:.2e}")
    out = {"rows": rows,
           "full_model_speedup": max(
               r["speedup"] for r in rows if r["case"].startswith("full"))}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {out_path} (full-model speedup "
          f"{out['full_model_speedup']:.1f}x)")
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
