"""Framework-wide before/after: paper-faithful baseline artifacts
(`dryrun_baseline/`) vs the optimized framework defaults (`dryrun/`),
three roofline terms per cell. Quantifies how much of the §Perf hillclimb
transferred to ALL cells (remat_chunk, serving sharding planner, decode
q-replication, vocab-sharded logits)."""
from __future__ import annotations

import glob
import gzip
import json
import os

from repro.graph.hlo_parser import summarize

from .common import ART_DIR, save_json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9


def _terms(path):
    s = summarize(gzip.open(path, "rt").read(), pod_size=256)
    return {
        "compute_s": s.dot_flops / PEAK_FLOPS,
        "memory_s": s.hbm_bytes / HBM_BW,
        "collective_s": (s.link_bytes(cross_pod=False) / ICI_BW
                         + s.link_bytes(cross_pod=True) / DCN_BW),
    }


def run() -> dict:
    rows = []
    for newp in sorted(glob.glob(os.path.join(ART_DIR, "dryrun",
                                              "*.hlo.txt.gz"))):
        base = newp.replace("/dryrun/", "/dryrun_baseline/")
        if not os.path.exists(base):
            continue
        cell = os.path.basename(newp).replace(".hlo.txt.gz", "")
        tb = _terms(base)
        tn = _terms(newp)
        dom_b = max(tb, key=tb.get)
        rows.append({
            "cell": cell,
            "baseline": tb, "optimized": tn,
            "dominant_baseline": dom_b,
            "dominant_term_ratio": (tn[dom_b] / tb[dom_b]
                                    if tb[dom_b] > 0 else 1.0),
        })
    save_json("perf_delta.json", rows)
    return {"rows": rows}


def main(print_csv=True):
    out = run()
    rows = out["rows"]
    if print_csv and rows:
        improved = [r for r in rows if r["dominant_term_ratio"] < 0.95]
        regressed = [r for r in rows if r["dominant_term_ratio"] > 1.05]
        import numpy as np

        ratios = [r["dominant_term_ratio"] for r in rows]
        print(f"# optimized/baseline dominant-term ratio over {len(rows)} "
              f"cells: geomean {np.exp(np.mean(np.log(ratios))):.3f} "
              f"({len(improved)} improved >5%, {len(regressed)} regressed)")
        for r in sorted(rows, key=lambda r: r["dominant_term_ratio"])[:12]:
            print(f"  {r['cell']:52s} {r['dominant_baseline']:10s} "
                  f"x{r['dominant_term_ratio']:.3f}")
    return out


if __name__ == "__main__":
    main()
