"""Table 1 analog: modeling-accuracy characterization.

The paper compares VPU-EM against RTL simulation and against VPUNN (a
learned cost model). No RTL exists here, so the roles are:

  REF     — the detailed event simulation (finest model in this repo; the
            "design ground truth" stand-in)
  EM-fast — the vectorized analytic scheduler (the speed-oriented
            projection whose accuracy is being characterized)
  TPU-NN  — a VPUNN-analog: per-op cost model fitted by least squares on a
            *held-out subset* of operator timings, then applied per-op and
            summed (no overlap modeling — exactly VPUNN's failure mode)

Grid: {MobileNetV2, ResNet50, TinyYOLOv2} x {orig, _C, _S, _SC}, deltas in
percent, mirroring the paper's table layout. Expected qualitative match:
single-digit % for EM-fast on dense models, larger TPU-NN error on sparse
variants (the paper sees the same structure).
"""
from __future__ import annotations

import numpy as np

from repro.core.vectorized import from_tasks, params_of, schedule_many
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.tasks import Task
from repro.graph.workloads import WORKLOADS
from repro.hw.chip import System, simulate
from repro.hw.mxu import GemmSpec
from repro.hw.presets import paper_skew
from repro.hw.vecunit import VecSpec

from .common import save_json

VARIANTS = {
    "": CompileOptions(n_tiles=2),
    "_C": CompileOptions(n_tiles=2, compression=True),
    "_S": CompileOptions(n_tiles=2, sparsity=True),
    "_SC": CompileOptions(n_tiles=2, compression=True, sparsity=True),
}


def _tpu_nn_predict(tasks, cfg, rng) -> float:
    """VPUNN analog: fit per-op linear model time ~ a*flops + b*elems +
    c*bytes + d on HALF the tasks (timed individually by the event engine),
    predict the rest, sum everything (no concurrency)."""
    feats, ys = [], []
    sample = [t for i, t in enumerate(tasks) if i % 2 == 0][:160]
    for t in sample:
        sysm = System(cfg, n_tiles=2)
        solo = Task(engine=t.engine, payload=t.payload)
        rep = sysm.run_workload([solo])
        p = t.payload
        flops = p.flops if isinstance(p, GemmSpec) else 0.0
        elems = p.n_elems if isinstance(p, VecSpec) else 0.0
        nbytes = getattr(p, "nbytes", 0.0)
        feats.append([flops, elems, nbytes, 1.0])
        ys.append(rep.makespan_ns)
    coef, *_ = np.linalg.lstsq(np.asarray(feats), np.asarray(ys), rcond=None)
    total = 0.0
    for t in tasks:
        p = t.payload
        flops = p.flops if isinstance(p, GemmSpec) else 0.0
        elems = p.n_elems if isinstance(p, VecSpec) else 0.0
        nbytes = getattr(p, "nbytes", 0.0)
        total += max(float(np.dot(coef, [flops, elems, nbytes, 1.0])), 0.0)
    return total


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []
    for wname, builder in WORKLOADS.items():
        ops = builder()
        for tag, opts in VARIANTS.items():
            cfg = paper_skew(dma_compression=opts.compression)
            cw = compile_ops(ops, cfg, opts)
            ref = simulate(cw.tasks, cfg, n_tiles=2).makespan_ns
            arrays = from_tasks(cw.tasks)
            em = float(schedule_many(arrays, params_of(cfg)[None])[0])
            nn = _tpu_nn_predict(cw.tasks, cfg, rng)
            rows.append({
                "model": wname + tag,
                "ref_ms": ref / 1e6,
                "em_fast_ms": em / 1e6,
                "tpu_nn_ms": nn / 1e6,
                "em_vs_ref_pct": 100 * (em - ref) / ref,
                "nn_vs_ref_pct": 100 * (nn - ref) / ref,
                "em_vs_nn_pct": 100 * (em - nn) / nn,
            })
    save_json("accuracy_characterization.json", rows)
    return {"rows": rows}


def main(print_csv=True):
    out = run()
    if print_csv:
        print("# Table-1 analog: EM-fast / TPU-NN vs detailed event sim")
        print(f"{'model':>18s} {'ref_ms':>9s} {'em%':>7s} {'nn%':>7s}")
        for r in out["rows"]:
            print(f"{r['model']:>18s} {r['ref_ms']:9.3f} "
                  f"{r['em_vs_ref_pct']:6.1f}% {r['nn_vs_ref_pct']:6.1f}%")
    return out


if __name__ == "__main__":
    main()
