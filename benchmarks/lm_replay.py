"""TPU-EM replay of compiled LM programs (the pod-scale counterpart of the
CNN benchmarks): extract the task DAG from selected dry-run artifacts and
run it through the event-simulated chip + fabric.

Consistency property reported per cell: the event-replayed step time must
be >= the roofline bound max(compute, memory, collective) — the replay adds
dependency-chain serialization the roofline's perfect-overlap bound ignores.
"""
from __future__ import annotations

import gzip
import os
import time

from repro.graph.hlo_parser import extract_tasks, summarize
from repro.hw.pod import simulate_program
from repro.hw.presets import V5E

from .common import ART_DIR, save_json

CELLS = [
    "qwen3-32b__decode_32k__pod2x16x16",
    "smollm-135m__train_4k__pod16x16",
    "hymba-1.5b__long_500k__pod16x16",
]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9


def run(max_tasks: int = 60_000) -> dict:
    rows = []
    for cell in CELLS:
        path = os.path.join(ART_DIR, "dryrun", cell + ".hlo.txt.gz")
        if not os.path.exists(path):
            continue
        text = gzip.open(path, "rt").read()
        s = summarize(text, pod_size=256)
        mem_bound = s.hbm_bytes / HBM_BW
        hard_bound = max(s.dot_flops / PEAK_FLOPS,
                         s.link_bytes(cross_pod=False) / ICI_BW
                         + s.link_bytes(cross_pod=True) / DCN_BW)
        specs = extract_tasks(text, pod_size=256, max_tasks=max_tasks)
        truncated = len(specs) >= max_tasks
        t0 = time.time()
        rep = simulate_program(specs, V5E)
        rows.append({
            "cell": cell,
            "n_tasks": len(specs),
            "truncated": truncated,
            "replay_step_ms": rep.makespan_ns / 1e6,
            # compute+collective cannot be dodged; the memory term is an
            # upper bound (the replay legitimately VMEM-forwards small
            # tiles, so it may land between hard_bound and mem_bound)
            "hard_bound_ms": hard_bound * 1e3,
            "memory_upper_bound_ms": mem_bound * 1e3,
            "bound_respected": rep.makespan_ns / 1e9 >= hard_bound * 0.95
            or truncated,
            "util_mxu": rep.utilization("tile0.mxu"),
            "util_vpu": rep.utilization("tile0.vpu"),
            "util_ici": rep.utilization("ici"),
            "sim_wall_s": time.time() - t0,
        })
    save_json("lm_replay.json", rows)
    return {"rows": rows}


def main(print_csv=True):
    out = run()
    if print_csv:
        print("# TPU-EM pod replay vs roofline bounds")
        for r in out["rows"]:
            trunc = " (TRUNCATED)" if r["truncated"] else ""
            print(f"  {r['cell']:45s} replay {r['replay_step_ms']:9.2f} ms "
                  f"in [{r['hard_bound_ms']:.2f}, "
                  f"{r['memory_upper_bound_ms']:.2f}] ms : "
                  f"{r['bound_respected']}{trunc}")
    return out


if __name__ == "__main__":
    main()
