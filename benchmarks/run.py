"""Benchmark suite driver — one module per paper table/figure.

  accuracy_characterization  Table 1   EM-fast / TPU-NN vs event sim
  computation_scaling        Fig 5     tiles x MAC-array scaling
  frequency_scaling          Fig 6     perf + power vs clock
  membw_scaling              Fig 7     DDR/HBM BW x CB capacity
  power_profile              Fig 8     per-module transient power (PTI)
  dvfs_sweep                 Fig 9     joint perf/power + DVFS policy
  sim_speed                  §2.3      full-model simulation wall time
  bench_refine               (ours)    refinement throughput: event vs fast
  roofline                   (ours)    3-term roofline per dry-run cell

Prints a ``name,value,derived`` CSV line per headline metric; artifacts in
benchmarks/artifacts/.
"""
from __future__ import annotations

import sys
import time

from . import (accuracy_characterization, computation_scaling, dvfs_sweep,
               frequency_scaling, lm_replay, membw_scaling, perf_delta,
               phase_roofline, power_profile, roofline, sim_speed)
from .common import csv_row


def main() -> int:
    t0 = time.time()
    print("== computation_scaling (Fig 5) ==")
    cs = computation_scaling.main()
    s = cs["summary"]
    print(csv_row("scaling_1to2_tiles_x", s["avg_scaling_1_to_2_tiles"],
                  "paper~1.9"))
    print(csv_row("scaling_2to4_tiles_x", s["avg_scaling_2_to_4_tiles"],
                  "paper~1.47"))
    print(csv_row("gain_2K_to_4K_macs_x", s["avg_gain_2K_to_4K_macs"],
                  "paper~1.25-1.45"))

    print("\n== frequency_scaling (Fig 6) ==")
    fs = frequency_scaling.main()
    print(csv_row("freq_perf_ratio", fs["summary"]["perf_ratio"],
                  "near-linear"))
    print(csv_row("freq_power_ratio", fs["summary"]["power_ratio"],
                  "super-linear"))

    print("\n== membw_scaling (Fig 7) ==")
    ms = membw_scaling.main()
    print(csv_row("bw_sensitivity_small_cb_x", ms["summary"]["small_CB"]))
    print(csv_row("bw_sensitivity_large_cb_x", ms["summary"]["large_CB"]))

    print("\n== power_profile (Fig 8) ==")
    pp = power_profile.main()
    print(csv_row("power_peak_w", pp["peak_w"]))
    print(csv_row("power_avg_w", pp["avg_w"]))

    print("\n== dvfs_sweep (Fig 9) ==")
    dv = dvfs_sweep.main()

    print("\n== sweep campaigns (repro.sweep runner) ==")
    campaigns = [out["campaign"] for out in (cs, fs, ms, pp, dv)
                 if "campaign" in out]
    print(csv_row("campaign_grid_points",
                  sum(s["grid_points"] for s in campaigns),
                  "analytic pre-screen (batched XLA)"))
    print(csv_row("campaign_refined",
                  sum(s["refined"] for s in campaigns),
                  "event-engine ground truth"))
    print(csv_row("campaign_cache_hits",
                  sum(s["cache_hits"] for s in campaigns),
                  "incremental re-runs"))

    print("\n== accuracy_characterization (Table 1) ==")
    ac = accuracy_characterization.main()
    dense = [abs(r["em_vs_ref_pct"]) for r in ac["rows"]
             if "_S" not in r["model"]]
    print(csv_row("em_fast_abs_err_dense_pct", sum(dense) / len(dense),
                  "paper: <=5-10%"))

    print("\n== sim_speed (objective §2.3) ==")
    ss = sim_speed.main()
    print(csv_row("resnet50_sim_wall_s",
                  next(r["wall_s"] for r in ss["rows"]
                       if r["workload"] == "resnet50"), "paper: minutes"))

    print("\n== bench_refine (event vs fast refinement engine) ==")
    from . import bench_refine
    br = bench_refine.run()
    print(csv_row("refine_full_model_speedup_x", br["full_model_speedup"],
                  "fast/event on lm_full_pod-class points"))

    print("\n== lm_replay (TPU-EM pod replay of compiled programs) ==")
    lr = lm_replay.main()
    if lr["rows"]:
        print(csv_row("replay_bound_respected",
                      float(all(r["bound_respected"] for r in lr["rows"]))))

    print("\n== phase_roofline (prefill vs decode) ==")
    phase_roofline.main()

    print("\n== roofline (dry-run artifacts) ==")
    rf = roofline.main(print_csv=False)
    ok = [r for r in rf["rows"] if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        print(csv_row("roofline_cells_ok", len(ok)))
        print(csv_row("worst_roofline_fraction", worst["roofline_fraction"],
                      f"{worst['arch']}/{worst['shape']}/{worst['mesh']}"))

    print("\n== perf_delta (baseline vs optimized framework, all cells) ==")
    pd = perf_delta.main()
    if pd["rows"]:
        import numpy as np

        ratios = [r["dominant_term_ratio"] for r in pd["rows"]]
        print(csv_row("dominant_term_geomean_ratio",
                      float(np.exp(np.mean(np.log(ratios)))),
                      "optimized/baseline, <1 is better"))

    print(f"\nbenchmarks done in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
