"""Shared helpers for the benchmark suite (one module per paper artifact)."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def art_path(*parts: str) -> str:
    p = os.path.join(ART_DIR, *parts)
    os.makedirs(os.path.dirname(p), exist_ok=True)
    return p


def save_json(name: str, obj: Any) -> str:
    p = art_path(name)
    with open(p, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return p


def csv_row(name: str, value: float, derived: str = "") -> str:
    return f"{name},{value:.6g},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *exc):
        self.wall_s = time.time() - self.t0
