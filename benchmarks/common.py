"""Shared helpers for the benchmark suite (one module per paper artifact)."""
from __future__ import annotations

import json
import os
import time
from typing import Any

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
CAMPAIGN_DIR = os.path.join(ART_DIR, "campaigns")
SWEEP_CACHE_DIR = os.path.join(ART_DIR, "sweep_cache")


def run_and_save_campaign(spec, *, workers=None, use_cache=True):
    """Drive one sweep campaign with the shared benchmarks cache and
    archive its records under ``artifacts/campaigns/<name>.json``."""
    from repro.sweep.runner import run_campaign, save_result

    res = run_campaign(spec, workers=workers, use_cache=use_cache,
                       cache_dir=SWEEP_CACHE_DIR)
    save_result(res, os.path.join(CAMPAIGN_DIR, f"{spec.name}.json"))
    return res


def art_path(*parts: str) -> str:
    p = os.path.join(ART_DIR, *parts)
    os.makedirs(os.path.dirname(p), exist_ok=True)
    return p


def save_json(name: str, obj: Any) -> str:
    p = art_path(name)
    with open(p, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return p


def csv_row(name: str, value: float, derived: str = "") -> str:
    return f"{name},{value:.6g},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *exc):
        self.wall_s = time.time() - self.t0
