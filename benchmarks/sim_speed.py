"""Simulation-speed objective (paper §2.3: full model within minutes).

Reports wall-clock per full-model simulation and the event rate, for the
paper CNNs and for a pod-scale LM replay from a dry-run artifact."""
from __future__ import annotations

import glob
import gzip
import os
import time

from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import WORKLOADS
from repro.hw.chip import simulate
from repro.hw.presets import V5E, paper_skew

from .common import ART_DIR, save_json


def run() -> dict:
    rows = []
    for wname, builder in WORKLOADS.items():
        ops = builder()
        cfg = paper_skew()
        cw = compile_ops(ops, cfg, CompileOptions(n_tiles=4))
        t0 = time.time()
        simulate(cw.tasks, cfg, n_tiles=4)
        wall = time.time() - t0
        rows.append({"workload": wname, "n_tasks": len(cw.tasks),
                     "wall_s": wall, "tasks_per_s": len(cw.tasks) / wall})
    # LM replay speed (if a decode artifact exists)
    cand = sorted(glob.glob(os.path.join(
        ART_DIR, "dryrun", "qwen3-32b__decode_32k__*.hlo.txt.gz")))
    if cand:
        from repro.graph.hlo_parser import extract_tasks
        from repro.hw.pod import simulate_program

        text = gzip.open(cand[0], "rt").read()
        specs = extract_tasks(text, pod_size=256, max_tasks=50_000)
        t0 = time.time()
        simulate_program(specs, V5E)
        wall = time.time() - t0
        rows.append({"workload": "qwen3-32b decode (HLO replay)",
                     "n_tasks": len(specs), "wall_s": wall,
                     "tasks_per_s": len(specs) / wall})
    save_json("sim_speed.json", rows)
    return {"rows": rows}


def main(print_csv=True):
    out = run()
    if print_csv:
        print("# sim-speed objective (paper: ResNet50-class in minutes)")
        for r in out["rows"]:
            print(f"{r['workload']:>32s}: {r['n_tasks']:6d} tasks in "
                  f"{r['wall_s']:6.2f}s ({r['tasks_per_s']:8.0f} tasks/s)")
    return out


if __name__ == "__main__":
    main()
