"""Spool saturation benchmark: jobs/sec through the filesystem queue.

Drives a synthetic job mix (trivial refine_fn, so the numbers measure
the spool substrate — claim-by-rename, heartbeat leases, atomic
publishes — not the simulator) through 1/2/4 concurrent workers on one
spool, then times a janitor compaction pass over the finished ``done/``
directory. Trajectory artifact (``BENCH_spool.json``), no gate: CI
runners are 2-CPU and shared-filesystem latency varies too much to
threshold, but regressions in the claim path show up clearly across
commits.

Run:  PYTHONPATH=src python benchmarks/bench_spool.py [--out PATH]
          [--jobs N] [--workers 1,2,4]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

from repro.exec import Spool, run_worker
from repro.exec.janitor import janitor_pass

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO, "BENCH_spool.json")


def _refine(payload: dict) -> dict:
    return {"out": payload["i"]}


def _drain(root: str, n_workers: int) -> float:
    threads = []
    t0 = time.time()
    for w in range(n_workers):
        t = threading.Thread(
            target=run_worker,
            kwargs=dict(root=root, worker=f"bench-w{w}",
                        refine_fn=_refine, hb_s=30.0),
            daemon=True)
        t.start()
        threads.append(t)
    for t in threads:
        t.join()
    return time.time() - t0


def run(out_path: str = DEFAULT_OUT, *, jobs: int = 300,
        workers: tuple = (1, 2, 4)) -> dict:
    sweep = {}
    compaction = None
    for k in workers:
        with tempfile.TemporaryDirectory() as td:
            root = os.path.join(td, "sp")
            spool = Spool(root)
            t0 = time.time()
            for i in range(jobs):
                spool.submit(f"job{i:05d}", {"i": i})
            submit_s = time.time() - t0
            wall_s = _drain(root, k)
            n_done = len(spool.done_keys())
            assert n_done == jobs, f"{n_done}/{jobs} done with {k} workers"
            sweep[f"workers_{k}"] = {
                "jobs": jobs,
                "submit_s": submit_s,
                "submit_jobs_per_s": jobs / submit_s,
                "drain_s": wall_s,
                "jobs_per_s": jobs / wall_s,
            }
            if k == max(workers):
                # compaction throughput over the full finished spool
                t0 = time.time()
                stats = janitor_pass(spool, tmp_age_s=-1.0,
                                     corrupt_age_s=-1.0,
                                     compact_age_s=-1.0)
                compact_s = time.time() - t0
                assert stats["compacted"] == jobs
                assert len(spool.done_keys()) == jobs  # still all visible
                compaction = {
                    "files": jobs,
                    "wall_s": compact_s,
                    "files_per_s": jobs / compact_s,
                }

    out = {"bench": "spool", "jobs": jobs, "sweep": sweep,
           "compaction": compaction}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1, default=float)

    base = sweep[f"workers_{workers[0]}"]["jobs_per_s"]
    for k in workers:
        s = sweep[f"workers_{k}"]
        print(f"spool_jobs_per_s_w{k},{s['jobs_per_s']:.6g},"
              f"x{s['jobs_per_s'] / base:.2f} vs 1 worker")
    if compaction:
        print(f"spool_compact_files_per_s,"
              f"{compaction['files_per_s']:.6g},")
    print(f"artifact,{out_path},")
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--jobs", type=int, default=300)
    ap.add_argument("--workers", default="1,2,4",
                    help="comma-separated worker counts")
    args = ap.parse_args()
    workers = tuple(int(w) for w in args.workers.split(","))
    run(args.out, jobs=args.jobs, workers=workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
