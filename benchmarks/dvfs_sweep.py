"""Fig 9 analog: joint perf/power across models x operating frequencies,
plus the battery-life DVFS policy pick (lowest energy meeting a floor)."""
from __future__ import annotations

import numpy as np

from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import WORKLOADS
from repro.hw.presets import paper_skew
from repro.power.dvfs import choose_operating_point, sweep

from .common import save_json


def run() -> dict:
    cfg = paper_skew()
    freqs = [round(f, 1) for f in np.arange(0.3, 1.25, 0.1)]  # 100MHz steps
    all_rows = {}
    picks = {}
    for wname, builder_fn in WORKLOADS.items():
        ops = builder_fn()

        def builder(c):
            return compile_ops(ops, c, CompileOptions(n_tiles=2)).tasks

        pts = sweep(builder, cfg, freqs, n_tiles=2)
        all_rows[wname] = [p.__dict__ for p in pts]
        floor = 0.5 * max(p.inf_per_s for p in pts)
        pick = choose_operating_point(pts, floor)
        picks[wname] = {"floor_inf_per_s": floor,
                        "freq_ghz": pick.freq_ghz if pick else None,
                        "avg_w": pick.avg_w if pick else None}
    save_json("dvfs_sweep.json", {"rows": all_rows, "picks": picks})
    return {"rows": all_rows, "picks": picks}


def main(print_csv=True):
    out = run()
    if print_csv:
        print("# Fig-9 analog: workload-specific DVFS operating points")
        for w, p in out["picks"].items():
            print(f"{w:>14s}: >= {p['floor_inf_per_s']:7.1f} inf/s -> "
                  f"{p['freq_ghz']} GHz @ {p['avg_w']:.1f} W")
    return out


if __name__ == "__main__":
    main()
