"""Fig 9 analog: joint perf/power across models x operating frequencies,
plus the battery-life DVFS policy pick (lowest energy meeting a floor).

Now a thin sweep spec over the campaign runner (``repro.sweep``): the
frequency axis is refined in full on the event engine (mode="all", the
figure needs ground truth at every point), in parallel workers behind the
shared result cache — re-running the benchmark suite is incremental.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.workloads import WORKLOADS
from repro.power.dvfs import DvfsPoint, choose_operating_point
from repro.sweep import RefineSpec, SweepSpec

from .common import run_and_save_campaign, save_json

FREQS = [round(f, 1) for f in np.arange(0.3, 1.25, 0.1)]  # 100MHz steps


def campaign_spec() -> SweepSpec:
    return SweepSpec(
        name="dvfs_sweep",
        description="Fig 9: joint perf/power DVFS policy, all workloads",
        workloads=list(WORKLOADS),
        preset="paper_skew",
        axes={"clock_ghz": FREQS},
        n_tiles=[2],
        refine=RefineSpec(mode="all"),
    )


def _points(records, wname):
    recs = sorted((r for r in records
                   if r["workload"] == wname and r["refined"]),
                  key=lambda r: r["overrides"]["clock_ghz"])
    return [DvfsPoint.from_record(r) for r in recs]


def run(workers: Optional[int] = None) -> dict:
    res = run_and_save_campaign(campaign_spec(), workers=workers)
    all_rows = {}
    picks = {}
    for wname in WORKLOADS:
        pts = _points(res.records, wname)
        all_rows[wname] = [p.__dict__ for p in pts]
        floor = 0.5 * max(p.inf_per_s for p in pts)
        pick = choose_operating_point(pts, floor)
        picks[wname] = {"floor_inf_per_s": floor,
                        "freq_ghz": pick.freq_ghz if pick else None,
                        "avg_w": pick.avg_w if pick else None}
    save_json("dvfs_sweep.json", {"rows": all_rows, "picks": picks})
    return {"rows": all_rows, "picks": picks, "campaign": res.summary}


def main(print_csv=True):
    out = run()
    if print_csv:
        print("# Fig-9 analog: workload-specific DVFS operating points")
        for w, p in out["picks"].items():
            print(f"{w:>14s}: >= {p['floor_inf_per_s']:7.1f} inf/s -> "
                  f"{p['freq_ghz']} GHz @ {p['avg_w']:.1f} W")
    return out


if __name__ == "__main__":
    main()
