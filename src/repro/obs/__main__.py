"""Observability CLI.

  python -m repro.obs trace <point.json|journal.jsonl|workload> \
      [-o trace.json] [--preset v5e] [--n-tiles N] [--pti-ns NS]

Emits a Perfetto/Chrome trace ('traceEvents' JSON — load it at
ui.perfetto.dev or chrome://tracing) for:

* a refinement/serve **payload file** (``.json`` — the cache-keyed dict
  a campaign dispatches; ``kind: "serve"`` routes to the fleet
  exporter, anything else re-simulates on the event engine),
* a campaign **journal** (``.jsonl`` — worker lanes from the exec
  journal's wall timings),
* a bare **workload name** (e.g. ``lm/qwen3-32b/L8/s512b1tp1``) —
  a payload is synthesized from ``--preset``/``--n-tiles``/``--pti-ns``
  and simulated on the event engine.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .perfetto import (trace_campaign_journal, trace_event_point,
                       trace_serve_point, write_trace)


def _payload_for(args: argparse.Namespace) -> dict:
    if os.path.isfile(args.target):
        with open(args.target) as f:
            return json.load(f)
    from ..hw.presets import resolve_preset, to_dict
    from ..sweep.refine import refine_payload
    return refine_payload(workload=args.target, n_tiles=args.n_tiles,
                          hw=to_dict(resolve_preset(args.preset)),
                          compile_opts={}, pti_ns=args.pti_ns,
                          temp_c=args.temp_c, keep_series=False)


def cmd_trace(args: argparse.Namespace) -> int:
    if args.target.endswith(".jsonl"):
        trace = trace_campaign_journal(args.target)
        kind = "campaign-journal"
    else:
        payload = _payload_for(args)
        if payload.get("kind") == "serve":
            trace = trace_serve_point(payload)
            kind = "serve-point"
        else:
            trace = trace_event_point(payload)
            kind = "event-point"
    write_trace(trace, args.output)
    print(f"{kind}: {len(trace['traceEvents'])} events -> {args.output}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    tp = sub.add_parser("trace", help="export a Perfetto/Chrome trace")
    tp.add_argument("target",
                    help="payload .json, campaign journal .jsonl, or "
                         "workload name")
    tp.add_argument("-o", "--output", default="trace.json")
    tp.add_argument("--preset", default="v5e",
                    help="hw preset for bare workload names")
    tp.add_argument("--n-tiles", type=int, default=2)
    tp.add_argument("--pti-ns", type=float, default=10_000.0)
    tp.add_argument("--temp-c", type=float, default=60.0)
    tp.set_defaults(fn=cmd_trace)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
