"""Zero-dependency metrics registry — the observability data plane.

Every instrumented subsystem (event engine, fast engine, batched
refinement — structural-class sizes, shared-vs-fallback point counts,
twin-replay memo hit rate under ``batch.*`` — serving fleet, exec
backends) records into a ``MetricsRegistry``: counters, gauges, and
fixed-bucket histograms, each addressable by name + sorted label pairs.
Two contracts make this a subsystem instead of scattered prints:

* **Determinism** — metrics are pure functions of the simulated inputs:
  no wall-clock, no RNG, insertion-independent snapshots (keys sorted).
  Equal inputs produce byte-identical ``snapshot()`` JSON; the property
  test in ``tests/test_obs.py`` holds this still.
* **Off-by-default, near-zero cost** — the module-level ``REGISTRY``
  starts disabled unless ``REPRO_METRICS=1``. Instrumentation sites
  guard on ``REGISTRY.enabled`` (one attribute load + branch, placed
  outside hot loops wherever possible), and the hot event-kernel loop
  switches to its instrumented variant only when enabled.
  ``benchmarks/bench_obs.py`` gates the measured overhead at <5% on the
  ``bench_refine`` 64-layer point.

Wall-clock timings live in *journals* (``exec.journal``), never here —
that split is what keeps campaign records byte-identical across
backends while telemetry still flows.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "REGISTRY", "enabled", "set_enabled", "collecting"]


class Counter:
    """Monotone accumulator (events processed, jobs claimed, ...)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-written level (queue depth, heap size). ``set`` overwrites;
    ``set_max`` keeps the high-water mark."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def set_max(self, value: float) -> None:
        if value > self.value:
            self.value = float(value)


# default histogram bounds: powers of two — matches the serve cost
# model's bucketing and keeps snapshots readable
_DEFAULT_BOUNDS = tuple(float(1 << i) for i in range(16))


class Histogram:
    """Fixed-bucket histogram (cumulative counts, like Prometheus).

    ``bounds`` are the inclusive upper edges; observations above the
    last bound land in the implicit +inf bucket. Also tracks count /
    sum / min / max so snapshots answer "p50-ish where" without
    per-observation storage.
    """

    __slots__ = ("bounds", "buckets", "count", "sum", "min", "max")

    def __init__(self, bounds: Iterable[float] = _DEFAULT_BOUNDS) -> None:
        self.bounds: Tuple[float, ...] = tuple(sorted(float(b)
                                                      for b in bounds))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = [0] * (len(self.bounds) + 1)   # last = +inf
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


def _key(name: str, labels: Dict[str, Any]) -> str:
    """Flattened metric identity: ``name{k=v,...}`` with sorted keys —
    the snapshot key, so identity never depends on call order."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Name+labels -> instrument store with a deterministic snapshot."""

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    # -- instrument accessors (get-or-create) -----------------------------
    def counter(self, name: str, **labels: Any) -> Counter:
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter()
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            g = self._gauges[k] = Gauge()
        return g

    def histogram(self, name: str,
                  bounds: Optional[Iterable[float]] = None,
                  **labels: Any) -> Histogram:
        k = _key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            h = self._histograms[k] = Histogram(bounds if bounds is not None
                                                else _DEFAULT_BOUNDS)
        return h

    # -- export ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-safe, sorted, wall-clock-free view of every instrument."""
        hist: Dict[str, Any] = {}
        for k in sorted(self._histograms):
            h = self._histograms[k]
            hist[k] = {
                "count": h.count,
                "sum": h.sum,
                "min": h.min if h.count else 0.0,
                "max": h.max if h.count else 0.0,
                "mean": h.mean(),
                "buckets": {f"le_{b:g}": n
                            for b, n in zip(h.bounds, h.buckets)},
                "overflow": h.buckets[-1],
            }
        return {
            "counters": {k: self._counters[k].value
                         for k in sorted(self._counters)},
            "gauges": {k: self._gauges[k].value
                       for k in sorted(self._gauges)},
            "histograms": hist,
        }

    def snapshot_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), sort_keys=True, indent=indent)

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._histograms.clear()


#: process-global registry: off unless REPRO_METRICS=1 (the overhead
#: contract); flip with ``set_enabled`` / the ``collecting`` helper.
REGISTRY = MetricsRegistry(
    enabled=os.environ.get("REPRO_METRICS", "0") not in ("", "0"))


def enabled() -> bool:
    return REGISTRY.enabled


def set_enabled(flag: bool) -> None:
    REGISTRY.enabled = bool(flag)


class collecting:
    """``with collecting() as reg:`` — enable the global registry for a
    scope (resetting it on entry), restore the prior state on exit.
    The test/bench harness idiom."""

    def __init__(self, reset: bool = True) -> None:
        self._reset = reset
        self._prev = False

    def __enter__(self) -> MetricsRegistry:
        self._prev = REGISTRY.enabled
        if self._reset:
            REGISTRY.reset()
        REGISTRY.enabled = True
        return REGISTRY

    def __exit__(self, *exc: Any) -> None:
        REGISTRY.enabled = self._prev


def _labels_of(key: str) -> Tuple[str, Dict[str, str]]:
    """Inverse of ``_key`` — used by the CLI/table renderers."""
    if "{" not in key:
        return key, {}
    name, _, rest = key.partition("{")
    pairs = rest.rstrip("}").split(",")
    return name, dict(p.split("=", 1) for p in pairs if "=" in p)


def render_table(snap: Dict[str, Any]) -> List[str]:
    """Plain-text ``name,value`` lines of a snapshot (CLI output)."""
    lines: List[str] = []
    for k, v in snap.get("counters", {}).items():
        lines.append(f"counter,{k},{v:g}")
    for k, v in snap.get("gauges", {}).items():
        lines.append(f"gauge,{k},{v:g}")
    for k, h in snap.get("histograms", {}).items():
        lines.append(f"histogram,{k},count={h['count']} mean={h['mean']:g} "
                     f"min={h['min']:g} max={h['max']:g}")
    return lines
