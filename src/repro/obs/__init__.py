"""Observability subsystem: metrics, Perfetto timelines, live progress.

Three cross-cutting pieces over the whole simulator stack:

* ``obs.metrics`` — zero-dep counters/gauges/histograms with labels and
  deterministic JSON snapshots, instrumented into the event engine
  (heap depth, events processed, resource-contention stalls), the fast
  engine (extrapolation hits vs full-replay fallbacks), the serving
  fleet (KV-slot occupancy, batch composition, admission/eviction,
  queue depth), and the exec backends (claims/reclaims/quarantines,
  cache hit rates). Off by default (``REPRO_METRICS=1`` enables).
* ``obs.perfetto`` — Chrome-trace/Perfetto exporter with three track
  families: engine task timelines with Power-EM counter tracks,
  serving-fleet request-lifecycle spans with KV-occupancy counters,
  and campaign worker lanes reconstructed from the exec journal.
  CLI: ``python -m repro.obs trace <point|journal> -o trace.json``.
* ``obs.progress`` — the incremental campaign-journal fold behind
  ``python -m repro.exec status --watch`` (per-phase throughput,
  per-worker liveness, ETA) and the ``progress`` block in campaign
  summaries.

``obs.metrics`` is eagerly importable from anywhere (pure stdlib, no
repro imports — instrumented hot paths depend on it, never the other
way around). The exporters are lazy (PEP 562) so importing the metrics
plane never drags simulation modules in.
"""
from typing import TYPE_CHECKING

from .metrics import (MetricsRegistry, REGISTRY, collecting, enabled,
                      set_enabled)

__all__ = ["MetricsRegistry", "REGISTRY", "collecting", "enabled",
           "set_enabled", "trace_event_point", "trace_serve_point",
           "trace_campaign_journal", "write_trace", "CampaignProgress",
           "JournalFollower", "render_progress"]

if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from .perfetto import (trace_campaign_journal, trace_event_point,
                           trace_serve_point, write_trace)
    from .progress import CampaignProgress, JournalFollower

_LAZY = {
    "trace_event_point": "perfetto",
    "trace_serve_point": "perfetto",
    "trace_campaign_journal": "perfetto",
    "write_trace": "perfetto",
    "CampaignProgress": "progress",
    "JournalFollower": "progress",
    "render_progress": "progress",
}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is not None:
        import importlib

        return getattr(importlib.import_module(f".{mod}", __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
