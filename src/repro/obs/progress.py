"""Live campaign progress: incremental journal fold -> throughput + ETA.

Two pieces:

* ``JournalFollower`` — a byte-offset tail over an append-only JSONL
  journal. Each ``poll()`` consumes only *complete* lines (the offset
  never advances past a line missing its newline), so a writer caught
  mid-``write`` just means the torn tail is parsed on the next poll —
  the watch loop never sees a corrupt event.
* ``CampaignProgress`` — folds journal events (one at a time, so the
  follower can stream into it) into per-phase throughput (points/s,
  cached vs simulated), per-worker liveness, and an ETA extrapolated
  from the simulated-point rate.

Everything here is a pure function of journal content: timestamps are
the journal's own wall-clock fields (``t``, ``wall_s``), never
``time.time()`` — so the same journal always folds to the same
``summary()``, and the ``progress`` block in campaign records stays
reproducible from the journal alone. The CLI (``python -m repro.exec
status --watch``) passes ``now=time.time()`` explicitly to age
liveness against the real clock.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from ..exec.journal import JournalView

__all__ = ["CampaignProgress", "JournalFollower", "render_progress"]

#: a worker with no journal event for this long is reported stalled
STALL_S = 120.0


class JournalFollower:
    """Tail a JSONL file incrementally, yielding parsed complete lines."""

    def __init__(self, path: str):
        self.path = path
        self.offset = 0
        self.warnings: List[str] = []
        self._lineno = 0

    def poll(self) -> List[Dict[str, Any]]:
        """Parse every complete line appended since the last poll."""
        try:
            size = os.path.getsize(self.path)
        except OSError:
            return []
        if size <= self.offset:
            return []
        with open(self.path, "rb") as f:
            f.seek(self.offset)
            data = f.read(size - self.offset)
        # consume up to the last newline only: a torn tail line stays
        # buffered in the file until its writer finishes it
        cut = data.rfind(b"\n")
        if cut < 0:
            return []
        chunk, self.offset = data[: cut + 1], self.offset + cut + 1
        events: List[Dict[str, Any]] = []
        for raw in chunk.split(b"\n"):
            if not raw.strip():
                continue
            self._lineno += 1
            try:
                ev = json.loads(raw)
            except json.JSONDecodeError:
                self.warnings.append(
                    f"{self.path}:{self._lineno}: skipped unparseable "
                    f"journal line ({len(raw)} bytes)")
                continue
            if isinstance(ev, dict):
                events.append(ev)
        return events


class CampaignProgress:
    """Fold journal events into phase throughput, liveness, and ETA."""

    def __init__(self) -> None:
        self.view = JournalView()
        self.workers: Dict[str, Dict[str, Any]] = {}
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None
        self.wall_s_sum = 0.0

    # -- folding -----------------------------------------------------------
    def feed(self, ev: Dict[str, Any]) -> None:
        self.view.fold(ev)
        t = ev.get("t")
        if isinstance(t, (int, float)):
            if self.t_first is None or t < self.t_first:
                self.t_first = float(t)
            if self.t_last is None or t > self.t_last:
                self.t_last = float(t)
        if ev.get("ev") != "point":
            return
        w = ev.get("worker")
        if w:
            st = self.workers.setdefault(
                str(w), {"points": 0, "wall_s": 0.0, "last_t": 0.0})
            st["points"] += 1
            st["wall_s"] += float(ev.get("wall_s") or 0.0)
            if isinstance(t, (int, float)) and t > st["last_t"]:
                st["last_t"] = float(t)
        if ev.get("status") == "done":
            self.wall_s_sum += float(ev.get("wall_s") or 0.0)

    def feed_all(self, events: List[Dict[str, Any]]) -> None:
        for ev in events:
            self.feed(ev)

    @classmethod
    def from_file(cls, path: str) -> "CampaignProgress":
        prog = cls()
        view = JournalView.from_file(path)
        for ev in view.events:
            prog.feed(ev)
        prog.view.warnings = list(view.warnings)
        return prog

    # -- derived view ------------------------------------------------------
    def summary(self, now: Optional[float] = None) -> Dict[str, Any]:
        """The ``progress`` block: phase counts/rates, workers, ETA.

        ``now`` defaults to the last journal timestamp (deterministic);
        the watch CLI passes ``time.time()`` to age worker liveness
        against the real clock.
        """
        c = self.view.counts()
        start = self.view.start_ev or {}
        to_refine = int(start.get("to_refine", 0)) or c["total"]
        resolved = c["done"] + c["cached"] + c["failed"]
        t_ref = now if now is not None else self.t_last
        elapsed = ((t_ref - self.t_first)
                   if (t_ref is not None and self.t_first is not None)
                   else 0.0)
        rate = resolved / elapsed if elapsed > 0 else 0.0
        sim_rate = c["done"] / elapsed if elapsed > 0 else 0.0
        remaining = max(to_refine - resolved, 0)
        finished = self.view.end_ev is not None or (
            to_refine > 0 and remaining == 0)
        if finished or remaining == 0:
            eta_s: Optional[float] = 0.0
        elif sim_rate > 0:
            # pending points will be simulated, not cache-served: the
            # simulated rate is the honest extrapolation basis
            eta_s = remaining / sim_rate
        elif rate > 0:
            eta_s = remaining / rate
        else:
            eta_s = None
        workers = {}
        for w in sorted(self.workers):
            st = self.workers[w]
            age = ((t_ref - st["last_t"])
                   if (t_ref is not None and st["last_t"]) else None)
            workers[w] = {
                "points": st["points"],
                "wall_s": st["wall_s"],
                "idle_s": age,
                "alive": age is not None and age < STALL_S,
            }
        return {
            "campaign": start.get("campaign"),
            "backend": start.get("backend"),
            "to_refine": to_refine,
            "resolved": resolved,
            "cached": c["cached"],
            "simulated": c["done"],
            "failed": c["failed"],
            "remaining": remaining,
            "elapsed_s": elapsed,
            "points_per_s": rate,
            "sim_points_per_s": sim_rate,
            "mean_point_wall_s": (self.wall_s_sum / c["done"]
                                  if c["done"] else 0.0),
            "eta_s": eta_s,
            "finished": finished,
            "workers": workers,
        }


def render_progress(s: Dict[str, Any]) -> List[str]:
    """Human-readable lines of a ``CampaignProgress.summary()``."""
    eta = s.get("eta_s")
    eta_txt = "done" if s.get("finished") else (
        f"{eta:.0f}s" if eta is not None else "?")
    lines = [
        f"campaign {s.get('campaign') or '?'} "
        f"[{s.get('backend') or '?'}]: "
        f"{s['resolved']}/{s['to_refine']} resolved "
        f"({s['cached']} cached, {s['simulated']} simulated, "
        f"{s['failed']} failed)",
        f"  rate {s['points_per_s']:.2f} pts/s "
        f"(sim {s['sim_points_per_s']:.2f}/s, "
        f"mean point {s['mean_point_wall_s']:.2f}s)  eta {eta_txt}",
    ]
    for w, st in s.get("workers", {}).items():
        mark = "+" if st["alive"] else "-"
        idle = (f"{st['idle_s']:.0f}s ago"
                if st["idle_s"] is not None else "never")
        lines.append(f"  worker {mark} {w}: {st['points']} pts "
                     f"({st['wall_s']:.1f}s busy, last {idle})")
    return lines
