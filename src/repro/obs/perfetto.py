"""Perfetto/Chrome-trace exporter: every simulation plane as a timeline.

Generalizes ``core.trace.to_chrome_trace`` (one tracer's task/activity
events) into three track families over the whole stack, all emitted in
'traceEvents' JSON that chrome://tracing and ui.perfetto.dev load
directly:

* **Engine points** (``trace_event_point``) — per-engine task timelines
  and sub-task activity samples from one event-engine simulation, plus
  Power-EM counter tracks: one watts counter per power node (and the
  chip total), sampled at the payload's PTI.
* **Serve points** (``trace_serve_point``) — request-lifecycle spans
  (queued -> prefill -> decode, colored by final status) on per-replica
  lanes, plus per-replica counter tracks for KV-resident tokens, queue
  depth, and batch composition, captured step by step from the fleet
  event loop.
* **Campaign journals** (``trace_campaign_journal``) — worker lanes
  reconstructed from the exec journal: each simulated point becomes a
  span of its journaled wall time ending at its completion timestamp;
  cache hits and failures become instant events.

Everything an exporter needs is re-simulated from the payload (points)
or folded from the journal (campaigns) — traces are derived artifacts,
never inputs, so point traces are as deterministic as the records.

CLI: ``python -m repro.obs trace <point.json|journal.jsonl|workload>``.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

__all__ = ["TraceBuilder", "trace_event_point", "trace_serve_point",
           "trace_campaign_journal", "write_trace"]

_STATUS_CATS = {"done": "good", "evicted": "warn", "rejected": "bad",
                "failed": "bad"}


class TraceBuilder:
    """Chrome-trace 'traceEvents' assembler: pids by process name,
    complete/instant/counter events, metadata emitted on first use."""

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []
        self._pids: Dict[str, int] = {}

    def pid(self, process: str) -> int:
        p = self._pids.get(process)
        if p is None:
            p = self._pids[process] = len(self._pids) + 1
            self.events.append({"ph": "M", "pid": p,
                                "name": "process_name",
                                "args": {"name": process}})
        return p

    def span(self, process: str, tid: Any, name: str, *, ts_us: float,
             dur_us: float, cat: str = "span",
             args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {"ph": "X", "name": name, "cat": cat,
                              "pid": self.pid(process), "tid": tid,
                              "ts": ts_us, "dur": max(dur_us, 1e-3)}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, process: str, tid: Any, name: str, *, ts_us: float,
                cat: str = "instant",
                args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {"ph": "i", "name": name, "cat": cat,
                              "pid": self.pid(process), "tid": tid,
                              "ts": ts_us, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, process: str, name: str, *, ts_us: float,
                values: Dict[str, float]) -> None:
        self.events.append({"ph": "C", "name": name, "cat": "counter",
                            "pid": self.pid(process), "tid": 0,
                            "ts": ts_us, "args": values})

    def trace(self) -> Dict[str, Any]:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}


def _tracer_events(tb: TraceBuilder, tracer) -> None:
    """The ``core.trace.to_chrome_trace`` family: task timeline spans
    (engines as threads of their root module) + activity samples."""
    for rec in tracer.tasks:
        tb.span(rec.engine.split(".")[0], rec.engine, rec.task,
                ts_us=rec.t_start / 1e3,
                dur_us=(rec.t_end - rec.t_start) / 1e3, cat="task",
                args={"queued_us": (rec.t_start - rec.t_enqueue) / 1e3})
    for s in tracer.samples:
        tb.span(s.module.split(".")[0], s.module,
                f"{s.kind}={s.amount:.3g}",
                ts_us=s.t0 / 1e3, dur_us=s.duration / 1e3,
                cat="activity")


def _power_counters(tb: TraceBuilder, prep) -> None:
    """Power-EM counter tracks: watts per node per PTI + chip total."""
    pti_us = prep.pti_ns / 1e3
    for node in sorted(prep.series):
        watts = prep.series[node]
        if not any(watts):
            continue
        for i, w in enumerate(watts):
            tb.counter("power", f"W {node}", ts_us=i * pti_us,
                       values={"watts": w})
    for i, w in enumerate(prep.total_series):
        tb.counter("power", "W total", ts_us=i * pti_us,
                   values={"watts": w})


def trace_event_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Simulate one refinement payload on the event engine and export
    its task timelines, activity samples, and Power-EM power counters."""
    from ..hw.chip import System
    from ..power.powerem import PowerEM
    from ..sweep.refine import _compile

    cfg, nt, cw = _compile(payload)
    sysm = System(cfg, n_tiles=nt)
    sysm.run_workload(cw.tasks)
    tb = TraceBuilder()
    _tracer_events(tb, sysm.tracer)
    pem = PowerEM(cfg, n_tiles=nt, freq_ghz=cfg.clock_ghz,
                  temp_c=payload.get("temp_c", 60.0))
    prep = pem.analyze(sysm.tracer,
                       pti_ns=payload.get("pti_ns", 10_000.0))
    _power_counters(tb, prep)
    return tb.trace()


def trace_serve_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Simulate one serve cell and export request-lifecycle spans plus
    per-replica KV-occupancy / queue / batch counter tracks."""
    from ..serve.fleet import fleet_from_payload

    timeline: List[Dict[str, Any]] = []
    res, p, _costs = fleet_from_payload(payload, timeline=timeline)
    tb = TraceBuilder()
    for i, r in enumerate(res.requests):
        proc = f"replica{r.replica}"
        tid = f"req{i}"
        cat = _STATUS_CATS.get(r.status, "span")
        if r.status == "rejected":
            tb.instant(proc, tid, "rejected", ts_us=r.arrival_ns / 1e3,
                       cat=cat, args={"prompt": r.prompt})
            continue
        if r.admit_ns >= 0:
            tb.span(proc, tid, "queued", ts_us=r.arrival_ns / 1e3,
                    dur_us=(r.admit_ns - r.arrival_ns) / 1e3, cat="queue",
                    args={"admit_depth": r.admit_depth})
        if r.first_ns >= 0:
            tb.span(proc, tid, "prefill", ts_us=r.admit_ns / 1e3,
                    dur_us=(r.first_ns - r.admit_ns) / 1e3, cat="prefill",
                    args={"prompt": r.prompt})
        if r.done_ns >= 0:
            tb.span(proc, tid, f"decode:{r.status}",
                    ts_us=r.first_ns / 1e3,
                    dur_us=(r.done_ns - r.first_ns) / 1e3, cat=cat,
                    args={"tokens": r.tokens, "status": r.status})
    # per-step counters: appended replica by replica, each in time
    # order, so every (pid, name) counter track is monotone
    for stp in timeline:
        proc = f"replica{stp['replica']}"
        ts = stp["t0"] / 1e3
        tb.counter(proc, "kv_tokens", ts_us=ts,
                   values={"tokens": stp["kv_tokens"]})
        tb.counter(proc, "queue_depth", ts_us=ts,
                   values={"requests": stp["queue"]})
        tb.counter(proc, "batch", ts_us=ts,
                   values={"prefill": stp["prefill"],
                           "decode": stp["decode"]})
    return tb.trace()


def trace_campaign_journal(path: str) -> Dict[str, Any]:
    """Fold an exec journal into campaign-execution worker lanes.

    Wall-clock timestamps are re-zeroed to the journal's first event so
    the trace starts at t=0 like the simulation traces."""
    from ..exec.journal import JournalView

    view = JournalView.from_file(path)

    def t0_of(ev: Dict[str, Any]) -> float:
        # a done point's span *starts* wall_s before its journal line —
        # possibly before the journal's first event; zero on the
        # earliest span start so no event lands at negative ts
        t = float(ev["t"])
        if ev.get("ev") == "point" and ev.get("status") == "done":
            return t - float(ev.get("wall_s") or 0.0)
        return t

    ts0 = min((t0_of(ev) for ev in view.events
               if isinstance(ev.get("t"), (int, float))), default=0.0)

    def us(t: float) -> float:
        return (t - ts0) * 1e6

    tb = TraceBuilder()
    start = view.start_ev
    if start is not None:
        tb.instant("campaign", "runner",
                   f"start {start.get('campaign', '?')}",
                   ts_us=us(start["t"]),
                   args={"backend": start.get("backend"),
                         "to_refine": start.get("to_refine")})
    for ev in view.events:
        if ev.get("ev") != "point" or not isinstance(
                ev.get("t"), (int, float)):
            continue
        status = ev.get("status")
        worker = str(ev.get("worker") or "runner")
        key = str(ev.get("key", ""))[:12]
        if status == "done":
            wall_s = float(ev.get("wall_s") or 0.0)
            tb.span("campaign", worker, key,
                    ts_us=us(ev["t"] - wall_s), dur_us=wall_s * 1e6,
                    cat="point", args={"status": status})
        else:
            tb.instant("campaign", worker, f"{status}:{key}",
                       ts_us=us(ev["t"]),
                       cat=_STATUS_CATS.get(status, "instant"),
                       args={"status": status,
                             "error": ev.get("error")})
    for ev in view.janitor_events:
        # maintenance passes get their own lane so reclaim/GC activity
        # is visually separable from refinement work
        if not isinstance(ev.get("t"), (int, float)):
            continue
        stats = {k: v for k, v in ev.items()
                 if k not in ("ev", "t", "worker")}
        label = ",".join(f"{k}={v}" for k, v in sorted(stats.items())
                         if v) or "pass"
        tb.instant("campaign", "janitor", label, ts_us=us(ev["t"]),
                   cat="janitor", args=stats)
    if view.end_ev is not None:
        tb.instant("campaign", "runner", "end", ts_us=us(view.end_ev["t"]),
                   args=view.end_ev.get("summary"))
    return tb.trace()


def write_trace(trace: Dict[str, Any], path: str) -> str:
    with open(path, "w") as f:
        json.dump(trace, f, sort_keys=True)
    return path
