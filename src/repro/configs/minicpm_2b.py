"""MiniCPM-2B — llama-like dense LM with muP-style scaling and the WSD
(warmup-stable-decay) LR schedule. [arXiv:2404.06395; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,         # MHA
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    rope_theta=10000.0,
    norm_eps=1e-5,
    # muP-style scaling from the MiniCPM paper:
    scale_emb=12.0,        # embedding output x12
    scale_depth=1.4,       # residual branch scaled by 1.4/sqrt(L)
    dim_model_base=256,    # logits scaled by 1/(d_model/256)
    source="[arXiv:2404.06395; hf]",
)
