"""Phi-3.5-MoE (42B, 6.6B active) — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,          # GQA kv=8
    d_ff=6400,             # per-expert FFN width
    vocab_size=32064,
    rope_theta=10000.0,
    norm_eps=1e-5,
    n_experts=16,
    experts_per_token=2,
    capacity_factor=1.25,
    source="[hf:microsoft/Phi-3.5-MoE-instruct; hf]",
)
