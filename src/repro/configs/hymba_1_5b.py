"""Hymba-1.5B — hybrid-head LM: every layer runs attention heads and Mamba
(SSM) heads IN PARALLEL on the same input, outputs fused. Sliding-window
attention (1k) everywhere except 3 full-attention layers (first/middle/last);
128 learnable meta tokens prepended. ssm_state=16. [arXiv:2411.13676; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,          # GQA kv=5
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    rope_theta=10000.0,
    norm_eps=1e-6,
    sliding_window=1024,
    global_attn_layers=(0, 15, 31),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    n_meta_tokens=128,
    source="[arXiv:2411.13676; hf]",
)
