"""Qwen2-1.5B — dense LM with GQA (kv=2) and QKV bias. [arXiv:2407.10671; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,          # GQA kv=2
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    norm_eps=1e-6,
    source="[arXiv:2407.10671; hf]",
)
