"""xLSTM-125M — sLSTM + mLSTM blocks (attention-free recurrent LM).
d_ff=0: xLSTM blocks contain their own up/down projections. O(1)-state
decode -> runs long_500k. Block ratio mLSTM:sLSTM ~ 7:1 per the paper's
small configs; sLSTM blocks at layers (1, 7). [arXiv:2405.04517; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,          # d_model / n_heads for the mLSTM cell
    norm_eps=1e-6,
    ssm_expand=2,          # mLSTM up-projection factor
    slstm_layers=(1, 7),
    source="[arXiv:2405.04517; unverified]",
)
