"""HuBERT-XLarge — encoder-only audio transformer backbone (w2v2 arch).
The conv waveform frontend is a STUB: ``input_specs`` provides precomputed
frame embeddings [B, T, d_model]. vocab=504 is the masked-unit target
codebook. [arXiv:2106.07447; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,         # MHA
    d_ff=5120,
    vocab_size=504,
    causal=False,          # encoder-only, bidirectional
    norm_eps=1e-5,
    frontend_stub_dim=1280,
    source="[arXiv:2106.07447; unverified]",
)
