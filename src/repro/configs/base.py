"""Architecture + shape configuration base classes.

``ArchConfig`` is the single config record every model family reads. One
``src/repro/configs/<id>.py`` per assigned architecture instantiates it with
the exact public numbers; ``reduced()`` derives the CPU smoke-test variant.

``ShapeSpec`` describes one assigned input-shape cell (train_4k /
prefill_32k / decode_32k / long_500k) and knows which program it lowers
(``train_step`` vs ``serve_step``) and whether it is applicable to a family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "applicable", "skip_reason"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 -> full attention
    global_attn_layers: Tuple[int, ...] = ()   # hymba: full-attn layer ids
    causal: bool = True            # False for encoder-only (hubert)
    # embeddings / head
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # muP-ish scaling (MiniCPM)
    scale_emb: float = 1.0
    scale_depth: float = 0.0       # >0 -> residual scaled by scale_depth/sqrt(L)
    dim_model_base: int = 0        # >0 -> logits scaled by 1/(d_model/dim_model_base)
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_layers: Tuple[int, ...] = ()          # xLSTM: sLSTM block positions
    # VLM
    cross_attn_every: int = 0      # insert 1 cross-attn layer per N self layers
    n_image_tokens: int = 0
    # audio
    frontend_stub_dim: int = 0     # precomputed frame-embedding dim (== d_model)
    # misc
    n_meta_tokens: int = 0         # hymba learnable meta tokens
    source: str = ""               # provenance tag "[source; tier]"

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (MXU lane alignment + TP
        divisibility) — standard deployment practice; labels never index
        the padded tail."""
        return -(-self.vocab_size // 128) * 128

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_self_layers(self) -> int:
        if self.cross_attn_every:
            # n_layers counts TOTAL layers (self + cross), e.g. 100 = 80 + 20.
            n_groups = self.n_layers // (self.cross_attn_every + 1)
            return self.n_layers - n_groups
        return self.n_layers

    @property
    def n_cross_layers(self) -> int:
        return self.n_layers - self.n_self_layers if self.cross_attn_every else 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can serve a 500k context without a full-attn KV."""
        return self.family in ("ssm", "hybrid")

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant of the same family: tiny but same code path."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, min(4, self.n_layers)) if not self.cross_attn_every
            else 2 * (self.cross_attn_every + 1),
            d_model=64,
            n_heads=4,
            # keep the MHA-vs-GQA distinction, at a divisor of 4 heads
            n_kv_heads=4 if self.n_kv_heads == self.n_heads else 2,
            head_dim=16 if self.head_dim else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.n_experts else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            global_attn_layers=tuple(
                g for g in self.global_attn_layers if g < 4
            ) or ((0,) if self.global_attn_layers else ()),
            slstm_layers=tuple(g for g in self.slstm_layers if g < 4)
            or ((1,) if self.slstm_layers else ()),
            n_image_tokens=16 if self.n_image_tokens else 0,
            n_meta_tokens=8 if self.n_meta_tokens else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
        )

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND MODEL_FLOPS and reports)."""
        d, hd = self.d_model, self.hd
        H, KV, L = self.n_heads, self.n_kv_heads, self.n_layers
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        per_attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if self.family == "ssm":
            # xLSTM blocks replace attention+FFN; rough analytic count.
            di = self.ssm_expand * d
            per_layer = 2 * d * di + di * d + 4 * di * hd  # projections + gates
            return emb + head + L * per_layer
        if self.is_moe:
            per_ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            per_ffn = 3 * d * self.d_ff
        per_layer = per_attn + per_ffn
        total = emb + head + self.n_self_layers * per_layer
        if self.n_cross_layers:
            total += self.n_cross_layers * (per_attn + 3 * d * self.d_ff)
        if self.family == "hybrid":
            di = self.ssm_expand * d
            total += L * (2 * d * di + di * d)  # mamba in/out projections
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_self_layers * (
            self.n_experts * 3 * d * self.d_ff
        )
        return dense + self.n_self_layers * (
            self.experts_per_token * 3 * d * self.d_ff
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def program(self) -> str:
        return "train_step" if self.kind == "train" else "serve_step"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    """Structural (arch-family) skip for a shape cell, or None if runnable.

    These are the 9 documented skips of the 40-cell table (DESIGN.md
    §Arch-applicability): encoder-only archs have no autoregressive step;
    long_500k is defined for sub-quadratic archs only.
    """
    if cfg.encoder_only and shape.kind == "decode":
        return "encoder-only architecture: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "pure full-attention architecture: 512k dense-attention decode is "
            "quadratic-cost/KV-infeasible by design; shape defined for "
            "sub-quadratic (SSM/hybrid) archs"
        )
    return None


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    return skip_reason(cfg, shape) is None
