"""Architecture config registry — ``--arch <id>`` resolution.

All 10 assigned architectures (plus the paper's own CNN-era workloads used
by the accuracy benchmark live in ``repro.graph.workloads``, not here — these
are the LM-family training/serving archs).
"""
from __future__ import annotations

from typing import Dict, List

from .base import ArchConfig, ShapeSpec, SHAPES, applicable, skip_reason

from . import (
    smollm_135m,
    minicpm_2b,
    qwen2_1_5b,
    qwen3_32b,
    hubert_xlarge,
    qwen3_moe_30b_a3b,
    phi35_moe_42b_a6_6b,
    xlstm_125m,
    llama32_vision_90b,
    hymba_1_5b,
)

_MODULES = (
    smollm_135m,
    minicpm_2b,
    qwen2_1_5b,
    qwen3_32b,
    hubert_xlarge,
    qwen3_moe_30b_a3b,
    phi35_moe_42b_a6_6b,
    xlstm_125m,
    llama32_vision_90b,
    hymba_1_5b,
)

REGISTRY: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def list_archs() -> List[str]:
    return list(REGISTRY)


def get_config(name: str) -> ArchConfig:
    """Resolve ``--arch`` ids; accepts dashed or underscored spellings."""
    key = name.strip()
    if key in REGISTRY:
        return REGISTRY[key]
    alt = key.replace("_", "-")
    if alt in REGISTRY:
        return REGISTRY[alt]
    raise KeyError(f"unknown arch {name!r}; known: {', '.join(REGISTRY)}")


def get_shape(name: str) -> ShapeSpec:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {', '.join(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ArchConfig",
    "ShapeSpec",
    "SHAPES",
    "REGISTRY",
    "applicable",
    "skip_reason",
    "list_archs",
    "get_config",
    "get_shape",
]
