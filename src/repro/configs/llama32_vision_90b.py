"""Llama-3.2-Vision-90B backbone — 100 total layers = 80 self-attn + 20
gated cross-attn (1 per 4 self layers). The vision tower is a STUB:
``input_specs`` provides precomputed patch embeddings [B, n_img, d_model].
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,          # total: 80 self + 20 cross
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,          # GQA kv=8
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    norm_eps=1e-5,
    cross_attn_every=4,    # one cross-attn layer per 4 self-attn layers
    n_image_tokens=1024,   # precomputed patch-embedding count (stub)
    source="[hf:meta-llama/Llama-3.2-11B-Vision; unverified]",
)
