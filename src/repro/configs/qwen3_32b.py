"""Qwen3-32B — dense LM with qk-norm and GQA (kv=8). [hf:Qwen/Qwen3-8B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,          # GQA kv=8
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,          # explicit head_dim (Qwen3 decouples from d_model/H)
    qk_norm=True,          # per-head RMSNorm on q and k
    rope_theta=1000000.0,
    norm_eps=1e-6,
    source="[hf:Qwen/Qwen3-8B; hf]",
)
