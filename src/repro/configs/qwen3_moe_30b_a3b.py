"""Qwen3-30B-A3B — MoE LM: 128 experts, top-8, expert d_ff=768.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,          # GQA kv=4
    d_ff=768,              # per-expert FFN width
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
    norm_eps=1e-6,
    n_experts=128,
    experts_per_token=8,
    capacity_factor=1.25,
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
)
