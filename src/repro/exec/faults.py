"""Deterministic fault injection for the campaign execution substrate.

Scale turns rare failures into routine ones: a fleet of spool workers
*will* be SIGKILLed mid-publish, write torn files on flaky shared
filesystems, stall their heartbeats under memory pressure, and drift
their clocks. This module makes every one of those failures a
first-class, **seeded, replayable input** so the exec layer's
crash-safety is proven by test, not asserted by comment.

A ``FaultPlan`` is a seed plus a set of rules ``(kind, site) -> value``:

=========  =======================  =====================================
kind       value                    effect at a matching site
=========  =======================  =====================================
``crash``  probability [0..1]       simulated SIGKILL: nothing after the
                                    site runs (``InjectedCrash`` — a
                                    ``BaseException`` that no ``except
                                    Exception`` handler swallows — or a
                                    real ``os._exit`` for ``hard`` plans
                                    in subprocess workers)
``error``  probability [0..1]       a *recoverable* ``RuntimeError`` at
                                    the site — the worker survives; used
                                    to prove release-safety of the
                                    complete/fail paths
``torn``   probability [0..1]       the publish writes a truncated JSON
                                    file at the final path (a
                                    non-atomic filesystem caught
                                    mid-write) and raises ``OSError``
``stall``  probability [0..1]       the job's heartbeat silently stops
                                    refreshing the lease (the worker
                                    keeps computing — a paged-out or
                                    GC-frozen process)
``latency``  seconds                every spool filesystem publish/claim
                                    sleeps this long first (slow NFS)
``skew``   seconds (+/-)            the spool's clock reads offset by
                                    this much (one host's clock is off)
=========  =======================  =====================================

Crash/error sites are the named crash-points threaded through
``worker.run_worker`` and ``Spool.complete``: ``after-claim``,
``mid-refine``, ``before-publish``, ``after-publish`` (the window
between the done-file publish and the lease release). Torn-write sites
name the publish being torn: ``publish-done``, ``publish-fail``,
``publish-job``.

**Determinism.** Every decision is a pure hash of ``(seed, kind, site,
job key, attempt)`` — no RNG state, no call-order dependence. The same
``REPRO_FAULTS`` value makes every worker subprocess misbehave
identically across runs, and a retried job (higher ``attempt``) redraws,
so sub-1.0 crash rates terminate: a job either eventually publishes or
exhausts its retry budget and is quarantined with a diagnosis.

Env grammar (parsed once per distinct value)::

    REPRO_FAULTS="<seed>:<kind>@<site>=<value>[,<kind>@<site>=<value>...]"
    REPRO_FAULTS="7:crash@before-publish=0.4,torn@publish-done=0.3"

Plans loaded from the environment are ``hard`` (``os._exit`` on crash —
the truest SIGKILL for subprocess workers); tests install soft plans
in-process with ``use_plan()``/``plan_scope()``.
"""
from __future__ import annotations

import hashlib
import os
import time
from contextlib import contextmanager
from typing import Dict, Optional, Tuple

from ..obs.metrics import REGISTRY

__all__ = ["FaultPlan", "InjectedCrash", "TornWrite", "CRASH_SITES",
           "TORN_SITES", "active_plan", "use_plan", "plan_scope"]

#: named crash-points, in worker-lifecycle order
CRASH_SITES = ("after-claim", "mid-refine", "before-publish",
               "after-publish")
#: publishes a torn-write rule can target
TORN_SITES = ("publish-done", "publish-fail", "publish-job")
KINDS = ("crash", "error", "torn", "stall", "latency", "skew")

#: exit code of a hard injected crash (visible in worker `$?`)
CRASH_EXIT = 137


class InjectedCrash(BaseException):
    """A simulated SIGKILL. Derives from ``BaseException`` on purpose:
    the worker's ``except Exception`` failure handling must NOT treat a
    simulated kill as a refinement error — nothing after the crash
    point runs except lease-keep-alive teardown (which a real SIGKILL
    would also take down, since the heartbeat thread dies with the
    process)."""


class TornWrite(OSError):
    """An injected non-atomic write: the destination file exists but is
    truncated mid-JSON, and the publish call reports failure."""


def _u01(seed: int, kind: str, site: str, key: str, attempt: int) -> float:
    """Uniform [0,1) from a pure hash — the whole source of randomness."""
    blob = f"{seed}:{kind}:{site}:{key}:{attempt}".encode()
    h = hashlib.sha256(blob).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64


class FaultPlan:
    """A seeded set of fault rules; see module docstring."""

    def __init__(self, seed: int,
                 rules: Dict[Tuple[str, str], float],
                 *, hard: bool = False):
        for (kind, site), _v in rules.items():
            if kind not in KINDS:
                raise ValueError(f"unknown fault kind {kind!r}; "
                                 f"have {'|'.join(KINDS)}")
            if kind in ("crash", "error") and site not in CRASH_SITES:
                raise ValueError(f"unknown crash site {site!r}; "
                                 f"have {'|'.join(CRASH_SITES)}")
            if kind == "torn" and site not in TORN_SITES:
                raise ValueError(f"unknown torn-write site {site!r}; "
                                 f"have {'|'.join(TORN_SITES)}")
        self.seed = int(seed)
        self.rules = dict(rules)
        self.hard = bool(hard)

    # -- construction ------------------------------------------------------

    @classmethod
    def parse(cls, spec: str, *, hard: bool = False) -> "FaultPlan":
        """Parse the ``REPRO_FAULTS`` grammar: ``seed:k@s=v,k@s=v``."""
        head, sep, body = spec.partition(":")
        if not sep:
            raise ValueError(
                f"REPRO_FAULTS must look like 'seed:kind@site=value,...', "
                f"got {spec!r}")
        try:
            seed = int(head)
        except ValueError:
            raise ValueError(f"REPRO_FAULTS seed must be an int, "
                             f"got {head!r}") from None
        rules: Dict[Tuple[str, str], float] = {}
        for token in filter(None, (t.strip() for t in body.split(","))):
            lhs, sep, val = token.partition("=")
            kind, sep2, site = lhs.partition("@")
            if not sep or not sep2:
                raise ValueError(f"bad REPRO_FAULTS rule {token!r} "
                                 f"(want kind@site=value)")
            rules[(kind.strip(), site.strip())] = float(val)
        return cls(seed, rules, hard=hard)

    def to_spec(self) -> str:
        body = ",".join(f"{k}@{s}={v:g}"
                        for (k, s), v in sorted(self.rules.items()))
        return f"{self.seed}:{body}"

    # -- decisions ---------------------------------------------------------

    def rate(self, kind: str, site: str) -> float:
        return self.rules.get((kind, site), 0.0)

    def fires(self, kind: str, site: str, key: str,
              attempt: int = 0) -> bool:
        r = self.rate(kind, site)
        if r <= 0.0:
            return False
        return _u01(self.seed, kind, site, key, attempt) < r

    def _count(self, kind: str, site: str) -> None:
        if REGISTRY.enabled:
            REGISTRY.counter("faults.injected", kind=kind, site=site).inc()

    def maybe_crash(self, site: str, key: str, attempt: int = 0) -> None:
        """Die (hard or soft) / raise a recoverable error at a named
        crash-point, per the plan. No-op when no rule fires."""
        if self.fires("crash", site, key, attempt):
            self._count("crash", site)
            if self.hard:
                # a real kill: no unwinding, no finally blocks, no
                # flushes — exactly what SIGKILL leaves behind
                os._exit(CRASH_EXIT)
            raise InjectedCrash(f"injected crash at {site} "
                                f"(key {key[:12]}, attempt {attempt})")
        if self.fires("error", site, key, attempt):
            self._count("error", site)
            raise RuntimeError(f"injected error at {site} "
                               f"(key {key[:12]}, attempt {attempt})")

    def torn_write(self, site: str, key: str, attempt: int = 0) -> bool:
        fired = self.fires("torn", site, key, attempt)
        if fired:
            self._count("torn", site)
        return fired

    def heartbeat_stalls(self, key: str, attempt: int = 0) -> bool:
        fired = self.fires("stall", "heartbeat", key, attempt)
        if fired:
            self._count("stall", "heartbeat")
        return fired

    def fs_latency_s(self) -> float:
        return self.rules.get(("latency", "fs"), 0.0)

    def sleep_fs(self) -> None:
        d = self.fs_latency_s()
        if d > 0:
            self._count("latency", "fs")
            time.sleep(d)

    def clock_skew_s(self) -> float:
        return self.rules.get(("skew", "clock"), 0.0)


# -- process-wide active plan ----------------------------------------------

_ENV_VAR = "REPRO_FAULTS"
_explicit: Optional[FaultPlan] = None
_explicit_set = False
_env_cache: Tuple[Optional[str], Optional[FaultPlan]] = (None, None)


def active_plan() -> Optional[FaultPlan]:
    """The plan fault hooks consult: an explicitly installed one
    (tests), else the ``REPRO_FAULTS`` env plan (hard crashes), else
    None. Cheap enough for hot paths: one dict lookup when the env
    value hasn't changed."""
    global _env_cache
    if _explicit_set:
        return _explicit
    spec = os.environ.get(_ENV_VAR) or None
    if spec == _env_cache[0]:
        return _env_cache[1]
    plan = FaultPlan.parse(spec, hard=True) if spec else None
    _env_cache = (spec, plan)
    return plan


def use_plan(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` process-wide (tests); ``None`` reverts to the
    environment-driven plan."""
    global _explicit, _explicit_set
    _explicit = plan
    _explicit_set = plan is not None


@contextmanager
def plan_scope(plan: Optional[FaultPlan]):
    """``with plan_scope(plan): ...`` — scoped ``use_plan``."""
    global _explicit, _explicit_set
    prev, prev_set = _explicit, _explicit_set
    _explicit, _explicit_set = plan, plan is not None
    try:
        yield plan
    finally:
        _explicit, _explicit_set = prev, prev_set


# -- hook helpers (inert when no plan is active) ---------------------------

def crash_point(site: str, key: str, attempt: int = 0) -> None:
    """The named crash-point hook worker/spool code calls inline."""
    plan = active_plan()
    if plan is not None:
        plan.maybe_crash(site, key, attempt)


def now(base: Optional[float] = None) -> float:
    """Wall clock through the active plan's skew (the spool's clock)."""
    t = time.time() if base is None else base
    plan = active_plan()
    if plan is not None:
        t += plan.clock_skew_s()
    return t
