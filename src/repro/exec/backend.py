"""The ``Backend`` contract and the inline reference implementation.

A backend executes an ordered batch of refinement payloads (the
cache-keyed dicts built by ``repro.sweep.refine.refine_payload``) and
returns the refined records **in the same order**. The campaign runner
owns everything else — pre-screen, selection, the result cache, journal
cache-hit events — so backends stay small and interchangeable:
``run_campaign(..., backend="inline"|"pool"|"spool")`` is the only
switch.

Payloads are opaque to backends: the ``engine`` field
(``"event"|"fast"|"auto"``, routing between the event engine and the
``core.fastsim`` interval-replay engine) rides inside the payload and
is resolved by ``refine_point`` wherever the job lands — an external
spool worker on another host refines with the same engine the campaign
asked for, and the cache key covers it.

Implementations must be deterministic in *content*: for a given payload
list every backend produces the same records (the equivalence tests and
the byte-identical acceptance check rely on it).

Worked example — refining two points by hand (normally ``run_campaign``
does this for you)::

    >>> from repro.exec.backend import get_backend
    >>> from repro.sweep.refine import refine_payload
    >>> from repro.hw.presets import resolve_preset, to_dict
    >>> hw = to_dict(resolve_preset("v5e"))
    >>> payloads = [refine_payload(workload=w, n_tiles=2, hw=hw,
    ...                            compile_opts={}, pti_ns=50_000.0,
    ...                            temp_c=65.0, keep_series=False)
    ...             for w in ("lm/qwen3-32b/s512b1tp1",
    ...                       "lm/qwen3-32b/decode/kv512b1tp1")]
    >>> bk = get_backend("inline")          # or "pool" / "spool"
    >>> recs = bk.refine(payloads)          # records in payload order
    >>> sorted(recs[0]) == sorted(recs[1])  # uniform record shape
    True
    >>> recs[1]["time_ns"] > 0              # the decode step, simulated
    True

Swapping ``"inline"`` for ``get_backend("pool", workers=4)`` or
``get_backend("spool", spool_dir="...")`` changes *where* the payloads
run, never the records.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Protocol, \
    runtime_checkable

__all__ = ["Backend", "BackendError", "InlineBackend", "get_backend",
           "BACKEND_NAMES", "is_batch_record", "is_failure_record",
           "failure_record"]

BACKEND_NAMES = ("inline", "pool", "spool")

Payload = Dict[str, Any]
Record = Dict[str, Any]
Progress = Optional[Callable[[str], None]]


class BackendError(RuntimeError):
    """A backend could not produce a record for one or more payloads."""


@runtime_checkable
class Backend(Protocol):
    """Refinement execution strategy."""

    name: str

    def refine(self, payloads: List[Payload], *,
               keys: Optional[List[str]] = None,
               journal: Optional[Any] = None,
               cache: Optional[Any] = None,
               progress: Progress = None,
               allow_partial: bool = False) -> List[Record]:
        """Refine every payload; return records in payload order.

        ``keys`` are the content-hash job ids (one per payload — the
        same keys the result cache uses), ``journal`` an optional
        ``CampaignJournal`` receiving per-point ``done`` events, and
        ``cache`` an optional ``ResultCache`` each record is written
        through to **as soon as it lands** — so a runner killed
        mid-batch loses nothing already refined, and the re-invocation
        sees those points as cache hits.

        With ``allow_partial=True`` a payload whose refinement fails
        yields a ``failure_record`` at its position (journaled as
        ``failed``, never cached) instead of the whole call raising
        ``BackendError`` — graceful degradation for long campaigns
        where one poison cell must not discard 71 finished ones.
        """
        ...


def is_batch_record(rec: Record) -> bool:
    """A batch-job result (``sweep.refine.refine_batch``): per-item
    records plus their own content keys. Backends expand it into
    per-point cache entries and journal events so batching stays
    invisible to the cache, the journal, and resumed campaigns."""
    return rec.get("kind") == "batch" and "records" in rec and "keys" in rec


def is_failure_record(rec: Record) -> bool:
    """A degraded placeholder from an ``allow_partial`` run — the point
    failed and carries a diagnosis instead of simulation results."""
    return isinstance(rec, dict) and rec.get("kind") == "refine_failed"


def failure_record(error: str, *, worker: str = "?") -> Record:
    """The record shape a failed point degrades to under
    ``allow_partial``: no simulation fields, ``failed: True``, and the
    diagnosis attached. Never cached (a transient failure must not
    poison future runs)."""
    return {"kind": "refine_failed", "failed": True,
            "error": str(error), "worker": worker}


def _cache_put(cache, key: Optional[str], rec: Record) -> None:
    if cache is None or is_failure_record(rec):
        return
    if is_batch_record(rec):
        # per-point write-through under each item's own key — never
        # under the batch-job key, so unbatched reruns hit the cache
        for sub_key, sub in zip(rec["keys"], rec["records"]):
            cache.put(sub_key, canonical(sub))
        return
    if key is not None:
        cache.put(key, canonical(rec))


def canonical(rec: Record) -> Record:
    """JSON round-trip (sorted keys) — the one shape records ever take
    on disk or in results, so backends, cache, and resumed runs are
    byte-identical."""
    import json

    return json.loads(json.dumps(rec, sort_keys=True, default=float))


def _journal_done(journal, key: Optional[str], *, worker: str,
                  wall_s: Optional[float],
                  rec: Optional[Record] = None) -> None:
    if journal is None:
        return
    if rec is not None and is_batch_record(rec):
        # one "done" event per point (the journal's unit is the point,
        # whatever the dispatch unit was); the job's wall time is split
        # evenly — per-point attribution inside a shared simulation is
        # not meaningful
        per = (wall_s / len(rec["keys"])
               if wall_s is not None and rec["keys"] else wall_s)
        for sub_key in rec["keys"]:
            journal.point(sub_key, "done", worker=worker, wall_s=per)
        return
    if key is not None:
        journal.point(key, "done", worker=worker, wall_s=wall_s)


class InlineBackend:
    """Sequential in-process refinement — deterministic, zero setup."""

    name = "inline"

    def refine(self, payloads: List[Payload], *,
               keys: Optional[List[str]] = None,
               journal: Optional[Any] = None,
               cache: Optional[Any] = None,
               progress: Progress = None,
               allow_partial: bool = False) -> List[Record]:
        from ..sweep.refine import refine_point

        keys = keys or [None] * len(payloads)
        out: List[Record] = []
        for payload, key in zip(payloads, keys):
            t0 = time.time()
            try:
                rec = refine_point(payload)
            except Exception as e:
                if not allow_partial:
                    raise
                rec = failure_record(e, worker="inline")
                if journal is not None and key is not None:
                    journal.point(key, "failed", worker="inline",
                                  error=rec["error"])
                out.append(rec)
                continue
            _cache_put(cache, key, rec)
            _journal_done(journal, key, worker="inline",
                          wall_s=time.time() - t0, rec=rec)
            out.append(rec)
        return out


def get_backend(name: str, *, workers: Optional[int] = None,
                spool_dir: Optional[str] = None, **opts: Any) -> Backend:
    """Build a backend from its CLI name.

    * ``inline``            — sequential in-process.
    * ``pool``              — ``workers`` local processes (None = per core).
    * ``spool``             — filesystem job spool at ``spool_dir`` with
      ``workers`` locally-spawned daemons (0 = rely on external workers
      attached via ``python -m repro.exec worker <spool_dir>``).
    """
    if name == "inline":
        return InlineBackend()
    if name == "pool":
        from .pool import PoolBackend
        return PoolBackend(workers=workers, **opts)
    if name == "spool":
        from .spool import SpoolBackend
        if not spool_dir:
            raise ValueError("spool backend needs spool_dir")
        n = workers if workers is not None else 1
        return SpoolBackend(spool_dir, workers=n, **opts)
    raise ValueError(f"unknown backend {name!r}; "
                     f"have {'|'.join(BACKEND_NAMES)}")
