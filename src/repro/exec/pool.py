"""Process-pool backend — today's parallel path, extracted from the
campaign runner into a ``Backend``.

Uses ``fork`` where available: the refinement import path is jax-free
(``repro.sweep.refine``), so forked workers never re-enter jax/XLA and
start in milliseconds. Falls back to inline refinement when the pool
cannot start (e.g. ``spawn`` re-importing an unguarded ``__main__``) —
refinement is pure, so the records are identical either way.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
import warnings
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Any, Dict, List, Optional

from .backend import Progress, _cache_put, _journal_done, \
    failure_record, is_failure_record

__all__ = ["PoolBackend", "mp_start_method"]


def mp_start_method() -> str:
    """Worker start method; override with ``SWEEP_MP_CONTEXT``."""
    env = os.environ.get("SWEEP_MP_CONTEXT")
    if env:
        return env
    return "fork" if "fork" in mp.get_all_start_methods() else "spawn"


class PoolBackend:
    """Refine on a local ``ProcessPoolExecutor``."""

    name = "pool"

    def __init__(self, workers: Optional[int] = None):
        # None -> one process per core (ProcessPoolExecutor default-ish)
        self.workers = workers if workers is not None else (os.cpu_count()
                                                            or 1)

    def refine(self, payloads: List[Dict[str, Any]], *,
               keys: Optional[List[str]] = None,
               journal: Optional[Any] = None,
               cache: Optional[Any] = None,
               progress: Progress = None,
               allow_partial: bool = False) -> List[Dict[str, Any]]:
        from ..sweep.refine import refine_point

        keys = keys or [None] * len(payloads)
        fresh: Optional[List[Dict[str, Any]]] = None
        t0 = time.time()
        if self.workers > 1 and len(payloads) > 1:
            try:
                ctx = mp.get_context(mp_start_method())
                with warnings.catch_warnings():
                    # jax warns about fork+threads; refinement workers
                    # never re-enter jax/XLA (refine.py is jax-free)
                    warnings.filterwarnings(
                        "ignore", message=".*os.fork.*",
                        category=RuntimeWarning)
                    with ProcessPoolExecutor(
                            max_workers=min(self.workers, len(payloads)),
                            mp_context=ctx) as pool:
                        # submit (not map): per-future results so one
                        # failed point can degrade instead of poisoning
                        # the whole ordered stream
                        futs = [pool.submit(refine_point, p)
                                for p in payloads]
                        fresh = []
                        # consume in order so each record is
                        # cache-durable before the batch finishes
                        for key, fut in zip(keys, futs):
                            try:
                                rec = fut.result()
                            except BrokenProcessPool:
                                raise
                            except Exception as e:
                                if not allow_partial:
                                    raise
                                rec = failure_record(e, worker=self.name)
                            _cache_put(cache, key, rec)
                            fresh.append(rec)
            except BrokenProcessPool:
                if progress:
                    progress("worker pool unavailable; refining inline")
                fresh = None
        if fresh is None:
            fresh = []
            for key, p in zip(keys, payloads):
                try:
                    rec = refine_point(p)
                except Exception as e:
                    if not allow_partial:
                        raise
                    rec = failure_record(e, worker=self.name)
                _cache_put(cache, key, rec)
                fresh.append(rec)
        # the futures give no per-point timing; journal the batch
        # average (batch-job records expand to per-point events inside)
        avg = (time.time() - t0) / max(len(payloads), 1)
        for key, rec in zip(keys, fresh):
            if is_failure_record(rec):
                if journal is not None and key is not None:
                    journal.point(key, "failed", worker=self.name,
                                  error=rec["error"])
                continue
            _journal_done(journal, key, worker=self.name, wall_s=avg,
                          rec=rec)
        return fresh
