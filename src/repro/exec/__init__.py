"""Execution service: pluggable refinement backends for sweep campaigns.

``repro.sweep`` decides *what* to refine (pre-screen -> Pareto select);
this package decides *how* those refinements execute. Every backend
implements the same tiny contract (``backend.Backend``): take an ordered
list of refinement payloads, return the refined records in the same
order. Three implementations:

* ``InlineBackend``  — sequential, in-process. Deterministic and
  test-friendly; zero setup cost.
* ``PoolBackend``    — a local ``ProcessPoolExecutor`` (the refinement
  import path is jax-free, so workers start in milliseconds).
* ``SpoolBackend``   — a filesystem job spool (``spool.Spool``): jobs are
  claimed by atomic rename, leases are kept alive by heartbeat, dead
  jobs are reclaimed, and any number of independent worker daemons
  (``python -m repro.exec worker <spool>``) drain the queue — across
  processes, container restarts, or a shared filesystem — with no
  network dependency. Campaigns become interruptible and resumable.

``journal.CampaignJournal`` is the append-only per-point telemetry
stream (status, wall time, worker id, cache-hit counters) every backend
feeds; ``python -m repro.exec journal <file> --expect-done`` turns it
into a CI assertion.

Attribute access is lazy (PEP 562) so worker processes never pay for
imports they don't need.
"""
from typing import TYPE_CHECKING

__all__ = [
    "Backend",
    "BackendError",
    "CampaignJournal",
    "FaultPlan",
    "InjectedCrash",
    "InlineBackend",
    "JournalView",
    "PoolBackend",
    "PublishError",
    "Spool",
    "SpoolBackend",
    "get_backend",
    "janitor_pass",
    "run_janitor",
    "run_worker",
]

_EXPORTS = {
    "Backend": "backend",
    "BackendError": "backend",
    "InlineBackend": "backend",
    "get_backend": "backend",
    "FaultPlan": "faults",
    "InjectedCrash": "faults",
    "PoolBackend": "pool",
    "PublishError": "spool",
    "Spool": "spool",
    "SpoolBackend": "spool",
    "CampaignJournal": "journal",
    "JournalView": "journal",
    "janitor_pass": "janitor",
    "run_janitor": "janitor",
    "run_worker": "worker",
}

if TYPE_CHECKING:  # pragma: no cover
    from .backend import Backend, BackendError, InlineBackend, get_backend
    from .faults import FaultPlan, InjectedCrash
    from .janitor import janitor_pass, run_janitor
    from .journal import CampaignJournal, JournalView
    from .pool import PoolBackend
    from .spool import PublishError, Spool, SpoolBackend
    from .worker import run_worker


def __getattr__(name: str):
    try:
        modname = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}") from None
    import importlib

    mod = importlib.import_module(f".{modname}", __name__)
    value = getattr(mod, name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
