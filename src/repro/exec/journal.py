"""Campaign journal: append-only JSONL telemetry for refinement runs.

One line per event, written with ``O_APPEND`` semantics so concurrent
writers (the runner plus any backend) never corrupt each other. Three
event kinds:

* ``start`` — campaign name, backend, grid size, refinement count;
* ``point`` — one refinement point changed status: ``cached`` (served
  from the result cache, zero re-simulation), ``done`` (simulated, with
  worker id + wall seconds), or ``failed``;
* ``end``   — the campaign summary (includes the cache hit counters the
  resume acceptance check reads);
* ``janitor`` — a maintenance pass touched the spool (lease reclaims,
  ``.tmp`` GC, quarantines, compaction) — emitted by the standalone
  janitor daemon and by the runner's in-loop reclaim, and rendered as
  its own lane by the Perfetto exporter.

``JournalView`` (``CampaignJournal.load``) folds the stream into the
latest status per point so CI / tooling can assert "all points done"
(``python -m repro.exec journal <file> --expect-done``).
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = ["CampaignJournal", "JournalView"]


class CampaignJournal:
    """Append-only JSONL writer; safe for multiple processes."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)

    def log(self, ev: str, **fields: Any) -> None:
        line = json.dumps({"ev": ev, "t": time.time(), **fields},
                          sort_keys=True, default=float)
        # one write() of one line: O_APPEND keeps concurrent writers whole
        with open(self.path, "a") as f:
            f.write(line + "\n")

    def start(self, *, campaign: str, backend: str, grid_points: int,
              to_refine: int) -> None:
        self.log("start", campaign=campaign, backend=backend,
                 grid_points=grid_points, to_refine=to_refine)

    def point(self, key: str, status: str, *,
              point_id: Optional[str] = None, worker: Optional[str] = None,
              wall_s: Optional[float] = None,
              error: Optional[str] = None) -> None:
        fields: Dict[str, Any] = {"key": key, "status": status}
        if point_id is not None:
            fields["point_id"] = point_id
        if worker is not None:
            fields["worker"] = worker
        if wall_s is not None:
            fields["wall_s"] = wall_s
        if error is not None:
            fields["error"] = error
        self.log("point", **fields)

    def end(self, summary: Dict[str, Any]) -> None:
        self.log("end", summary=summary)

    def janitor(self, *, worker: str, **stats: Any) -> None:
        """One maintenance pass (reclaims/GC counts ride in ``stats``)."""
        self.log("janitor", worker=worker,
                 **{k: v for k, v in stats.items() if v is not None})

    @staticmethod
    def load(path: str) -> "JournalView":
        return JournalView.from_file(path)


@dataclass
class JournalView:
    """Folded view of a journal stream: latest status per point.

    Torn lines (a writer killed mid-``write`` leaves a truncated final
    line; a line missing its newline gets the next event glued onto it)
    are skipped, never fatal — each skip is recorded in ``warnings`` so
    CLI consumers can surface them instead of silently under-counting.
    """

    events: List[Dict[str, Any]] = field(default_factory=list)
    points: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    start_ev: Optional[Dict[str, Any]] = None
    end_ev: Optional[Dict[str, Any]] = None
    janitor_events: List[Dict[str, Any]] = field(default_factory=list)
    warnings: List[str] = field(default_factory=list)

    @classmethod
    def from_file(cls, path: str) -> "JournalView":
        view = cls()
        with open(path) as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    ev = json.loads(line)
                except json.JSONDecodeError:
                    view.warnings.append(
                        f"{path}:{lineno}: skipped torn/unparseable "
                        f"journal line ({len(line)} bytes)")
                    continue
                if not isinstance(ev, dict):
                    view.warnings.append(
                        f"{path}:{lineno}: skipped non-object journal "
                        f"line ({type(ev).__name__})")
                    continue
                view.fold(ev)
        return view

    def fold(self, ev: Dict[str, Any]) -> None:
        """Fold one parsed event into the view (incremental consumers —
        ``obs.progress`` — feed events here as they tail the file)."""
        self.events.append(ev)
        kind = ev.get("ev")
        if kind == "start":
            self.start_ev = ev
        elif kind == "end":
            self.end_ev = ev
        elif kind == "point" and "key" in ev:
            self.points[ev["key"]] = ev
        elif kind == "janitor":
            self.janitor_events.append(ev)

    @property
    def summary(self) -> Dict[str, Any]:
        return (self.end_ev or {}).get("summary", {})

    def counts(self) -> Dict[str, int]:
        c = {"done": 0, "cached": 0, "failed": 0, "other": 0}
        for ev in self.points.values():
            c[ev.get("status") if ev.get("status") in c else "other"] += 1
        c["total"] = len(self.points)
        return c

    def cache_hits(self) -> int:
        return self.counts()["cached"]

    def simulated(self) -> int:
        return self.counts()["done"]

    def all_done(self, min_points: int = 1,
                 allow_failed: bool = False) -> bool:
        """True when the campaign finished and every point resolved to
        ``done`` or ``cached`` (the CI smoke assertion).
        ``allow_failed=True`` relaxes to *every point terminal* —
        ``failed`` points count, matching ``--allow-partial`` runs."""
        c = self.counts()
        terminal = c["done"] + c["cached"]
        if allow_failed:
            terminal += c["failed"]
        return (self.end_ev is not None and c["total"] >= min_points
                and (allow_failed or c["failed"] == 0)
                and c["other"] == 0 and terminal == c["total"])
