"""Spool worker: claim -> heartbeat -> refine -> publish, in a loop.

``run_worker`` is the whole daemon; ``python -m repro.exec worker
<spool>`` wraps it. Two modes:

* ``drain=True``  — exit once the queue is empty (the mode the
  ``SpoolBackend`` uses for the workers it spawns itself);
* ``drain=False`` — keep polling forever (a detached daemon that
  outlives any single campaign; new jobs are picked up as they appear).

While a refinement runs, a daemon thread refreshes the job's lease every
``hb_s`` seconds so long simulations survive the spool's dead-job
reclamation; a worker that is SIGKILLed simply stops heartbeating and
its job is reclaimed by someone else after ``lease_s``.

The import path is jax-free (``repro.sweep.refine``), so worker startup
is milliseconds, not an XLA initialization.
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Callable, Dict, Optional

from ..obs.metrics import REGISTRY
from .spool import Spool, SpoolJob, worker_id

__all__ = ["run_worker"]


def _heartbeat_loop(job: SpoolJob, stop: threading.Event,
                    hb_s: float) -> None:
    while not stop.wait(hb_s):
        if not job.heartbeat():
            return                     # reclaimed under us; stop touching


def run_worker(root: str, *, drain: bool = True, poll_s: float = 0.5,
               hb_s: float = 5.0, max_jobs: Optional[int] = None,
               worker: Optional[str] = None,
               refine_fn: Optional[Callable[[Dict[str, Any]],
                                            Dict[str, Any]]] = None,
               log: Optional[Callable[[str], None]] = None) -> int:
    """Drain (or follow) a spool; returns the number of jobs completed.

    ``refine_fn`` is injectable for tests; the default is the real
    refinement entrypoint (``repro.sweep.refine.refine_point``), which
    honors each payload's ``engine`` field — jobs spooled by a
    ``refine.engine="fast"`` campaign run on the fastsim engine here
    too, whichever host drains them.
    """
    if refine_fn is None:
        from ..sweep.refine import refine_point
        refine_fn = refine_point
    spool = Spool(root)
    wid = worker or worker_id()
    n_done = 0
    while True:
        job = spool.claim(wid)
        if job is None:
            # maybe a dead worker holds the remaining jobs
            reclaimed = spool.reclaim()
            if reclaimed:
                continue
            if drain:
                break
            time.sleep(poll_s)
            continue
        if log:
            log(f"[{wid}] claim {job.key[:12]}")
        stop = threading.Event()
        hb = threading.Thread(target=_heartbeat_loop, args=(job, stop, hb_s),
                              daemon=True)
        hb.start()
        t0 = time.time()
        try:
            record = refine_fn(job.payload)
        except Exception:
            stop.set()
            hb.join(timeout=hb_s + 1)
            spool.fail(job, traceback.format_exc(limit=8))
            if REGISTRY.enabled:
                REGISTRY.counter("worker.jobs_failed").inc()
            if log:
                log(f"[{wid}] FAIL {job.key[:12]}")
            continue
        stop.set()
        hb.join(timeout=hb_s + 1)
        spool.complete(job, record, wall_s=time.time() - t0)
        if REGISTRY.enabled:
            REGISTRY.counter("worker.jobs_done").inc()
        n_done += 1
        if log:
            log(f"[{wid}] done {job.key[:12]} ({time.time() - t0:.2f}s)")
        if max_jobs is not None and n_done >= max_jobs:
            break
    return n_done
