"""Spool worker: claim -> heartbeat -> refine -> publish, in a loop.

``run_worker`` is the whole daemon; ``python -m repro.exec worker
<spool>`` wraps it. Two modes:

* ``drain=True``  — exit once the queue is empty (the mode the
  ``SpoolBackend`` uses for the workers it spawns itself);
* ``drain=False`` — keep polling forever (a detached daemon that
  outlives any single campaign; new jobs are picked up as they appear).

While a refinement runs, a daemon thread refreshes the job's lease every
``hb_s`` seconds so long simulations survive the spool's dead-job
reclamation; a worker that is SIGKILLed simply stops heartbeating and
its job is reclaimed by someone else after ``lease_s``.

The claim->publish lifecycle is threaded with the named crash-points
from ``exec.faults`` (``after-claim``, ``mid-refine``,
``before-publish``; ``after-publish`` lives inside
``Spool.complete``), which is how the chaos suite kills a worker at
every interesting instant. A simulated kill (``InjectedCrash``) tears
down only the heartbeat thread — a real SIGKILL would take that down
too — and deliberately leaks the lease for reclaim to recover, exactly
like the real failure it models. A failed outcome *publish*
(``PublishError``) is never fatal: the spool already requeued the job,
the worker logs and moves on.

The import path is jax-free (``repro.sweep.refine``), so worker startup
is milliseconds, not an XLA initialization.
"""
from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Callable, Dict, Optional

from ..obs.metrics import REGISTRY
from . import faults
from .spool import PublishError, Spool, SpoolJob, worker_id

__all__ = ["run_worker"]


def _heartbeat_loop(job: SpoolJob, stop: threading.Event,
                    hb_s: float) -> None:
    while not stop.wait(hb_s):
        if not job.heartbeat():
            return                     # reclaimed under us; stop touching


def _stop_hb(stop: threading.Event, hb: threading.Thread,
             hb_s: float) -> None:
    stop.set()
    if hb.ident is not None:           # never started if we crashed early
        hb.join(timeout=hb_s + 1)


def run_worker(root: str, *, drain: bool = True, poll_s: float = 0.5,
               hb_s: float = 5.0, max_jobs: Optional[int] = None,
               worker: Optional[str] = None,
               refine_fn: Optional[Callable[[Dict[str, Any]],
                                            Dict[str, Any]]] = None,
               log: Optional[Callable[[str], None]] = None,
               spool: Optional[Spool] = None) -> int:
    """Drain (or follow) a spool; returns the number of jobs completed.

    ``refine_fn`` is injectable for tests; the default is the real
    refinement entrypoint (``repro.sweep.refine.refine_point``), which
    honors each payload's ``engine`` field — jobs spooled by a
    ``refine.engine="fast"`` campaign run on the fastsim engine here
    too, whichever host drains them. ``spool`` injects a
    pre-configured ``Spool`` (non-default lease/backoff — the chaos
    suite); default is ``Spool(root)``.
    """
    if refine_fn is None:
        from ..sweep.refine import refine_point
        refine_fn = refine_point
    spool = spool or Spool(root)
    wid = worker or worker_id()
    n_done = 0
    while True:
        job = spool.claim(wid)
        if job is None:
            # maybe a dead worker holds the remaining jobs
            reclaimed = spool.reclaim()
            if reclaimed:
                continue
            if drain:
                eta = spool.next_retry_eta()
                if eta is not None:
                    # backed-off retries still pending: a drain worker
                    # waits them out instead of stranding them
                    time.sleep(min(max(eta, 0.01), poll_s))
                    continue
                break
            time.sleep(poll_s)
            continue
        if log:
            log(f"[{wid}] claim {job.key[:12]} (attempt {job.attempts})")
        stop = threading.Event()
        hb = threading.Thread(target=_heartbeat_loop, args=(job, stop, hb_s),
                              daemon=True)
        t0 = time.time()
        try:
            faults.crash_point("after-claim", job.key, job.attempts)
            hb.start()
            faults.crash_point("mid-refine", job.key, job.attempts)
            record = refine_fn(job.payload)
            faults.crash_point("before-publish", job.key, job.attempts)
        except Exception:
            err = traceback.format_exc(limit=8)
            _stop_hb(stop, hb, hb_s)
            try:
                spool.fail(job, err)
            except PublishError:
                pass                   # requeued; someone retries it
            if REGISTRY.enabled:
                REGISTRY.counter("worker.jobs_failed").inc()
            if log:
                log(f"[{wid}] FAIL {job.key[:12]}")
            continue
        except BaseException:
            # simulated kill (or genuine KeyboardInterrupt): stop the
            # lease keep-alive — a real SIGKILL takes the heartbeat
            # thread down with the process — but do NOT release the
            # lease; reclaim is the recovery path being modeled
            _stop_hb(stop, hb, hb_s)
            raise
        _stop_hb(stop, hb, hb_s)
        try:
            spool.complete(job, record, wall_s=time.time() - t0)
        except PublishError:
            if log:
                log(f"[{wid}] PUBLISH-FAIL {job.key[:12]} (requeued)")
            continue
        except Exception:
            # the done file IS published and the lease released
            # (complete's release-safe crash window) — the job counts
            pass
        if REGISTRY.enabled:
            REGISTRY.counter("worker.jobs_done").inc()
        n_done += 1
        if log:
            log(f"[{wid}] done {job.key[:12]} ({time.time() - t0:.2f}s)")
        if max_jobs is not None and n_done >= max_jobs:
            break
    return n_done
