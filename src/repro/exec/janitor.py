"""Standalone spool janitor: the maintenance duties that must outlive
any single campaign runner.

``SpoolBackend`` reclaims dead jobs while it polls — but a long-lived
shared spool (multi-host workers on one filesystem, detached daemons)
has no guarantee a runner is alive. A SIGKILLed runner used to strand
the fleet: leases expire, nobody reclaims, workers starve. The janitor
is a tiny daemon (``python -m repro.exec janitor <spool>``) that owns
four periodic duties:

* **lease reclaim + poison quarantine** — ``Spool.reclaim()``: orphaned
  active jobs go back to ``jobs/`` with a retry backoff; jobs past the
  retry budget are quarantined to ``failed/`` with a diagnosis;
* **stale ``.tmp`` GC** — staging files from atomic publishes whose
  writer died mid-``mkstemp``/``os.replace`` accumulate forever on a
  shared directory; anything matching ``*.tmp`` older than
  ``tmp_age_s`` is removed (``spool.tmp_gc`` counter);
* **corrupt-done GC** — a torn ``done/<key>.json`` (non-atomic
  filesystem) reads as *not finished* everywhere, but the wreckage
  blocks nothing and tells nobody; older than ``corrupt_age_s`` it is
  deleted so the key is cleanly resubmittable;
* **``done/`` compaction** — thousands of finished single-result files
  make every ``listdir`` slow; results older than ``compact_age_s``
  are appended to ``done/_compact.jsonl`` (append-then-unlink, so a
  janitor killed mid-pass duplicates a line at worst — the compact
  index is last-write-wins by key) and the per-key files removed.
  ``Spool.result``/``done_keys``/``counts`` consult the compacted
  archive transparently.

Every pass bumps ``janitor.passes`` and, when a campaign journal is
attached, appends an ``ev: "janitor"`` line the Perfetto exporter
renders as its own lane.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Optional

from ..obs.metrics import REGISTRY
from .journal import CampaignJournal
from .spool import COMPACT_FILE, Spool, _STATES, worker_id

__all__ = ["janitor_pass", "run_janitor", "DEFAULT_TMP_AGE_S",
           "DEFAULT_CORRUPT_AGE_S", "DEFAULT_COMPACT_AGE_S"]

DEFAULT_TMP_AGE_S = 300.0      # staging files are normally sub-second
DEFAULT_CORRUPT_AGE_S = 300.0  # give in-flight rewrites time to win
DEFAULT_COMPACT_AGE_S = 60.0   # keep hot results as plain files


def _gc_tmp(spool: Spool, age_s: float, now: float) -> int:
    """Remove orphaned atomic-write staging files (``*.tmp``)."""
    n = 0
    for state in _STATES:
        d = spool._dir(state)
        try:
            names = os.listdir(d)
        except OSError:
            continue
        for fname in names:
            if not fname.endswith(".tmp"):
                continue
            p = os.path.join(d, fname)
            try:
                if now - os.stat(p).st_mtime > age_s:
                    os.unlink(p)
                    n += 1
            except FileNotFoundError:
                pass
    if n and REGISTRY.enabled:
        REGISTRY.counter("spool.tmp_gc").inc(n)
    return n


def _gc_corrupt_done(spool: Spool, age_s: float, now: float) -> int:
    """Remove torn ``done/`` files old enough that no writer is coming
    back for them. The key simply reads as unfinished (it already did)
    and can be resubmitted cleanly."""
    n = 0
    d = spool._dir("done")
    for fname in spool._list("done"):
        p = os.path.join(d, fname)
        try:
            if now - os.stat(p).st_mtime <= age_s:
                continue
            with open(p) as f:
                obj = json.load(f)
            if isinstance(obj, dict) and "record" in obj:
                continue               # healthy
        except FileNotFoundError:
            continue
        except json.JSONDecodeError:
            pass                       # torn: fall through to unlink
        try:
            os.unlink(p)
            n += 1
        except FileNotFoundError:
            pass
    if n and REGISTRY.enabled:
        REGISTRY.counter("spool.corrupt_gc").inc(n)
    return n


def _compact_done(spool: Spool, age_s: float, now: float) -> int:
    """Fold cold ``done/<key>.json`` files into ``done/_compact.jsonl``.

    Append-then-unlink per file: a crash in between leaves both copies,
    which is harmless — ``Spool.result`` prefers the per-key file and
    the compact index is last-write-wins by key. One ``O_APPEND`` write
    per line keeps concurrent janitors from interleaving bytes."""
    n = 0
    d = spool._dir("done")
    compact = os.path.join(d, COMPACT_FILE)
    for fname in spool._list("done"):
        p = os.path.join(d, fname)
        try:
            if now - os.stat(p).st_mtime <= age_s:
                continue
            with open(p) as f:
                obj = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            continue                   # corrupt-GC's department
        if not (isinstance(obj, dict) and "key" in obj
                and "record" in obj):
            continue
        line = json.dumps(obj, sort_keys=True, default=float)
        with open(compact, "a") as f:
            f.write(line + "\n")
        try:
            os.unlink(p)
        except FileNotFoundError:
            pass
        n += 1
    if n and REGISTRY.enabled:
        REGISTRY.counter("spool.compacted").inc(n)
    return n


def janitor_pass(spool: Spool, *,
                 tmp_age_s: float = DEFAULT_TMP_AGE_S,
                 corrupt_age_s: float = DEFAULT_CORRUPT_AGE_S,
                 compact_age_s: Optional[float] = DEFAULT_COMPACT_AGE_S,
                 now: Optional[float] = None) -> Dict[str, int]:
    """One full maintenance sweep; returns per-duty counts.

    ``compact_age_s=None`` disables compaction (e.g. while debugging a
    spool with plain ``ls``)."""
    now = now if now is not None else spool._now()
    stats = {
        "reclaimed": spool.reclaim(now=now),
        "tmp_gc": _gc_tmp(spool, tmp_age_s, now),
        "corrupt_gc": _gc_corrupt_done(spool, corrupt_age_s, now),
        "compacted": (_compact_done(spool, compact_age_s, now)
                      if compact_age_s is not None else 0),
    }
    if REGISTRY.enabled:
        REGISTRY.counter("janitor.passes").inc()
    return stats


def run_janitor(root: str, *, interval_s: float = 10.0,
                lease_s: Optional[float] = None,
                tmp_age_s: float = DEFAULT_TMP_AGE_S,
                corrupt_age_s: float = DEFAULT_CORRUPT_AGE_S,
                compact_age_s: Optional[float] = DEFAULT_COMPACT_AGE_S,
                iterations: Optional[int] = None,
                journal_path: Optional[str] = None,
                log: Optional[Callable[[str], None]] = None) -> int:
    """The janitor daemon loop: sweep every ``interval_s`` seconds.

    ``iterations=None`` runs forever (the deployed mode — pair one
    janitor with any shared spool); a finite count makes one-shot
    sweeps scriptable (``--once`` in the CLI). Returns the total number
    of jobs reclaimed across all passes."""
    spool = Spool(root) if lease_s is None else Spool(root,
                                                     lease_s=lease_s)
    journal = CampaignJournal(journal_path) if journal_path else None
    wid = f"janitor-{worker_id()}"
    total_reclaimed = 0
    i = 0
    while iterations is None or i < iterations:
        i += 1
        stats = janitor_pass(spool, tmp_age_s=tmp_age_s,
                             corrupt_age_s=corrupt_age_s,
                             compact_age_s=compact_age_s)
        total_reclaimed += stats["reclaimed"]
        if journal is not None and any(stats.values()):
            journal.janitor(worker=wid, **stats)
        if log and any(stats.values()):
            log(f"[{wid}] pass {i}: " +
                ", ".join(f"{k}={v}" for k, v in stats.items() if v))
        if iterations is not None and i >= iterations:
            break
        time.sleep(interval_s)
    return total_reclaimed


def janitor_status(root: str) -> Dict[str, Any]:
    """The ``exec status`` payload for a spool: state counts plus
    backoff/quarantine detail."""
    return Spool(root).status()
