"""Execution-service CLI.

  python -m repro.exec worker <spool> [--follow] [--max-jobs N]
  python -m repro.exec status <spool>
  python -m repro.exec journal <file> [--expect-done] [--min-points N]

``worker`` drains (or, with ``--follow``, keeps watching) a filesystem
job spool — run any number of these, from any process or host sharing
the spool directory. ``status`` prints queue counts. ``journal`` folds a
campaign journal into per-status counts; ``--expect-done`` exits
non-zero unless every point resolved (the CI smoke assertion).
"""
from __future__ import annotations

import argparse
import json
import sys

from .journal import CampaignJournal
from .spool import Spool
from .worker import run_worker


def cmd_worker(args: argparse.Namespace) -> int:
    n = run_worker(args.spool, drain=not args.follow, poll_s=args.poll_s,
                   hb_s=args.hb_s, max_jobs=args.max_jobs,
                   log=lambda m: print(m, flush=True))
    print(f"worker exit: {n} jobs completed")
    return 0


def cmd_status(args: argparse.Namespace) -> int:
    counts = Spool(args.spool).counts()
    for state, n in counts.items():
        print(f"{state},{n}")
    return 0


def cmd_journal(args: argparse.Namespace) -> int:
    view = CampaignJournal.load(args.path)
    counts = view.counts()
    for k in ("total", "done", "cached", "failed", "other"):
        print(f"{k},{counts[k]}")
    if view.summary:
        print(f"summary,{json.dumps(view.summary, sort_keys=True)}")
    if args.expect_done:
        ok = view.all_done(min_points=args.min_points)
        print(f"all_done,{ok}")
        return 0 if ok else 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.exec",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    wp = sub.add_parser("worker", help="drain/follow a job spool")
    wp.add_argument("spool", help="spool directory")
    wp.add_argument("--follow", action="store_true",
                    help="keep polling instead of exiting when drained")
    wp.add_argument("--poll-s", type=float, default=0.5)
    wp.add_argument("--hb-s", type=float, default=5.0,
                    help="heartbeat interval (lease keep-alive)")
    wp.add_argument("--max-jobs", type=int, default=None)
    wp.set_defaults(fn=cmd_worker)

    stp = sub.add_parser("status", help="print spool queue counts")
    stp.add_argument("spool")
    stp.set_defaults(fn=cmd_status)

    jp = sub.add_parser("journal", help="summarize a campaign journal")
    jp.add_argument("path")
    jp.add_argument("--expect-done", action="store_true",
                    help="exit 1 unless all points are done/cached")
    jp.add_argument("--min-points", type=int, default=1)
    jp.set_defaults(fn=cmd_journal)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
