"""Execution-service CLI.

  python -m repro.exec worker <spool> [--follow] [--max-jobs N]
  python -m repro.exec janitor <spool> [--once] [--interval S]
  python -m repro.exec status <spool-dir|journal.jsonl> [--watch]
  python -m repro.exec journal <file> [--expect-done] [--allow-failed]

``worker`` drains (or, with ``--follow``, keeps watching) a filesystem
job spool — run any number of these, from any process or host sharing
the spool directory. ``janitor`` is the standalone maintenance daemon
(lease reclaim, poison quarantine, ``.tmp``/corrupt GC, ``done/``
compaction) — pair one with any shared spool so a dead runner never
strands the fleet; ``--once`` does a single sweep and exits. ``status``
on a spool directory prints queue counts plus backoff and quarantine
detail (``backed_off``, ``next_retry_eta_s``, ``quarantined``); on a
campaign journal it folds per-phase throughput (points/s, cached vs
simulated), per-worker liveness, and an ETA — ``--watch`` tails the
journal incrementally (complete lines only, torn-tail safe) and
reprints until the campaign finishes. ``journal`` folds a campaign
journal into per-status counts; ``--expect-done`` exits non-zero unless
every point resolved (the CI smoke assertion; add ``--allow-failed``
for ``--allow-partial`` campaigns where failed is a terminal status).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from .journal import CampaignJournal
from .spool import Spool
from .worker import run_worker


def cmd_worker(args: argparse.Namespace) -> int:
    n = run_worker(args.spool, drain=not args.follow, poll_s=args.poll_s,
                   hb_s=args.hb_s, max_jobs=args.max_jobs,
                   log=lambda m: print(m, flush=True))
    print(f"worker exit: {n} jobs completed")
    return 0


def cmd_janitor(args: argparse.Namespace) -> int:
    from .janitor import run_janitor
    n = run_janitor(args.spool, interval_s=args.interval,
                    lease_s=args.lease_s, tmp_age_s=args.tmp_age_s,
                    corrupt_age_s=args.corrupt_age_s,
                    compact_age_s=(None if args.no_compact
                                   else args.compact_age_s),
                    iterations=1 if args.once else args.passes,
                    journal_path=args.journal,
                    log=lambda m: print(m, flush=True))
    print(f"janitor exit: {n} jobs reclaimed")
    return 0


def cmd_status(args: argparse.Namespace) -> int:
    if os.path.isdir(args.path):
        spool = Spool(args.path)
        while True:
            st = spool.status()
            for k, v in st.items():
                if v is None:
                    continue
                v = f"{v:.1f}" if isinstance(v, float) else v
                print(f"{k},{v}", flush=True)
            if not args.watch or (st["jobs"] == 0 and st["active"] == 0):
                return 0
            time.sleep(args.interval)
    # a campaign journal: fold incrementally into progress + ETA
    from ..obs.progress import (CampaignProgress, JournalFollower,
                                render_progress)
    prog = CampaignProgress()
    follower = JournalFollower(args.path)
    while True:
        prog.feed_all(follower.poll())
        for w in follower.warnings:
            print(f"warning: {w}", file=sys.stderr)
        follower.warnings.clear()
        s = prog.summary(now=time.time() if args.watch else None)
        for line in render_progress(s):
            print(line, flush=True)
        if not args.watch or s["finished"]:
            return 0
        time.sleep(args.interval)


def cmd_journal(args: argparse.Namespace) -> int:
    view = CampaignJournal.load(args.path)
    for w in view.warnings:
        print(f"warning: {w}", file=sys.stderr)
    counts = view.counts()
    for k in ("total", "done", "cached", "failed", "other"):
        print(f"{k},{counts[k]}")
    if view.summary:
        print(f"summary,{json.dumps(view.summary, sort_keys=True)}")
    if args.expect_done:
        ok = view.all_done(min_points=args.min_points,
                           allow_failed=args.allow_failed)
        print(f"all_done,{ok}")
        return 0 if ok else 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.exec",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    wp = sub.add_parser("worker", help="drain/follow a job spool")
    wp.add_argument("spool", help="spool directory")
    wp.add_argument("--follow", action="store_true",
                    help="keep polling instead of exiting when drained")
    wp.add_argument("--poll-s", type=float, default=0.5)
    wp.add_argument("--hb-s", type=float, default=5.0,
                    help="heartbeat interval (lease keep-alive)")
    wp.add_argument("--max-jobs", type=int, default=None)
    wp.set_defaults(fn=cmd_worker)

    janp = sub.add_parser(
        "janitor", help="spool maintenance daemon: lease reclaim, "
                        "poison quarantine, .tmp/corrupt GC, done/ "
                        "compaction")
    janp.add_argument("spool", help="spool directory")
    janp.add_argument("--interval", type=float, default=10.0,
                      help="seconds between maintenance passes")
    janp.add_argument("--once", action="store_true",
                      help="single pass, then exit")
    janp.add_argument("--passes", type=int, default=None,
                      help="exit after N passes (default: run forever)")
    janp.add_argument("--lease-s", type=float, default=None,
                      help="override the spool's reclaim lease")
    janp.add_argument("--tmp-age-s", type=float, default=300.0,
                      help="GC .tmp staging files older than this")
    janp.add_argument("--corrupt-age-s", type=float, default=300.0,
                      help="GC torn done/ files older than this")
    janp.add_argument("--compact-age-s", type=float, default=60.0,
                      help="compact done/ files older than this")
    janp.add_argument("--no-compact", action="store_true",
                      help="disable done/ compaction")
    janp.add_argument("--journal", default=None,
                      help="append ev:janitor lines to this campaign "
                           "journal")
    janp.set_defaults(fn=cmd_janitor)

    stp = sub.add_parser(
        "status", help="spool queue counts, or campaign progress + ETA "
                       "from a journal file")
    stp.add_argument("path", help="spool directory or journal .jsonl")
    stp.add_argument("--watch", action="store_true",
                     help="keep tailing/reprinting until finished")
    stp.add_argument("--interval", type=float, default=2.0,
                     help="watch poll interval in seconds")
    stp.set_defaults(fn=cmd_status)

    jp = sub.add_parser("journal", help="summarize a campaign journal")
    jp.add_argument("path")
    jp.add_argument("--expect-done", action="store_true",
                    help="exit 1 unless all points are done/cached")
    jp.add_argument("--allow-failed", action="store_true",
                    help="with --expect-done: failed counts as terminal "
                         "(--allow-partial campaigns)")
    jp.add_argument("--min-points", type=int, default=1)
    jp.set_defaults(fn=cmd_journal)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
