"""Execution-service CLI.

  python -m repro.exec worker <spool> [--follow] [--max-jobs N]
  python -m repro.exec status <spool-dir|journal.jsonl> [--watch]
  python -m repro.exec journal <file> [--expect-done] [--min-points N]

``worker`` drains (or, with ``--follow``, keeps watching) a filesystem
job spool — run any number of these, from any process or host sharing
the spool directory. ``status`` on a spool directory prints queue
counts; on a campaign journal it folds per-phase throughput (points/s,
cached vs simulated), per-worker liveness, and an ETA — ``--watch``
tails the journal incrementally (complete lines only, torn-tail safe)
and reprints until the campaign finishes. ``journal`` folds a campaign
journal into per-status counts; ``--expect-done`` exits non-zero unless
every point resolved (the CI smoke assertion).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from .journal import CampaignJournal
from .spool import Spool
from .worker import run_worker


def cmd_worker(args: argparse.Namespace) -> int:
    n = run_worker(args.spool, drain=not args.follow, poll_s=args.poll_s,
                   hb_s=args.hb_s, max_jobs=args.max_jobs,
                   log=lambda m: print(m, flush=True))
    print(f"worker exit: {n} jobs completed")
    return 0


def cmd_status(args: argparse.Namespace) -> int:
    if os.path.isdir(args.path):
        spool = Spool(args.path)
        while True:
            counts = spool.counts()
            for state, n in counts.items():
                print(f"{state},{n}", flush=True)
            if not args.watch or (counts["jobs"] == 0
                                  and counts["active"] == 0):
                return 0
            time.sleep(args.interval)
    # a campaign journal: fold incrementally into progress + ETA
    from ..obs.progress import (CampaignProgress, JournalFollower,
                                render_progress)
    prog = CampaignProgress()
    follower = JournalFollower(args.path)
    while True:
        prog.feed_all(follower.poll())
        for w in follower.warnings:
            print(f"warning: {w}", file=sys.stderr)
        follower.warnings.clear()
        s = prog.summary(now=time.time() if args.watch else None)
        for line in render_progress(s):
            print(line, flush=True)
        if not args.watch or s["finished"]:
            return 0
        time.sleep(args.interval)


def cmd_journal(args: argparse.Namespace) -> int:
    view = CampaignJournal.load(args.path)
    for w in view.warnings:
        print(f"warning: {w}", file=sys.stderr)
    counts = view.counts()
    for k in ("total", "done", "cached", "failed", "other"):
        print(f"{k},{counts[k]}")
    if view.summary:
        print(f"summary,{json.dumps(view.summary, sort_keys=True)}")
    if args.expect_done:
        ok = view.all_done(min_points=args.min_points)
        print(f"all_done,{ok}")
        return 0 if ok else 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.exec",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    wp = sub.add_parser("worker", help="drain/follow a job spool")
    wp.add_argument("spool", help="spool directory")
    wp.add_argument("--follow", action="store_true",
                    help="keep polling instead of exiting when drained")
    wp.add_argument("--poll-s", type=float, default=0.5)
    wp.add_argument("--hb-s", type=float, default=5.0,
                    help="heartbeat interval (lease keep-alive)")
    wp.add_argument("--max-jobs", type=int, default=None)
    wp.set_defaults(fn=cmd_worker)

    stp = sub.add_parser(
        "status", help="spool queue counts, or campaign progress + ETA "
                       "from a journal file")
    stp.add_argument("path", help="spool directory or journal .jsonl")
    stp.add_argument("--watch", action="store_true",
                     help="keep tailing/reprinting until finished")
    stp.add_argument("--interval", type=float, default=2.0,
                     help="watch poll interval in seconds")
    stp.set_defaults(fn=cmd_status)

    jp = sub.add_parser("journal", help="summarize a campaign journal")
    jp.add_argument("path")
    jp.add_argument("--expect-done", action="store_true",
                    help="exit 1 unless all points are done/cached")
    jp.add_argument("--min-points", type=int, default=1)
    jp.set_defaults(fn=cmd_journal)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
