"""Filesystem job spool: durable, multi-process refinement queue.

Layout (everything is plain JSON under one root directory)::

    <root>/jobs/<key>.json            pending   {"key", "payload"}
    <root>/active/<key>@<worker>.json claimed   (heartbeat = file mtime)
    <root>/done/<key>.json            finished  {"key","record","worker",..}
    <root>/failed/<key>.json          errored   {"key","error","worker",..}

Concurrency is pure POSIX filesystem semantics — no locks, no network:

* **claim** — ``rename(jobs/k.json, active/k@w.json)``. Rename is
  atomic; exactly one of any number of racing workers wins, the losers
  get ``FileNotFoundError`` and move on.
* **heartbeat lease** — a claiming worker touches its active file
  periodically. An active file whose mtime is older than ``lease_s`` is
  presumed orphaned (killed worker) and **reclaimed**: returned to
  ``jobs/`` where any worker can claim it again.
* **retry budget** — every reclaim increments the job's ``attempts``
  counter. A job reclaimed more than ``retry_budget`` times is a
  *poison job* (it kills every worker that touches it — an OOM, a
  segfaulting extension, a pathological input): it is quarantined to
  ``failed/`` instead of being lease-reclaimed forever, so a campaign
  fails fast with a diagnosable error instead of cycling the fleet.
* **complete** — results are staged as invisible ``.tmp`` files and
  published with ``os.replace`` so readers never observe a torn
  ``done`` file.

Job ids are the refinement content keys (``sweep.cache.content_key``),
so the spool is naturally idempotent: re-submitting a campaign after a
kill re-creates only the jobs that never finished, and a ``done`` file
surviving a dead runner is picked up without re-simulation.

``SpoolBackend`` drives a campaign's misses through a spool: submit,
optionally spawn local worker daemons, poll for completion while
reclaiming dead jobs, and collect records in payload order.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from ..obs.metrics import REGISTRY
from ..sweep.cache import atomic_write_json
from .backend import BackendError, Progress, _cache_put, _journal_done

__all__ = ["Spool", "SpoolJob", "SpoolBackend", "DEFAULT_LEASE_S",
           "DEFAULT_RETRY_BUDGET", "worker_id"]

DEFAULT_LEASE_S = 60.0
DEFAULT_RETRY_BUDGET = 3       # reclaims before a job is quarantined
_STATES = ("jobs", "active", "done", "failed")


def worker_id() -> str:
    return f"{os.uname().nodename}-{os.getpid()}"


def _publish(directory: str, key: str, obj: Dict[str, Any]) -> str:
    """Atomic in-place publish; the .tmp staging files are invisible to
    every listing (they all filter on the .json suffix)."""
    return atomic_write_json(os.path.join(directory, key + ".json"), obj,
                             sort_keys=True)


@dataclass
class SpoolJob:
    """A claimed job: payload plus the active-file lease to heartbeat."""

    key: str
    payload: Dict[str, Any]
    active_path: str
    worker: str
    t_claim: float
    attempts: int = 0          # completed reclaim cycles before this claim

    def heartbeat(self) -> bool:
        """Refresh the lease; False if the job was reclaimed under us."""
        try:
            os.utime(self.active_path)
            return True
        except FileNotFoundError:
            return False


class Spool:
    """One job spool rooted at a directory; see module docstring."""

    def __init__(self, root: str, *, lease_s: float = DEFAULT_LEASE_S,
                 retry_budget: int = DEFAULT_RETRY_BUDGET):
        self.root = os.path.abspath(root)
        self.lease_s = lease_s
        self.retry_budget = retry_budget
        for d in _STATES:
            os.makedirs(os.path.join(self.root, d), exist_ok=True)

    def _dir(self, state: str) -> str:
        return os.path.join(self.root, state)

    def _list(self, state: str) -> List[str]:
        return sorted(f for f in os.listdir(self._dir(state))
                      if f.endswith(".json"))

    # -- producer side ----------------------------------------------------

    def submit(self, key: str, payload: Dict[str, Any]) -> bool:
        """Enqueue one job; no-op (False) if the key is already pending,
        claimed, or done — submission is idempotent. A ``failed`` entry
        from an earlier run is cleared and retried."""
        for state in ("jobs", "active", "done"):
            probe = self._dir(state)
            if state == "active":
                if any(f.startswith(key + "@") for f in os.listdir(probe)):
                    return False
            elif os.path.exists(os.path.join(probe, key + ".json")):
                return False
        try:
            os.unlink(os.path.join(self._dir("failed"), key + ".json"))
        except FileNotFoundError:
            pass
        _publish(self._dir("jobs"), key,
                 {"key": key, "payload": payload})
        return True

    def result(self, key: str) -> Optional[Dict[str, Any]]:
        """The done-file dict for ``key`` (or None). Tolerates a torn
        file only insofar as done files are published atomically."""
        p = os.path.join(self._dir("done"), key + ".json")
        try:
            with open(p) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def failure(self, key: str) -> Optional[Dict[str, Any]]:
        p = os.path.join(self._dir("failed"), key + ".json")
        try:
            with open(p) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def counts(self) -> Dict[str, int]:
        return {state: len(self._list(state)) for state in _STATES}

    def done_keys(self) -> set:
        """Keys with a published result — one listdir, no file reads."""
        return {f[:-len(".json")] for f in self._list("done")}

    def failed_keys(self) -> set:
        return {f[:-len(".json")] for f in self._list("failed")}

    # -- worker side ------------------------------------------------------

    def claim(self, worker: Optional[str] = None) -> Optional[SpoolJob]:
        """Claim one pending job by atomic rename; None when empty."""
        worker = worker or worker_id()
        for fname in self._list("jobs"):
            key = fname[:-len(".json")]
            if os.path.exists(os.path.join(self._dir("done"),
                                           key + ".json")):
                # finished elsewhere (e.g. requeued by an over-eager
                # reclaim while its worker kept computing): drop it
                try:
                    os.unlink(os.path.join(self._dir("jobs"), fname))
                except FileNotFoundError:
                    pass
                continue
            src = os.path.join(self._dir("jobs"), fname)
            dst = os.path.join(self._dir("active"), f"{key}@{worker}.json")
            try:
                # rename preserves mtime and the job file's may already
                # be older than the lease (a resumed spool): restart the
                # lease clock BEFORE the rename so the active file is
                # never observable with a stale heartbeat
                os.utime(src)
                os.rename(src, dst)
                with open(dst) as f:
                    job_d = json.load(f)
                payload = job_d["payload"]
            except FileNotFoundError:
                continue               # lost the race for this job
            except (json.JSONDecodeError, KeyError):
                # torn job file (non-atomic producer fs): surface it as
                # a failure so a waiting backend fails fast instead of
                # hanging; resubmission retries the key
                _publish(self._dir("failed"), key,
                         {"key": key, "error": "corrupt job file",
                          "worker": worker, "t_failed": time.time()})
                os.unlink(dst)
                continue
            if REGISTRY.enabled:
                REGISTRY.counter("spool.jobs_claimed").inc()
            return SpoolJob(key=key, payload=payload, active_path=dst,
                            worker=worker, t_claim=time.time(),
                            attempts=int(job_d.get("attempts", 0)))
        return None

    def complete(self, job: SpoolJob, record: Dict[str, Any], *,
                 wall_s: float) -> str:
        dst = _publish(
            self._dir("done"), job.key,
            {"key": job.key, "record": record, "worker": job.worker,
             "wall_s": wall_s, "t_done": time.time()})
        self._release(job)
        return dst

    def fail(self, job: SpoolJob, error: str) -> str:
        dst = _publish(
            self._dir("failed"), job.key,
            {"key": job.key, "error": error, "worker": job.worker,
             "t_failed": time.time()})
        self._release(job)
        return dst

    def _release(self, job: SpoolJob) -> None:
        try:
            os.unlink(job.active_path)
        except FileNotFoundError:
            pass                       # reclaimed while we worked: the
            #                            done/failed file still wins

    # -- janitor ----------------------------------------------------------

    def reclaim(self, *, lease_s: Optional[float] = None,
                now: Optional[float] = None) -> int:
        """Return orphaned active jobs (stale heartbeat) to ``jobs/``.

        Each reclaim cycle increments the job's ``attempts`` counter; a
        job past ``retry_budget`` reclaims is quarantined to ``failed/``
        (poison job: it keeps killing its workers) instead of being
        requeued forever. Quarantined jobs count toward the return
        value (they were taken off a dead worker)."""
        lease = lease_s if lease_s is not None else self.lease_s
        now = now if now is not None else time.time()
        n = 0
        for fname in self._list("active"):
            p = os.path.join(self._dir("active"), fname)
            try:
                age = now - os.stat(p).st_mtime
            except FileNotFoundError:
                continue
            if age <= lease:
                continue
            # partition, not split: a stray active file without an "@"
            # (shared-directory operator artifact) must not abort the
            # whole reclaim pass — it falls through to the corrupt-file
            # quarantine below
            key, _, worker = fname[:-len(".json")].partition("@")
            if os.path.exists(os.path.join(self._dir("done"),
                                           key + ".json")):
                # finished but the worker died before releasing the claim
                try:
                    os.unlink(p)
                except FileNotFoundError:
                    pass
                continue
            try:
                with open(p) as f:
                    job_d = json.load(f)
                attempts = int(job_d.get("attempts", 0)) + 1
            except FileNotFoundError:
                continue               # released/reclaimed under us
            except (json.JSONDecodeError, KeyError, ValueError):
                _publish(self._dir("failed"), key,
                         {"key": key, "error": "corrupt active file",
                          "worker": worker, "t_failed": now})
                try:
                    os.unlink(p)
                except FileNotFoundError:
                    pass
                n += 1
                continue
            if attempts > self.retry_budget:
                _publish(self._dir("failed"), key,
                         {"key": key, "worker": worker, "t_failed": now,
                          "attempts": attempts,
                          "error": f"retry budget exhausted: reclaimed "
                                   f"from {attempts} dead workers "
                                   f"(budget {self.retry_budget}); "
                                   f"quarantined as a poison job"})
                if REGISTRY.enabled:
                    REGISTRY.counter("spool.jobs_quarantined").inc()
            else:
                # requeue with the bumped counter: publish-then-unlink
                # so a crash in between leaves a claimable job file,
                # never a lost one (claim() drops stale duplicates)
                _publish(self._dir("jobs"), key, {**job_d, "key": key,
                                                  "attempts": attempts})
            try:
                os.unlink(p)
            except FileNotFoundError:
                pass
            n += 1
        if n and REGISTRY.enabled:
            REGISTRY.counter("spool.jobs_reclaimed").inc(n)
        return n


class SpoolBackend:
    """Refine through a ``Spool``, optionally spawning local workers.

    ``workers=N`` (N>=1) spawns N ``python -m repro.exec worker --drain``
    subprocesses that exit when the queue empties; ``workers=0`` relies
    entirely on externally attached workers (detached daemons, other
    hosts on a shared filesystem). Either way the backend polls for
    completion, reclaims dead jobs, and respawns a local drain worker if
    its fleet dies with jobs still pending.
    """

    name = "spool"

    def __init__(self, root: str, *, workers: int = 1,
                 lease_s: float = DEFAULT_LEASE_S, poll_s: float = 0.2,
                 timeout_s: Optional[float] = None):
        self.root = root
        self.workers = workers
        self.lease_s = lease_s
        self.poll_s = poll_s
        self.timeout_s = timeout_s

    def _spawn_worker(self) -> subprocess.Popen:
        import repro
        src = os.path.dirname(repro.__path__[0])
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.Popen(
            [sys.executable, "-m", "repro.exec", "worker", self.root],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)

    def refine(self, payloads: List[Dict[str, Any]], *,
               keys: Optional[List[str]] = None,
               journal: Optional[Any] = None,
               cache: Optional[Any] = None,
               progress: Progress = None) -> List[Dict[str, Any]]:
        if keys is None:
            from ..sweep.cache import content_key
            keys = [content_key(p) for p in payloads]
        spool = Spool(self.root, lease_s=self.lease_s)

        submitted = 0
        for key, payload in zip(keys, payloads):
            if spool.result(key) is None:  # resume: keep surviving results
                submitted += spool.submit(key, payload)
        if progress:
            progress(f"spool {self.root}: {submitted} submitted, "
                     f"{len(keys) - submitted} already queued/finished")

        procs = [self._spawn_worker() for _ in range(self.workers)]
        respawns_left = max(self.workers, 1)
        pending = set(keys)
        collected: Dict[str, Dict[str, Any]] = {}
        journaled: set = set()
        t0 = time.time()
        t_report = t0
        try:
            while pending:
                # one listdir per state per tick; files are read only
                # for newly resolved keys
                for key in sorted(pending & spool.done_keys()):
                    res = spool.result(key)
                    if res is None:
                        continue       # torn listing race; next tick
                    pending.discard(key)
                    collected[key] = res["record"]
                    if cache is not None:
                        # write-through: durable even if this runner
                        # dies before the batch completes
                        _cache_put(cache, key, res["record"])
                    if journal is not None and key not in journaled:
                        # batch-job records expand to per-point events
                        _journal_done(journal, key,
                                      worker=res.get("worker"),
                                      wall_s=res.get("wall_s"),
                                      rec=res["record"])
                        journaled.add(key)
                for key in sorted(pending & spool.failed_keys()):
                    fail = spool.failure(key)
                    if fail is None:
                        continue
                    pending.discard(key)
                    if journal is not None and key not in journaled:
                        journal.point(key, "failed",
                                      worker=fail.get("worker"),
                                      error=fail.get("error"))
                        journaled.add(key)
                if not pending:
                    break
                spool.reclaim()
                procs = [p for p in procs if p.poll() is None]
                if (not procs and self.workers > 0 and respawns_left > 0
                        and spool.counts()["jobs"] > 0):
                    # local fleet died with work pending (e.g. a reclaim
                    # landed after the drain workers exited)
                    procs.append(self._spawn_worker())
                    respawns_left -= 1
                if progress and time.time() - t_report > 2.0:
                    done = len(keys) - len(pending)
                    progress(f"spool: {done}/{len(keys)} done "
                             f"({len(procs)} local workers)")
                    t_report = time.time()
                if (self.timeout_s is not None
                        and time.time() - t0 > self.timeout_s):
                    raise BackendError(
                        f"spool backend timed out after {self.timeout_s}s "
                        f"with {len(pending)} points pending "
                        f"(spool: {self.root})")
                time.sleep(self.poll_s)
        finally:
            for p in procs:
                if p.poll() is None:
                    p.terminate()
            for p in procs:
                try:
                    p.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    p.kill()

        out: List[Dict[str, Any]] = []
        failures: List[str] = []
        for key in keys:
            rec = collected.get(key)
            if rec is None:
                fail = spool.failure(key) or {}
                failures.append(f"{key[:12]}: {fail.get('error', '?')}")
                continue
            out.append(rec)
        if failures:
            raise BackendError(
                f"{len(failures)} refinement(s) failed in spool "
                f"{self.root}: " + "; ".join(failures[:3]))
        return out
