"""Filesystem job spool: durable, multi-process refinement queue.

Layout (everything is plain JSON under one root directory)::

    <root>/jobs/<key>.json            pending   {"key", "payload",
                                                 "attempts"?, "not_before"?}
    <root>/active/<key>@<worker>.json claimed   (heartbeat = file mtime)
    <root>/done/<key>.json            finished  {"key","record","worker",..}
    <root>/done/_compact.jsonl        janitor-compacted finished jobs
    <root>/failed/<key>.json          errored   {"key","error","worker",..}

Concurrency is pure POSIX filesystem semantics — no locks, no network:

* **claim** — ``rename(jobs/k.json, active/k@w.json)``. Rename is
  atomic; exactly one of any number of racing workers wins, the losers
  get ``FileNotFoundError`` and move on.
* **heartbeat lease** — a claiming worker touches its active file
  periodically. An active file whose mtime is older than ``lease_s`` is
  presumed orphaned (killed worker) and **reclaimed**: returned to
  ``jobs/`` where any worker can claim it again.
* **retry budget** — every reclaim/requeue increments the job's
  ``attempts`` counter. A job past ``retry_budget`` attempts is a
  *poison job* (it kills every worker that touches it — an OOM, a
  segfaulting extension, a pathological input): it is quarantined to
  ``failed/`` instead of being lease-reclaimed forever, so a campaign
  fails fast with a diagnosable error instead of cycling the fleet.
* **retry backoff** — a requeued job carries a ``not_before`` timestamp
  (exponential in ``attempts`` with deterministic jitter keyed on the
  job key) that ``claim()`` honors, so a flaky job stops hot-looping
  the queue while healthy jobs flow around it.
* **complete** — results are staged as invisible ``.tmp`` files and
  published with ``os.replace`` so readers never observe a torn
  ``done`` file. The complete/fail paths are *release-safe*: a
  recoverable exception after the outcome publish still releases the
  lease, and a failed outcome publish requeues the job immediately
  instead of leaking the claim until lease expiry.

Job ids are the refinement content keys (``sweep.cache.content_key``),
so the spool is naturally idempotent: re-submitting a campaign after a
kill re-creates only the jobs that never finished, and a ``done`` file
surviving a dead runner is picked up without re-simulation.

Failure injection: every mutation site here consults
``exec.faults.active_plan()`` (inert unless ``REPRO_FAULTS`` is set or
a test installs a plan), which is how the chaos suite proves the
exactly-once/quarantine invariant. ``exec.janitor`` owns the
maintenance duties (periodic reclaim, ``.tmp`` GC, corrupt-done GC,
``done/`` compaction) for spools that outlive any single runner.

``SpoolBackend`` drives a campaign's misses through a spool: submit,
optionally spawn local worker daemons, poll for completion while
reclaiming dead jobs, and collect records in payload order.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from ..obs.metrics import REGISTRY
from ..sweep.cache import atomic_write_json
from . import faults
from .backend import BackendError, Progress, _cache_put, _journal_done, \
    failure_record

__all__ = ["Spool", "SpoolJob", "SpoolBackend", "PublishError",
           "DEFAULT_LEASE_S", "DEFAULT_RETRY_BUDGET", "DEFAULT_BACKOFF_S",
           "DEFAULT_BACKOFF_CAP_S", "backoff_s", "worker_id"]

DEFAULT_LEASE_S = 60.0
DEFAULT_RETRY_BUDGET = 3       # reclaims/requeues before quarantine
DEFAULT_BACKOFF_S = 2.0        # base of the exponential retry backoff
DEFAULT_BACKOFF_CAP_S = 60.0   # backoff ceiling (before jitter)
_STATES = ("jobs", "active", "done", "failed")
COMPACT_FILE = "_compact.jsonl"


class PublishError(RuntimeError):
    """A job outcome (done/failed file) could not be published. The job
    was requeued (or left leased for reclaim) — the worker should log
    and move on, never die on it."""


def worker_id() -> str:
    return f"{os.uname().nodename}-{os.getpid()}"


def backoff_s(key: str, attempts: int, *,
              base_s: float = DEFAULT_BACKOFF_S,
              cap_s: float = DEFAULT_BACKOFF_CAP_S) -> float:
    """Exponential retry backoff with deterministic jitter.

    ``base * 2^(attempts-1)`` capped at ``cap_s``, scaled by a jitter
    factor in [0.75, 1.25) keyed on ``(key, attempts)`` — a pure hash,
    so every host computes the same ``not_before`` for the same retry
    (records and replays stay deterministic) while distinct jobs
    de-synchronize instead of thundering back together."""
    if base_s <= 0.0 or attempts <= 0:
        return 0.0
    raw = min(base_s * (2.0 ** (attempts - 1)), cap_s)
    h = hashlib.sha256(f"{key}:{attempts}".encode()).digest()
    jitter = 0.75 + 0.5 * (int.from_bytes(h[:8], "big") / 2.0 ** 64)
    return raw * jitter


def _publish(directory: str, key: str, obj: Dict[str, Any], *,
             site: str = "publish-job", salt: int = 0) -> str:
    """Atomic in-place publish; the .tmp staging files are invisible to
    every listing (they all filter on the .json suffix). The active
    fault plan can inject slow-filesystem latency or a torn write (the
    final path holds truncated JSON and the call raises)."""
    path = os.path.join(directory, key + ".json")
    plan = faults.active_plan()
    if plan is not None:
        plan.sleep_fs()
        if plan.torn_write(site, key, salt):
            os.makedirs(directory, exist_ok=True)
            blob = json.dumps(obj, sort_keys=True, default=float)
            with open(path, "w") as f:
                f.write(blob[: max(1, len(blob) // 2)])
            raise faults.TornWrite(
                f"injected torn write at {site} for {key[:12]}")
    return atomic_write_json(path, obj, sort_keys=True)


@dataclass
class SpoolJob:
    """A claimed job: payload plus the active-file lease to heartbeat."""

    key: str
    payload: Dict[str, Any]
    active_path: str
    worker: str
    t_claim: float
    attempts: int = 0          # completed reclaim cycles before this claim

    def heartbeat(self) -> bool:
        """Refresh the lease; False if the job was reclaimed under us.
        An injected heartbeat stall silently stops refreshing (the
        worker thinks everything is fine — a paged-out process)."""
        plan = faults.active_plan()
        if plan is not None and plan.heartbeat_stalls(self.key,
                                                      self.attempts):
            return True
        try:
            os.utime(self.active_path)
            return True
        except FileNotFoundError:
            return False


class Spool:
    """One job spool rooted at a directory; see module docstring."""

    def __init__(self, root: str, *, lease_s: float = DEFAULT_LEASE_S,
                 retry_budget: int = DEFAULT_RETRY_BUDGET,
                 backoff_base_s: float = DEFAULT_BACKOFF_S,
                 backoff_cap_s: float = DEFAULT_BACKOFF_CAP_S):
        self.root = os.path.abspath(root)
        self.lease_s = lease_s
        self.retry_budget = retry_budget
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self._compact_cache: Tuple[Any, Dict[str, Dict[str, Any]]] = \
            (None, {})
        for d in _STATES:
            os.makedirs(os.path.join(self.root, d), exist_ok=True)

    def _dir(self, state: str) -> str:
        return os.path.join(self.root, state)

    def _list(self, state: str) -> List[str]:
        return sorted(f for f in os.listdir(self._dir(state))
                      if f.endswith(".json"))

    def _now(self) -> float:
        """The spool's clock — wall time through any injected skew."""
        return faults.now()

    # -- compacted done files ---------------------------------------------

    def _compact_path(self) -> str:
        return os.path.join(self._dir("done"), COMPACT_FILE)

    def _compact_index(self) -> Dict[str, Dict[str, Any]]:
        """Key -> done-dict for janitor-compacted results. Cached on the
        compact file's (mtime_ns, size) signature; torn tail lines (a
        janitor killed mid-append) are skipped, the file stays
        append-only so earlier lines are never at risk."""
        p = self._compact_path()
        try:
            st = os.stat(p)
        except OSError:
            return {}
        sig = (st.st_mtime_ns, st.st_size)
        if self._compact_cache[0] == sig:
            return self._compact_cache[1]
        idx: Dict[str, Dict[str, Any]] = {}
        with open(p) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(d, dict) and "key" in d:
                    idx[d["key"]] = d
        self._compact_cache = (sig, idx)
        return idx

    # -- producer side ----------------------------------------------------

    def submit(self, key: str, payload: Dict[str, Any]) -> bool:
        """Enqueue one job; no-op (False) if the key is already pending,
        claimed, or done — submission is idempotent. A ``failed`` entry
        from an earlier run is cleared and retried."""
        if os.path.exists(os.path.join(self._dir("jobs"), key + ".json")):
            return False
        if any(f.startswith(key + "@")
               for f in os.listdir(self._dir("active"))):
            return False
        if self.result(key) is not None:
            return False
        try:
            os.unlink(os.path.join(self._dir("failed"), key + ".json"))
        except FileNotFoundError:
            pass
        _publish(self._dir("jobs"), key,
                 {"key": key, "payload": payload})
        return True

    def result(self, key: str) -> Optional[Dict[str, Any]]:
        """The done-file dict for ``key`` (or None), looking through the
        janitor's compacted archive too. A torn done file (non-atomic
        filesystem) reads as *not finished* — the job stays claimable
        and the next complete atomically overwrites the wreckage."""
        p = os.path.join(self._dir("done"), key + ".json")
        try:
            with open(p) as f:
                d = json.load(f)
            if isinstance(d, dict) and "record" in d:
                return d
        except (FileNotFoundError, json.JSONDecodeError):
            pass
        return self._compact_index().get(key)

    def failure(self, key: str) -> Optional[Dict[str, Any]]:
        p = os.path.join(self._dir("failed"), key + ".json")
        try:
            with open(p) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def counts(self) -> Dict[str, int]:
        c = {state: len(self._list(state)) for state in _STATES}
        compact = self._compact_index()
        if compact:
            listed = {f[:-len(".json")] for f in self._list("done")}
            c["done"] += len(set(compact) - listed)
        return c

    def done_keys(self) -> set:
        """Keys with a published result — one listdir (plus the cached
        compact index), no per-key file reads."""
        keys = {f[:-len(".json")] for f in self._list("done")}
        keys.update(self._compact_index())
        return keys

    def failed_keys(self) -> set:
        return {f[:-len(".json")] for f in self._list("failed")}

    def next_retry_eta(self, now: Optional[float] = None
                       ) -> Optional[float]:
        """Seconds until the earliest backed-off pending job becomes
        claimable; None when no pending job is backed off."""
        now = now if now is not None else self._now()
        eta: Optional[float] = None
        for fname in self._list("jobs"):
            try:
                with open(os.path.join(self._dir("jobs"), fname)) as f:
                    nb = float(json.load(f).get("not_before", 0.0))
            except (OSError, json.JSONDecodeError, TypeError, ValueError):
                continue
            if nb > now and (eta is None or nb - now < eta):
                eta = nb - now
        return eta

    def status(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Operator view: state counts plus backoff/quarantine detail
        (``python -m repro.exec status <spool>``)."""
        now = now if now is not None else self._now()
        st: Dict[str, Any] = dict(self.counts())
        backed_off = 0
        eta: Optional[float] = None
        for fname in self._list("jobs"):
            try:
                with open(os.path.join(self._dir("jobs"), fname)) as f:
                    d = json.load(f)
                nb = float(d.get("not_before", 0.0))
            except (OSError, json.JSONDecodeError, TypeError, ValueError):
                continue
            if nb > now:
                backed_off += 1
                if eta is None or nb - now < eta:
                    eta = nb - now
        quarantined = 0
        for fname in self._list("failed"):
            try:
                with open(os.path.join(self._dir("failed"), fname)) as f:
                    d = json.load(f)
            except (OSError, json.JSONDecodeError):
                continue
            if int(d.get("attempts", 0)) > 0:
                quarantined += 1
        st["backed_off"] = backed_off
        st["next_retry_eta_s"] = eta
        st["quarantined"] = quarantined
        return st

    # -- worker side ------------------------------------------------------

    def claim(self, worker: Optional[str] = None) -> Optional[SpoolJob]:
        """Claim one pending job by atomic rename; None when empty.

        Honors retry backoff (``not_before`` in the job file), drops
        stale duplicates of finished jobs, quarantines corrupt job
        files and over-budget retries."""
        worker = worker or worker_id()
        now = self._now()
        for fname in self._list("jobs"):
            key = fname[:-len(".json")]
            src = os.path.join(self._dir("jobs"), fname)
            # peek for backoff before claiming — skipping must not cost
            # a rename round-trip
            try:
                with open(src) as f:
                    peek = json.load(f)
                nb = float(peek.get("not_before", 0.0))
            except FileNotFoundError:
                continue               # claimed/unlinked under us
            except (json.JSONDecodeError, TypeError, ValueError):
                nb = 0.0               # torn: claim it to quarantine below
            if nb > now:
                if REGISTRY.enabled:
                    REGISTRY.counter("spool.backoff_skips").inc()
                continue
            if self.result(key) is not None:
                # finished elsewhere (e.g. requeued by an over-eager
                # reclaim while its worker kept computing): drop it
                try:
                    os.unlink(src)
                except FileNotFoundError:
                    pass
                continue
            dst = os.path.join(self._dir("active"), f"{key}@{worker}.json")
            plan = faults.active_plan()
            if plan is not None:
                plan.sleep_fs()
            try:
                # rename preserves mtime and the job file's may already
                # be older than the lease (a resumed spool): restart the
                # lease clock BEFORE the rename so the active file is
                # never observable with a stale heartbeat
                os.utime(src)
                os.rename(src, dst)
                with open(dst) as f:
                    job_d = json.load(f)
                payload = job_d["payload"]
            except FileNotFoundError:
                continue               # lost the race for this job
            except (json.JSONDecodeError, KeyError):
                # torn job file (non-atomic producer fs): surface it as
                # a failure so a waiting backend fails fast instead of
                # hanging; resubmission retries the key
                _publish(self._dir("failed"), key,
                         {"key": key, "error": "corrupt job file",
                          "worker": worker, "t_failed": now},
                         site="publish-fail")
                os.unlink(dst)
                continue
            attempts = int(job_d.get("attempts", 0))
            if attempts > self.retry_budget:
                # requeue paths (failed publishes) bump attempts without
                # passing through reclaim — enforce the budget here too
                self._quarantine(key, worker=worker, attempts=attempts,
                                 now=now)
                try:
                    os.unlink(dst)
                except FileNotFoundError:
                    pass
                continue
            if REGISTRY.enabled:
                REGISTRY.counter("spool.jobs_claimed").inc()
            return SpoolJob(key=key, payload=payload, active_path=dst,
                            worker=worker, t_claim=now,
                            attempts=attempts)
        return None

    def complete(self, job: SpoolJob, record: Dict[str, Any], *,
                 wall_s: float) -> str:
        """Publish the result, then release the lease.

        Release-safe: a recoverable exception between the done publish
        and the release (the satellite crash-window) still releases; a
        *failed* done publish (torn write, full disk) requeues the job
        immediately — with a backoff and a bumped attempt counter —
        instead of leaking the claim until lease expiry, and raises
        ``PublishError`` so the worker logs and moves on. An injected
        hard crash (``InjectedCrash``/SIGKILL) runs neither path: the
        lease is left for reclaim, which is exactly what it models."""
        try:
            dst = _publish(
                self._dir("done"), job.key,
                {"key": job.key, "record": record, "worker": job.worker,
                 "wall_s": wall_s, "t_done": self._now()},
                site="publish-done", salt=job.attempts)
        except Exception as e:
            self._requeue(job)
            if REGISTRY.enabled:
                REGISTRY.counter("spool.publish_errors",
                                 site="publish-done").inc()
            raise PublishError(f"done publish failed for "
                               f"{job.key[:12]}: {e}") from e
        try:
            faults.crash_point("after-publish", job.key, job.attempts)
        except Exception:
            self._release(job)         # release-safe crash window
            raise
        self._release(job)
        return dst

    def fail(self, job: SpoolJob, error: str) -> str:
        """Publish a failure diagnosis, then release. Same
        release-safety contract as ``complete``."""
        try:
            dst = _publish(
                self._dir("failed"), job.key,
                {"key": job.key, "error": error, "worker": job.worker,
                 "t_failed": self._now()},
                site="publish-fail", salt=job.attempts)
        except Exception as e:
            self._requeue(job)
            if REGISTRY.enabled:
                REGISTRY.counter("spool.publish_errors",
                                 site="publish-fail").inc()
            raise PublishError(f"failure publish failed for "
                               f"{job.key[:12]}: {e}") from e
        self._release(job)
        return dst

    def _release(self, job: SpoolJob) -> None:
        try:
            os.unlink(job.active_path)
        except FileNotFoundError:
            pass                       # reclaimed while we worked: the
            #                            done/failed file still wins

    def _requeue(self, job: SpoolJob) -> bool:
        """Return a claimed job to ``jobs/`` with a bumped attempt
        counter and a backoff window. Best-effort: if even the requeue
        publish fails, the lease is left in place for reclaim (the
        last-resort recovery path) and False is returned."""
        attempts = job.attempts + 1
        now = self._now()
        entry = {"key": job.key, "payload": job.payload,
                 "attempts": attempts}
        b = backoff_s(job.key, attempts, base_s=self.backoff_base_s,
                      cap_s=self.backoff_cap_s)
        if b > 0:
            entry["not_before"] = now + b
        try:
            _publish(self._dir("jobs"), job.key, entry, salt=attempts)
        except Exception:
            return False
        self._release(job)
        if REGISTRY.enabled:
            REGISTRY.counter("spool.jobs_requeued").inc()
        return True

    def _quarantine(self, key: str, *, worker: str, attempts: int,
                    now: float, error: Optional[str] = None) -> None:
        _publish(self._dir("failed"), key,
                 {"key": key, "worker": worker, "t_failed": now,
                  "attempts": attempts,
                  "error": error or
                  f"retry budget exhausted: {attempts} attempts from "
                  f"dead/failing workers (budget {self.retry_budget}); "
                  f"quarantined as a poison job"},
                 site="publish-fail", salt=attempts)
        if REGISTRY.enabled:
            REGISTRY.counter("spool.jobs_quarantined").inc()

    # -- janitor duties ---------------------------------------------------

    def reclaim(self, *, lease_s: Optional[float] = None,
                now: Optional[float] = None) -> int:
        """Return orphaned active jobs (stale heartbeat) to ``jobs/``.

        Each reclaim cycle increments the job's ``attempts`` counter
        and stamps a ``not_before`` backoff; a job past
        ``retry_budget`` reclaims is quarantined to ``failed/`` (poison
        job: it keeps killing its workers) instead of being requeued
        forever. Quarantined jobs count toward the return value (they
        were taken off a dead worker)."""
        lease = lease_s if lease_s is not None else self.lease_s
        now = now if now is not None else self._now()
        n = 0
        for fname in self._list("active"):
            p = os.path.join(self._dir("active"), fname)
            try:
                age = now - os.stat(p).st_mtime
            except FileNotFoundError:
                continue
            if age <= lease:
                continue
            # partition, not split: a stray active file without an "@"
            # (shared-directory operator artifact) must not abort the
            # whole reclaim pass — it falls through to the corrupt-file
            # quarantine below
            key, _, worker = fname[:-len(".json")].partition("@")
            if self.result(key) is not None:
                # finished but the worker died before releasing the claim
                try:
                    os.unlink(p)
                except FileNotFoundError:
                    pass
                continue
            try:
                with open(p) as f:
                    job_d = json.load(f)
                attempts = int(job_d.get("attempts", 0)) + 1
            except FileNotFoundError:
                continue               # released/reclaimed under us
            except (json.JSONDecodeError, KeyError, ValueError):
                self._quarantine(key, worker=worker, attempts=0, now=now,
                                 error="corrupt active file")
                try:
                    os.unlink(p)
                except FileNotFoundError:
                    pass
                n += 1
                continue
            if attempts > self.retry_budget:
                self._quarantine(key, worker=worker, attempts=attempts,
                                 now=now)
            else:
                # requeue with the bumped counter and a retry backoff:
                # publish-then-unlink so a crash in between leaves a
                # claimable job file, never a lost one (claim() drops
                # stale duplicates)
                entry = {**job_d, "key": key, "attempts": attempts}
                b = backoff_s(key, attempts, base_s=self.backoff_base_s,
                              cap_s=self.backoff_cap_s)
                if b > 0:
                    entry["not_before"] = now + b
                else:
                    entry.pop("not_before", None)
                _publish(self._dir("jobs"), key, entry, salt=attempts)
            try:
                os.unlink(p)
            except FileNotFoundError:
                pass
            n += 1
        if n and REGISTRY.enabled:
            REGISTRY.counter("spool.jobs_reclaimed").inc(n)
        return n


class SpoolBackend:
    """Refine through a ``Spool``, optionally spawning local workers.

    ``workers=N`` (N>=1) spawns N ``python -m repro.exec worker --drain``
    subprocesses that exit when the queue empties; ``workers=0`` relies
    entirely on externally attached workers (detached daemons, other
    hosts on a shared filesystem). Either way the backend polls for
    completion, reclaims dead jobs, and respawns local drain workers
    (up to ``respawns``, default ``max(workers, 1)``) if its fleet dies
    with jobs still pending.

    **Stall fail-fast**: when jobs are pending but no worker is making
    heartbeat progress — the local fleet is dead with no respawns left
    and no external worker ever attached — the backend raises a
    diagnosable ``BackendError`` naming the spool root after
    ``stall_s`` seconds (default ``max(2*lease_s, 30)``) instead of
    spinning until ``timeout_s`` (default: forever). ``stall_s=0``
    disables the check.

    ``allow_partial=True`` (threaded through ``Backend.refine``)
    degrades failed/quarantined jobs into ``refine_failed`` records
    instead of aborting the whole batch with ``BackendError``.
    """

    name = "spool"

    def __init__(self, root: str, *, workers: int = 1,
                 lease_s: float = DEFAULT_LEASE_S, poll_s: float = 0.2,
                 timeout_s: Optional[float] = None,
                 respawns: Optional[int] = None,
                 stall_s: Optional[float] = None):
        self.root = root
        self.workers = workers
        self.lease_s = lease_s
        self.poll_s = poll_s
        self.timeout_s = timeout_s
        self.respawns = respawns if respawns is not None \
            else max(workers, 1)
        self.stall_s = stall_s if stall_s is not None \
            else max(2.0 * lease_s, 30.0)

    def _spawn_worker(self) -> subprocess.Popen:
        import repro
        src = os.path.dirname(repro.__path__[0])
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        return subprocess.Popen(
            [sys.executable, "-m", "repro.exec", "worker", self.root],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)

    def _heartbeat_mtime(self, spool: Spool) -> float:
        """Newest active-file mtime — external workers show up here."""
        latest = 0.0
        d = spool._dir("active")
        try:
            names = os.listdir(d)
        except OSError:
            return latest
        for f in names:
            try:
                latest = max(latest, os.stat(os.path.join(d, f)).st_mtime)
            except OSError:
                pass
        return latest

    def refine(self, payloads: List[Dict[str, Any]], *,
               keys: Optional[List[str]] = None,
               journal: Optional[Any] = None,
               cache: Optional[Any] = None,
               progress: Progress = None,
               allow_partial: bool = False) -> List[Dict[str, Any]]:
        if keys is None:
            from ..sweep.cache import content_key
            keys = [content_key(p) for p in payloads]
        spool = Spool(self.root, lease_s=self.lease_s)

        submitted = 0
        for key, payload in zip(keys, payloads):
            if spool.result(key) is None:  # resume: keep surviving results
                submitted += spool.submit(key, payload)
        if progress:
            progress(f"spool {self.root}: {submitted} submitted, "
                     f"{len(keys) - submitted} already queued/finished")

        procs = [self._spawn_worker() for _ in range(self.workers)]
        respawns_left = self.respawns
        pending = set(keys)
        collected: Dict[str, Dict[str, Any]] = {}
        journaled: set = set()
        t0 = time.time()
        t_report = t0
        t_progress = t0
        progress_sig: Tuple[Any, ...] = ()
        try:
            while pending:
                # one listdir per state per tick; files are read only
                # for newly resolved keys
                for key in sorted(pending & spool.done_keys()):
                    res = spool.result(key)
                    if res is None:
                        continue       # torn listing race; next tick
                    pending.discard(key)
                    collected[key] = res["record"]
                    if cache is not None:
                        # write-through: durable even if this runner
                        # dies before the batch completes
                        _cache_put(cache, key, res["record"])
                    if journal is not None and key not in journaled:
                        # batch-job records expand to per-point events
                        _journal_done(journal, key,
                                      worker=res.get("worker"),
                                      wall_s=res.get("wall_s"),
                                      rec=res["record"])
                        journaled.add(key)
                for key in sorted(pending & spool.failed_keys()):
                    fail = spool.failure(key)
                    if fail is None:
                        continue
                    pending.discard(key)
                    if journal is not None and key not in journaled:
                        journal.point(key, "failed",
                                      worker=fail.get("worker"),
                                      error=fail.get("error"))
                        journaled.add(key)
                if not pending:
                    break
                reclaimed = spool.reclaim()
                if reclaimed and journal is not None:
                    journal.janitor(worker="runner", reclaimed=reclaimed)
                procs = [p for p in procs if p.poll() is None]
                if (not procs and self.workers > 0 and respawns_left > 0
                        and spool.counts()["jobs"] > 0):
                    # local fleet died with work pending (e.g. a reclaim
                    # landed after the drain workers exited)
                    procs.append(self._spawn_worker())
                    respawns_left -= 1
                now = time.time()
                # stall detection: any resolution, worker heartbeat, or
                # upcoming backoff retry counts as progress
                sig = (len(pending), self._heartbeat_mtime(spool),
                       reclaimed)
                if sig != progress_sig or procs:
                    progress_sig = sig
                    t_progress = now
                eta = spool.next_retry_eta()
                if (self.stall_s and not procs
                        and now - t_progress > self.stall_s
                        and (eta is None or eta > self.stall_s)):
                    counts = spool.counts()
                    raise BackendError(
                        f"spool backend stalled: {len(pending)} point(s) "
                        f"pending with no live workers and no heartbeat "
                        f"progress for {self.stall_s:.0f}s "
                        f"(spool root: {self.root}; counts: {counts}) — "
                        f"attach workers with `python -m repro.exec "
                        f"worker {self.root}` or start a janitor with "
                        f"`python -m repro.exec janitor {self.root}`")
                if progress and now - t_report > 2.0:
                    done = len(keys) - len(pending)
                    progress(f"spool: {done}/{len(keys)} done "
                             f"({len(procs)} local workers)")
                    t_report = now
                if (self.timeout_s is not None
                        and now - t0 > self.timeout_s):
                    raise BackendError(
                        f"spool backend timed out after {self.timeout_s}s "
                        f"with {len(pending)} points pending "
                        f"(spool: {self.root})")
                time.sleep(self.poll_s)
        finally:
            for p in procs:
                if p.poll() is None:
                    p.terminate()
            for p in procs:
                try:
                    p.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    p.kill()

        out: List[Dict[str, Any]] = []
        failures: List[str] = []
        for key in keys:
            rec = collected.get(key)
            if rec is None:
                fail = spool.failure(key) or {}
                err = fail.get("error", "?")
                if allow_partial:
                    out.append(failure_record(
                        err, worker=fail.get("worker", "spool")))
                    continue
                failures.append(f"{key[:12]}: {err}")
                continue
            out.append(rec)
        if failures:
            raise BackendError(
                f"{len(failures)} refinement(s) failed in spool "
                f"{self.root}: " + "; ".join(failures[:3]))
        return out
