"""Power characterization data (paper §5.1).

Per power node:  P_total = P_lkg + P_dyn
  P_lkg = P_lkg0 * LkgRatio_LUT(T, V) / LkgRatio_LUT(T0, V0)
  P_dyn = (Cdyn_idle + Cdyn_active * utilization) * F * V_adj^2,
  V_adj = f2v(F, T)                                  (characterized VF curve)

The paper extracts Cdyn/leakage from PrimePower runs on the backend
implementation; no silicon backend exists here, so the default set below is
an invented-but-self-consistent characterization for the v5e-like target
(sums to a ~200W chip at peak) — the *machinery* (LUTs, VF curves, fitting)
is the reproduction target, and ``fit_table``-style validation lives in
tests. All values are per *chip*; tile-level nodes divide by tile count.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Dict, Tuple


__all__ = ["LeakageLUT", "VFCurve", "PowerChar", "DEFAULT_CHARS",
           "NOMINAL_TEMP_C", "NOMINAL_FREQ_GHZ"]

NOMINAL_TEMP_C = 60.0
NOMINAL_FREQ_GHZ = 0.94


@dataclass(frozen=True)
class LeakageLUT:
    """Leakage ratio grid over (temp C, voltage V) — bilinear interp."""

    temps: Tuple[float, ...] = (25.0, 60.0, 85.0, 105.0)
    volts: Tuple[float, ...] = (0.6, 0.75, 0.9, 1.05)
    # ratios[i][j] at (temps[i], volts[j]); leakage grows ~exp in T and ~V^2
    ratios: Tuple[Tuple[float, ...], ...] = (
        (0.45, 0.62, 0.85, 1.15),
        (0.72, 1.00, 1.38, 1.86),
        (1.10, 1.52, 2.10, 2.84),
        (1.55, 2.15, 2.96, 4.00),
    )

    def lookup(self, temp: float, volt: float) -> float:
        ts, vs = self.temps, self.volts
        t = min(max(temp, ts[0]), ts[-1])
        v = min(max(volt, vs[0]), vs[-1])
        i = min(bisect.bisect_right(ts, t) - 1, len(ts) - 2)
        j = min(bisect.bisect_right(vs, v) - 1, len(vs) - 2)
        ft = (t - ts[i]) / (ts[i + 1] - ts[i])
        fv = (v - vs[j]) / (vs[j + 1] - vs[j])
        r = self.ratios
        return ((1 - ft) * (1 - fv) * r[i][j] + (1 - ft) * fv * r[i][j + 1]
                + ft * (1 - fv) * r[i + 1][j] + ft * fv * r[i + 1][j + 1])


@dataclass(frozen=True)
class VFCurve:
    """f2v: piecewise-linear minimum voltage vs frequency, + temp adder."""

    freqs_ghz: Tuple[float, ...] = (0.3, 0.5, 0.7, 0.94, 1.1, 1.25)
    volts: Tuple[float, ...] = (0.60, 0.65, 0.70, 0.75, 0.85, 0.95)
    temp_coeff_v_per_c: float = 0.0004   # hot silicon needs a little more V

    def f2v(self, freq_ghz: float, temp_c: float = NOMINAL_TEMP_C) -> float:
        fs, vs = self.freqs_ghz, self.volts
        f = min(max(freq_ghz, fs[0]), fs[-1])
        i = min(bisect.bisect_right(fs, f) - 1, len(fs) - 2)
        frac = (f - fs[i]) / (fs[i + 1] - fs[i])
        v = vs[i] + frac * (vs[i + 1] - vs[i])
        return v + self.temp_coeff_v_per_c * (temp_c - NOMINAL_TEMP_C)


@dataclass(frozen=True)
class PowerChar:
    """One module's characterization (PrimePower-extraction stand-in)."""

    p_lkg0_w: float            # leakage @ (T0, V0)
    c_dyn_idle_nf: float       # clock-tree etc., workload-independent
    c_dyn_active_nf: float     # at utilization=1 (synthetic max workload)
    lut: LeakageLUT = LeakageLUT()
    vf: VFCurve = VFCurve()

    def leakage_w(self, temp_c: float, volt: float) -> float:
        base = self.lut.lookup(NOMINAL_TEMP_C, self.vf.f2v(NOMINAL_FREQ_GHZ))
        return self.p_lkg0_w * self.lut.lookup(temp_c, volt) / base

    def dynamic_w(self, freq_ghz: float, utilization: float,
                  temp_c: float = NOMINAL_TEMP_C) -> float:
        v = self.vf.f2v(freq_ghz, temp_c)
        c_nf = self.c_dyn_idle_nf + self.c_dyn_active_nf * min(
            max(utilization, 0.0), 1.0)
        # P = C * F * V^2 ; nF * GHz = watts per V^2
        return c_nf * freq_ghz * v * v

    def total_w(self, freq_ghz: float, utilization: float,
                temp_c: float = NOMINAL_TEMP_C) -> float:
        v = self.vf.f2v(freq_ghz, temp_c)
        return self.leakage_w(temp_c, v) + self.dynamic_w(
            freq_ghz, utilization, temp_c)


# invented characterization: ~200W chip at peak, ~45W idle+leakage
# (per-chip; Power-EM divides tile-level nodes by n_tiles)
DEFAULT_CHARS: Dict[str, PowerChar] = {
    "mxu": PowerChar(p_lkg0_w=6.0, c_dyn_idle_nf=14.0, c_dyn_active_nf=160.0),
    "vpu": PowerChar(p_lkg0_w=2.0, c_dyn_idle_nf=5.0, c_dyn_active_nf=38.0),
    "vmem": PowerChar(p_lkg0_w=3.0, c_dyn_idle_nf=6.0, c_dyn_active_nf=30.0),
    "hbm": PowerChar(p_lkg0_w=4.0, c_dyn_idle_nf=8.0, c_dyn_active_nf=52.0),
    "dma": PowerChar(p_lkg0_w=0.8, c_dyn_idle_nf=1.5, c_dyn_active_nf=9.0),
    "noc": PowerChar(p_lkg0_w=0.7, c_dyn_idle_nf=1.5, c_dyn_active_nf=7.0),
    "ici": PowerChar(p_lkg0_w=1.5, c_dyn_idle_nf=3.0, c_dyn_active_nf=16.0),
    "top": PowerChar(p_lkg0_w=5.0, c_dyn_idle_nf=10.0, c_dyn_active_nf=12.0),
}
