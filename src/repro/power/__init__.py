"""Power-EM: joint performance/power analysis (paper §5)."""
from .characterization import DEFAULT_CHARS, LeakageLUT, PowerChar, VFCurve
from .dvfs import DvfsPoint, choose_operating_point, sweep
from .powerem import PowerEM, PowerNode, PowerReport, build_power_tree

__all__ = [
    "DEFAULT_CHARS",
    "DvfsPoint",
    "LeakageLUT",
    "PowerChar",
    "PowerEM",
    "PowerNode",
    "PowerReport",
    "VFCurve",
    "build_power_tree",
    "choose_operating_point",
    "sweep",
]
