"""Power-EM mode: joint performance/power analysis (paper §5).

A hierarchical **power-node tree** (from a config dict, the yaml analog) is
bonded to the performance models through the shared activity ``Tracer``:
each node names the tracer-module prefix it measures and its *maximum
activity* per Table 2 (DMA/NOC: max transfer BW; CB/DDR: max access bytes;
DPU/DSP: ideal op count). Per user-defined **power-trace interval (PTI)**,
utilization = measured / max activity, and

    P(node, pti) = P_lkg(T, V_adj) + (Cdyn_idle + Cdyn_active*util)*F*V_adj^2

with V_adj from the characterized VF curve. Peak/average power, per-module
transient profiles (Fig 8) and joint perf/power sweeps (Fig 9) all read
from this.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import numpy as np

from ..core import SampleArrays, Tracer
from ..core.trace import pti_bins
from ..hw.presets import HwConfig
from .characterization import DEFAULT_CHARS, NOMINAL_TEMP_C, PowerChar

__all__ = ["PowerNode", "build_power_tree", "PowerEM", "PowerReport",
           "analytic_power_w", "pod_power_w"]


@dataclass
class PowerNode:
    name: str
    char: PowerChar
    module_prefix: str            # tracer module prefix this node measures
    activity_kind: str            # "ops" | "bytes"
    max_rate_per_ns: float        # Table-2 maximum activity per ns
    scale: float = 1.0            # char fraction (tile-level split)
    children: List["PowerNode"] = field(default_factory=list)

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()


def build_power_tree(cfg: HwConfig, n_tiles: int = 1) -> PowerNode:
    """Chip power hierarchy bonded to the System's tracer module names.

    Characterization constants are the v5e-reference values; area-dependent
    nodes scale with the configured hardware size (MACs, lanes, capacities,
    BW) so down-skewed NPU configs draw NPU-scale power."""
    import dataclasses as _dc

    ref = HwConfig()  # v5e reference the DEFAULT_CHARS were sized for

    def sized(c: PowerChar, ratio: float) -> PowerChar:
        r = max(min(ratio, 4.0), 1e-3)
        return _dc.replace(c, p_lkg0_w=c.p_lkg0_w * r,
                           c_dyn_idle_nf=c.c_dyn_idle_nf * r,
                           c_dyn_active_nf=c.c_dyn_active_nf * r)

    ch = dict(DEFAULT_CHARS)
    ch["mxu"] = sized(ch["mxu"], n_tiles * cfg.macs / ref.macs)
    ch["vpu"] = sized(ch["vpu"], n_tiles * cfg.vpu_flops_per_cycle
                      / ref.vpu_flops_per_cycle)
    ch["vmem"] = sized(ch["vmem"], n_tiles * cfg.vmem_bytes / ref.vmem_bytes)
    ch["hbm"] = sized(ch["hbm"], cfg.hbm_gbps / ref.hbm_gbps)
    ch["dma"] = sized(ch["dma"], cfg.dma_channels / ref.dma_channels)
    ch["ici"] = sized(ch["ici"], cfg.ici_link_gbps / ref.ici_link_gbps)
    ch["noc"] = sized(ch["noc"], cfg.ici_link_gbps / ref.ici_link_gbps)
    tile_scale = 1.0 / n_tiles
    tiles = []
    for i in range(n_tiles):
        t = PowerNode(
            name=f"tile{i}", char=ch["top"], module_prefix=f"tile{i}",
            activity_kind="ops", max_rate_per_ns=1.0, scale=0.0,
            children=[
                PowerNode(f"tile{i}.mxu", ch["mxu"], f"tile{i}.mxu", "ops",
                          max_rate_per_ns=cfg.macs * cfg.clock_ghz,
                          scale=tile_scale),
                PowerNode(f"tile{i}.vpu", ch["vpu"], f"tile{i}.vpu", "ops",
                          max_rate_per_ns=cfg.vpu_flops_per_cycle
                          * cfg.clock_ghz, scale=tile_scale),
                PowerNode(f"tile{i}.vmem", ch["vmem"], f"tile{i}.vmem",
                          "bytes",
                          max_rate_per_ns=cfg.vmem_ports
                          * cfg.vmem_port_bytes_per_cycle * cfg.clock_ghz,
                          scale=tile_scale),
            ])
        tiles.append(t)
    root = PowerNode(
        name="chip", char=ch["top"], module_prefix="", activity_kind="ops",
        max_rate_per_ns=1.0, scale=1.0,
        children=tiles + [
            PowerNode("hbm", ch["hbm"], "hbm", "bytes",
                      max_rate_per_ns=cfg.hbm_gbps),
            PowerNode("dma", ch["dma"], "dma", "bytes",
                      max_rate_per_ns=cfg.hbm_gbps),
            PowerNode("noc", ch["noc"], "noc", "bytes",
                      max_rate_per_ns=cfg.ici_link_gbps * cfg.ici_links),
            PowerNode("ici", ch["ici"], "ici", "bytes",
                      max_rate_per_ns=cfg.ici_link_gbps * cfg.ici_links),
        ])
    return root


def analytic_power_w(cfg: HwConfig, util: Dict[str, float], *,
                     n_tiles: int = 1, freq_ghz: Optional[float] = None,
                     temp_c: float = NOMINAL_TEMP_C) -> float:
    """Whole-run average chip power from coarse per-module utilizations.

    The sweep pre-screen has no tracer — only the analytic scheduler's
    per-engine-class busy fractions. This walks the same characterized
    power tree as ``PowerEM`` but applies one flat utilization per module
    family (keys of ``util``: ``mxu``/``vpu``/``vmem``/``hbm``/``dma``/
    ``ici``/``noc``; missing keys default to 0). Used to rank grid points
    (Pareto energy axis); the event-engine refinement replaces it with the
    PTI-resolved number.
    """
    f = freq_ghz if freq_ghz is not None else cfg.clock_ghz
    tree = build_power_tree(cfg, n_tiles)
    total = 0.0
    for node in tree.walk():
        if node.scale <= 0.0 and node.children:
            continue
        family = node.name.rsplit(".", 1)[-1] if "." in node.name \
            else node.name
        u = util.get(family, 0.0) if node.name != "chip" else 1.0
        total += node.scale * node.char.total_w(f, u, temp_c)
    return total


def pod_power_w(cfg: HwConfig, util: Dict[str, float], *, chips: int,
                n_tiles: int = 1, freq_ghz: Optional[float] = None,
                temp_c: float = NOMINAL_TEMP_C) -> float:
    """Fleet-level average power for ``chips`` identical devices.

    Serving fleets and pod campaigns run symmetric SPMD programs: every
    chip executes the same per-device schedule, so one chip's analytic
    power under the shared utilization profile scales linearly to the
    whole fleet. (DCN switches and host machines are out of scope, as
    they are for the per-chip power tree.)
    """
    if chips < 1:
        raise ValueError(f"need chips >= 1, got {chips}")
    return chips * analytic_power_w(cfg, util, n_tiles=n_tiles,
                                    freq_ghz=freq_ghz, temp_c=temp_c)


@dataclass
class PowerReport:
    pti_ns: float
    t_end_ns: float
    series: Dict[str, List[float]]      # node -> watts per PTI
    util: Dict[str, List[float]]        # node -> utilization per PTI

    @property
    def total_series(self) -> List[float]:
        n = max((len(v) for v in self.series.values()), default=0)
        out = [0.0] * n
        for v in self.series.values():
            for i, x in enumerate(v):
                out[i] += x
        return out

    @property
    def avg_w(self) -> float:
        s = self.total_series
        return sum(s) / len(s) if s else 0.0

    @property
    def peak_w(self) -> float:
        return max(self.total_series, default=0.0)

    def energy_j(self) -> float:
        return self.avg_w * self.t_end_ns * 1e-9


class PowerEM:
    """Bond a power tree to a finished simulation's tracer and integrate."""

    def __init__(self, cfg: HwConfig, *, n_tiles: int = 1,
                 freq_ghz: Optional[float] = None,
                 temp_c: float = NOMINAL_TEMP_C,
                 tree: Optional[PowerNode] = None):
        self.cfg = cfg
        self.freq = freq_ghz if freq_ghz is not None else cfg.clock_ghz
        self.temp = temp_c
        self.tree = tree or build_power_tree(cfg, n_tiles)

    def analyze(self, tracer: Union[Tracer, SampleArrays], *,
                pti_ns: float = 10_000.0,
                t_end_ns: Optional[float] = None,
                power_gating: bool = False,
                gate_after_idle_ptis: int = 2,
                gate_residual: float = 0.3) -> PowerReport:
        """Per-PTI joint analysis, vectorized over interval arrays.

        Accepts either a live ``Tracer`` or its ``SampleArrays`` export
        (the form ``core.fastsim`` synthesizes). Activity is binned with
        one ``np.add.at`` per node and the affine per-node power curve is
        applied array-wise — arithmetic replicates the reference loop
        operation for operation, so records are byte-identical to the
        pre-vectorization implementation (locked by a test).

        ``power_gating`` implements the paper's §6.2 future work (active
        power-state management): a module idle for ``gate_after_idle_ptis``
        consecutive PTIs drops to a gated state — idle dynamic power off,
        leakage scaled by ``gate_residual`` (retention rails). Wake is
        charged one PTI of full idle power (state-transition cost); its
        sequential idle-run state keeps that path on the scalar loop.
        """
        sa = tracer if isinstance(tracer, SampleArrays) \
            else tracer.sample_arrays()
        horizon = t_end_ns if t_end_ns is not None else sa.makespan()
        series: Dict[str, List[float]] = {}
        util: Dict[str, List[float]] = {}
        for node in self.tree.walk():
            if node.scale <= 0.0 and node.children:
                continue  # pure grouping node
            acts = pti_bins(sa, sa.module_ids_with_prefix(node.module_prefix),
                            node.activity_kind, pti_ns, t_end=horizon)
            max_per_pti = node.max_rate_per_ns * pti_ns
            # frequency scaling moves compute capacity with F
            if node.activity_kind == "ops":
                max_per_pti *= self.freq / self.cfg.clock_ghz
            if max_per_pti > 0:
                u_arr = np.minimum(acts / max_per_pti, 1.0)
            else:
                u_arr = np.zeros_like(acts)
            us = u_arr.tolist()
            if power_gating:
                ws = []
                idle_run = 0
                gated = False
                for u in us:
                    if u <= 0.0:
                        idle_run += 1
                    else:
                        if gated:
                            idle_run = 0  # wake-up: full power this PTI
                        gated = False
                        idle_run = 0
                    if not gated and idle_run >= gate_after_idle_ptis:
                        gated = True
                    if gated and u <= 0.0:
                        v = node.char.vf.f2v(self.freq, self.temp)
                        ws.append(node.scale * gate_residual
                                  * node.char.leakage_w(self.temp, v))
                        continue
                    ws.append(node.scale * node.char.total_w(
                        self.freq, u, self.temp))
            else:
                # affine per-node power: same expression tree as
                # PowerChar.total_w, applied array-wise (bitwise-equal)
                ch = node.char
                v = ch.vf.f2v(self.freq, self.temp)
                leak = ch.leakage_w(self.temp, v)
                c_nf = ch.c_dyn_idle_nf + ch.c_dyn_active_nf * \
                    np.minimum(np.maximum(u_arr, 0.0), 1.0)
                ws = (node.scale * (leak + c_nf * self.freq * v * v)).tolist()
            series[node.name] = ws
            util[node.name] = us
        return PowerReport(pti_ns=pti_ns, t_end_ns=horizon, series=series,
                           util=util)
