"""DVFS sweeps + workload-aware policy search (paper §5.2, Fig 9).

``sweep`` re-simulates a workload at each frequency step (the event models
derive all timing from ``clock_ghz``), runs Power-EM at the matching
operating point (voltage from the VF curve), and returns the joint
perf/power table. ``choose_operating_point`` is the paper's punchline use
case: pick the lowest-energy frequency that still meets a minimum
performance requirement (battery-life-optimal DVFS policy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from ..graph.tasks import Task
from ..hw.chip import System
from ..hw.presets import HwConfig
from .characterization import NOMINAL_TEMP_C
from .powerem import PowerEM

__all__ = ["DvfsPoint", "sweep", "choose_operating_point"]


@dataclass
class DvfsPoint:
    freq_ghz: float
    volt: float
    time_ns: float
    inf_per_s: float
    avg_w: float
    peak_w: float
    energy_j: float
    inf_per_j: float

    @classmethod
    def from_record(cls, rec: dict) -> "DvfsPoint":
        """One refined sweep-campaign record (repro.sweep) -> DvfsPoint.
        The campaign's clock axis is the operating frequency."""
        return cls(freq_ghz=rec["overrides"]["clock_ghz"],
                   volt=rec["volt"], time_ns=rec["time_ns"],
                   inf_per_s=rec["inf_per_s"], avg_w=rec["avg_w"],
                   peak_w=rec["peak_w"], energy_j=rec["energy_j"],
                   inf_per_j=rec["inf_per_j"])


def sweep(task_builder: Callable[[HwConfig], Sequence[Task]],
          cfg: HwConfig, freqs_ghz: Sequence[float], *, n_tiles: int = 1,
          pti_ns: float = 10_000.0,
          temp_c: float = NOMINAL_TEMP_C) -> List[DvfsPoint]:
    """Joint perf/power at each frequency (task_builder re-tiles per cfg —
    block choices may legitimately change with clock)."""
    out: List[DvfsPoint] = []
    for f in freqs_ghz:
        cfg_f = cfg.replace(clock_ghz=f)
        tasks = task_builder(cfg_f)
        sysm = System(cfg_f, n_tiles=n_tiles)
        rep = sysm.run_workload(tasks)
        pem = PowerEM(cfg_f, n_tiles=n_tiles, freq_ghz=f, temp_c=temp_c)
        prep = pem.analyze(sysm.tracer, pti_ns=pti_ns)
        t = rep.makespan_ns
        e = prep.energy_j()
        out.append(DvfsPoint(
            freq_ghz=f,
            volt=pem.tree.char.vf.f2v(f, temp_c),
            time_ns=t,
            inf_per_s=1e9 / t if t > 0 else 0.0,
            avg_w=prep.avg_w,
            peak_w=prep.peak_w,
            energy_j=e,
            inf_per_j=(1.0 / e) if e > 0 else 0.0,
        ))
    return out


def choose_operating_point(points: Sequence[DvfsPoint],
                           min_inf_per_s: float) -> Optional[DvfsPoint]:
    """Lowest-energy point meeting the performance floor (DVFS policy)."""
    ok = [p for p in points if p.inf_per_s >= min_inf_per_s]
    if not ok:
        return None
    return min(ok, key=lambda p: p.energy_j)
