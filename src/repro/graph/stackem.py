"""Stack-EM mode (the paper's §6.2 future work, implemented).

"the addition of Stack-EM mode to analyze the performance impacts of
different layers of the software stack with multi-context use case based
scheduling pipeline"

A **context** is one inference stream (its own workload + submission
period + priority). Stack-EM submits several contexts to ONE System and
models the software-stack layers above the hardware scheduler:

  * per-context submission queues with arrival periods (use-case rate)
  * a stack-dispatch process that interleaves contexts into the hardware
    task FIFOs by priority (preemption boundary = task, as on real NPUs)
  * per-request end-to-end latency accounting (queueing + hardware), so
    stack-level effects — head-of-line blocking, priority inversion,
    context switch overhead — are visible separately from hardware time.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Sequence

from ..hw.chip import System
from ..hw.presets import HwConfig
from .tasks import Task

__all__ = ["StackContext", "StackReport", "run_stack"]

_ids = itertools.count()


@dataclass
class StackContext:
    name: str
    tasks: List[Task]                 # one inference's task list (template)
    period_ns: float                  # submission period (use-case rate)
    n_requests: int = 4
    priority: int = 1                 # lower = more important
    dispatch_overhead_ns: float = 2_000.0   # driver/runtime cost / request


@dataclass
class StackReport:
    latencies_ns: Dict[str, List[float]]
    hw_busy_ns: float
    makespan_ns: float

    def avg_latency_ms(self, ctx: str) -> float:
        ls = self.latencies_ns[ctx]
        return sum(ls) / len(ls) / 1e6 if ls else 0.0

    def p_worst_ms(self, ctx: str) -> float:
        return max(self.latencies_ns[ctx], default=0.0) / 1e6


def _clone_tasks(tasks: Sequence[Task], tag: str) -> List[Task]:
    """Re-instance a task-list template with fresh barrier ids."""
    mapping: Dict[int, int] = {}

    def remap(bid: int) -> int:
        if bid not in mapping:
            mapping[bid] = 1_000_000 + next(_ids)
        return mapping[bid]

    out = []
    for t in tasks:
        out.append(Task(
            engine=t.engine, payload=t.payload,
            waits=tuple((remap(b), n) for b, n in t.waits),
            signals=tuple(remap(b) for b in t.signals),
            name=f"{tag}.{t.name}"))
    return out


def run_stack(contexts: Sequence[StackContext], cfg: HwConfig, *,
              n_tiles: int = 1) -> StackReport:
    sysm = System(cfg, n_tiles=n_tiles)
    env = sysm.env
    latencies: Dict[str, List[float]] = {c.name: [] for c in contexts}

    def context_proc(ctx: StackContext):
        for r in range(ctx.n_requests):
            # arrival
            target = r * ctx.period_ns
            if env.now < target:
                yield env.timeout(target - env.now)
            t_submit = env.now
            yield env.timeout(ctx.dispatch_overhead_ns)  # stack layers
            tasks = _clone_tasks(ctx.tasks, f"{ctx.name}.r{r}")
            done = sysm.scheduler.run(tasks)
            yield done
            latencies[ctx.name].append(env.now - t_submit)

    # priority ordering: start high-priority contexts first (the shared
    # FIFO depth then arbitrates naturally; finer-grained preemption would
    # need per-engine priority queues — recorded as a limitation)
    for ctx in sorted(contexts, key=lambda c: c.priority):
        env.process(context_proc(ctx), name=f"stack.{ctx.name}")
    env.run()
    busy = sum(sysm.tracer.busy_time(m) for m in sysm.tracer.modules()
               if m.endswith(".mxu"))
    return StackReport(latencies_ns=latencies, hw_busy_ns=busy,
                       makespan_ns=sysm.tracer.makespan())
