"""The NN-compiler analog: op list -> tiled, barrier-synchronized task graph.

Mirrors the paper's processing-flow model (§3.3):

* operators are tiled across compute tiles (output rows split — "a
  computing task may contain a partial operator from tiling");
* weight tensors stream HBM->VMEM via tensor-aware DMA, **broadcast** to
  all tiles, optionally compressed (``_C`` variants);
* activations stay VMEM-resident while they fit (tracked against the tile
  VMEM budget); otherwise they spill/stream through HBM — this is what
  makes small-CB configs DDR-BW-bound (Fig 7);
* logical **barriers** express producer/consumer deps: compute of layer i
  waits on (weights-of-i arrived) and (all tiles finished layer i-1);
  weight DMA of layer i+1 is issued early (double buffering) so transfer
  overlaps compute exactly as in the DPU pipeline description;
* sparsity acceleration (``_S``) skips the sparse fraction of MACs.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from ..hw.dma import DmaDescriptor
from ..hw.ici import CollectiveSpec
from ..hw.mxu import GemmSpec
from ..hw.presets import HwConfig
from ..hw.vecunit import VecSpec
from .tasks import Task
from .workloads import Op

__all__ = ["CompileOptions", "compile_ops", "CompiledWorkload"]

# op kind -> hw.ici.CollectiveSpec op name
_COLLECTIVE_OPS = {"allreduce": "all-reduce", "alltoall": "all-to-all",
                   "allgather": "all-gather",
                   "reducescatter": "reduce-scatter",
                   "permute": "collective-permute"}


@dataclass
class CompileOptions:
    n_tiles: int = 1
    dtype_bytes: int = 1          # int8 inference (CNN); 2 for bf16 LM
    compression: bool = False     # "_C" variants
    sparsity: bool = False        # "_S" variants
    weight_prefetch: bool = True  # double-buffer next layer's weights
    resident_fraction: float = 0.5  # VMEM fraction usable for activations


@dataclass
class CompiledWorkload:
    tasks: List[Task]
    total_flops: float
    hbm_bytes: float
    n_barriers: int   # dense per-compile count: ids are exactly 0..n-1
    spilled_layers: int


def compile_ops(ops: Sequence[Op], cfg: HwConfig,
                opts: Optional[CompileOptions] = None) -> CompiledWorkload:
    """Compile an op list into a barrier-synchronized task graph.

    Barrier ids are **per-compile and dense from 0** (``n_barriers`` is
    the exact count), so array consumers (``core.fastsim``) can index
    barriers directly; ``graph.stackem`` re-instances templates with its
    own remapping, and every other caller runs one compile per System.
    """
    opts = opts or CompileOptions()
    _bid = itertools.count(0)
    nt = max(opts.n_tiles, 1)
    tasks: List[Task] = []
    hbm_addr = 0
    hbm_bytes = 0.0
    total_flops = 0.0
    spilled = 0
    # (barrier id, signal count) of the previous layer: nt for tiled
    # compute layers, 1 for single-task collective layers
    prev_barrier: Optional[Tuple[int, int]] = None
    budget = cfg.vmem_bytes * opts.resident_fraction

    def alloc(nbytes: float) -> int:
        nonlocal hbm_addr
        a = hbm_addr
        hbm_addr += int(nbytes) + 256
        return a

    for i, op in enumerate(ops):
        dtb = opts.dtype_bytes
        w_bytes = op.w_bytes * dtb
        in_bytes = op.in_bytes * dtb
        out_bytes = op.out_bytes * dtb
        total_flops += op.flops * (1.0 - (op.sparsity if opts.sparsity else 0))

        waits: List[Tuple[int, int]] = []
        if prev_barrier is not None:
            waits.append(prev_barrier)

        # collectives run on the ICI fabric: one per-device task, no
        # tiling, no weight traffic. allreduce = Megatron TP combine /
        # DP gradient sync; alltoall = MoE expert-parallel dispatch/
        # combine (ring phases and per-link bytes come from
        # hw.ici.CollectiveSpec); rings that leave the pod
        # (Op.cross_pod, set by the PodShape placement) are paced by
        # DCN instead of ICI
        if op.kind in _COLLECTIVE_OPS:
            done_b = next(_bid)
            tasks.append(Task(
                engine="ici",
                payload=CollectiveSpec(op=_COLLECTIVE_OPS[op.kind],
                                       payload_bytes=in_bytes,
                                       group_size=op.group,
                                       cross_pod=op.cross_pod,
                                       name=op.name),
                waits=tuple(waits), signals=(done_b,), name=op.name))
            prev_barrier = (done_b, 1)
            continue

        # weight DMA (broadcast to all tiles, optionally compressed)
        if w_bytes > 0:
            wb = next(_bid)
            tasks.append(Task(
                engine="dma",
                payload=DmaDescriptor(
                    nbytes=w_bytes, src="hbm", dst="vmem", addr=alloc(w_bytes),
                    contiguous_run=min(int(w_bytes), 1 << 20),
                    compressed=opts.compression, broadcast=nt,
                    name=f"{op.name}.w"),
                waits=(),  # prefetch: no dependency on previous layer
                signals=(wb,),
                name=f"dma.{op.name}.w"))
            hbm_bytes += w_bytes * (cfg.dma_compression_ratio
                                    if opts.compression else 1.0)
            waits.append((wb, 1))

        # activation residency: spill to HBM when the tile working set
        # exceeds the budget; ops flagged ``stream`` (KV-cache reads /
        # appends, which live in HBM across decode steps) always stream
        act_ws = (in_bytes + out_bytes) / nt
        streams = (act_ws + w_bytes) > budget or op.stream
        if streams:
            spilled += 1
            ab = next(_bid)
            tasks.append(Task(
                engine="dma",
                payload=DmaDescriptor(
                    nbytes=in_bytes / nt, src="hbm", dst="vmem",
                    addr=alloc(in_bytes),
                    contiguous_run=min(int(in_bytes / nt) or 1, 1 << 20),
                    compressed=opts.compression, name=f"{op.name}.act"),
                waits=tuple(waits),
                signals=(ab,),
                name=f"dma.{op.name}.act"))
            hbm_bytes += in_bytes * (cfg.dma_compression_ratio
                                     if opts.compression else 1.0)
            waits = [(ab, 1)]

        done_b = next(_bid)
        for t in range(nt):
            if op.kind in ("conv", "matmul"):
                m_tile = -(-op.m // nt)
                payload = GemmSpec(
                    m=min(m_tile, max(op.m - t * m_tile, 1)), n=op.n, k=op.k,
                    a_bytes_per_elem=dtb, b_bytes_per_elem=dtb,
                    out_bytes_per_elem=dtb,
                    name=f"{op.name}@t{t}")
                if opts.sparsity and op.sparsity > 0:
                    # sparsity acceleration: skip the sparse MAC fraction by
                    # shrinking the contraction dim the array actually walks
                    payload = GemmSpec(
                        m=payload.m, n=payload.n,
                        k=max(int(op.k * (1 - op.sparsity)), 1),
                        a_bytes_per_elem=dtb, b_bytes_per_elem=dtb,
                        out_bytes_per_elem=dtb, name=payload.name)
                engine = f"tile{t}.mxu"
            else:
                payload = VecSpec(
                    n_elems=op.elems / nt, kind=op.vec_kind,
                    bytes_in=in_bytes / nt, bytes_out=out_bytes / nt,
                    name=f"{op.name}@t{t}")
                engine = f"tile{t}.vpu"
            tasks.append(Task(engine=engine, payload=payload,
                              waits=tuple(waits), signals=(done_b,),
                              name=f"{op.name}@t{t}"))
        prev_barrier = (done_b, nt)

        if streams:
            tasks.append(Task(
                engine="dma",
                payload=DmaDescriptor(
                    nbytes=out_bytes, src="vmem", dst="hbm",
                    addr=alloc(out_bytes),
                    contiguous_run=min(int(out_bytes) or 1, 1 << 20),
                    compressed=opts.compression, name=f"{op.name}.out"),
                waits=((done_b, nt),),
                signals=(),
                name=f"dma.{op.name}.out"))
            hbm_bytes += out_bytes * (cfg.dma_compression_ratio
                                      if opts.compression else 1.0)

    n_barriers = next(_bid)
    used = {b for t in tasks for b in t.signals}
    used.update(b for t in tasks for b, _ in t.waits)
    assert used <= set(range(n_barriers)), \
        f"barrier ids not dense: {sorted(used)} vs n={n_barriers}"
    return CompiledWorkload(tasks=tasks, total_flops=total_flops,
                            hbm_bytes=hbm_bytes, n_barriers=n_barriers,
                            spilled_layers=spilled)
