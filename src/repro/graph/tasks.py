"""Tasks, barrier scoreboard and the centralized scheduler (paper §3.3).

* The unit of scheduling is a **Task**: a computing task (a partial operator
  from tiling, or multiple fused operators) or a DMA task (one or more
  descriptors). Tasks are factory-extensible records targeting one engine.
* A **centralized scheduler** parses the workload into a task list and
  enqueues tasks into bounded per-engine FIFOs *when there is room*
  (backpressure). Engines process asynchronously; completions are tracked
  in separate watcher processes.
* **Barrier scoreboard**: logical barriers with semaphore counters inserted
  by the compiler; engines wait on consumer barriers before executing and
  signal producer barriers after, forming atomic producer-consumer
  relationships.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Sequence, Tuple

from ..core import Environment, Event, Store, Tracer

__all__ = ["Task", "BarrierScoreboard", "Scheduler"]

_task_ids = itertools.count()


@dataclass
class Task:
    engine: str                 # e.g. "tile0.mxu", "dma", "ici"
    payload: Any                # GemmSpec | VecSpec | DmaDescriptor | ...
    waits: Tuple[Tuple[int, int], ...] = ()    # (barrier_id, required_count)
    signals: Tuple[int, ...] = ()
    name: str = ""
    tid: int = field(default_factory=lambda: next(_task_ids))


class BarrierScoreboard:
    """Semaphore-counter barriers with globally observable events."""

    def __init__(self, env: Environment):
        self.env = env
        self._count: Dict[int, int] = {}
        self._waiters: Dict[Tuple[int, int], Event] = {}

    def count(self, bid: int) -> int:
        return self._count.get(bid, 0)

    def signal(self, bid: int, n: int = 1) -> None:
        c = self._count.get(bid, 0) + n
        self._count[bid] = c
        for (wb, need), ev in list(self._waiters.items()):
            if wb == bid and c >= need and not ev.triggered:
                ev.succeed(c)
                del self._waiters[(wb, need)]

    def wait(self, bid: int, need: int = 1) -> Event:
        ev = self.env.event()
        if self._count.get(bid, 0) >= need:
            ev.succeed(self._count[bid])
            return ev
        key = (bid, need)
        # coalesce identical waits onto one event via chaining
        if key in self._waiters:
            base = self._waiters[key]
            base.callbacks.append(lambda e: ev.succeed(e._value))
            return ev
        self._waiters[key] = ev
        return ev


class Scheduler:
    """Centralized scheduler: task list -> per-engine FIFOs + completion
    tracking. ``run`` returns the completion event for the whole list."""

    def __init__(self, env: Environment, tracer: Tracer,
                 fifos: Dict[str, Store], scoreboard: BarrierScoreboard):
        self.env = env
        self.tracer = tracer
        self.fifos = fifos
        self.scoreboard = scoreboard
        self.n_done = 0
        self.n_total = 0

    def run(self, tasks: Sequence[Task]) -> Event:
        done = self.env.event()
        self.n_total += len(tasks)
        state = {"left": len(tasks)}
        if not tasks:
            done.succeed()
            return done

        def feeder():
            for t in tasks:
                if t.engine not in self.fifos:
                    raise KeyError(
                        f"task {t.name or t.tid} targets unknown engine "
                        f"{t.engine!r}; have {sorted(self.fifos)}")
                t._enqueue_time = self.env.now
                yield self.fifos[t.engine].put(t)   # blocks when FIFO full

        def watcher(t: Task):
            yield t._done_event
            self.n_done += 1
            state["left"] -= 1
            if state["left"] == 0:
                done.succeed()

        for t in tasks:
            t._done_event = self.env.event()
            self.env.process(watcher(t), name=f"watch.{t.tid}")
        self.env.process(feeder(), name="scheduler.feeder")
        return done
