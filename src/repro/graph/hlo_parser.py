"""Post-optimization HLO parser: the workload-ingestion front door of TPU-EM.

The paper's VPU-EM "interfaces directly with AI frameworks ... linking
in-house NPU graph compilers". Our compiler is XLA/GSPMD: this module parses
``compiled.as_text()`` (the scheduled, SPMD-partitioned, per-device HLO) and
produces:

  * trip-count-aware aggregate cost: dot/conv FLOPs, vector-unit element ops,
    an HBM-traffic estimate (fusion-level read+write), per-collective payload
    bytes with decoded replica groups (incl. iota format) and cross-pod
    detection — the three roofline terms come straight from this;
  * a dependency-carrying task list (``extract_tasks``) in scheduled order,
    which the event-driven simulator replays through the hardware models.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits a while
body ONCE — scanned-layer models under-count by the layer count. This parser
multiplies while bodies by their parsed trip counts (constant in the loop
condition), validated against cost_analysis on unrolled modules in tests.
"""
from __future__ import annotations

import re
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["parse_module", "summarize", "HloModule", "HloComputation",
           "HloInstr", "Collective", "Summary", "TaskSpec",
           "extract_tasks"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "all-reduce-start", "all-gather-start",
                  "collective-permute-start")

TRIVIAL_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-done",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
    "opt-barrier",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# dtypes we have already warned about (warn ONCE per dtype token, not per
# shape): a missing DTYPE_BYTES entry silently zeroes every byte estimate
# that touches the shape, which ingestion would propagate into HBM/payload
# totals — make the gap loud without flooding the log
_WARNED_DTYPES: set = set()


def _shapes_of(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            if dt not in _WARNED_DTYPES:
                _WARNED_DTYPES.add(dt)
                warnings.warn(
                    f"hlo_parser: unknown dtype {dt!r} in {type_str!r}; "
                    f"its shapes are dropped from every byte/element "
                    f"estimate — add it to DTYPE_BYTES",
                    stacklevel=2)
            continue
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append((dt, dims))
    return out


def _bytes_of(shapes: Sequence[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def _elems_of(shapes: Sequence[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class HloInstr:
    name: str
    opcode: str
    out_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str
    root: bool = False
    raw_operands: str = ""   # literal payload (constants carry values here)


@dataclass
class HloComputation:
    name: str
    instrs: List[HloInstr] = field(default_factory=list)
    table: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = field(
        default_factory=dict)


@dataclass
class HloModule:
    name: str
    computations: Dict[str, HloComputation]
    entry: str


_COMP_HEAD = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->\s*(.+)\s*\{\s*$")
_INSTR = re.compile(r"^\s+(ROOT\s+)?%?([\w\.\-]+)\s+=\s+(.*)$")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def _split_rhs(rhs: str) -> Tuple[str, str, str, str]:
    """rhs = 'TYPE opcode(operands), attrs' -> (type, opcode, operands, attrs)."""
    i = 0
    if rhs.startswith("("):  # tuple type: balanced parens
        depth = 0
        for j, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    i = j + 1
                    break
        type_str = rhs[:i]
    else:
        i = rhs.index(" ")
        type_str = rhs[:i]
        # layout suffix like {1,0} belongs to the type
        rest = rhs[i:].lstrip()
        while rest.startswith("{"):
            j = rest.index("}")
            type_str += rest[: j + 1]
            rest = rest[j + 1:].lstrip()
            i = rhs.index(rest, i) if rest else len(rhs)
        if not rest:
            return type_str, "", "", ""
        rhs = rhs[: rhs.rindex(rest)] + rest  # normalize (no-op)
        i = rhs.rindex(rest)
    rest = rhs[i:].strip()
    p = rest.find("(")
    if p < 0:
        return type_str, rest, "", ""
    opcode = rest[:p].strip()
    depth = 0
    for j in range(p, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                return type_str, opcode, rest[p + 1: j], rest[j + 1:]
    return type_str, opcode, rest[p + 1:], ""


def parse_module(text: str) -> HloModule:
    lines = text.split("\n")
    mod_name = "module"
    if lines and lines[0].startswith("HloModule"):
        mod_name = lines[0].split(",")[0].split()[1]
    comps: Dict[str, HloComputation] = {}
    entry = ""
    cur: Optional[HloComputation] = None
    for line in lines:
        if cur is None:
            m = _COMP_HEAD.match(line)
            if m:
                cur = HloComputation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
                # header params: "name: type, name: type"
                params = m.group(3)
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\)|[^,])+)",
                                      params):
                    cur.table[pm.group(1)] = _shapes_of(pm.group(2))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        root, name, rhs = bool(m.group(1)), m.group(2), m.group(3)
        type_str, opcode, operands_str, attrs = _split_rhs(rhs)
        # strip metadata tail (big) but keep functional attrs
        operands = []
        # top-level comma split of operands
        depth = 0
        start = 0
        for j, ch in enumerate(operands_str):
            if ch in "({[":
                depth += 1
            elif ch in ")}]":
                depth -= 1
            elif ch == "," and depth == 0:
                operands.append(operands_str[start:j])
                start = j + 1
        if operands_str.strip():
            operands.append(operands_str[start:])
        names = []
        for op in operands:
            mm = list(_OPERAND_NAME.finditer(op))
            if mm:
                names.append(mm[-1].group(1))
        instr = HloInstr(name, opcode, _shapes_of(type_str), names, attrs,
                         root, raw_operands=operands_str)
        cur.instrs.append(instr)
        cur.table[name] = instr.out_shapes
    if cur is not None:
        comps[cur.name] = cur
    if not entry and comps:
        entry = list(comps)[-1]
    return HloModule(mod_name, comps, entry)


# ---------------------------------------------------------------------------
# replica-group decoding
# ---------------------------------------------------------------------------

_IOTA_RG = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_EXPL_RG = re.compile(r"replica_groups=\{\{([\d,{}\s]*)\}\}")


def decode_replica_groups(attrs: str) -> Optional[np.ndarray]:
    m = _IOTA_RG.search(attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        reshape = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(reshape)))
        ids = ids.reshape(reshape)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(g, s)
    m = _EXPL_RG.search(attrs)
    if m:
        groups = [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in m.group(1).split("},{")
        ]
        width = max(len(g) for g in groups)
        return np.array([g + [-1] * (width - len(g)) for g in groups])
    return None


def _collective_io(op: str, out_b: float, opnd_b: float
                   ) -> Tuple[float, float]:
    """``(payload_bytes, out_bytes)`` of one collective instruction.

    Async ``*-start`` ops type their output as a tuple carrying BOTH the
    operand aliases and the result buffers (the ``-done`` peels the
    result off), so the raw output-byte count double-counts the payload
    — an ``all-reduce-start`` over N bytes parses as a 2N-byte output.
    Subtract the operand bytes to recover the result size; a backend
    that types ``-start`` as a bare array (no operand alias in the
    tuple) yields ``out_eff == 0`` and the operand size wins the max,
    which is the same payload the sync op would report.
    """
    out_eff = max(out_b - opnd_b, 0.0) if op.endswith("-start") else out_b
    return max(out_eff, opnd_b), out_eff


# ---------------------------------------------------------------------------
# cost aggregation
# ---------------------------------------------------------------------------

@dataclass
class Collective:
    op: str
    payload_bytes: int
    group_size: int
    n_groups: int
    count: float          # trip-scaled occurrence count
    crosses_pod: bool
    name: str = ""

    @property
    def total_bytes(self) -> float:
        return self.payload_bytes * self.count


@dataclass
class Summary:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    vector_elems: float = 0.0
    hbm_bytes: float = 0.0
    collectives: List[Collective] = field(default_factory=list)
    op_counts: Dict[str, float] = field(default_factory=dict)
    unparsed_while: int = 0

    @property
    def flops(self) -> float:
        return self.dot_flops + self.conv_flops

    def collective_bytes(self, *, cross_pod: Optional[bool] = None) -> float:
        return sum(c.total_bytes for c in self.collectives
                   if cross_pod is None or c.crosses_pod == cross_pod)

    def link_bytes(self, *, cross_pod: Optional[bool] = None) -> float:
        """Per-device link traffic under ring schedules:
        all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n, all-to-all
        (n-1)/n, permute 1x."""
        total = 0.0
        for c in self.collectives:
            if cross_pod is not None and c.crosses_pod != cross_pod:
                continue
            n = max(c.group_size, 1)
            if n == 1:
                continue
            if c.op.startswith("all-reduce"):
                f = 2 * (n - 1) / n
            elif c.op.startswith("collective-permute"):
                f = 1.0
            else:
                f = (n - 1) / n
            total += c.payload_bytes * c.count * f
        return total


class _Analyzer:
    def __init__(self, mod: HloModule, pod_size: int = 0,
                 free_converts: bool = True):
        self.mod = mod
        self.pod_size = pod_size
        self.free_converts = free_converts
        self.memo: Dict[Tuple[str, bool], Summary] = {}
        self.raw_trips: Dict[str, int] = {}

    # -- helpers -----------------------------------------------------------
    def _called(self, attrs: str, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w\.\-]+)", attrs)
        return m.group(1) if m else None

    def _io_bytes(self, comp: HloComputation, ins: HloInstr) -> Tuple[float, float]:
        """(read, write) HBM-traffic estimate with slice/in-place semantics:
        a (dynamic-)slice/gather reads only the slice it produces; a
        dynamic-update-slice (incl. DUS-rooted fusions — XLA aliases these
        in place) reads/writes only the update region, not the buffer."""
        out_b = _bytes_of(ins.out_shapes)
        op = ins.opcode
        if op in ("dynamic-slice", "slice", "gather"):
            return float(out_b), float(out_b)
        if op == "dynamic-update-slice":
            upd = _bytes_of(comp.table.get(ins.operands[1], [])) \
                if len(ins.operands) > 1 else out_b
            return float(upd), float(upd)
        opnd_b = sum(_bytes_of(comp.table.get(o, [])) for o in ins.operands)
        if op in ("fusion", "call"):
            called = self._called(ins.attrs, "calls") or \
                self._called(ins.attrs, "to_apply")
            sub = self.mod.computations.get(called) if called else None
            if sub is not None:
                # refine reads: (dynamic-)slices/gathers of fusion params
                # read only their slice; resolve through trivial unary
                # chains (bitcast/reshape/copy/convert) back to the param
                alias: Dict[str, str] = {}
                for si in sub.instrs:
                    if si.opcode in ("bitcast", "reshape", "copy",
                                     "convert") and len(si.operands) == 1:
                        src = si.operands[0]
                        alias[si.name] = alias.get(src, src)
                sliced_params = {}
                for si in sub.instrs:
                    if si.opcode in ("dynamic-slice", "slice", "gather") and \
                            si.operands:
                        src = si.operands[0]
                        src = alias.get(src, src)
                        if src in sliced_params:
                            sliced_params[src] += _bytes_of(si.out_shapes)
                        else:
                            sliced_params[src] = _bytes_of(si.out_shapes)
                # map positional params (parameter(N) carries N) to operands
                param_names: Dict[int, str] = {}
                for si in sub.instrs:
                    if si.opcode == "parameter":
                        m = re.match(r"\s*(\d+)\s*$", si.raw_operands)
                        if m:
                            param_names[int(m.group(1))] = si.name
                reads = 0.0
                for idx, oname in enumerate(ins.operands):
                    pname = param_names.get(idx)
                    full = _bytes_of(comp.table.get(oname, []))
                    if pname is not None and pname in sliced_params:
                        reads += min(full, sliced_params[pname])
                    else:
                        reads += full
                # refine writes: a fusion containing a dynamic-update-slice
                # whose buffer is a same-sized fusion operand is an in-place
                # update (XLA aliases it): traffic = update region only,
                # and the buffer operand is not actually read in full
                dus_updates = 0
                for si in sub.instrs:
                    if si.opcode == "dynamic-update-slice" and \
                            len(si.operands) > 1:
                        dus_updates += _bytes_of(
                            sub.table.get(si.operands[1], []))
                if dus_updates and any(
                        _bytes_of(comp.table.get(o, [])) == out_b
                        for o in ins.operands):
                    reads = max(reads - out_b, 0.0) + float(dus_updates)
                    return reads, float(dus_updates)
                return reads, float(out_b)
        return float(opnd_b), float(out_b)

    def _trip(self, cond_name: str, body_name: str) -> int:
        cond = self.mod.computations.get(cond_name)
        if cond is None:
            return 1
        # find scalar s32 constants in the condition computation; a jax scan
        # lowers to `i = 0; while (i < L)`, so the compare constant is L
        consts = []
        for ins in cond.instrs:
            if ins.opcode == "constant" and ins.out_shapes and \
                    ins.out_shapes[0][1] == ():
                m = re.match(r"\s*(\d+)\s*$", ins.raw_operands)
                if m:
                    consts.append(int(m.group(1)))
        if consts:
            return max(consts)
        return 1

    def _fusion_gemm(self, called: Optional[str], depth: int = 0
                     ) -> Optional[Tuple[int, int, int, float]]:
        """Dominant inner dot/convolution geometry of a fusion/call
        computation: ``(m, n, k, flops)`` of the highest-FLOP
        contraction found (recursing through nested fusions), or None
        when the computation contains no contraction."""
        comp = self.mod.computations.get(called) if called else None
        if comp is None or depth > 4:
            return None
        best: Optional[Tuple[int, int, int, float]] = None
        for ins in comp.instrs:
            g = None
            if ins.opcode == "dot":
                g = _dot_mnk(comp, ins)
            elif ins.opcode == "convolution":
                g = _conv_mnk(comp, ins)
            elif ins.opcode in ("fusion", "call"):
                sub = self._called(ins.attrs, "calls") or \
                    self._called(ins.attrs, "to_apply")
                gf = self._fusion_gemm(sub, depth + 1)
                if gf and (best is None or gf[3] > best[3]):
                    best = gf
                continue
            if g is not None:
                cand = (*g, 2.0 * g[0] * g[1] * g[2])
                if best is None or cand[3] > best[3]:
                    best = cand
        return best

    def _dot_flops(self, comp: HloComputation, ins: HloInstr) -> float:
        out_elems = _elems_of(ins.out_shapes)
        lhs = comp.table.get(ins.operands[0]) if ins.operands else None
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        k = 1
        if lhs and m and m.group(1):
            dims = lhs[0][1]
            for c in m.group(1).split(","):
                ci = int(c)
                if ci < len(dims):
                    k *= dims[ci]
        return 2.0 * out_elems * k

    def _conv_flops(self, comp: HloComputation, ins: HloInstr) -> float:
        out_elems = _elems_of(ins.out_shapes)
        rhs = comp.table.get(ins.operands[1]) if len(ins.operands) > 1 else None
        if not rhs:
            return 2.0 * out_elems
        kernel_elems = 1
        for d in rhs[0][1]:
            kernel_elems *= d
        # divide by output-feature dim (approx: largest dim of kernel)
        of = max(rhs[0][1]) if rhs[0][1] else 1
        return 2.0 * out_elems * max(kernel_elems // max(of, 1), 1)

    # -- main recursion ------------------------------------------------------
    def analyze(self, comp_name: str, in_fusion: bool = False) -> Summary:
        key = (comp_name, in_fusion)
        if key in self.memo:
            return self.memo[key]
        comp = self.mod.computations.get(comp_name)
        s = Summary()
        if comp is None:
            self.memo[key] = s
            return s
        # placeholder to break recursion cycles (shouldn't occur in HLO)
        self.memo[key] = s
        for ins in comp.instrs:
            op = ins.opcode
            s.op_counts[op] = s.op_counts.get(op, 0) + 1
            if op in TRIVIAL_OPS:
                continue
            if op == "convert" and self.free_converts:
                # TPU semantics: dtype conversion is fused into the
                # producer/consumer (MXU output stage / VPU op) — the
                # CPU backend's materialized f32<->bf16 round-trips would
                # not exist in the target's program
                continue
            out_b = _bytes_of(ins.out_shapes)
            opnd_b = sum(
                _bytes_of(comp.table.get(o, [])) for o in ins.operands)
            rd, wr = self._io_bytes(comp, ins)
            io_b = rd + wr
            if op == "while":
                cond = self._called(ins.attrs, "condition")
                body = self._called(ins.attrs, "body")
                trip = self._trip(cond, body) if cond else 1
                if trip <= 0:
                    trip = 1
                    s.unparsed_while += 1
                for sub_name in (body, cond):
                    if not sub_name:
                        continue
                    sub = self.analyze(sub_name, in_fusion)
                    _accumulate(s, sub, trip)
                continue
            if op == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}",
                                     ins.attrs)
                best = None
                if branches:
                    for b in branches.group(1).split(","):
                        sub = self.analyze(b.strip().lstrip("%"), in_fusion)
                        if best is None or sub.flops > best.flops:
                            best = sub
                # true/false computations (binary conditional)
                for keyname in ("true_computation", "false_computation"):
                    cn = self._called(ins.attrs, keyname)
                    if cn:
                        sub = self.analyze(cn, in_fusion)
                        if best is None or sub.flops > best.flops:
                            best = sub
                if best:
                    _accumulate(s, best, 1.0)
                if not in_fusion:
                    s.hbm_bytes += io_b
                continue
            if op == "fusion" or op == "call":
                called = self._called(ins.attrs, "calls") or \
                    self._called(ins.attrs, "to_apply")
                if called:
                    sub = self.analyze(called, True)
                    s.dot_flops += sub.dot_flops
                    s.conv_flops += sub.conv_flops
                    s.vector_elems += sub.vector_elems
                    # collectives can't be fused; ignore sub.hbm (fused)
                if not in_fusion:
                    s.hbm_bytes += io_b
                continue
            if any(op.startswith(c) for c in
                   ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")):
                if op.endswith("-done"):
                    continue
                groups = decode_replica_groups(ins.attrs)
                gsize = int(groups.shape[1]) if groups is not None else 1
                ngroups = int(groups.shape[0]) if groups is not None else 1
                crosses = False
                if groups is not None and self.pod_size:
                    pods = groups // self.pod_size
                    crosses = bool(np.any(pods.max(axis=1) != pods.min(axis=1)))
                payload, out_eff = _collective_io(op, out_b, opnd_b)
                s.collectives.append(Collective(
                    op=op.replace("-start", ""), payload_bytes=payload,
                    group_size=gsize, n_groups=ngroups, count=1.0,
                    crosses_pod=crosses, name=ins.name))
                if not in_fusion:
                    s.hbm_bytes += opnd_b + out_eff
                continue
            if op == "dot":
                s.dot_flops += self._dot_flops(comp, ins)
                if not in_fusion:
                    s.hbm_bytes += io_b
                continue
            if op == "convolution":
                s.conv_flops += self._conv_flops(comp, ins)
                if not in_fusion:
                    s.hbm_bytes += io_b
                continue
            if op == "dynamic-update-slice":
                # in-place: vector work = the update region, not the buffer
                upd = _elems_of(comp.table.get(ins.operands[1], [])) \
                    if len(ins.operands) > 1 else _elems_of(ins.out_shapes)
                s.vector_elems += max(upd, 1)
                if not in_fusion:
                    s.hbm_bytes += io_b
                continue
            if op in ("reduce", "reduce-window", "sort", "scatter", "gather",
                      "select-and-scatter", "dynamic-slice",
                      "pad", "concatenate", "slice",
                      "broadcast", "transpose", "reshape", "convert", "copy",
                      "select", "compare", "add", "subtract", "multiply",
                      "divide", "exponential", "tanh", "rsqrt", "sqrt",
                      "maximum", "minimum", "log", "custom-call",
                      "rng-bit-generator", "reverse", "clamp", "map",
                      "reduce-precision", "copy-start"):
                s.vector_elems += max(_elems_of(ins.out_shapes), 1)
                if not in_fusion:
                    s.hbm_bytes += io_b
                continue
            # default: treat as vector work
            s.vector_elems += max(_elems_of(ins.out_shapes), 1)
            if not in_fusion:
                s.hbm_bytes += io_b
        self.memo[key] = s
        return s


def _accumulate(dst: Summary, src: Summary, factor: float):
    dst.dot_flops += src.dot_flops * factor
    dst.conv_flops += src.conv_flops * factor
    dst.vector_elems += src.vector_elems * factor
    dst.hbm_bytes += src.hbm_bytes * factor
    dst.unparsed_while += src.unparsed_while
    for c in src.collectives:
        dst.collectives.append(Collective(
            op=c.op, payload_bytes=c.payload_bytes, group_size=c.group_size,
            n_groups=c.n_groups, count=c.count * factor,
            crosses_pod=c.crosses_pod, name=c.name))
    for k, v in src.op_counts.items():
        dst.op_counts[k] = dst.op_counts.get(k, 0) + v * factor


def summarize(text: str, *, pod_size: int = 0,
              free_converts: bool = True) -> Summary:
    """Full-module trip-count-aware cost summary (per device).

    ``free_converts`` (default) applies TPU semantics to dtype converts —
    the CPU backend materializes f32<->bf16 round-trips around dots that
    the TPU target fuses away; counting them would distort the memory and
    vector terms of bf16 programs (recorded in EXPERIMENTS.md)."""
    mod = parse_module(text)
    return _Analyzer(mod, pod_size=pod_size,
                     free_converts=free_converts).analyze(mod.entry)


# ---------------------------------------------------------------------------
# task extraction for the event simulator
# ---------------------------------------------------------------------------

def _dot_mnk(comp: HloComputation, ins: HloInstr
             ) -> Optional[Tuple[int, int, int]]:
    """GEMM view of a dot: k = product of lhs contracting dims, n = the
    trailing output dim (rhs non-contracting), m = out_elems / n (batch
    dims fold into m — a batched GEMM walks the array batch-by-batch)."""
    if not ins.out_shapes:
        return None
    out_elems = _elems_of(ins.out_shapes)
    lhs = comp.table.get(ins.operands[0]) if ins.operands else None
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    k = 1
    if lhs and m and m.group(1):
        dims = lhs[0][1]
        for c in m.group(1).split(","):
            ci = int(c)
            if ci < len(dims):
                k *= dims[ci]
    dims = ins.out_shapes[0][1]
    n = dims[-1] if dims else 1
    return (max(out_elems // max(n, 1), 1), max(int(n), 1), max(int(k), 1))


def _conv_mnk(comp: HloComputation, ins: HloInstr
              ) -> Optional[Tuple[int, int, int]]:
    """im2col GEMM view of a convolution, consistent with
    ``_Analyzer._conv_flops``: n = output features (approx: the largest
    kernel dim), k = kernel elems per output feature."""
    out_elems = _elems_of(ins.out_shapes)
    rhs = comp.table.get(ins.operands[1]) if len(ins.operands) > 1 else None
    if not rhs or not rhs[0][1]:
        return (max(int(out_elems), 1), 1, 1)
    ke = 1
    for d in rhs[0][1]:
        ke *= d
    of = max(rhs[0][1])
    return (max(out_elems // max(of, 1), 1), max(int(of), 1),
            max(ke // max(of, 1), 1))


@dataclass
class TaskSpec:
    """One schedulable unit for TPU-EM (engine-mapped HLO instruction)."""

    name: str
    engine: str            # "mxu" | "vector" | "dma" | "ici"
    flops: float = 0.0
    elems: float = 0.0
    bytes_in: float = 0.0
    bytes_out: float = 0.0
    collective: Optional[Collective] = None
    deps: Tuple[int, ...] = ()
    # GEMM view of the dominant contraction for "mxu" tasks (m, n, k);
    # None when the engine is not mxu or no dot/conv was found. For
    # fusions this is the geometry of the highest-FLOP inner dot — the
    # task's total ``flops`` may exceed 2*m*n*k when several dots fused.
    gemm: Optional[Tuple[int, int, int]] = None


def extract_tasks(text: str, *, pod_size: int = 0,
                  max_tasks: int = 2_000_000,
                  free_converts: bool = True) -> List[TaskSpec]:
    """Flatten the entry computation (expanding while loops by trip count)
    into an engine-mapped task DAG in scheduled order."""
    mod = parse_module(text)
    an = _Analyzer(mod, pod_size=pod_size, free_converts=free_converts)
    tasks: List[TaskSpec] = []

    def emit(comp_name: str, prefix: str, entry_deps: Tuple[int, ...]):
        comp = mod.computations.get(comp_name)
        if comp is None:
            return entry_deps
        local: Dict[str, int] = {}
        last: Tuple[int, ...] = entry_deps
        for ins in comp.instrs:
            if len(tasks) >= max_tasks:
                return last
            op = ins.opcode
            if op in TRIVIAL_OPS:
                continue
            if op == "convert" and free_converts:
                # alias through: consumers depend on the convert's operand
                src = ins.operands[0] if ins.operands else None
                if src in local:
                    local[ins.name] = local[src]
                continue
            deps = tuple(sorted({local[o] for o in ins.operands
                                 if o in local})) or entry_deps
            if op == "while":
                cond = an._called(ins.attrs, "condition")
                body = an._called(ins.attrs, "body")
                trip = an._trip(cond, body) if cond else 1
                carry = deps
                for it in range(max(trip, 1)):
                    carry = emit(body, f"{prefix}{ins.name}[{it}].", carry)
                    if len(tasks) >= max_tasks:
                        break
                if carry:
                    local[ins.name] = carry[-1]
                continue
            out_b = _bytes_of(ins.out_shapes)
            opnd_b = sum(_bytes_of(comp.table.get(o, []))
                         for o in ins.operands)
            rd, wr = an._io_bytes(comp, ins)
            if any(op.startswith(c) for c in
                   ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")):
                groups = decode_replica_groups(ins.attrs)
                gsize = int(groups.shape[1]) if groups is not None else 1
                crosses = False
                if groups is not None and pod_size:
                    pods = groups // pod_size
                    crosses = bool(np.any(pods.max(axis=1) != pods.min(axis=1)))
                payload, out_eff = _collective_io(op, out_b, opnd_b)
                coll = Collective(op=op.replace("-start", ""),
                                  payload_bytes=payload,
                                  group_size=gsize,
                                  n_groups=int(groups.shape[0]) if groups is not None else 1,
                                  count=1.0, crosses_pod=crosses,
                                  name=ins.name)
                t = TaskSpec(prefix + ins.name, "ici", bytes_in=opnd_b,
                             bytes_out=out_eff, collective=coll, deps=deps)
            elif op == "dot":
                t = TaskSpec(prefix + ins.name, "mxu",
                             flops=an._dot_flops(comp, ins),
                             bytes_in=rd, bytes_out=wr, deps=deps,
                             gemm=_dot_mnk(comp, ins))
            elif op == "convolution":
                t = TaskSpec(prefix + ins.name, "mxu",
                             flops=an._conv_flops(comp, ins),
                             bytes_in=rd, bytes_out=wr, deps=deps,
                             gemm=_conv_mnk(comp, ins))
            elif op in ("fusion", "call"):
                called = an._called(ins.attrs, "calls") or \
                    an._called(ins.attrs, "to_apply")
                sub = an.analyze(called, True) if called else Summary()
                engine = "mxu" if sub.flops > 0 else "vector"
                g = an._fusion_gemm(called) if engine == "mxu" else None
                t = TaskSpec(prefix + ins.name, engine, flops=sub.flops,
                             elems=sub.vector_elems, bytes_in=rd,
                             bytes_out=wr, deps=deps,
                             gemm=g[:3] if g else None)
            elif op in ("copy", "copy-start", "transpose", "reshape",
                        "broadcast", "concatenate", "slice",
                        "dynamic-slice", "dynamic-update-slice"):
                t = TaskSpec(prefix + ins.name, "dma", bytes_in=rd,
                             bytes_out=wr, deps=deps)
            else:
                t = TaskSpec(prefix + ins.name, "vector",
                             elems=max(_elems_of(ins.out_shapes), 1),
                             bytes_in=rd, bytes_out=wr, deps=deps)
            tasks.append(t)
            local[ins.name] = len(tasks) - 1
            last = (len(tasks) - 1,)
        return last

    emit(mod.entry, "", ())
    return tasks
