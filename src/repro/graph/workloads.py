"""Operator-level workloads for TPU-EM analyses.

Two families:

* The paper's own CNN-era benchmark models (Table 1 / Figs 5-9):
  MobileNet v2 (224), ResNet50 (224), Tiny YOLO v2 (416) as explicit op
  lists built from their public layer specs. Variants: ``_C`` (DMA
  compression), ``_S`` (sparsity acceleration), ``_SC`` (both) — matching
  the paper's accuracy-characterization grid.

* LM-family workloads derived from an ``ArchConfig`` (per-device op list
  for one layer stack step) — used to cross-check the HLO-extracted task
  graphs and to run Fig-5-style scaling on modern workloads.

Ops are engine-agnostic records; ``graph.compiler`` maps them to tiles,
inserts DMA tasks + barriers, and applies variant effects.

LM workloads carry an **inference phase**:

* ``phase="prefill"`` (default) — one forward pass over ``seq`` prompt
  tokens per sequence; compute-bound at realistic sizes (big GEMMs,
  weights amortized over ``seq * batch`` tokens).
* ``phase="decode"`` — ONE autoregressive step: ``batch`` new tokens
  (m=batch GEMVs against the full weight set) attending over a
  ``kv_len``-token KV cache. The cache lives in HBM, so its read/append
  traffic is emitted with ``Op.stream=True`` (never VMEM-resident) —
  this is the memory-bound, latency-dominated regime; flops/byte
  collapses from O(seq) to O(batch).

Worked example — the decode op-list shape::

    >>> from repro.configs import get_config
    >>> ops = lm_layer_ops(get_config("qwen3-32b"), batch=8,
    ...                    phase="decode", kv_len=4096, tp_shards=2)
    >>> [(o.name, o.kind) for o in ops][:6]
    [('qkv', 'matmul'), ('kv_append', 'eltwise'), ('scores', 'matmul'),
     ('softmax', 'softmax'), ('pv', 'matmul'), ('attn_out', 'matmul')]
    >>> next(o for o in ops if o.name == "qkv").m     # m = batch GEMVs
    8
    >>> next(o for o in ops if o.name == "scores").n  # contracts the cache
    4096

MoE archs additionally take ``ep_shards`` (expert parallelism): with
``ep_shards > 1`` the experts are sharded over an EP group and the op
list carries ``alltoall`` dispatch/combine collectives — the op-list
mirror of ``models/moe.py``'s ``moe_ep`` shard_map path (capacity-
bucketed tokens exchanged with ``jax.lax.all_to_all``).

**Full-model workloads** (``lm_model_ops``) compose ``lm_layer_ops``
into the paper's "full model performance ... at scale in minutes"
object (§2.3): ``layers`` sequential copies of the layer op list (each
layer's weights re-streamed from HBM, each layer's KV traffic emitted)
plus a model head (final norm + vocab-sharded LM head), placed on a
``hw.pod.PodShape`` (DP x EP x TP over pods). Placement semantics:

* ``batch`` is the **global** batch; DP shards it (``batch/dp_shards``
  sequences per chip). Inference phases need **no** DP collective —
  replicas are independent — while ``phase="train"`` appends a DP
  gradient all-reduce over the per-device weight-shard bytes (the
  gradient/none split per phase).
* TP all-reduces, EP all-to-alls, and the DP gradient all-reduce carry
  ``Op.cross_pod`` from ``PodShape.crosses_pod(axis)``: a collective
  whose ring leaves the pod is paced by DCN instead of ICI when
  ``graph.compiler`` lowers it onto the fabric (symmetric replay: one
  paced chip, ring collectives — see the ``hw/pod.py`` docstring).
* ``phase="train"`` models a step as the standard 3x-forward shape:
  forward + dgrad (same GEMMs, TP/EP collectives re-run) + wgrad (same
  GEMMs, no collectives, no weight re-read) per layer.

Parameterized workload names (``resolve_workload``) encode all of this:

    lm/<arch>/s<seq>b<batch>tp<tp>[ep<ep>]          prefill (one layer)
    lm/<arch>/decode/kv<kv_len>b<batch>tp<tp>[ep<ep>]  decode (one layer)
    lm/<arch>/L<layers>/[train/|decode/]...[dp<dp>][pod<chips>]  full model

e.g. ``lm/qwen3-32b/decode/kv4096b8tp2`` (one decode layer) or
``lm/qwen3-32b/L64/decode/kv4096b16tp4dp4pod8`` (the full 64-layer
model, global batch 16 over DP=4, TP=4, on 8-chip pods) or
``lm/qwen3-32b/L64/train/s1024b8tp4dp2``.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from ..configs.base import ArchConfig
from ..hw.pod import PodShape

__all__ = ["Op", "mobilenet_v2", "resnet50", "tiny_yolo_v2", "WORKLOADS",
           "lm_layer_ops", "lm_model_ops", "ModelParts", "model_parts",
           "lm_workload_name", "lm_grid_names", "parse_lm_name",
           "resolve_workload", "is_workload", "workload_flops",
           "workload_bytes"]


@dataclass(frozen=True)
class Op:
    name: str
    kind: str              # conv | dwconv | matmul | pool | eltwise | act |
    #                        softmax | global_pool | allreduce | alltoall
    # GEMM view (conv is im2col'd): out[M,N] = in[M,K] @ w[K,N]
    m: int = 0
    n: int = 0
    k: int = 0
    # element counts for vector ops
    elems: float = 0.0
    vec_kind: str = "generic"
    # tensor footprints (bytes, at dtype_bytes=1 int8 unless overridden)
    in_bytes: float = 0.0
    out_bytes: float = 0.0
    w_bytes: float = 0.0
    sparsity: float = 0.0  # fraction of MACs skippable by sparsity HW
    group: int = 1         # collective group size (allreduce/alltoall ops)
    stream: bool = False   # force HBM streaming even when the working set
    #                        fits VMEM (KV-cache reads/appends: the cache
    #                        lives in HBM across decode steps)
    cross_pod: bool = False  # collective ring leaves the ICI domain and
    #                          is paced by DCN (set from PodShape)

    @property
    def flops(self) -> float:
        if self.kind in ("conv", "matmul"):
            return 2.0 * self.m * self.n * self.k
        return self.elems


def _conv(name, hw_in, cin, cout, k, stride=1, act_sparsity=0.35) -> Op:
    ho = hw_in // stride
    m = ho * ho
    kk = k * k * cin
    return Op(name=name, kind="conv", m=m, n=cout, k=kk,
              in_bytes=hw_in * hw_in * cin, out_bytes=ho * ho * cout,
              w_bytes=k * k * cin * cout, sparsity=act_sparsity)


def _dwconv(name, hw_in, c, k, stride=1) -> Op:
    ho = hw_in // stride
    return Op(name=name, kind="dwconv", elems=ho * ho * c * k * k,
              vec_kind="mul",
              in_bytes=hw_in * hw_in * c, out_bytes=ho * ho * c,
              w_bytes=k * k * c)


def _pool(name, hw_in, c, k=2, stride=2) -> Op:
    ho = hw_in // stride
    return Op(name=name, kind="pool", elems=ho * ho * c * k * k,
              vec_kind="reduce",
              in_bytes=hw_in * hw_in * c, out_bytes=ho * ho * c)


def _eltwise(name, hw, c) -> Op:
    return Op(name=name, kind="eltwise", elems=hw * hw * c, vec_kind="add",
              in_bytes=2 * hw * hw * c, out_bytes=hw * hw * c)


def _fc(name, cin, cout) -> Op:
    return Op(name=name, kind="matmul", m=1, n=cout, k=cin,
              in_bytes=cin, out_bytes=cout, w_bytes=cin * cout)


def mobilenet_v2(res: int = 224) -> List[Op]:
    ops: List[Op] = [_conv("stem", res, 3, 32, 3, 2)]
    hw = res // 2
    cin = 32
    # (expansion t, out channels c, repeats n, stride s) — the public config
    stages = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
              (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    bi = 0
    for t, c, n, s in stages:
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = cin * t
            pre = f"b{bi}"
            if t != 1:
                ops.append(_conv(f"{pre}.expand", hw, cin, hidden, 1))
            ops.append(_dwconv(f"{pre}.dw", hw, hidden, 3, stride))
            hw2 = hw // stride
            ops.append(_conv(f"{pre}.project", hw2, hidden, c, 1))
            if stride == 1 and cin == c:
                ops.append(_eltwise(f"{pre}.res", hw2, c))
            hw, cin = hw2, c
            bi += 1
    ops.append(_conv("head", hw, cin, 1280, 1))
    ops.append(Op("gap", "global_pool", elems=hw * hw * 1280,
                  vec_kind="reduce", in_bytes=hw * hw * 1280,
                  out_bytes=1280))
    ops.append(_fc("fc", 1280, 1000))
    return ops


def resnet50(res: int = 224) -> List[Op]:
    ops: List[Op] = [_conv("stem", res, 3, 64, 7, 2),
                     _pool("stem.pool", res // 2, 64, 3, 2)]
    hw = res // 4
    cin = 64
    stages = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    bi = 0
    for width, n, s in stages:
        for i in range(n):
            stride = s if i == 0 else 1
            pre = f"b{bi}"
            ops.append(_conv(f"{pre}.c1", hw, cin, width, 1))
            hw2 = hw // stride
            ops.append(_conv(f"{pre}.c2", hw, width, width, 3, stride))
            ops.append(_conv(f"{pre}.c3", hw2, width, width * 4, 1))
            if i == 0:
                ops.append(_conv(f"{pre}.down", hw, cin, width * 4, 1,
                                 stride))
            ops.append(_eltwise(f"{pre}.res", hw2, width * 4))
            hw, cin = hw2, width * 4
            bi += 1
    ops.append(Op("gap", "global_pool", elems=hw * hw * cin,
                  vec_kind="reduce", in_bytes=hw * hw * cin, out_bytes=cin))
    ops.append(_fc("fc", cin, 1000))
    return ops


def tiny_yolo_v2(res: int = 416) -> List[Op]:
    ops: List[Op] = []
    hw = res
    cin = 3
    for i, c in enumerate([16, 32, 64, 128, 256, 512]):
        ops.append(_conv(f"c{i}", hw, cin, c, 3))
        stride = 2 if i < 5 else 1
        if i < 5:
            ops.append(_pool(f"p{i}", hw, c, 2, 2))
            hw //= 2
        else:
            ops.append(_pool(f"p{i}", hw, c, 2, 1))
        cin = c
    ops.append(_conv("c6", hw, cin, 1024, 3))
    ops.append(_conv("c7", hw, 1024, 1024, 3))
    ops.append(_conv("out", hw, 1024, 125, 1))
    return ops


WORKLOADS = {
    "mobilenet_v2": mobilenet_v2,
    "resnet50": resnet50,
    "tiny_yolo_v2": tiny_yolo_v2,
}


def lm_layer_ops(cfg: ArchConfig, *, seq: int = 0, batch: int,
                 dtype_bytes: int = 2, tp_shards: int = 1,
                 phase: str = "prefill", kv_len: int = 0,
                 ep_shards: int = 1) -> List[Op]:
    """Per-device op list for ONE transformer layer (forward): qkv/attn/out
    + FFN or MoE. TP sharding divides head and ff dims.

    ``phase="prefill"`` processes ``seq`` tokens per sequence (one
    forward pass over the prompt; ``kv_len`` must stay 0). ``phase=
    "decode"`` emits ONE autoregressive step: ``T = batch`` new tokens
    (m=batch GEMVs), a per-layer KV-cache append, and score/pv GEMMs
    contracting over the ``kv_len``-token cache whose HBM read traffic
    (``batch * n_kv_heads/tp * kv_len * hd`` bytes per side, GQA-aware)
    is forced to stream (``Op.stream``).

    MoE archs: ``ep_shards > 1`` shards experts over an EP group and
    adds ``alltoall`` dispatch/combine collectives (tokens bucketed per
    peer at ``capacity_factor``, as in ``models.moe.moe_ep``); with
    ``ep_shards == 1`` experts stay tensor-sharded over TP and the
    combine is the Megatron ``mlp_allreduce``.
    """
    if phase not in ("prefill", "decode"):
        raise ValueError(f"phase must be prefill|decode, got {phase!r}")
    if phase == "decode":
        if kv_len < 1:
            raise ValueError("decode phase needs kv_len >= 1")
        if seq not in (0, 1):
            raise ValueError("decode phase processes one token per "
                             "sequence; leave seq unset")
    else:
        if seq < 1:
            raise ValueError("prefill phase needs seq >= 1")
        if kv_len:
            raise ValueError("kv_len only applies to phase='decode'")
    if ep_shards > 1 and not cfg.is_moe:
        raise ValueError(f"ep_shards > 1 needs a MoE arch, "
                         f"got {cfg.name} ({cfg.family})")
    d = cfg.d_model
    H = max(cfg.n_heads // tp_shards, 1)
    KV = max(cfg.n_kv_heads // max(tp_shards, 1), 1)
    hd = cfg.hd
    decode = phase == "decode"
    # tokens processed this step (per device): the whole prompt in
    # prefill, one new token per sequence in decode
    T = batch if decode else seq * batch
    ctx = kv_len if decode else seq     # attention context length
    # bytes of K (or V) cache read per step: GQA reads kv heads only
    kv_side = batch * KV * ctx * hd * dtype_bytes
    ops = [
        Op("qkv", "matmul", m=T, n=(H + 2 * KV) * hd, k=d,
           in_bytes=T * d * dtype_bytes,
           out_bytes=T * (H + 2 * KV) * hd * dtype_bytes,
           w_bytes=d * (H + 2 * KV) * hd * dtype_bytes),
    ]
    if decode:
        # append this step's K,V rows to the HBM-resident cache
        ops.append(Op("kv_append", "eltwise", elems=2 * T * KV * hd,
                      vec_kind="copy",
                      in_bytes=2 * T * KV * hd * dtype_bytes,
                      out_bytes=2 * T * KV * hd * dtype_bytes, stream=True))
    ops += [
        Op("scores", "matmul", m=T * H, n=ctx, k=hd,
           in_bytes=(T * H * hd * dtype_bytes + kv_side) if decode
           else 2 * T * H * hd * dtype_bytes,
           out_bytes=T * H * ctx * 4, stream=decode),
        Op("softmax", "softmax", elems=T * H * ctx, vec_kind="softmax",
           in_bytes=T * H * ctx * 4, out_bytes=T * H * ctx * dtype_bytes),
        Op("pv", "matmul", m=T * H, n=hd, k=ctx,
           in_bytes=T * H * ctx * dtype_bytes + (kv_side if decode else 0),
           out_bytes=T * H * hd * dtype_bytes, stream=decode),
        Op("attn_out", "matmul", m=T, n=d, k=H * hd,
           in_bytes=T * H * hd * dtype_bytes, out_bytes=T * d * dtype_bytes,
           w_bytes=H * hd * d * dtype_bytes),
    ]
    if cfg.is_moe:
        k_top, E = cfg.experts_per_token, cfg.n_experts
        cf = cfg.capacity_factor
        f = cfg.d_ff
        ep = max(ep_shards, 1)
        # ep==1: experts tensor-sharded over TP (Megatron expert-TP,
        # tokens replicated). ep>1: experts owned by EP peers; every
        # peer contributes T local tokens, so per-expert capacity sees
        # the whole group's assignments (ep * T * k / E).
        E_local = max(E // (ep if ep > 1 else tp_shards), 1)
        cap = int(max(ep, 1) * T * k_top / E * cf) + 1 if ep > 1 \
            else int(T * k_top / E * cf) + 1
        # capacity-bucketed token exchange to the expert owners (mirrors
        # models.moe.moe_ep: send buffer [ep, cap, d]); dispatch and
        # combine move the same bytes
        a2a_bytes = int(T * k_top * cf + 1) * d * dtype_bytes
        ops.append(
            Op("router", "matmul", m=T, n=E, k=d,
               in_bytes=T * d * dtype_bytes, out_bytes=T * E * 4,
               w_bytes=d * E * dtype_bytes))
        if ep > 1:
            ops.append(Op("moe_dispatch", "alltoall",
                          in_bytes=a2a_bytes, out_bytes=a2a_bytes,
                          group=ep))
        ops += [
            Op("experts_up", "matmul", m=E_local * cap, n=2 * f, k=d,
               in_bytes=E_local * cap * d * dtype_bytes,
               out_bytes=E_local * cap * 2 * f * dtype_bytes,
               w_bytes=E_local * 2 * d * f * dtype_bytes),
            Op("experts_down", "matmul", m=E_local * cap, n=d, k=f,
               in_bytes=E_local * cap * f * dtype_bytes,
               out_bytes=E_local * cap * d * dtype_bytes,
               w_bytes=E_local * f * d * dtype_bytes),
        ]
        if ep > 1:
            ops.append(Op("moe_combine", "alltoall",
                          in_bytes=a2a_bytes, out_bytes=a2a_bytes,
                          group=ep))
    elif cfg.d_ff:
        f = cfg.d_ff // max(tp_shards, 1)
        ops += [
            Op("ffn_up", "matmul", m=T, n=2 * f, k=d,
               in_bytes=T * d * dtype_bytes, out_bytes=T * 2 * f * dtype_bytes,
               w_bytes=2 * d * f * dtype_bytes),
            Op("silu", "act", elems=T * f, vec_kind="sigmoid",
               in_bytes=T * 2 * f * dtype_bytes,
               out_bytes=T * f * dtype_bytes),
            Op("ffn_down", "matmul", m=T, n=d, k=f,
               in_bytes=T * f * dtype_bytes, out_bytes=T * d * dtype_bytes,
               w_bytes=f * d * dtype_bytes),
        ]
    if tp_shards > 1:
        # Megatron-style TP: one all-reduce after the attention output
        # projection and one after the MLP/MoE down projection (the MoE
        # combine is the EP alltoall instead when ep_shards > 1)
        ar_bytes = T * d * dtype_bytes
        i_attn = next(i for i, o in enumerate(ops) if o.name == "attn_out")
        ops.insert(i_attn + 1, Op("attn_allreduce", "allreduce",
                                  in_bytes=ar_bytes, out_bytes=ar_bytes,
                                  group=tp_shards))
        if not (cfg.is_moe and ep_shards > 1):
            ops.append(Op("mlp_allreduce", "allreduce",
                          in_bytes=ar_bytes, out_bytes=ar_bytes,
                          group=tp_shards))
    ops.append(Op("norms", "eltwise", elems=2 * T * d, vec_kind="rsqrt",
                  in_bytes=T * d * dtype_bytes, out_bytes=T * d * dtype_bytes))
    return ops


# -- full-model composition -------------------------------------------------

_MODEL_PHASES = ("prefill", "decode", "train")
# op kind -> parallelism axis its collective group lives on
_COLLECTIVE_AXIS = {"allreduce": "tp", "alltoall": "ep"}


def _place(ops: List[Op], pod: PodShape) -> List[Op]:
    """Stamp ``cross_pod`` onto collectives per the pod placement."""
    return [dataclasses.replace(o, cross_pod=pod.crosses_pod(
        _COLLECTIVE_AXIS[o.kind])) if o.kind in _COLLECTIVE_AXIS else o
        for o in ops]


def _lm_body_ops(cfg: ArchConfig, *, seq: int, local_batch: int, phase: str,
                 kv_len: int, tp_shards: int, ep_shards: int, pod: PodShape,
                 dtype_bytes: int) -> List[Op]:
    """One layer of the full model (per-device, placed on ``pod``).

    ``train`` is the standard 3x-forward step shape: forward + dgrad
    (same GEMMs and TP/EP collectives, backward through the layer) +
    wgrad (same GEMMs, no collectives, produces rather than reads
    weights). Inference phases are ``lm_layer_ops`` verbatim.
    """
    if phase == "train":
        fwd = lm_layer_ops(cfg, seq=seq, batch=local_batch,
                           tp_shards=tp_shards, ep_shards=ep_shards,
                           dtype_bytes=dtype_bytes)
        body = list(fwd)
        body += [dataclasses.replace(o, name="dgrad." + o.name)
                 for o in fwd]
        body += [dataclasses.replace(o, name="wgrad." + o.name, w_bytes=0.0)
                 for o in fwd if o.kind not in _COLLECTIVE_AXIS]
    else:
        body = lm_layer_ops(cfg, seq=seq, batch=local_batch, phase=phase,
                            kv_len=kv_len, tp_shards=tp_shards,
                            ep_shards=ep_shards, dtype_bytes=dtype_bytes)
    return _place(body, pod)


def _lm_head_ops(cfg: ArchConfig, *, T: int, phase: str, layers: int,
                 tp_shards: int, pod: PodShape, dtype_bytes: int,
                 layer_w_bytes: float) -> List[Op]:
    """Once-per-model ops: final norm + vocab-sharded LM head (logits
    stay TP-sharded, no collective), plus — train only, the DP
    "gradient" semantics — one gradient all-reduce over the per-device
    weight-shard bytes. Inference DP replicas are independent: "none".
    """
    d = cfg.d_model
    V = max(cfg.padded_vocab // max(tp_shards, 1), 1)
    ops = [
        Op("final_norm", "eltwise", elems=T * d, vec_kind="rsqrt",
           in_bytes=T * d * dtype_bytes, out_bytes=T * d * dtype_bytes),
        Op("lm_head", "matmul", m=T, n=V, k=d,
           in_bytes=T * d * dtype_bytes, out_bytes=T * V * 4,
           w_bytes=d * V * dtype_bytes),
    ]
    if phase == "train" and pod.dp > 1:
        grad_bytes = layers * layer_w_bytes + d * V * dtype_bytes
        ops.append(Op("grad_allreduce", "allreduce", in_bytes=grad_bytes,
                      out_bytes=grad_bytes, group=pod.dp,
                      cross_pod=pod.crosses_pod("dp")))
    return ops


def _model_args(cfg: ArchConfig, *, layers: int, batch: int, seq: int,
                phase: str, kv_len: int, dp_shards: int, tp_shards: int,
                ep_shards: int, pod_chips: int) -> Tuple[int, int, PodShape]:
    """Validate full-model parameters; return (local_batch, T, pod)."""
    if phase not in _MODEL_PHASES:
        raise ValueError(f"phase must be prefill|decode|train, "
                         f"got {phase!r}")
    if layers < 1:
        raise ValueError(f"full model needs layers >= 1, got {layers}")
    if dp_shards < 1 or batch % dp_shards:
        raise ValueError(f"global batch {batch} must divide over "
                         f"dp_shards={dp_shards}")
    if phase == "train" and (seq < 1 or kv_len):
        raise ValueError("train phase needs seq >= 1 and no kv_len")
    local = batch // dp_shards
    if local < 1:
        raise ValueError(f"batch {batch} < dp_shards {dp_shards}")
    pod = PodShape(dp=dp_shards, tp=tp_shards, ep=ep_shards,
                   pod_chips=pod_chips)
    T = local if phase == "decode" else seq * local
    return local, T, pod


def lm_model_ops(cfg: ArchConfig, *, layers: int, batch: int, seq: int = 0,
                 phase: str = "prefill", kv_len: int = 0,
                 dp_shards: int = 1, tp_shards: int = 1, ep_shards: int = 1,
                 pod_chips: int = 0, dtype_bytes: int = 2) -> List[Op]:
    """Per-device op list for the FULL model on a pod shape.

    ``layers`` sequential copies of the per-layer op list (ops renamed
    ``L<i>.<name>``; every layer's weights re-stream HBM->VMEM, every
    layer's KV traffic is emitted) followed by the model head. ``batch``
    is the global batch, sharded over ``dp_shards`` replicas; TP/EP/DP
    collectives carry ``cross_pod`` per ``PodShape(dp, tp, ep,
    pod_chips)`` placement. Embedding lookup (a cheap gather) is not
    modeled.

    The per-layer body is exactly ``model_parts(name).body()``, so the
    sweep pre-screen can evaluate one layer analytically and scale the
    stats in closed form instead of walking ``layers`` copies — the
    event engine still simulates this full list.
    """
    local, T, pod = _model_args(
        cfg, layers=layers, batch=batch, seq=seq, phase=phase,
        kv_len=kv_len, dp_shards=dp_shards, tp_shards=tp_shards,
        ep_shards=ep_shards, pod_chips=pod_chips)
    body = _lm_body_ops(cfg, seq=seq, local_batch=local, phase=phase,
                        kv_len=kv_len, tp_shards=tp_shards,
                        ep_shards=ep_shards, pod=pod,
                        dtype_bytes=dtype_bytes)
    layer_w = sum(o.w_bytes for o in body
                  if not o.name.startswith(("dgrad.", "wgrad.")))
    ops = [dataclasses.replace(o, name=f"L{i}.{o.name}")
           for i in range(layers) for o in body]
    ops += _lm_head_ops(cfg, T=T, phase=phase, layers=layers,
                        tp_shards=tp_shards, pod=pod,
                        dtype_bytes=dtype_bytes, layer_w_bytes=layer_w)
    return ops


@dataclass(frozen=True)
class ModelParts:
    """Layer-replication decomposition of a full-model workload.

    ``full == layers x body (renamed L<i>.*) + head`` — the contract
    ``tests/test_invariants.py`` locks down. ``body_key``/``head_key``
    identify the part graphs independently of ``layers``, so a sweep
    over layer counts compiles/pre-screens each distinct part once.
    """

    layers: int
    body: Callable[[], List[Op]]
    head: Callable[[], List[Op]]
    body_key: str
    head_key: str


# -- parameterized LM workload names ---------------------------------------
#
# ``lm/<arch>/s<seq>b<batch>tp<tp>[ep<ep>]`` names one prefill
# ``lm_layer_ops`` instance; ``lm/<arch>/decode/kv<kv>b<batch>tp<tp>[ep<ep>]``
# names one decode step (one token per sequence against a <kv>-token KV
# cache). An ``L<layers>/`` segment selects the FULL model
# (``lm_model_ops``): ``train/`` becomes a valid phase, ``b<batch>`` is
# the global batch, and optional ``dp<dp>``/``pod<chips>`` suffixes set
# the DP degree and pod size. ``resolve_workload`` accepts these
# anywhere a plain ``WORKLOADS`` name is accepted, which is what lets
# sweep campaigns grid LM workloads over phase x seq/kv_len x batch x
# TP x EP x DP x layers x pod shape.

_LM_NAME_RE = re.compile(
    r"^lm/(?P<arch>[A-Za-z0-9_.\-]+)/"
    r"(?:L(?P<layers>\d+)/)?"
    r"(?:train/s(?P<trseq>\d+)|decode/kv(?P<kv>\d+)|s(?P<seq>\d+))"
    r"b(?P<batch>\d+)tp(?P<tp>\d+)(?:ep(?P<ep>\d+))?"
    r"(?:dp(?P<dp>\d+))?(?:pod(?P<pod>\d+))?$")


def lm_workload_name(arch: str, *, seq: int = 0, batch: int, tp: int,
                     phase: str = "prefill", kv_len: int = 0,
                     ep: int = 1, layers: int = 0, dp: int = 1,
                     pod: int = 0) -> str:
    """Single-layer name (``layers=0``, historical spelling) or
    full-model name (``layers>=1`` adds the ``L<layers>/`` segment and
    unlocks ``train``/``dp``/``pod``)."""
    if phase == "train":
        head = f"train/s{seq}"
    elif phase == "decode":
        head = f"decode/kv{kv_len}"
    else:
        head = f"s{seq}"
    model = f"L{layers}/" if layers else ""
    return (f"lm/{arch}/{model}{head}b{batch}tp{tp}"
            + (f"ep{ep}" if ep > 1 else "")
            + (f"dp{dp}" if dp > 1 else "")
            + (f"pod{pod}" if pod else ""))


def lm_grid_names(arch: str, seq: List[int], batch: List[int],
                  tp: List[int], *, phase: List[str] = ("prefill",),
                  kv_len: List[int] = (0,),
                  ep: List[int] = (1,), layers: List[int] = (0,),
                  dp: List[int] = (1,),
                  pod: List[int] = (0,)) -> List[str]:
    """Expand a phase x (seq | kv_len) x batch x TP x EP x DP x layers
    x pod grid into workload names. Grid order: phase-major, then seq
    (prefill/train) or kv_len (decode), then batch, tp, ep, dp, layers,
    pod — so the default arguments reproduce the historical seq-major
    prefill ordering."""
    out: List[str] = []
    for ph in phase:
        lens = kv_len if ph == "decode" else seq
        out += [lm_workload_name(arch, seq=0 if ph == "decode" else s,
                                 batch=b, tp=t, phase=ph,
                                 kv_len=s if ph == "decode" else 0, ep=e,
                                 layers=lyr, dp=d, pod=pc)
                for s in lens for b in batch for t in tp for e in ep
                for d in dp for lyr in layers for pc in pod]
    return out


def parse_lm_name(name: str) -> Optional[Dict[str, object]]:
    """Parse an ``lm/...`` name into its parameters (validated), or
    None when the name is not LM-shaped. Raises KeyError on an LM name
    with bad parameters (unknown arch, dp on a single layer, ...)."""
    m = _LM_NAME_RE.match(name)
    if not m:
        return None
    from ..configs import get_config   # deferred: avoids import cycle
    cfg = get_config(m["arch"])        # raises KeyError on bad arch
    phase = ("train" if m["trseq"] else
             "decode" if m["kv"] else "prefill")
    seq = int(m["trseq"] or m["seq"] or 0)
    kv = int(m["kv"]) if m["kv"] else 0
    batch, tp = int(m["batch"]), int(m["tp"])
    ep = int(m["ep"]) if m["ep"] else 1
    layers = int(m["layers"]) if m["layers"] else 0
    dp = int(m["dp"]) if m["dp"] else 1
    pod = int(m["pod"]) if m["pod"] else 0
    if m["layers"] is not None and layers < 1:
        raise KeyError(f"full model needs L >= 1 in {name!r}")
    if batch < 1 or tp < 1 or ep < 1 or dp < 1 or \
            (kv < 1 if phase == "decode" else seq < 1):
        raise KeyError(f"bad LM workload parameters in {name!r}")
    if ep > 1 and not cfg.is_moe:
        raise KeyError(f"ep>1 in {name!r} needs a MoE arch; "
                       f"{cfg.name} is {cfg.family}")
    if not layers and (dp > 1 or pod or phase == "train"):
        raise KeyError(f"train/dp/pod in {name!r} need the full-model "
                       f"L<layers>/ segment")
    if layers and batch % dp:
        raise KeyError(f"global batch {batch} must divide over dp={dp} "
                       f"in {name!r}")
    return {"cfg": cfg, "arch": m["arch"], "phase": phase, "seq": seq,
            "kv_len": kv, "batch": batch, "tp": tp, "ep": ep,
            "layers": layers, "dp": dp, "pod": pod}


def resolve_workload(name: str) -> Callable[[], List[Op]]:
    """Map a workload name — builtin CNN or parameterized ``lm/...`` —
    to its op-list factory; raises KeyError for unknown names."""
    if name in WORKLOADS:
        return WORKLOADS[name]
    if name.startswith("hlo/"):
        # captured compiler graphs (imported lazily: ingest pulls in the
        # HLO parser + fixture IO most callers never need)
        from . import ingest
        return ingest.resolve_hlo(name)
    p = parse_lm_name(name)
    if p is None:
        raise KeyError(
            f"unknown workload {name!r}; have {sorted(WORKLOADS)} or "
            f"'lm/<arch>/s<seq>b<batch>tp<tp>[ep<ep>]' or "
            f"'lm/<arch>/decode/kv<kv>b<batch>tp<tp>[ep<ep>]' or "
            f"'lm/<arch>/L<layers>/[train/|decode/]...[dp<dp>]"
            f"[pod<chips>]' or 'hlo/<fixture>[@L<k>]' (captured HLO "
            f"graphs, see graph/ingest.py)")
    cfg = p["cfg"]

    if p["layers"]:
        def build() -> List[Op]:
            return lm_model_ops(cfg, layers=p["layers"], batch=p["batch"],
                                seq=p["seq"], phase=p["phase"],
                                kv_len=p["kv_len"], dp_shards=p["dp"],
                                tp_shards=p["tp"], ep_shards=p["ep"],
                                pod_chips=p["pod"])
    else:
        def build() -> List[Op]:
            return lm_layer_ops(cfg, seq=p["seq"], batch=p["batch"],
                                tp_shards=p["tp"], phase=p["phase"],
                                kv_len=p["kv_len"], ep_shards=p["ep"])

    return build


def model_parts(name: str) -> Optional[ModelParts]:
    """The layer-replication decomposition of a full-model workload
    name, or None for CNN / single-layer names. The sweep pre-screen
    uses this to compile + analytically schedule one layer body and one
    head instead of ``layers`` copies (``core.vectorized``'s closed-form
    ``repeats`` path); ``resolve_workload`` still builds the full list
    for event-engine refinement."""
    if name in WORKLOADS:
        return None
    p = parse_lm_name(name)
    if p is None or not p["layers"]:
        return None
    cfg = p["cfg"]
    local, T, pod = _model_args(
        cfg, layers=p["layers"], batch=p["batch"], seq=p["seq"],
        phase=p["phase"], kv_len=p["kv_len"], dp_shards=p["dp"],
        tp_shards=p["tp"], ep_shards=p["ep"], pod_chips=p["pod"])

    def body() -> List[Op]:
        return _lm_body_ops(cfg, seq=p["seq"], local_batch=local,
                            phase=p["phase"], kv_len=p["kv_len"],
                            tp_shards=p["tp"], ep_shards=p["ep"], pod=pod,
                            dtype_bytes=2)

    def head() -> List[Op]:
        layer_w = sum(o.w_bytes for o in body()
                      if not o.name.startswith(("dgrad.", "wgrad.")))
        return _lm_head_ops(cfg, T=T, phase=p["phase"], layers=p["layers"],
                            tp_shards=p["tp"], pod=pod, dtype_bytes=2,
                            layer_w_bytes=layer_w)

    # part keys are layers-independent EXCEPT the head in train+DP,
    # whose grad_allreduce payload scales with the layer count
    base = (f"{p['arch']}/{p['phase']}/s{p['seq']}kv{p['kv_len']}"
            f"b{p['batch']}tp{p['tp']}ep{p['ep']}dp{p['dp']}pod{p['pod']}")
    head_key = base + "/head"
    if p["phase"] == "train" and p["dp"] > 1:
        head_key += f"L{p['layers']}"
    return ModelParts(layers=p["layers"], body=body, head=head,
                      body_key=base + "/body", head_key=head_key)


def is_workload(name: str) -> bool:
    try:
        resolve_workload(name)
        return True
    except KeyError:
        return False


def workload_flops(ops: List[Op]) -> float:
    return sum(o.flops for o in ops)


def workload_bytes(ops: List[Op]) -> float:
    return sum(o.in_bytes + o.out_bytes + o.w_bytes for o in ops)
