"""Operator-level workloads for TPU-EM analyses.

Two families:

* The paper's own CNN-era benchmark models (Table 1 / Figs 5-9):
  MobileNet v2 (224), ResNet50 (224), Tiny YOLO v2 (416) as explicit op
  lists built from their public layer specs. Variants: ``_C`` (DMA
  compression), ``_S`` (sparsity acceleration), ``_SC`` (both) — matching
  the paper's accuracy-characterization grid.

* LM-family workloads derived from an ``ArchConfig`` (per-device op list
  for one layer stack step) — used to cross-check the HLO-extracted task
  graphs and to run Fig-5-style scaling on modern workloads.

Ops are engine-agnostic records; ``graph.compiler`` maps them to tiles,
inserts DMA tasks + barriers, and applies variant effects.

LM workloads carry an **inference phase**:

* ``phase="prefill"`` (default) — one forward pass over ``seq`` prompt
  tokens per sequence; compute-bound at realistic sizes (big GEMMs,
  weights amortized over ``seq * batch`` tokens).
* ``phase="decode"`` — ONE autoregressive step: ``batch`` new tokens
  (m=batch GEMVs against the full weight set) attending over a
  ``kv_len``-token KV cache. The cache lives in HBM, so its read/append
  traffic is emitted with ``Op.stream=True`` (never VMEM-resident) —
  this is the memory-bound, latency-dominated regime; flops/byte
  collapses from O(seq) to O(batch).

Worked example — the decode op-list shape::

    >>> from repro.configs import get_config
    >>> ops = lm_layer_ops(get_config("qwen3-32b"), batch=8,
    ...                    phase="decode", kv_len=4096, tp_shards=2)
    >>> [(o.name, o.kind) for o in ops][:6]
    [('qkv', 'matmul'), ('kv_append', 'eltwise'), ('scores', 'matmul'),
     ('softmax', 'softmax'), ('pv', 'matmul'), ('attn_out', 'matmul')]
    >>> next(o for o in ops if o.name == "qkv").m     # m = batch GEMVs
    8
    >>> next(o for o in ops if o.name == "scores").n  # contracts the cache
    4096

MoE archs additionally take ``ep_shards`` (expert parallelism): with
``ep_shards > 1`` the experts are sharded over an EP group and the op
list carries ``alltoall`` dispatch/combine collectives — the op-list
mirror of ``models/moe.py``'s ``moe_ep`` shard_map path (capacity-
bucketed tokens exchanged with ``jax.lax.all_to_all``).

Parameterized workload names (``resolve_workload``) encode all of this:

    lm/<arch>/s<seq>b<batch>tp<tp>[ep<ep>]          prefill
    lm/<arch>/decode/kv<kv_len>b<batch>tp<tp>[ep<ep>]  decode

e.g. ``lm/qwen3-32b/decode/kv4096b8tp2`` or
``lm/qwen3-moe-30b-a3b/s1024b4tp1ep16``.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from ..configs.base import ArchConfig

__all__ = ["Op", "mobilenet_v2", "resnet50", "tiny_yolo_v2", "WORKLOADS",
           "lm_layer_ops", "lm_workload_name", "lm_grid_names",
           "resolve_workload", "is_workload", "workload_flops",
           "workload_bytes"]


@dataclass(frozen=True)
class Op:
    name: str
    kind: str              # conv | dwconv | matmul | pool | eltwise | act |
    #                        softmax | global_pool | allreduce | alltoall
    # GEMM view (conv is im2col'd): out[M,N] = in[M,K] @ w[K,N]
    m: int = 0
    n: int = 0
    k: int = 0
    # element counts for vector ops
    elems: float = 0.0
    vec_kind: str = "generic"
    # tensor footprints (bytes, at dtype_bytes=1 int8 unless overridden)
    in_bytes: float = 0.0
    out_bytes: float = 0.0
    w_bytes: float = 0.0
    sparsity: float = 0.0  # fraction of MACs skippable by sparsity HW
    group: int = 1         # collective group size (allreduce/alltoall ops)
    stream: bool = False   # force HBM streaming even when the working set
    #                        fits VMEM (KV-cache reads/appends: the cache
    #                        lives in HBM across decode steps)

    @property
    def flops(self) -> float:
        if self.kind in ("conv", "matmul"):
            return 2.0 * self.m * self.n * self.k
        return self.elems


def _conv(name, hw_in, cin, cout, k, stride=1, act_sparsity=0.35) -> Op:
    ho = hw_in // stride
    m = ho * ho
    kk = k * k * cin
    return Op(name=name, kind="conv", m=m, n=cout, k=kk,
              in_bytes=hw_in * hw_in * cin, out_bytes=ho * ho * cout,
              w_bytes=k * k * cin * cout, sparsity=act_sparsity)


def _dwconv(name, hw_in, c, k, stride=1) -> Op:
    ho = hw_in // stride
    return Op(name=name, kind="dwconv", elems=ho * ho * c * k * k,
              vec_kind="mul",
              in_bytes=hw_in * hw_in * c, out_bytes=ho * ho * c,
              w_bytes=k * k * c)


def _pool(name, hw_in, c, k=2, stride=2) -> Op:
    ho = hw_in // stride
    return Op(name=name, kind="pool", elems=ho * ho * c * k * k,
              vec_kind="reduce",
              in_bytes=hw_in * hw_in * c, out_bytes=ho * ho * c)


def _eltwise(name, hw, c) -> Op:
    return Op(name=name, kind="eltwise", elems=hw * hw * c, vec_kind="add",
              in_bytes=2 * hw * hw * c, out_bytes=hw * hw * c)


def _fc(name, cin, cout) -> Op:
    return Op(name=name, kind="matmul", m=1, n=cout, k=cin,
              in_bytes=cin, out_bytes=cout, w_bytes=cin * cout)


def mobilenet_v2(res: int = 224) -> List[Op]:
    ops: List[Op] = [_conv("stem", res, 3, 32, 3, 2)]
    hw = res // 2
    cin = 32
    # (expansion t, out channels c, repeats n, stride s) — the public config
    stages = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
              (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    bi = 0
    for t, c, n, s in stages:
        for i in range(n):
            stride = s if i == 0 else 1
            hidden = cin * t
            pre = f"b{bi}"
            if t != 1:
                ops.append(_conv(f"{pre}.expand", hw, cin, hidden, 1))
            ops.append(_dwconv(f"{pre}.dw", hw, hidden, 3, stride))
            hw2 = hw // stride
            ops.append(_conv(f"{pre}.project", hw2, hidden, c, 1))
            if stride == 1 and cin == c:
                ops.append(_eltwise(f"{pre}.res", hw2, c))
            hw, cin = hw2, c
            bi += 1
    ops.append(_conv("head", hw, cin, 1280, 1))
    ops.append(Op("gap", "global_pool", elems=hw * hw * 1280,
                  vec_kind="reduce", in_bytes=hw * hw * 1280,
                  out_bytes=1280))
    ops.append(_fc("fc", 1280, 1000))
    return ops


def resnet50(res: int = 224) -> List[Op]:
    ops: List[Op] = [_conv("stem", res, 3, 64, 7, 2),
                     _pool("stem.pool", res // 2, 64, 3, 2)]
    hw = res // 4
    cin = 64
    stages = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    bi = 0
    for width, n, s in stages:
        for i in range(n):
            stride = s if i == 0 else 1
            pre = f"b{bi}"
            ops.append(_conv(f"{pre}.c1", hw, cin, width, 1))
            hw2 = hw // stride
            ops.append(_conv(f"{pre}.c2", hw, width, width, 3, stride))
            ops.append(_conv(f"{pre}.c3", hw2, width, width * 4, 1))
            if i == 0:
                ops.append(_conv(f"{pre}.down", hw, cin, width * 4, 1,
                                 stride))
            ops.append(_eltwise(f"{pre}.res", hw2, width * 4))
            hw, cin = hw2, width * 4
            bi += 1
    ops.append(Op("gap", "global_pool", elems=hw * hw * cin,
                  vec_kind="reduce", in_bytes=hw * hw * cin, out_bytes=cin))
    ops.append(_fc("fc", cin, 1000))
    return ops


def tiny_yolo_v2(res: int = 416) -> List[Op]:
    ops: List[Op] = []
    hw = res
    cin = 3
    for i, c in enumerate([16, 32, 64, 128, 256, 512]):
        ops.append(_conv(f"c{i}", hw, cin, c, 3))
        stride = 2 if i < 5 else 1
        if i < 5:
            ops.append(_pool(f"p{i}", hw, c, 2, 2))
            hw //= 2
        else:
            ops.append(_pool(f"p{i}", hw, c, 2, 1))
        cin = c
    ops.append(_conv("c6", hw, cin, 1024, 3))
    ops.append(_conv("c7", hw, 1024, 1024, 3))
    ops.append(_conv("out", hw, 1024, 125, 1))
    return ops


WORKLOADS = {
    "mobilenet_v2": mobilenet_v2,
    "resnet50": resnet50,
    "tiny_yolo_v2": tiny_yolo_v2,
}


def lm_layer_ops(cfg: ArchConfig, *, seq: int = 0, batch: int,
                 dtype_bytes: int = 2, tp_shards: int = 1,
                 phase: str = "prefill", kv_len: int = 0,
                 ep_shards: int = 1) -> List[Op]:
    """Per-device op list for ONE transformer layer (forward): qkv/attn/out
    + FFN or MoE. TP sharding divides head and ff dims.

    ``phase="prefill"`` processes ``seq`` tokens per sequence (one
    forward pass over the prompt; ``kv_len`` must stay 0). ``phase=
    "decode"`` emits ONE autoregressive step: ``T = batch`` new tokens
    (m=batch GEMVs), a per-layer KV-cache append, and score/pv GEMMs
    contracting over the ``kv_len``-token cache whose HBM read traffic
    (``batch * n_kv_heads/tp * kv_len * hd`` bytes per side, GQA-aware)
    is forced to stream (``Op.stream``).

    MoE archs: ``ep_shards > 1`` shards experts over an EP group and
    adds ``alltoall`` dispatch/combine collectives (tokens bucketed per
    peer at ``capacity_factor``, as in ``models.moe.moe_ep``); with
    ``ep_shards == 1`` experts stay tensor-sharded over TP and the
    combine is the Megatron ``mlp_allreduce``.
    """
    if phase not in ("prefill", "decode"):
        raise ValueError(f"phase must be prefill|decode, got {phase!r}")
    if phase == "decode":
        if kv_len < 1:
            raise ValueError("decode phase needs kv_len >= 1")
        if seq not in (0, 1):
            raise ValueError("decode phase processes one token per "
                             "sequence; leave seq unset")
    else:
        if seq < 1:
            raise ValueError("prefill phase needs seq >= 1")
        if kv_len:
            raise ValueError("kv_len only applies to phase='decode'")
    if ep_shards > 1 and not cfg.is_moe:
        raise ValueError(f"ep_shards > 1 needs a MoE arch, "
                         f"got {cfg.name} ({cfg.family})")
    d = cfg.d_model
    H = max(cfg.n_heads // tp_shards, 1)
    KV = max(cfg.n_kv_heads // max(tp_shards, 1), 1)
    hd = cfg.hd
    decode = phase == "decode"
    # tokens processed this step (per device): the whole prompt in
    # prefill, one new token per sequence in decode
    T = batch if decode else seq * batch
    ctx = kv_len if decode else seq     # attention context length
    # bytes of K (or V) cache read per step: GQA reads kv heads only
    kv_side = batch * KV * ctx * hd * dtype_bytes
    ops = [
        Op("qkv", "matmul", m=T, n=(H + 2 * KV) * hd, k=d,
           in_bytes=T * d * dtype_bytes,
           out_bytes=T * (H + 2 * KV) * hd * dtype_bytes,
           w_bytes=d * (H + 2 * KV) * hd * dtype_bytes),
    ]
    if decode:
        # append this step's K,V rows to the HBM-resident cache
        ops.append(Op("kv_append", "eltwise", elems=2 * T * KV * hd,
                      vec_kind="copy",
                      in_bytes=2 * T * KV * hd * dtype_bytes,
                      out_bytes=2 * T * KV * hd * dtype_bytes, stream=True))
    ops += [
        Op("scores", "matmul", m=T * H, n=ctx, k=hd,
           in_bytes=(T * H * hd * dtype_bytes + kv_side) if decode
           else 2 * T * H * hd * dtype_bytes,
           out_bytes=T * H * ctx * 4, stream=decode),
        Op("softmax", "softmax", elems=T * H * ctx, vec_kind="softmax",
           in_bytes=T * H * ctx * 4, out_bytes=T * H * ctx * dtype_bytes),
        Op("pv", "matmul", m=T * H, n=hd, k=ctx,
           in_bytes=T * H * ctx * dtype_bytes + (kv_side if decode else 0),
           out_bytes=T * H * hd * dtype_bytes, stream=decode),
        Op("attn_out", "matmul", m=T, n=d, k=H * hd,
           in_bytes=T * H * hd * dtype_bytes, out_bytes=T * d * dtype_bytes,
           w_bytes=H * hd * d * dtype_bytes),
    ]
    if cfg.is_moe:
        k_top, E = cfg.experts_per_token, cfg.n_experts
        cf = cfg.capacity_factor
        f = cfg.d_ff
        ep = max(ep_shards, 1)
        # ep==1: experts tensor-sharded over TP (Megatron expert-TP,
        # tokens replicated). ep>1: experts owned by EP peers; every
        # peer contributes T local tokens, so per-expert capacity sees
        # the whole group's assignments (ep * T * k / E).
        E_local = max(E // (ep if ep > 1 else tp_shards), 1)
        cap = int(max(ep, 1) * T * k_top / E * cf) + 1 if ep > 1 \
            else int(T * k_top / E * cf) + 1
        # capacity-bucketed token exchange to the expert owners (mirrors
        # models.moe.moe_ep: send buffer [ep, cap, d]); dispatch and
        # combine move the same bytes
        a2a_bytes = int(T * k_top * cf + 1) * d * dtype_bytes
        ops.append(
            Op("router", "matmul", m=T, n=E, k=d,
               in_bytes=T * d * dtype_bytes, out_bytes=T * E * 4,
               w_bytes=d * E * dtype_bytes))
        if ep > 1:
            ops.append(Op("moe_dispatch", "alltoall",
                          in_bytes=a2a_bytes, out_bytes=a2a_bytes,
                          group=ep))
        ops += [
            Op("experts_up", "matmul", m=E_local * cap, n=2 * f, k=d,
               in_bytes=E_local * cap * d * dtype_bytes,
               out_bytes=E_local * cap * 2 * f * dtype_bytes,
               w_bytes=E_local * 2 * d * f * dtype_bytes),
            Op("experts_down", "matmul", m=E_local * cap, n=d, k=f,
               in_bytes=E_local * cap * f * dtype_bytes,
               out_bytes=E_local * cap * d * dtype_bytes,
               w_bytes=E_local * f * d * dtype_bytes),
        ]
        if ep > 1:
            ops.append(Op("moe_combine", "alltoall",
                          in_bytes=a2a_bytes, out_bytes=a2a_bytes,
                          group=ep))
    elif cfg.d_ff:
        f = cfg.d_ff // max(tp_shards, 1)
        ops += [
            Op("ffn_up", "matmul", m=T, n=2 * f, k=d,
               in_bytes=T * d * dtype_bytes, out_bytes=T * 2 * f * dtype_bytes,
               w_bytes=2 * d * f * dtype_bytes),
            Op("silu", "act", elems=T * f, vec_kind="sigmoid",
               in_bytes=T * 2 * f * dtype_bytes,
               out_bytes=T * f * dtype_bytes),
            Op("ffn_down", "matmul", m=T, n=d, k=f,
               in_bytes=T * f * dtype_bytes, out_bytes=T * d * dtype_bytes,
               w_bytes=f * d * dtype_bytes),
        ]
    if tp_shards > 1:
        # Megatron-style TP: one all-reduce after the attention output
        # projection and one after the MLP/MoE down projection (the MoE
        # combine is the EP alltoall instead when ep_shards > 1)
        ar_bytes = T * d * dtype_bytes
        i_attn = next(i for i, o in enumerate(ops) if o.name == "attn_out")
        ops.insert(i_attn + 1, Op("attn_allreduce", "allreduce",
                                  in_bytes=ar_bytes, out_bytes=ar_bytes,
                                  group=tp_shards))
        if not (cfg.is_moe and ep_shards > 1):
            ops.append(Op("mlp_allreduce", "allreduce",
                          in_bytes=ar_bytes, out_bytes=ar_bytes,
                          group=tp_shards))
    ops.append(Op("norms", "eltwise", elems=2 * T * d, vec_kind="rsqrt",
                  in_bytes=T * d * dtype_bytes, out_bytes=T * d * dtype_bytes))
    return ops


# -- parameterized LM workload names ---------------------------------------
#
# ``lm/<arch>/s<seq>b<batch>tp<tp>[ep<ep>]`` names one prefill
# ``lm_layer_ops`` instance; ``lm/<arch>/decode/kv<kv>b<batch>tp<tp>[ep<ep>]``
# names one decode step (one token per sequence against a <kv>-token KV
# cache). ``resolve_workload`` accepts these anywhere a plain
# ``WORKLOADS`` name is accepted, which is what lets sweep campaigns
# grid LM workloads over phase x seq/kv_len x batch x TP x EP.

_LM_NAME_RE = re.compile(
    r"^lm/(?P<arch>[A-Za-z0-9_.\-]+)/"
    r"(?:decode/kv(?P<kv>\d+)|s(?P<seq>\d+))"
    r"b(?P<batch>\d+)tp(?P<tp>\d+)(?:ep(?P<ep>\d+))?$")


def lm_workload_name(arch: str, *, seq: int = 0, batch: int, tp: int,
                     phase: str = "prefill", kv_len: int = 0,
                     ep: int = 1) -> str:
    if phase == "decode":
        head = f"decode/kv{kv_len}"
    else:
        head = f"s{seq}"
    return f"lm/{arch}/{head}b{batch}tp{tp}" + (f"ep{ep}" if ep > 1 else "")


def lm_grid_names(arch: str, seq: List[int], batch: List[int],
                  tp: List[int], *, phase: List[str] = ("prefill",),
                  kv_len: List[int] = (0,),
                  ep: List[int] = (1,)) -> List[str]:
    """Expand a phase x (seq | kv_len) x batch x TP x EP grid into
    workload names. Grid order: phase-major, then seq (prefill) or
    kv_len (decode), then batch, tp, ep — so the default arguments
    reproduce the historical seq-major prefill ordering."""
    out: List[str] = []
    for ph in phase:
        lens = kv_len if ph == "decode" else seq
        out += [lm_workload_name(arch, seq=0 if ph == "decode" else s,
                                 batch=b, tp=t, phase=ph,
                                 kv_len=s if ph == "decode" else 0, ep=e)
                for s in lens for b in batch for t in tp for e in ep]
    return out


def resolve_workload(name: str) -> Callable[[], List[Op]]:
    """Map a workload name — builtin CNN or parameterized ``lm/...`` —
    to its op-list factory; raises KeyError for unknown names."""
    if name in WORKLOADS:
        return WORKLOADS[name]
    m = _LM_NAME_RE.match(name)
    if not m:
        raise KeyError(
            f"unknown workload {name!r}; have {sorted(WORKLOADS)} or "
            f"'lm/<arch>/s<seq>b<batch>tp<tp>[ep<ep>]' or "
            f"'lm/<arch>/decode/kv<kv>b<batch>tp<tp>[ep<ep>]'")
    from ..configs import get_config   # deferred: avoids import cycle
    cfg = get_config(m["arch"])        # raises KeyError on bad arch
    decode = m["kv"] is not None
    seq = int(m["seq"]) if m["seq"] else 0
    kv = int(m["kv"]) if m["kv"] else 0
    batch, tp = int(m["batch"]), int(m["tp"])
    ep = int(m["ep"]) if m["ep"] else 1
    if batch < 1 or tp < 1 or ep < 1 or (kv < 1 if decode else seq < 1):
        raise KeyError(f"bad LM workload parameters in {name!r}")
    if ep > 1 and not cfg.is_moe:
        raise KeyError(f"ep>1 in {name!r} needs a MoE arch; "
                       f"{cfg.name} is {cfg.family}")

    def build() -> List[Op]:
        return lm_layer_ops(cfg, seq=seq, batch=batch, tp_shards=tp,
                            phase="decode" if decode else "prefill",
                            kv_len=kv, ep_shards=ep)

    return build


def is_workload(name: str) -> bool:
    try:
        resolve_workload(name)
        return True
    except KeyError:
        return False


def workload_flops(ops: List[Op]) -> float:
    return sum(o.flops for o in ops)


def workload_bytes(ops: List[Op]) -> float:
    return sum(o.in_bytes + o.out_bytes + o.w_bytes for o in ops)
