"""Real-graph ingestion: scheduled HLO -> the hand-built ``Op`` contract.

The paper's pitch is "interfacing directly with AI frameworks ... linking
various in-house NPU graph compilers"; ``graph/hlo_parser.py`` already
parses ``jax.jit(...).lower(...).compile().as_text()`` into an
engine-mapped task list, and this module closes the loop by lowering
that list into the exact ``Op`` contract ``graph/workloads.py`` factories
produce — so every downstream consumer (``graph/compiler.py``,
the event engine, ``core/fastsim``/``core/batchsim``, the sweep
pre-screen, Power-EM) runs real compiler output unchanged.

Mapping rules (see docs/ARCHITECTURE.md for the worked tour):

* ``mxu`` tasks -> ``Op(kind="matmul")``. GEMM geometry comes from the
  parser's dominant-contraction view (``TaskSpec.gemm``: k = contracting
  dims, n = trailing output dim); ``m`` is rescaled so ``2*m*n*k``
  reproduces the task's total FLOPs (a fusion may contain several dots).
  A fused vector epilogue (``TaskSpec.elems``) becomes a companion
  VMEM-resident eltwise op so vector work is conserved.
* ``vector`` tasks -> ``Op(kind="eltwise", vec_kind="generic")`` (the
  kernel table kind is *estimated* — HLO fusion names don't identify the
  dominant kernel); ``dma`` tasks (copies/slices/layout ops) ->
  ``Op(kind="eltwise", vec_kind="copy", elems=1)`` — pure data movement,
  costed by their byte footprint.
* ``ici`` tasks -> collective op kinds (``allreduce``/``allgather``/
  ``reducescatter``/``alltoall``/``permute``) carrying the parser's
  payload bytes, decoded replica-group size, and cross-pod flag;
  trivial one-member groups are dropped.
* every non-collective op carries ``stream=True`` with the parser's
  fusion-level read/write byte estimates (and ``w_bytes=0`` — XLA
  already scheduled the weight movement as explicit tasks), so the
  compiled ``hbm_bytes`` equals the parser's HBM-traffic estimate
  exactly, at ``dtype_bytes=1`` (ingested byte counts are real bytes).

**Layer blocks**: the dominant while loop (a ``jax.lax.scan`` over
layers) is emitted as ``L<i>.<instr>`` blocks *first*, with every
outside-the-loop op (embedding/rope prologue, final norm + LM head)
moved after them — ``core.fastsim`` requires ``L0`` at task index 0 and
a contiguous tail to verify layer periodicity and extrapolate. The op
list is barrier-serialized by ``graph/compiler.py`` regardless of
order, so the move is latency-neutral; it is recorded as a modeling
choice in docs/ARCHITECTURE.md.

**Workload names** (registered in ``graph.workloads.resolve_workload``):

    hlo/<fixture>           the captured graph, all layers
    hlo/<fixture>@L<k>      first k layer blocks only (reduced twin —
                            what ``sweep.refine`` replays to extrapolate)

Fixtures are gzipped ``.hlo.txt.gz`` captures under
``src/repro/configs/hlo/`` with a ``manifest.json`` recording the
generation parameters, the hand-built twin workload name, the SHA-256 of
the decompressed text (staleness-checked by ``tools/check_fixtures.py``),
and the documented hand-built-vs-ingested analytic deviation band that
``python -m repro.sweep crosscheck-hlo`` and ``tests/test_ingest.py``
enforce. Regenerate with ``tools/gen_hlo_fixtures.py``.

No jax anywhere on the import path: refinement workers resolve
``hlo/...`` names in spawn-context subprocesses (see ``sweep/refine.py``).
"""
from __future__ import annotations

import gzip
import hashlib
import json
import os
import re
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from .hlo_parser import TaskSpec, extract_tasks
from .workloads import Op

__all__ = ["FIXTURE_DIR", "IngestReport", "lower_tasks", "structural_hash",
           "parse_hlo_name", "fixture_names", "fixture_meta", "load_fixture",
           "hlo_workload_name", "ingest_fixture", "load_manifest",
           "twin_name"]

FIXTURE_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "configs", "hlo")

_HLO_NAME_RE = re.compile(
    r"^hlo/(?P<fixture>[A-Za-z0-9_.\-]+)(?:@L(?P<layers>\d+))?$")

# parser collective op -> Op.kind (graph.compiler maps these onto
# hw.ici.CollectiveSpec op strings)
_COLLECTIVE_KINDS = {
    "all-reduce": "allreduce",
    "all-gather": "allgather",
    "reduce-scatter": "reducescatter",
    "all-to-all": "alltoall",
    "collective-permute": "permute",
}

_LOOP_RE = re.compile(r"^(?P<loop>[\w.\-]+)\[(?P<it>\d+)\]\.(?P<rest>.+)$")


@dataclass(frozen=True)
class IngestReport:
    """Conservation totals of one lowering (the differential harness in
    ``tests/test_ingest.py`` checks them against ``hlo_parser.summarize``
    and against the compiled workload)."""

    n_tasks: int                   # parser tasks consumed
    n_ops: int                     # ops emitted
    n_layers: int                  # dominant-loop trip count (0: no loop)
    layer_ops: int                 # ops per layer block
    mxu_flops: float               # sum of 2*m*n*k over matmul ops
    vector_elems: float            # sum of eltwise elems
    hbm_bytes: float               # sum of in+out bytes on streamed ops
    collective_bytes: float        # sum of collective payload bytes
    dropped_collectives: int       # group_size <= 1 collectives skipped
    structural_hash: str = ""


def structural_hash(ops: List[Op]) -> str:
    """Deterministic identity of a lowered op list: SHA-256 over every
    field of every op, in order. Same HLO text -> same hash (the
    determinism property in tests/test_ingest.py)."""
    h = hashlib.sha256()
    for op in ops:
        h.update(repr(op).encode())
        h.update(b"\0")
    return h.hexdigest()


def _dominant_loop(tasks: List[TaskSpec]) -> Tuple[Optional[str], int]:
    """(loop instruction name, trip count) of the while loop carrying the
    most tasks — the scanned layer stack — or (None, 0) without loops."""
    counts: Dict[str, int] = {}
    trips: Dict[str, int] = {}
    for t in tasks:
        m = _LOOP_RE.match(t.name)
        if m:
            loop = m.group("loop")
            counts[loop] = counts.get(loop, 0) + 1
            trips[loop] = max(trips.get(loop, 0), int(m.group("it")) + 1)
    if not counts:
        return None, 0
    loop = max(counts, key=lambda n: (counts[n], n))
    return loop, trips[loop]


def _gemm_dims(t: TaskSpec) -> Tuple[int, int, int]:
    """(m, n, k) for one mxu task, FLOP-preserving: n/k come from the
    parser's dominant contraction, m is rescaled so 2*m*n*k == flops
    (fusions can contain several dots; ``m`` absorbs them)."""
    if t.gemm:
        _, n, k = t.gemm
    else:
        # no recoverable contraction: spread the FLOPs over a cube-ish
        # GEMM so the MXU model sees a realistic blocking, not a GEMV
        s = max(int(round((t.flops / 2.0) ** (1.0 / 3.0))), 1)
        n = k = s
    m = max(int(round(t.flops / (2.0 * n * k))), 1)
    return m, int(n), int(k)


def _lower_one(t: TaskSpec, name: str, out: List[Op]) -> Dict[str, float]:
    """Append the Op(s) for one parser task; returns its totals."""
    tot = {"mxu_flops": 0.0, "vector_elems": 0.0, "hbm_bytes": 0.0,
           "collective_bytes": 0.0, "dropped": 0.0}
    if t.engine == "ici":
        coll = t.collective
        kind = _COLLECTIVE_KINDS.get(coll.op if coll else "", None)
        if kind is None or coll is None or coll.group_size <= 1:
            tot["dropped"] = 1.0
            return tot
        out.append(Op(name=name, kind=kind, in_bytes=coll.payload_bytes,
                      out_bytes=t.bytes_out, group=coll.group_size,
                      cross_pod=coll.crosses_pod))
        tot["collective_bytes"] = float(coll.payload_bytes)
        return tot
    # the event engine's Dma never completes a zero-byte descriptor, and
    # fusions rooted at iota/constant legitimately read nothing — clamp
    # streamed footprints to one byte (noise next to the 5% byte band)
    b_in, b_out = max(t.bytes_in, 1.0), max(t.bytes_out, 1.0)
    if t.engine == "mxu":
        m, n, k = _gemm_dims(t)
        out.append(Op(name=name, kind="matmul", m=m, n=n, k=k,
                      in_bytes=b_in, out_bytes=b_out,
                      stream=True))
        tot["mxu_flops"] = 2.0 * m * n * k
        tot["hbm_bytes"] = b_in + b_out
        if t.elems > 0:
            # fused vector epilogue: VMEM-resident companion (no byte
            # footprint — the mxu op already carries the HBM traffic)
            out.append(Op(name=f"{name}.post", kind="eltwise",
                          elems=t.elems, vec_kind="generic"))
            tot["vector_elems"] = float(t.elems)
        return tot
    vec_kind = "copy" if t.engine == "dma" else "generic"
    elems = 1.0 if t.engine == "dma" else max(t.elems, 1.0)
    out.append(Op(name=name, kind="eltwise", elems=elems,
                  vec_kind=vec_kind, in_bytes=b_in,
                  out_bytes=b_out, stream=True))
    tot["vector_elems"] = elems
    tot["hbm_bytes"] = b_in + b_out
    return tot


def lower_tasks(tasks: List[TaskSpec], *,
                layers_keep: Optional[int] = None
                ) -> Tuple[List[Op], IngestReport]:
    """Lower a parser task list into the hand-built ``Op`` contract.

    The dominant while loop's iterations become ``L<i>.*`` layer blocks
    emitted first; everything outside the loop follows in scheduled
    order (see module docstring for why). ``layers_keep`` truncates to
    the first k layer blocks (the ``@L<k>`` reduced-twin form) while
    keeping the out-of-loop prologue/epilogue intact, so full and
    reduced lowerings share block structure and tail — exactly what
    ``core.fastsim.match_blocks`` requires.
    """
    loop, trip = _dominant_loop(tasks)
    if layers_keep is not None:
        if loop is None:
            raise KeyError("@L<k> reduction needs a scanned layer loop; "
                           "this graph has none")
        if not 1 <= layers_keep <= trip:
            raise KeyError(f"@L{layers_keep} out of range: graph has "
                           f"{trip} layers")
    layer_ops: List[Op] = []
    rest_ops: List[Op] = []
    tot = {"mxu_flops": 0.0, "vector_elems": 0.0, "hbm_bytes": 0.0,
           "collective_bytes": 0.0, "dropped": 0.0}
    layer0_ops = 0
    for t in tasks:
        m = _LOOP_RE.match(t.name)
        if m and m.group("loop") == loop:
            it = int(m.group("it"))
            if layers_keep is not None and it >= layers_keep:
                continue
            dst, name = layer_ops, f"L{it}.{m.group('rest')}"
        else:
            dst, name = rest_ops, t.name.replace("[", "_").replace("]", "_")
        before = len(dst)
        sub = _lower_one(t, name, dst)
        if m and m.group("loop") == loop and int(m.group("it")) == 0:
            layer0_ops += len(dst) - before
        for key in tot:
            tot[key] += sub[key]
    ops = layer_ops + rest_ops
    rep = IngestReport(
        n_tasks=len(tasks), n_ops=len(ops),
        n_layers=(layers_keep if layers_keep is not None else trip),
        layer_ops=layer0_ops,
        mxu_flops=tot["mxu_flops"], vector_elems=tot["vector_elems"],
        hbm_bytes=tot["hbm_bytes"],
        collective_bytes=tot["collective_bytes"],
        dropped_collectives=int(tot["dropped"]),
        structural_hash=structural_hash(ops))
    return ops, rep


# ---------------------------------------------------------------------------
# fixture registry (``hlo/<fixture>[@L<k>]`` workload names)
# ---------------------------------------------------------------------------

_manifest_cache: Dict[str, Any] = {}
_ops_cache: Dict[Tuple[str, Optional[int]], Tuple[List[Op], IngestReport]] = {}


def load_manifest(fixture_dir: str = FIXTURE_DIR) -> Dict[str, Any]:
    """The fixture manifest (cached per directory)."""
    hit = _manifest_cache.get(fixture_dir)
    if hit is not None:
        return hit
    path = os.path.join(fixture_dir, "manifest.json")
    if not os.path.exists(path):
        man: Dict[str, Any] = {"fixtures": {}}
    else:
        with open(path) as f:
            man = json.load(f)
    _manifest_cache[fixture_dir] = man
    return man


def fixture_names(fixture_dir: str = FIXTURE_DIR) -> List[str]:
    return sorted(load_manifest(fixture_dir)["fixtures"])


def fixture_meta(fixture: str, fixture_dir: str = FIXTURE_DIR
                 ) -> Dict[str, Any]:
    fixtures = load_manifest(fixture_dir)["fixtures"]
    if fixture not in fixtures:
        raise KeyError(f"unknown HLO fixture {fixture!r}; have "
                       f"{sorted(fixtures)} (regenerate with "
                       f"tools/gen_hlo_fixtures.py)")
    return fixtures[fixture]


def load_fixture(fixture: str, fixture_dir: str = FIXTURE_DIR) -> str:
    """Decompressed HLO text of one fixture."""
    meta = fixture_meta(fixture, fixture_dir)
    path = os.path.join(fixture_dir, meta["file"])
    with gzip.open(path, "rt") as f:
        return f.read()


def parse_hlo_name(name: str) -> Optional[Dict[str, Any]]:
    """``hlo/<fixture>[@L<k>]`` -> {"fixture", "layers_keep"}, or None
    when the name is not HLO-shaped."""
    m = _HLO_NAME_RE.match(name)
    if not m:
        return None
    return {"fixture": m.group("fixture"),
            "layers_keep": int(m.group("layers")) if m.group("layers")
            else None}


def hlo_workload_name(fixture: str, *, layers: Optional[int] = None) -> str:
    return f"hlo/{fixture}" + (f"@L{layers}" if layers else "")


def ingest_fixture(fixture: str, *, layers_keep: Optional[int] = None,
                   fixture_dir: str = FIXTURE_DIR
                   ) -> Tuple[List[Op], IngestReport]:
    """Parse + lower one fixture (memoized: campaigns resolve the same
    ``hlo/...`` name once per cell and twin replays re-resolve it)."""
    key = (os.path.join(fixture_dir, fixture), layers_keep)
    hit = _ops_cache.get(key)
    if hit is not None:
        return hit
    meta = fixture_meta(fixture, fixture_dir)
    tasks = extract_tasks(load_fixture(fixture, fixture_dir),
                          pod_size=int(meta.get("pod_size", 0)))
    ops, rep = lower_tasks(tasks, layers_keep=layers_keep)
    _ops_cache[key] = (ops, rep)
    return ops, rep


def twin_name(fixture: str, *, layers: Optional[int] = None,
              fixture_dir: str = FIXTURE_DIR) -> str:
    """The hand-built ``lm/...`` twin of a fixture (from the manifest),
    with its ``L<layers>`` segment rewritten for ``@L<k>`` reductions."""
    meta = fixture_meta(fixture, fixture_dir)
    twin = meta["twin"]
    if layers:
        twin = re.sub(r"/L\d+/", f"/L{layers}/", twin, count=1)
    return twin


def resolve_hlo(name: str):
    """``resolve_workload`` hook: op-list factory for an ``hlo/...``
    name; raises KeyError (with the available fixtures) on bad names."""
    p = parse_hlo_name(name)
    if p is None:
        raise KeyError(
            f"bad HLO workload name {name!r}; grammar: "
            f"'hlo/<fixture>[@L<k>]' with fixtures "
            f"{fixture_names()}")
    fixture, keep = p["fixture"], p["layers_keep"]
    fixture_meta(fixture)             # raise early on unknown fixture
    if keep is not None:              # validate the reduction eagerly
        ingest_fixture(fixture, layers_keep=keep)

    def build() -> List[Op]:
        return list(ingest_fixture(fixture, layers_keep=keep)[0])

    return build
