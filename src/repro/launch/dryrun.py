import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell: build the production
program, ``.lower().compile()`` it against ShapeDtypeStruct stand-ins (no
allocation), print ``memory_analysis()`` / ``cost_analysis()``, and write a
JSON artifact (+ gzip'd optimized HLO) that the roofline analysis and the
TPU-EM simulator ingest.

The first two lines above MUST run before any jax import: jax locks the
device count on first initialization, and this driver needs 512 host
placeholder devices to build the 2x16x16 production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out benchmarks/artifacts/dryrun
"""
import argparse
import gzip
import json
import time
import traceback
from typing import Dict, Optional

import jax

from ..configs import REGISTRY, SHAPES, get_config, get_shape, skip_reason
from .mesh import make_production_mesh
from .programs import build_program

__all__ = ["run_cell", "main"]


def _mem_dict(compiled) -> Dict:
    try:
        m = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(m)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, save_hlo: bool = True,
             verbose: bool = True, **program_kw) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_tag,
            "program": shape.program, "devices": 512 if multi_pod else 256}

    reason = skip_reason(cfg, shape)
    if reason:
        cell.update(status="skip", skip_reason=reason)
        _write(cell, out_dir)
        if verbose:
            print(f"[skip] {cfg.name} x {shape.name} x {mesh_tag}: {reason}")
        return cell

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        prog = build_program(cfg, shape, mesh, **program_kw)
        lowered = prog.lower()
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        cost = {k: float(v) for k, v in (compiled.cost_analysis() or {}).items()
                if isinstance(v, (int, float))}
        mem = _mem_dict(compiled)
        cell.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            cost_analysis=cost,
            memory_analysis=mem,
            param_count=cfg.param_count(),
            active_param_count=cfg.active_param_count(),
        )
        if verbose:
            print(f"[ok]   {cfg.name} x {shape.name} x {mesh_tag} "
                  f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
            print(f"       memory_analysis: {mem}")
            fl = cost.get("flops", 0.0)
            print(f"       cost_analysis: flops={fl:.3e} "
                  f"bytes={cost.get('bytes accessed', 0.0):.3e}")
        if save_hlo and out_dir:
            hlo = compiled.as_text()
            path = os.path.join(
                out_dir, f"{cfg.name}__{shape.name}__{mesh_tag}.hlo.txt.gz")
            os.makedirs(out_dir, exist_ok=True)
            with gzip.open(path, "wt") as f:
                f.write(hlo)
            cell["hlo_file"] = os.path.basename(path)
    except Exception as e:
        cell.update(status="fail", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[FAIL] {cfg.name} x {shape.name} x {mesh_tag}: "
                  f"{type(e).__name__}: {e}")
    _write(cell, out_dir)
    return cell


def _write(cell: Dict, out_dir: Optional[str]):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{cell['arch']}__{cell['shape']}__{cell['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(cell, f, indent=1)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="all")
    p.add_argument("--shape", default="all")
    p.add_argument("--mesh", default="both",
                   choices=["single", "multi", "both"])
    p.add_argument("--out", default="benchmarks/artifacts/dryrun")
    p.add_argument("--no-hlo", action="store_true")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--remat-policy", default="full",
                   choices=["full", "save-attn"],
                   help="activation-checkpoint policy (perf iterations)")
    p.add_argument("--microbatches", type=int, default=1)
    args = p.parse_args(argv)
    program_kw = {}
    if args.remat_policy != "full":
        program_kw["model_kw"] = {"remat_policy": args.remat_policy}
    if args.microbatches > 1:
        program_kw["microbatches"] = args.microbatches

    archs = list(REGISTRY) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = "pod2x16x16" if multi else "pod16x16"
                if args.skip_existing:
                    f = os.path.join(args.out,
                                     f"{arch}__{shape}__{tag}.json")
                    if os.path.exists(f):
                        prev = json.load(open(f))
                        if prev.get("status") in ("ok", "skip"):
                            print(f"[cached] {arch} x {shape} x {tag}: "
                                  f"{prev['status']}")
                            results.append(prev)
                            continue
                results.append(run_cell(arch, shape, multi, args.out,
                                        save_hlo=not args.no_hlo,
                                        **program_kw))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\n=== dry-run summary: {n_ok} ok, {n_skip} structural skips, "
          f"{n_fail} FAILED of {len(results)} cells ===")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
