"""Mesh construction for the production pods.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because the dry-run
driver forces 512 host platform devices while tests/benches must see 1.

Production topology (TPU v5e target):
  single pod : 16 x 16  = 256 chips, axes (data, model)
  multi-pod  : 2 x 16 x 16 = 512 chips, axes (pod, data, model)
The 'pod' axis crosses DCN; 'data'/'model' stay on intra-pod ICI.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_mesh", "single_device_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    """1-device mesh with the standard axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
