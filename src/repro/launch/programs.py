"""Shared program builder: (arch x shape x mesh) -> jit-able program.

Used by the dry-run driver (lower+compile only), the real train/serve
drivers (same program, real data), and the benchmarks. One construction
path means the dry-run provably exercises the deployed program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..distributed.sharding import ShardingRules, rules_for
from ..models.layers import abstract_params
from ..models.model import Model, build_model
from ..serve.engine import make_decode_fn, make_prefill_fn
from ..train.loop import abstract_state, batch_pspecs, make_train_step, \
    state_pspecs

__all__ = ["Program", "build_program", "rules_for_arch"]


@dataclass
class Program:
    name: str
    fn: Callable
    abstract_args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    model: Model
    rules: ShardingRules

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.abstract_args)


def rules_for_arch(cfg: ArchConfig, mesh: Mesh, *,
                   serving: bool = False) -> ShardingRules:
    fsdp = True
    if serving:
        # serving memory planner: replicate weights over 'data' (kills the
        # per-layer FSDP all-gathers in each decode step) unless the
        # TP-sharded parameters alone would crowd HBM
        msize = dict(mesh.shape).get("model", 1)
        per_chip_param_bytes = 2.0 * cfg.param_count() / max(msize, 1)
        fsdp = per_chip_param_bytes > 8e9
    return rules_for(
        mesh,
        n_heads=cfg.n_heads,
        n_experts=cfg.n_experts,
        d_ff=cfg.d_ff,
        moe=cfg.is_moe,
        fsdp=fsdp,
    )


def _named(tree_pspec, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_pspec,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_program(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh: Mesh,
    *,
    microbatches: int = 1,
    compress: bool = False,
    remat: bool = True,
    model_kw: Optional[Dict] = None,
) -> Program:
    rules = rules_for_arch(cfg, mesh, serving=shape.kind != "train")
    model = build_model(cfg, remat=remat, **(model_kw or {}))
    batch_abs = abstract_params(model.batch_template(shape))
    batch_ps = batch_pspecs(model, shape, rules)

    if shape.kind == "train":
        fn = make_train_step(model, rules, microbatches=microbatches,
                             compress=compress)
        st_abs = abstract_state(model, compress=compress)
        st_ps = state_pspecs(model, rules, compress=compress)
        metrics_ps = {"loss": P(), "grad_norm": P(), "lr": P()}
        return Program(
            name=f"train_step[{cfg.name}/{shape.name}]",
            fn=fn,
            abstract_args=(st_abs, batch_abs),
            in_shardings=(_named(st_ps, mesh), _named(batch_ps, mesh)),
            out_shardings=(_named(st_ps, mesh), _named(metrics_ps, mesh)),
            donate_argnums=(0,),
            model=model,
            rules=rules,
        )

    if shape.kind == "prefill":
        smax = shape.seq_len
        fn = make_prefill_fn(model, rules, smax)
        params_abs = model.abstract()
        params_ps = model.pspecs(rules)
        if cfg.encoder_only:
            # encoder "prefill" = full forward; no cache exists
            def enc_fn(params, batch):
                from ..distributed.sharding import use_rules
                with use_rules(rules):
                    h = model.forward(params, batch, for_train=False)
                    return h

            return Program(
                name=f"encode[{cfg.name}/{shape.name}]",
                fn=enc_fn,
                abstract_args=(params_abs, batch_abs),
                in_shardings=(_named(params_ps, mesh), _named(batch_ps, mesh)),
                out_shardings=None,
                donate_argnums=(),
                model=model,
                rules=rules,
            )
        cache_ps = model.cache_pspecs(shape.global_batch, smax, rules)
        logits_ps = P(rules.table.get("batch"), rules.table.get("vocab"))
        return Program(
            name=f"prefill[{cfg.name}/{shape.name}]",
            fn=fn,
            abstract_args=(params_abs, batch_abs),
            in_shardings=(_named(params_ps, mesh), _named(batch_ps, mesh)),
            out_shardings=(NamedSharding(mesh, logits_ps),
                           _named(cache_ps, mesh)),
            donate_argnums=(),
            model=model,
            rules=rules,
        )

    # decode: one token against a cache of capacity seq_len
    smax = shape.seq_len
    B = shape.global_batch
    fn = make_decode_fn(model, rules)
    params_abs = model.abstract()
    params_ps = model.pspecs(rules)
    cache_abs = model.abstract_cache(B, smax)
    cache_ps = model.cache_pspecs(B, smax, rules)
    batch_guard = rules.table.get("batch")
    if batch_guard is not None:
        n = rules.axis_size("batch")
        if B % max(n, 1) != 0:
            batch_guard = None
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, P(batch_guard, None))
    logits_sh = NamedSharding(mesh, P(batch_guard, rules.table.get("vocab")))
    return Program(
        name=f"decode[{cfg.name}/{shape.name}]",
        fn=fn,
        abstract_args=(params_abs, cache_abs, tok_abs),
        in_shardings=(_named(params_ps, mesh), _named(cache_ps, mesh), tok_sh),
        out_shardings=(logits_sh, _named(cache_ps, mesh)),
        donate_argnums=(1,),
        model=model,
        rules=rules,
    )
