"""Training driver with checkpoint/restart fault tolerance.

Runs REAL steps on the host devices (CPU here, TPU pod in production —
the same ``build_program`` path the dry-run validates). Synthetic data
pipeline with a checkpointed cursor: kill the process at any step and
re-launch with the same --ckpt-dir to resume bit-identically.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..configs.base import ShapeSpec
from ..models import build_model
from ..train import (CheckpointManager, SyntheticData, init_state,
                     latest_step, make_train_step, restore_checkpoint,
                     schedule_for)

__all__ = ["main", "train"]


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
          reduced: bool = False, ckpt_dir: str = "", save_every: int = 25,
          microbatches: int = 1, compress: bool = False,
          dtype=jnp.float32, log_every: int = 10, peak_lr: float = 3e-4,
          seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=True)
    shape = ShapeSpec("cli", seq, batch, "train")
    data = SyntheticData(cfg, shape, seed=seed)
    step_fn = jax.jit(make_train_step(
        model, None, microbatches=microbatches, compress=compress,
        lr_schedule=schedule_for(cfg, peak_lr=peak_lr, warmup=max(steps // 20, 1),
                                 total=steps)),
        donate_argnums=(0,))

    start = 0
    state = None
    mgr = CheckpointManager(ckpt_dir, save_every=save_every) if ckpt_dir \
        else None
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            tmpl = init_state(model, jax.random.PRNGKey(seed), dtype=dtype,
                              compress=compress)
            state, cursor, _ = restore_checkpoint(ckpt_dir, last, tmpl)
            start = cursor
            print(f"[resume] restored step {last}, data cursor {cursor}")
    if state is None:
        state = init_state(model, jax.random.PRNGKey(seed), dtype=dtype,
                           compress=compress)
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(state["params"]))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={batch} seq={seq} steps {start}->{steps}")

    losses = []
    t0 = time.time()
    for s in range(start, steps):
        state, metrics = step_fn(state, data.batch_at(s))
        losses.append(float(metrics["loss"]))
        if s % log_every == 0 or s == steps - 1:
            dt = time.time() - t0
            tps = (s - start + 1) * batch * seq / max(dt, 1e-9)
            print(f"  step {s:5d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({tps:,.0f} tok/s)")
        if mgr is not None:
            mgr.maybe_save(s + 1, state, data_cursor=s + 1,
                           meta={"arch": cfg.name})
    if mgr is not None:
        mgr.wait()
    return state, losses


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--save-every", type=int, default=25)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--compress", action="store_true",
                   help="int8 EF gradient compression")
    p.add_argument("--bf16", action="store_true")
    p.add_argument("--lr", type=float, default=3e-4)
    args = p.parse_args(argv)
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          reduced=args.reduced, ckpt_dir=args.ckpt_dir,
          save_every=args.save_every, microbatches=args.microbatches,
          compress=args.compress, peak_lr=args.lr,
          dtype=jnp.bfloat16 if args.bf16 else jnp.float32)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
