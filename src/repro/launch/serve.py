"""Serving driver: batched generation with the ServeEngine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 6 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import build_model
from ..serve import ServeEngine

__all__ = ["main"]


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new", type=int, default=12)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--smax", type=int, default=128)
    p.add_argument("--deadline", type=int, default=0,
                   help="straggler deadline (decode steps); 0 = none")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, smax=args.smax)
    rng = np.random.default_rng(0)
    rids = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 16))
        rids.append(eng.submit(prompt, max_new=args.max_new,
                               deadline_steps=args.deadline or None))
    t0 = time.time()
    out = eng.run(batch_size=args.batch)
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    print(f"[serve] {cfg.name}: {len(out)}/{args.requests} requests, "
          f"{total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s), "
          f"evicted={len(eng.evicted)}")
    for rid in rids[:3]:
        if rid in out:
            print(f"  req {rid}: {out[rid]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
