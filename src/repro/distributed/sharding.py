"""Logical-axis sharding rules (DP / FSDP / TP / SP / EP).

Models are written against *logical* axis names; a ``ShardingRules`` object
maps them to physical mesh axes. Outside any rules context (CPU smoke tests)
every constraint is a no-op, so the same model code runs on 1 device and on
the 512-chip production mesh.

Logical axes
------------
  batch      activation batch dim                    -> ('pod','data')
  act_seq    activation sequence dim (SP regime)     -> 'model' | None
  heads      attention-head dim (TP regime)          -> 'model' | None
  kv_heads   kv-head dim                             -> usually None (small)
  ff         FFN hidden dim                          -> 'model'
  vocab      vocabulary dim (embed/logits)           -> 'model'
  embed      parameter d_model dim (FSDP shard)      -> 'data'
  expert     MoE expert dim                          -> 'model'
  kv_seq     KV-cache sequence dim (flash-decoding)  -> 'model'
  ssm_inner  SSM inner-channel dim                   -> 'model'
  stack      layer-stack dim of scanned params       -> None (never sharded)

Exactly one of {heads, act_seq} maps to 'model' for a given arch: head-TP
when n_heads divides the model axis, sequence-parallel attention otherwise
(divisibility-aware axis assignment).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "rules_for",
    "active_rules",
    "use_rules",
    "constrain",
    "logical_to_pspec",
    "named_sharding",
]

Axis = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    table: Dict[str, Axis]
    moe_impl: str = "dense"      # "dense" | "ep"
    ep_axis: Optional[str] = None

    def axis_size(self, logical: str) -> int:
        phys = self.table.get(logical)
        if phys is None:
            return 1
        if isinstance(phys, str):
            phys = (phys,)
        n = 1
        for a in phys:
            n *= self.mesh.shape[a]
        return n


def rules_for(
    mesh: Mesh,
    *,
    n_heads: int = 0,
    n_experts: int = 0,
    d_ff: int = 0,
    moe: bool = False,
    fsdp: bool = True,
    sp_residual: bool = False,
) -> ShardingRules:
    """Divisibility-aware assignment of logical->physical axes for one arch.

    ``fsdp=False`` replicates parameters over the data axis (serving mode:
    no optimizer state, and per-layer weight all-gathers would dominate a
    decode step — the serving memory planner in launch/programs decides).
    """
    names = mesh.axis_names
    data_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in names)
    model_ax = "model" if "model" in names else None
    msize = mesh.shape[model_ax] if model_ax else 1

    head_tp = model_ax is not None and n_heads > 0 and n_heads % msize == 0
    table: Dict[str, Axis] = {
        "batch": data_axes if data_axes else None,
        "heads": model_ax if head_tp else None,
        # sp_residual: Megatron-SP — keep the residual stream seq-sharded
        # even under heads-TP (the rightmost-wins dedup in ``constrain``
        # resolves the conflict inside attention/MLP tensors); turns the
        # backward dgrad all-reduces into reduce-scatters
        "act_seq": (model_ax if (sp_residual or not head_tp) else None),
        "kv_heads": None,
        "ff": model_ax if (d_ff == 0 or d_ff % max(msize, 1) == 0) else None,
        "vocab": model_ax,
        "embed": ("data" if ("data" in names and fsdp) else None),
        "expert": model_ax,
        "kv_seq": model_ax,
        "ssm_inner": model_ax,
        "stack": None,
    }
    ep_ok = moe and model_ax is not None and n_experts % max(msize, 1) == 0
    return ShardingRules(
        mesh=mesh,
        table=table,
        moe_impl="ep" if ep_ok else "dense",
        ep_axis=model_ax if ep_ok else None,
    )


def active_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def logical_to_pspec(axes: Sequence[Optional[str]], rules: ShardingRules) -> P:
    parts = []
    for name in axes:
        if name is None:
            parts.append(None)
        else:
            parts.append(rules.table.get(name))
    # trim trailing Nones (cosmetic)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def named_sharding(axes: Sequence[Optional[str]], rules: ShardingRules) -> NamedSharding:
    return NamedSharding(rules.mesh, logical_to_pspec(axes, rules))


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical axis names; no-op w/o rules.

    Divisibility guard: a dim that the mapped mesh axes do not evenly divide
    is left unsharded (avoids GSPMD padding surprises, e.g. batch=1 decode).
    """
    rules = active_rules()
    if rules is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"{len(axes)} axes for rank-{x.ndim} array")
    parts = []
    for dim, name in zip(x.shape, axes):
        phys = rules.table.get(name) if name is not None else None
        if phys is not None:
            n = rules.axis_size(name)
            if n <= 1 or dim % n != 0:
                phys = None
        parts.append(phys)
    # dedup mesh axes: rightmost dim wins (feature/TP dims sit rightmost —
    # e.g. [B, S(act_seq->model), ff(->model)] resolves to ff-sharded, the
    # Megatron-SP convention: gather seq, compute TP-sharded hidden)
    used: set = set()
    for i in range(len(parts) - 1, -1, -1):
        phys = parts[i]
        if phys is None:
            continue
        names = (phys,) if isinstance(phys, str) else tuple(phys)
        if any(a in used for a in names):
            parts[i] = None
        else:
            used.update(names)
    while parts and parts[-1] is None:
        parts.pop()
    spec = P(*parts)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
