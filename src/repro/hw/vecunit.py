"""Vector unit model — the VLIW-DSP analog (paper §3.2, Figure 4).

3-stage pipeline (load -> exec -> store) over SIMD data blocks, with the
paper's **kernel characterization table**: per elementwise kernel kind,

    cycles = offset + a * unroll_blocks + b * vectors + c * scalars

where a vector is one SIMD row (lanes*sublanes elements) and an unroll
block is ``unroll`` vectors. The paper fits these from MoviSim ISA runs;
MoviSim is proprietary, so ``fit_table`` provides the same least-squares
fit from (n_elems, cycles) samples — tests fit against a golden cost
model to validate the machinery, and the default table carries hand-set
constants for the common kernels (DESIGN.md §assumption-changes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Generator, Iterable, Tuple

import numpy as np

from ..core import Environment, Store, Tracer
from .memory import VMem
from .presets import HwConfig

__all__ = ["VecKernel", "VecSpec", "VecUnit", "fit_table", "DEFAULT_TABLE"]


@dataclass(frozen=True)
class VecKernel:
    """Characterization row: cycles = offset + a*unroll_blocks + b*vectors
    + c*scalars."""

    offset: float
    a: float       # per unroll block (``unroll`` vectors)
    b: float       # per SIMD vector
    c: float       # per scalar remainder element
    unroll: int = 8

    def cycles(self, n_elems: float, lane_width: int) -> float:
        vectors = int(n_elems // lane_width)
        scalars = n_elems - vectors * lane_width
        blocks = vectors // self.unroll
        rem_vectors = vectors - blocks * self.unroll
        return (self.offset + self.a * blocks + self.b * rem_vectors
                + self.c * scalars)


# offsets/slopes in cycles; a ~= unroll * b with slight amortization gain
DEFAULT_TABLE: Dict[str, VecKernel] = {
    "add": VecKernel(offset=24, a=7.0, b=1.0, c=1.0),
    "mul": VecKernel(offset=24, a=7.0, b=1.0, c=1.0),
    "copy": VecKernel(offset=16, a=6.5, b=1.0, c=1.0),
    "exp": VecKernel(offset=40, a=22.0, b=3.0, c=6.0),
    "tanh": VecKernel(offset=40, a=26.0, b=3.5, c=7.0),
    "sigmoid": VecKernel(offset=40, a=24.0, b=3.2, c=6.5),
    "hswish": VecKernel(offset=36, a=14.0, b=2.0, c=3.0),
    "rsqrt": VecKernel(offset=40, a=18.0, b=2.5, c=5.0),
    "reduce": VecKernel(offset=32, a=8.0, b=1.2, c=1.5),
    "softmax": VecKernel(offset=64, a=46.0, b=6.2, c=12.0),
    "generic": VecKernel(offset=32, a=10.0, b=1.4, c=2.0),
}


@dataclass(frozen=True)
class VecSpec:
    """One vector-unit task."""

    n_elems: float
    kind: str = "generic"
    bytes_in: float = 0.0
    bytes_out: float = 0.0
    name: str = ""


class VecUnit:
    def __init__(self, env: Environment, cfg: HwConfig, vmem: VMem,
                 tracer: Tracer, name: str = "vpu",
                 table: Dict[str, VecKernel] = None):
        self.env = env
        self.cfg = cfg
        self.vmem = vmem
        self.tracer = tracer
        self.name = name
        self.table = dict(DEFAULT_TABLE if table is None else table)
        self.lane_width = cfg.vpu_lanes * cfg.vpu_sublanes

    def kernel(self, kind: str) -> VecKernel:
        return self.table.get(kind, self.table["generic"])

    def run(self, spec: VecSpec) -> Generator:
        """3-stage pipeline over SIMD blocks of the element stream."""
        env, cfg = self.env, self.cfg
        kern = self.kernel(spec.kind)
        block_elems = self.lane_width * kern.unroll * 64  # data block
        n_blocks = max(1, int(-(-spec.n_elems // block_elems)))
        bytes_in = (spec.bytes_in or spec.n_elems * 2) / n_blocks
        bytes_out = (spec.bytes_out or spec.n_elems * 2) / n_blocks

        q_in = Store(env, capacity=cfg.pipeline_depth)
        q_out = Store(env, capacity=cfg.pipeline_depth)
        done = env.event()

        def load_proc():
            rem = spec.n_elems
            for _ in range(n_blocks):
                elems = min(block_elems, rem)
                rem -= elems
                yield from self.vmem.transfer(bytes_in)
                yield q_in.put(elems)

        def exec_proc():
            for _ in range(n_blocks):
                elems = yield q_in.get()
                cycles = kern.cycles(elems, self.lane_width)
                t0 = env.now
                yield env.timeout(cycles * cfg.cycle_ns)
                self.tracer.emit(self.name, "ops", t0, env.now, elems)
                yield q_out.put(elems)

        def store_proc():
            for _ in range(n_blocks):
                yield q_out.get()
                yield from self.vmem.transfer(bytes_out)
            done.succeed()

        env.process(load_proc(), name=f"{self.name}.load")
        env.process(exec_proc(), name=f"{self.name}.exec")
        env.process(store_proc(), name=f"{self.name}.store")
        yield done

    def ideal_time_ns(self, spec: VecSpec) -> float:
        kern = self.kernel(spec.kind)
        return kern.cycles(spec.n_elems, self.lane_width) * self.cfg.cycle_ns


def fit_table(samples: Iterable[Tuple[float, float]], lane_width: int,
              unroll: int = 8) -> VecKernel:
    """Least-squares fit of (n_elems, cycles) samples to the paper's
    offset + 3-linear-curves model (the MoviSim-characterization stand-in)."""
    rows = []
    ys = []
    for n_elems, cycles in samples:
        vectors = int(n_elems // lane_width)
        scalars = n_elems - vectors * lane_width
        blocks = vectors // unroll
        rem_vectors = vectors - blocks * unroll
        rows.append([1.0, blocks, rem_vectors, scalars])
        ys.append(cycles)
    coef, *_ = np.linalg.lstsq(np.asarray(rows), np.asarray(ys), rcond=None)
    return VecKernel(offset=float(coef[0]), a=float(coef[1]),
                     b=float(coef[2]), c=float(coef[3]), unroll=unroll)
