"""Pod-scale simulation: replay a compiled (SPMD) program through TPU-EM.

SPMD symmetry argument: post-GSPMD, all 256 (or 512) chips execute the
same per-device program; chips are interchangeable, so ONE detailed chip
model paces the pod while collectives run on the ICI/DCN fabric model with
ring schedules. This is the "at scale" adaptation of the paper's multi-tile
simulation — the paper simulates 1-4 tiles exhaustively; at 256+ chips the
symmetric-replay is what keeps full-model simulation within the paper's
"minutes" speed objective (§2.3).

``hlo_to_tasks`` converts the HLO-extracted TaskSpec DAG (graph.hlo_parser)
into engine tasks with one barrier per producer, preserving the real
dependency structure of the compiled program, including the
compute/collective overlap XLA scheduled.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence

from ..graph.hlo_parser import TaskSpec
from ..graph.tasks import Task
from .chip import Report, System
from .dma import DmaDescriptor
from .ici import CollectiveSpec
from .mxu import GemmSpec
from .presets import HwConfig
from .vecunit import VecSpec

__all__ = ["PodShape", "hlo_to_tasks", "simulate_program"]


@dataclass(frozen=True)
class PodShape:
    """Placement of a DP x EP x TP parallelism cube onto pods.

    Chips are laid out with TP innermost (contiguous chips, fastest
    collectives), EP next, DP outermost — the standard serving/training
    placement. ``pod_chips`` is the size of one ICI domain; a collective
    whose group *span* (group size x chip stride of its axis) exceeds it
    has at least one ring hop crossing pod boundaries, so the whole ring
    is paced by the DCN segment (``CollectiveSpec.cross_pod`` routes it
    onto the DCN resource in ``hw.ici.IciFabric``). ``pod_chips == 0``
    means a single unbounded pod (nothing crosses).
    """

    dp: int = 1
    tp: int = 1
    ep: int = 1
    pod_chips: int = 0

    def __post_init__(self):
        if min(self.dp, self.tp, self.ep) < 1 or self.pod_chips < 0:
            raise ValueError(f"bad pod shape {self}")

    @property
    def chips(self) -> int:
        return self.dp * self.tp * self.ep

    @property
    def n_pods(self) -> int:
        if not self.pod_chips:
            return 1
        return -(-self.chips // self.pod_chips)

    def span(self, axis: str) -> int:
        """Chip span of one collective group on ``axis``: group size
        times the stride between successive members (TP stride 1, EP
        stride tp, DP stride tp*ep)."""
        if axis == "tp":
            return self.tp
        if axis == "ep":
            return self.tp * self.ep
        if axis == "dp":
            return self.tp * self.ep * self.dp
        raise ValueError(f"axis must be tp|ep|dp, got {axis!r}")

    def crosses_pod(self, axis: str) -> bool:
        """True when an ``axis`` collective's ring leaves the pod."""
        return bool(self.pod_chips) and self.span(axis) > self.pod_chips


def _gemm_dims(flops: float, bytes_in: float, bytes_out: float
               ) -> GemmSpec:
    """Reconstruct plausible GEMM dims from flops + IO bytes.

    Output elems ~ bytes_out/2 = M*N; flops = 2*M*N*K -> K; split M,N evenly.
    Falls back to a cube when IO hints are degenerate. Approximation is
    recorded in DESIGN.md (the block-efficiency model only needs the
    magnitude + raggedness of the dims, not their exact split).
    """
    f = max(flops, 1.0)
    out_elems = max(bytes_out / 2.0, 1.0)
    k = max(f / (2.0 * out_elems), 1.0)
    mn = out_elems
    m = max(int(math.sqrt(mn)), 1)
    n = max(int(mn / m), 1)
    return GemmSpec(m=m, n=n, k=max(int(k), 1))


def hlo_to_tasks(specs: Sequence[TaskSpec], *, min_flops: float = 0.0,
                 stream_io: bool = True,
                 io_threshold: float = 4 * 2**20) -> List[Task]:
    """TaskSpec DAG -> engine task list with per-producer barriers.

    stream_io: HLO buffers are HBM-resident on the target, so compute tasks
    whose IO exceeds ``io_threshold`` get a DMA prologue (HBM->VMEM input
    stream) the compute depends on — without this, large-working-set
    programs under-run the memory-roofline bound (small tiles are assumed
    VMEM-resident/forwarded)."""
    tasks: List[Task] = []
    barrier_of: Dict[int, int] = {}
    next_b = 1
    for i, s in enumerate(specs):
        waits = tuple((barrier_of[d], 1) for d in s.deps if d in barrier_of)
        own = next_b
        next_b += 1
        barrier_of[i] = own
        if s.engine == "ici" and s.collective is not None:
            payload = CollectiveSpec(
                op=s.collective.op, payload_bytes=s.collective.payload_bytes,
                group_size=s.collective.group_size,
                cross_pod=s.collective.crosses_pod, name=s.name)
            engine = "ici"
        elif s.engine == "mxu" and s.flops > min_flops:
            payload = _gemm_dims(s.flops, s.bytes_in, s.bytes_out)
            engine = "tile0.mxu"
        elif s.engine == "dma":
            payload = DmaDescriptor(nbytes=max(s.bytes_in + s.bytes_out, 1.0),
                                    contiguous_run=1 << 20, name=s.name)
            engine = "dma"
        else:
            payload = VecSpec(n_elems=max(s.elems, 1.0),
                              bytes_in=s.bytes_in, bytes_out=s.bytes_out,
                              name=s.name)
            engine = "tile0.vpu"
        io = s.bytes_in + s.bytes_out
        if stream_io and engine.startswith("tile0") and io > io_threshold:
            pre_b = next_b
            next_b += 1
            tasks.append(Task(
                engine="dma",
                payload=DmaDescriptor(nbytes=io, contiguous_run=1 << 20,
                                      name=s.name + ".io"),
                waits=waits, signals=(pre_b,), name=s.name + ".io"))
            waits = waits + ((pre_b, 1),)
        tasks.append(Task(engine=engine, payload=payload, waits=waits,
                          signals=(own,), name=s.name))
    return tasks


def simulate_program(specs: Sequence[TaskSpec], cfg: HwConfig) -> Report:
    """Replay one compiled per-device program on the chip+fabric model."""
    tasks = hlo_to_tasks(specs)
    sysm = System(cfg, n_tiles=1)
    return sysm.run_workload(tasks)
