"""Memory models: VMEM (compute-buffer analog) and HBM (DDR analog).

VMEM — multi-port high-BW local RAM (paper §3.2 "Compute Buffer Memory"):
capacity is a Container (allocation/residency), bandwidth is N port
Resources each moving ``port_bytes_per_cycle``; MXU/VPU load-store stages,
the DMA and ICI all contend for ports, which is how CB pressure shows up
in the timeline exactly as the paper describes.

HBM — same base-class memory model re-parameterized from DDR to HBM2e
(paper §3.2 "DDR Memory"): linear addresses translate to
(channel, bank, row) with channel interleaving; per-access latency follows
the open/closed page policy against per-(channel,bank) open-row state;
bandwidth is per-channel. The paper's DDR timing/bank/page machinery is
retained, only the constants changed (recorded in DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Generator, Tuple

from ..core import Container, Environment, Resource, Tracer
from .presets import HwConfig

__all__ = ["VMem", "Hbm"]


class VMem:
    """Multi-port local RAM. ``transfer`` seizes one port for the duration
    bytes/port_bw; ``alloc``/``free`` manage capacity residency."""

    def __init__(self, env: Environment, cfg: HwConfig, tracer: Tracer,
                 name: str = "vmem"):
        self.env = env
        self.cfg = cfg
        self.tracer = tracer
        self.name = name
        self.capacity = Container(env, capacity=cfg.vmem_bytes,
                                  init=cfg.vmem_bytes, name=name + ".cap")
        self.ports = Resource(env, capacity=cfg.vmem_ports,
                              name=name + ".ports")
        self._port_bytes_per_ns = (cfg.vmem_port_bytes_per_cycle
                                   * cfg.clock_ghz)

    def alloc(self, nbytes: float):
        """Blocks until nbytes of VMEM are free (compiler-planned residency)."""
        return self.capacity.get(nbytes)

    def free(self, nbytes: float):
        return self.capacity.put(nbytes)

    def transfer(self, nbytes: float, priority: float = 0.0) -> Generator:
        """Process helper: move nbytes through one port."""
        req = self.ports.request(priority)
        yield req
        t0 = self.env.now
        dur = nbytes / self._port_bytes_per_ns
        yield self.env.timeout(dur)
        self.ports.release(req)
        self.tracer.emit(self.name, "bytes", t0, self.env.now, nbytes)

    @property
    def level(self) -> float:
        return self.capacity.level


@dataclass(slots=True)
class _BankState:
    open_row: int = -1


class Hbm:
    """Banked, paged, channel-interleaved memory with open/closed page
    policy. Addresses are synthetic linear offsets assigned by the
    compiler's tensor allocator."""

    def __init__(self, env: Environment, cfg: HwConfig, tracer: Tracer,
                 name: str = "hbm"):
        self.env = env
        self.cfg = cfg
        self.tracer = tracer
        self.name = name
        self.channels = [Resource(env, 1, name=f"{name}.ch{i}")
                         for i in range(cfg.hbm_channels)]
        self._banks: Dict[Tuple[int, int], _BankState] = {}
        self._ch_bytes_per_ns = cfg.hbm_gbps / cfg.hbm_channels
        self._rr = 0
        self.row_hits = 0
        self.row_misses = 0

    def _translate(self, addr: int) -> Tuple[int, int, int]:
        """linear addr -> (channel, bank, row): bursts interleave across
        channels; rows are page-sized within a (channel, bank)."""
        cfg = self.cfg
        burst_idx = addr // cfg.hbm_burst_bytes
        ch = burst_idx % cfg.hbm_channels
        within = burst_idx // cfg.hbm_channels * cfg.hbm_burst_bytes
        row_global = within // cfg.hbm_page_bytes
        bank = row_global % cfg.hbm_banks
        row = row_global // cfg.hbm_banks
        return ch, bank, row

    def access(self, addr: int, nbytes: float, *, write: bool = False
               ) -> Generator:
        """One contiguous access: split across channels, page-policy latency
        on the first burst per channel, then streaming at channel BW."""
        cfg = self.cfg
        n_ch = min(cfg.hbm_channels,
                   max(1, int(nbytes // cfg.hbm_burst_bytes) or 1))
        per_ch = nbytes / n_ch
        _, bank, row = self._translate(int(addr))
        t0 = self.env.now
        # a long access interleaves its bursts over ALL channels; the
        # pacing-channel abstraction rotates so concurrent streams share
        # aggregate bandwidth instead of false-serializing on channel 0
        ch0 = self._rr
        self._rr = (self._rr + 1) % cfg.hbm_channels
        chan = self.channels[ch0]
        req = chan.request()
        yield req
        st = self._banks.setdefault((ch0, bank), _BankState())
        if cfg.hbm_page_policy == "open" and st.open_row == row:
            lat = cfg.hbm_t_hit_ns
            self.row_hits += 1
        else:
            lat = cfg.hbm_t_miss_ns
            self.row_misses += 1
        st.open_row = row if cfg.hbm_page_policy == "open" else -1
        dur = lat + per_ch / self._ch_bytes_per_ns
        yield self.env.timeout(dur)
        chan.release(req)
        self.tracer.emit(self.name, "bytes", t0, self.env.now, nbytes)

    def stream_time_ns(self, nbytes: float) -> float:
        """Analytic lower bound (all channels, no page misses)."""
        return nbytes / self.cfg.hbm_gbps
