"""Chip/tile assembly — the paper's Figure 1 brought to TPU terms.

A **Tile** is one compute tile: MXU complex + vector unit sharing a local
VMEM (the CB analog). A **System** is the testbench: ``n_tiles`` tiles, a
shared HBM + tensor-aware DMA (with broadcast to tile VMEMs), an inter-tile
router, an ICI fabric for pod-level collectives, the barrier scoreboard and
the centralized scheduler. ``System.run_workload`` executes a task list and
returns the timeline report.

Engine processes implement the paper's task loop: pop task from FIFO ->
wait consumer barriers -> execute (sub-task pipeline inside the hw model)
-> signal producer barriers -> emit a task-level trace record.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Generator, Optional, Sequence

from ..core import Environment, Store, TaskRecord, Tracer
from ..graph.tasks import BarrierScoreboard, Scheduler, Task
from ..obs.metrics import REGISTRY
from .dma import Dma
from .ici import IciFabric, Router
from .memory import Hbm, VMem
from .mxu import Mxu
from .presets import HwConfig
from .vecunit import VecUnit

__all__ = ["Tile", "System", "simulate", "Report"]


class Tile:
    def __init__(self, env: Environment, cfg: HwConfig, tracer: Tracer,
                 name: str):
        self.name = name
        self.vmem = VMem(env, cfg, tracer, name=f"{name}.vmem")
        self.mxu = Mxu(env, cfg, self.vmem, tracer, name=f"{name}.mxu")
        self.vpu = VecUnit(env, cfg, self.vmem, tracer, name=f"{name}.vpu")


@dataclass
class Report:
    makespan_ns: float
    busy_ns: Dict[str, float]
    amounts: Dict[str, float]
    n_tasks: int
    row_hits: int = 0
    row_misses: int = 0

    def utilization(self, module: str) -> float:
        if self.makespan_ns <= 0:
            return 0.0
        return self.busy_ns.get(module, 0.0) / self.makespan_ns


class System:
    """One simulated NPU sub-system (n_tiles compute tiles)."""

    def __init__(self, cfg: HwConfig, *, n_tiles: int = 1,
                 tracer: Optional[Tracer] = None,
                 env: Optional[Environment] = None):
        self.cfg = cfg
        # kernel telemetry follows the global metrics switch: the stats
        # run-loop variant is only paid for when observability is on
        self.env = env or Environment(stats=REGISTRY.enabled)
        self.tracer = tracer or Tracer()
        self.scoreboard = BarrierScoreboard(self.env)
        self.tiles = [Tile(self.env, cfg, self.tracer, f"tile{i}")
                      for i in range(n_tiles)]
        self.hbm = Hbm(self.env, cfg, self.tracer)
        self.dma = Dma(self.env, cfg, self.hbm, self.tiles[0].vmem,
                       self.tracer,
                       peer_vmems=[t.vmem for t in self.tiles[1:]])
        self.router = Router(self.env, cfg, self.tracer,
                             n_ports=max(n_tiles, 2))
        self.ici = IciFabric(self.env, cfg, self.tracer)

        # engine task FIFOs (bounded: backpressure to the scheduler)
        q = cfg.queue_depth
        self.fifos: Dict[str, Store] = {}
        for t in self.tiles:
            self.fifos[f"{t.name}.mxu"] = Store(self.env, q)
            self.fifos[f"{t.name}.vpu"] = Store(self.env, q)
        self.fifos["dma"] = Store(self.env, q)
        self.fifos["ici"] = Store(self.env, q)
        self.scheduler = Scheduler(self.env, self.tracer, self.fifos,
                                   self.scoreboard)
        self._spawn_engines()

    # ------------------------------------------------------------------
    def _spawn_engines(self):
        for t in self.tiles:
            self.env.process(
                self._engine_loop(f"{t.name}.mxu", t.mxu.run),
                name=f"{t.name}.mxu.loop")
            self.env.process(
                self._engine_loop(f"{t.name}.vpu", t.vpu.run),
                name=f"{t.name}.vpu.loop")
        self.env.process(self._engine_loop("dma", self.dma.run),
                         name="dma.loop")
        self.env.process(self._engine_loop("ici", self.ici.run),
                         name="ici.loop")

    def _engine_loop(self, engine: str, run_fn) -> Generator:
        fifo = self.fifos[engine]
        while True:
            task: Task = yield fifo.get()
            for bid, need in task.waits:
                yield self.scoreboard.wait(bid, need)
            t_start = self.env.now
            yield from run_fn(task.payload)
            for bid in task.signals:
                self.scoreboard.signal(bid)
            self.tracer.emit_task(TaskRecord(
                task=task.name or str(task.tid), engine=engine,
                t_enqueue=getattr(task, "_enqueue_time", t_start),
                t_start=t_start, t_end=self.env.now, tid=task.tid))
            task._done_event.succeed()

    # ------------------------------------------------------------------
    def run_workload(self, tasks: Sequence[Task],
                     until: Optional[float] = None) -> Report:
        done = self.scheduler.run(tasks)
        self.env.run(until=done if until is None else until)
        self.emit_metrics()
        return self.report(n_tasks=len(tasks))

    def emit_metrics(self, registry=None) -> None:
        """Flush kernel + resource-contention telemetry into a metrics
        registry (the global one by default; no-op while disabled).

        Counters are pure functions of the simulated inputs — event
        counts, heap high-water mark, and per-resource-class stall
        counts (a *stall* is a ``Resource.request`` that could not be
        granted at issue time: VMEM-port, HBM-bank, DMA-channel, or
        ICI-link contention — exactly the effects the analytic
        relaxation cannot see)."""
        reg = registry if registry is not None else REGISTRY
        if not reg.enabled:
            return
        reg.counter("engine.events_processed").inc(
            self.env.events_processed)
        reg.counter("engine.events_scheduled").inc(self.env._eid)
        reg.gauge("engine.peak_heap_depth").set_max(self.env.peak_heap)
        reg.counter("engine.tasks_done").inc(self.scheduler.n_done)
        reg.counter("engine.runs").inc()
        groups = {
            "vmem_port": [t.vmem.ports for t in self.tiles],
            "hbm_bank": list(self.hbm.channels),
            "dma_channel": [self.dma.channels],
            "ici_link": [self.ici.links, self.ici.dcn],
        }
        for cls, resources in groups.items():
            reqs = sum(r.n_requests for r in resources)
            stalls = sum(r.n_stalls for r in resources)
            if reqs:
                reg.counter("engine.resource_requests",
                            resource=cls).inc(reqs)
            if stalls:
                reg.counter("engine.resource_stalls",
                            resource=cls).inc(stalls)

    def report(self, n_tasks: int = 0) -> Report:
        tr = self.tracer
        modules = tr.modules()
        return Report(
            makespan_ns=tr.makespan(),
            busy_ns={m: tr.busy_time(m) for m in modules},
            amounts={m + "/" + k: tr.total_amount(m, k)
                     for m in modules for k in ("ops", "bytes")
                     if tr.total_amount(m, k) > 0},
            n_tasks=n_tasks,
            row_hits=self.hbm.row_hits,
            row_misses=self.hbm.row_misses,
        )


def simulate(tasks: Sequence[Task], cfg: HwConfig, *, n_tiles: int = 1
             ) -> Report:
    """One-shot: build a System, run the task list, return the report."""
    sys = System(cfg, n_tiles=n_tiles)
    return sys.run_workload(tasks)
