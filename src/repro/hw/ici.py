"""Interconnect models: intra-pod ICI (the NOC analog) and cross-pod DCN.

Paper §3.2 "Interconnect": parameterized NOC with slave/master ports, a
router forwarding unicast/multicast with configurable arbitration, latency
and BW. TPU adaptation: the same router model carries point-to-point
traffic between tiles (multi-tile CNN mode), and collectives are scheduled
on the torus **links** as ring phases (reduce-scatter / all-gather), so
concurrent collectives contend for link Resources and the contention shows
up in the timeline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Generator

from ..core import Environment, PriorityItem, PriorityStore, Resource, Tracer
from .presets import HwConfig

__all__ = ["Router", "IciFabric", "CollectiveSpec"]


@dataclass(frozen=True)
class CollectiveSpec:
    """One collective task (per-device view)."""

    op: str              # all-reduce | all-gather | reduce-scatter |
    #                      all-to-all | collective-permute
    payload_bytes: float  # per-device payload (post-GSPMD shard bytes)
    group_size: int
    cross_pod: bool = False
    name: str = ""

    def link_bytes(self) -> float:
        """Ring-schedule bytes crossing each device's link."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0.0
        if self.op.startswith("all-reduce"):
            return self.payload_bytes * 2 * (n - 1) / n
        if self.op.startswith("collective-permute"):
            return self.payload_bytes
        return self.payload_bytes * (n - 1) / n

    def phases(self) -> int:
        n = max(self.group_size, 1)
        if n == 1:
            return 0
        if self.op.startswith("all-reduce"):
            return 2 * (n - 1)
        if self.op.startswith("collective-permute"):
            return 1
        return n - 1


class Router:
    """Paper-faithful NOC router: N input (slave) ports feed a centralized
    router process that forwards packets to output (master) port queues
    with round-robin or priority arbitration."""

    def __init__(self, env: Environment, cfg: HwConfig, tracer: Tracer,
                 n_ports: int, name: str = "noc"):
        self.env = env
        self.cfg = cfg
        self.tracer = tracer
        self.name = name
        self.n_ports = n_ports
        self.in_q = PriorityStore(env, capacity=64, name=name + ".in")
        self.out_q = [PriorityStore(env, capacity=64, name=f"{name}.out{i}")
                      for i in range(n_ports)]
        self._proc = env.process(self._route(), name=name + ".router")
        self._bytes_per_ns = cfg.ici_link_gbps

    def send(self, src: int, dst: int, nbytes: float, priority: float = 1.0):
        """Enqueue a packet; returns the completion event."""
        done = self.env.event()
        item = PriorityItem(priority, (src, dst, nbytes, done))
        return self.in_q.put(item), done

    def _route(self) -> Generator:
        while True:
            item = yield self.in_q.get()
            src, dst, nbytes, done = item.item
            # forwarding: header latency + serialization on the output port
            yield self.env.timeout(self.cfg.ici_latency_ns * 0.1)
            q = self.out_q[dst % self.n_ports]
            yield q.put(PriorityItem(item.priority, (nbytes, done)))
            if not getattr(q, "_drainer", None):
                q._drainer = self.env.process(
                    self._drain(dst % self.n_ports),
                    name=f"{self.name}.drain{dst % self.n_ports}")

    def _drain(self, port: int) -> Generator:
        q = self.out_q[port]
        while True:
            if q.level == 0:
                q._drainer = None
                return
            item = yield q.get()
            nbytes, done = item.item
            t0 = self.env.now
            yield self.env.timeout(nbytes / self._bytes_per_ns)
            self.tracer.emit(f"{self.name}.port{port}", "bytes", t0,
                             self.env.now, nbytes)
            done.succeed()


class IciFabric:
    """Per-chip link set + collective scheduling. One ``IciFabric`` models
    the SPMD-symmetric view: every chip executes the same phases, so one
    fabric instance paces the pod (chips are interchangeable by symmetry).
    Cross-pod segments run at DCN bandwidth/latency."""

    def __init__(self, env: Environment, cfg: HwConfig, tracer: Tracer,
                 name: str = "ici"):
        self.env = env
        self.cfg = cfg
        self.tracer = tracer
        self.name = name
        self.links = Resource(env, cfg.ici_links, name=name + ".links")
        self.dcn = Resource(env, 1, name=name + ".dcn")
        self._link_bytes_per_ns = cfg.ici_link_gbps
        self._dcn_bytes_per_ns = cfg.dcn_gbps

    def run(self, spec: CollectiveSpec) -> Generator:
        """Execute a collective as ring phases over one link (a 2D-torus
        ring uses one link per direction; concurrent collectives contend)."""
        env, cfg = self.env, self.cfg
        phases = spec.phases()
        if phases == 0 or spec.payload_bytes <= 0:
            return
        per_phase = spec.payload_bytes / max(spec.group_size, 1)
        bw = self._dcn_bytes_per_ns if spec.cross_pod else \
            self._link_bytes_per_ns
        lat = cfg.dcn_latency_ns if spec.cross_pod else cfg.ici_latency_ns
        res = self.dcn if spec.cross_pod else self.links
        req = res.request()
        yield req
        t0 = env.now
        yield env.timeout(phases * (lat + per_phase / bw))
        res.release(req)
        self.tracer.emit(self.name + (".dcn" if spec.cross_pod else ""),
                         "bytes", t0, env.now, spec.link_bytes())

    def ideal_time_ns(self, spec: CollectiveSpec) -> float:
        phases = spec.phases()
        if phases == 0:
            return 0.0
        per_phase = spec.payload_bytes / max(spec.group_size, 1)
        bw = self._dcn_bytes_per_ns if spec.cross_pod else \
            self._link_bytes_per_ns
        lat = self.cfg.dcn_latency_ns if spec.cross_pod else \
            self.cfg.ici_latency_ns
        return phases * (lat + per_phase / bw)
