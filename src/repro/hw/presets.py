"""Hardware configuration records (the paper's hierarchical yaml configs).

``HwConfig`` carries every tunable the scaling analyses sweep: MAC array
geometry (computation scaling, Fig 5), clock (frequency scaling, Fig 6),
HBM bandwidth/latency (memory-BW scaling, Fig 7), VMEM capacity/ports, DMA
channels/compression, ICI/DCN links. ``from_yaml``/``to_yaml`` round-trip
the hierarchy exactly as §3.3 "Parameter Configuration" describes.

The v5e preset is the TPU-adaptation reference point: 4x(128x128) MXU
@940MHz -> 197 bf16 TFLOP/s, 16 GiB HBM2e @819 GB/s, 128 MiB VMEM,
4 ICI links x ~50 GB/s/dir, DCN 25 GB/s.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict

__all__ = ["HwConfig", "V5E", "V5E_HALF_MACS", "paper_skew", "from_dict",
           "to_dict", "PRESETS", "resolve_preset"]


@dataclass(frozen=True)
class HwConfig:
    name: str = "tpu-v5e"
    # clock / voltage operating point
    clock_ghz: float = 0.94
    # MXU (DPU analog): n_mxu systolic arrays of rows x cols MACs
    n_mxu: int = 4
    mxu_rows: int = 128
    mxu_cols: int = 128
    mxu_fill_overlap: bool = True     # pipelined fill between blocks
    # vector unit (DSP analog): lanes x sublanes, flops/lane/cycle
    vpu_lanes: int = 128
    vpu_sublanes: int = 8
    vpu_flops_per_lane: float = 2.0
    # VMEM (compute buffer analog)
    vmem_bytes: int = 128 * 2**20
    vmem_ports: int = 4
    vmem_port_bytes_per_cycle: int = 1024
    vmem_block_budget: int = 12 * 2**20   # working set per MXU block set
    # HBM (DDR analog)
    hbm_bytes: int = 16 * 2**30
    hbm_gbps: float = 819.0
    hbm_channels: int = 16
    hbm_burst_bytes: int = 512
    hbm_page_bytes: int = 2048
    hbm_banks: int = 16
    hbm_t_hit_ns: float = 25.0
    hbm_t_miss_ns: float = 55.0
    hbm_page_policy: str = "open"     # open | closed
    # DMA (tensor-aware, multi-channel)
    dma_channels: int = 8
    dma_desc_overhead_ns: float = 250.0
    dma_max_request_bytes: int = 1 * 2**20
    dma_compression: bool = False
    dma_compression_ratio: float = 0.6    # compressed/raw (activations)
    dma_decomp_ns_per_kb: float = 1.0
    # ICI (inter-chip NOC analog)
    ici_links: int = 4
    ici_link_gbps: float = 50.0
    ici_latency_ns: float = 1000.0
    router_arbitration: str = "rr"    # rr | priority
    # DCN (cross-pod)
    dcn_gbps: float = 25.0
    dcn_latency_ns: float = 10_000.0
    # scheduling
    queue_depth: int = 16
    pipeline_depth: int = 2           # double buffering between stages

    # -- derived ------------------------------------------------------------
    @property
    def macs(self) -> int:
        return self.n_mxu * self.mxu_rows * self.mxu_cols

    @property
    def peak_tflops(self) -> float:
        """bf16 peak: 2 flops/MAC/cycle."""
        return 2 * self.macs * self.clock_ghz * 1e9 / 1e12

    @property
    def vpu_flops_per_cycle(self) -> float:
        return self.vpu_lanes * self.vpu_sublanes * self.vpu_flops_per_lane

    @property
    def hbm_bytes_per_ns(self) -> float:
        return self.hbm_gbps  # GB/s == bytes/ns

    @property
    def ici_bytes_per_ns(self) -> float:
        return self.ici_link_gbps

    @property
    def cycle_ns(self) -> float:
        return 1.0 / self.clock_ghz

    def replace(self, **kw) -> "HwConfig":
        return dataclasses.replace(self, **kw)


V5E = HwConfig()

# the paper's Fig-5 style "2K MAC" variant (half the MXUs)
V5E_HALF_MACS = V5E.replace(name="tpu-v5e-half", n_mxu=2)


def paper_skew(**kw) -> HwConfig:
    """NPU-scale config for the paper's §4 analyses (the paper notes its
    data uses deliberately skewed configs, not product KPIs). Sized like
    the VPU compute tile: a 2K-MAC array, small CB, DDR-class memory —
    at this scale the CNN workloads reproduce the paper's tile/MAC/BW
    scaling behaviors."""
    base = V5E.replace(
        name="npu-2k",
        clock_ghz=1.0,
        n_mxu=1, mxu_rows=32, mxu_cols=64,          # 2K MACs ("2K" config)
        vpu_lanes=64, vpu_sublanes=2,
        vmem_bytes=2 * 2**20, vmem_ports=2, vmem_port_bytes_per_cycle=128,
        vmem_block_budget=512 * 2**10,
        hbm_gbps=34.0, hbm_channels=4, hbm_page_bytes=4096,
        hbm_t_hit_ns=30.0, hbm_t_miss_ns=70.0,
        dma_channels=4, dma_desc_overhead_ns=400.0,
        ici_link_gbps=16.0, ici_latency_ns=300.0,
        queue_depth=8,
    )
    return base.replace(**kw)


# named base points for declarative sweep specs (repro.sweep)
PRESETS: Dict[str, Any] = {
    "v5e": lambda **kw: V5E.replace(**kw) if kw else V5E,
    "v5e-half": lambda **kw: V5E_HALF_MACS.replace(**kw) if kw
    else V5E_HALF_MACS,
    "paper_skew": paper_skew,
}


def resolve_preset(name: str, **overrides) -> HwConfig:
    """Preset name + field overrides -> HwConfig (sweep-spec entrypoint)."""
    try:
        factory = PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown hw preset {name!r}; "
                       f"have {sorted(PRESETS)}") from None
    return factory(**overrides)


def to_dict(cfg: HwConfig) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def from_dict(d: Dict[str, Any]) -> HwConfig:
    return HwConfig(**d)
