"""Tensor-aware multi-channel DMA model (paper §3.2 "DMA").

A ``DmaDescriptor`` describes a (possibly strided) tensor transfer between
HBM and VMEM (or HBM->HBM, VMEM->VMEM). The engine splits a descriptor into
pipelined transfer requests (max ``dma_max_request_bytes``), issues them on
one of ``dma_channels`` channels, and aggregates latency/BW per request —
"models how a DMA descriptor is split into pipelined data transfer
requests ... projects latency and BW data ... aggregated to provide the
final result of a DMA task".

Inline processing is retained from the paper: optional compression
(HBM bytes scaled by the compression ratio + per-KB decompress latency)
and broadcast (one HBM read fanned out to N tile VMEMs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Generator, List, Optional, Sequence, Tuple

from ..core import Environment, Resource, Tracer
from .memory import Hbm, VMem
from .presets import HwConfig

__all__ = ["DmaDescriptor", "Dma"]


@dataclass(frozen=True)
class DmaDescriptor:
    nbytes: float
    src: str = "hbm"                 # hbm | vmem
    dst: str = "vmem"
    addr: int = 0                    # linear base address (hbm side)
    contiguous_run: int = 0          # bytes per contiguous row (0 = all)
    compressed: bool = False
    broadcast: int = 1               # fan-out count (multi-tile weights)
    name: str = ""


class Dma:
    def __init__(self, env: Environment, cfg: HwConfig, hbm: Hbm,
                 vmem: VMem, tracer: Tracer, name: str = "dma",
                 peer_vmems: Optional[Sequence[VMem]] = None):
        self.env = env
        self.cfg = cfg
        self.hbm = hbm
        self.vmem = vmem
        self.tracer = tracer
        self.name = name
        self.peer_vmems = list(peer_vmems or [])
        self.channels = Resource(env, cfg.dma_channels, name=name + ".ch")

    def _requests(self, d: DmaDescriptor) -> List[Tuple[int, float]]:
        """Split a descriptor into (addr, nbytes) pipelined requests."""
        run = d.contiguous_run or int(d.nbytes)
        run = min(run, self.cfg.dma_max_request_bytes)
        reqs = []
        left = d.nbytes
        addr = d.addr
        while left > 0:
            n = min(run, left)
            reqs.append((addr, n))
            addr += int(n)
            left -= n
        return reqs

    def run(self, d: DmaDescriptor) -> Generator:
        """Execute one DMA task; yields until all requests complete."""
        env, cfg = self.env, self.cfg
        reqs = self._requests(d)
        done = env.event()
        outstanding = len(reqs)
        t_start = env.now
        state = {"left": outstanding}

        def one(addr: int, nbytes: float):
            nonlocal_state = state
            ch = self.channels.request()
            yield ch
            yield env.timeout(cfg.dma_desc_overhead_ns)
            hbm_bytes = nbytes
            if d.compressed and cfg.dma_compression:
                hbm_bytes = nbytes * cfg.dma_compression_ratio
            # source side
            if d.src == "hbm":
                yield from self.hbm.access(addr, hbm_bytes)
            else:
                yield from self.vmem.transfer(nbytes)
            if d.compressed and cfg.dma_compression:
                yield env.timeout(cfg.dma_decomp_ns_per_kb * nbytes / 1024.0)
            # destination side (broadcast: one read, N writes)
            fanout = max(1, d.broadcast)
            targets = [self.vmem] + self.peer_vmems
            for i in range(fanout):
                tgt = targets[i % len(targets)] if d.dst == "vmem" else None
                if tgt is not None:
                    yield from tgt.transfer(nbytes)
                else:
                    yield from self.hbm.access(addr + (1 << 20), hbm_bytes,
                                               write=True)
            self.channels.release(ch)
            nonlocal_state["left"] -= 1
            self.tracer.emit(self.name, "bytes", t_start, env.now, nbytes)
            if nonlocal_state["left"] == 0:
                done.succeed()

        for addr, nbytes in reqs:
            env.process(one(addr, nbytes), name=f"{self.name}.req")
        yield done

    def ideal_time_ns(self, d: DmaDescriptor) -> float:
        hbm_bytes = d.nbytes
        if d.compressed and self.cfg.dma_compression:
            hbm_bytes *= self.cfg.dma_compression_ratio
        return (self.cfg.dma_desc_overhead_ns
                + self.hbm.stream_time_ns(hbm_bytes))
