"""MXU engine model — the DPU analog (paper §3.2, Figure 3).

Faithful structure, TPU-adapted constants:

  * 4-stage pipeline: **load -> MAC -> post-process -> store**, connected by
    depth-``pipeline_depth`` Stores (double buffering). Each stage is its
    own process, so load of block i+1 overlaps MAC of block i — the
    compute-bound vs memory-bound character emerges from the pipeline, not
    from a formula.
  * unit of processing = a **data block** (the paper's stencil-multiple
    sub-partition): a GEMM (M,N,K) is tiled into (bm,bn,bk) blocks chosen so
    the working set fits the VMEM block budget and dims align to the
    128-lane hardware. Utilization loss from ragged edges (bm<128 etc.) is
    exactly how "2K->4K MACs only +25-45%" reproduces.
  * post-processing stage executes fused ops (bias/activation/residual) at
    vector-unit rate, like the DPU's post-stage.
  * emits Table-2 activity: "ops" = issued MACs (ideal = rows*cols*n_mxu *
    busy-cycles), consumed by Power-EM utilization.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Generator, Tuple

from ..core import Environment, Store, Tracer
from .memory import VMem
from .presets import HwConfig

__all__ = ["GemmSpec", "Mxu", "choose_block"]


@dataclass(frozen=True)
class GemmSpec:
    """One MXU task: C[M,N] += A[M,K] @ B[K,N] (+ fused post ops)."""

    m: int
    n: int
    k: int
    a_bytes_per_elem: int = 2
    b_bytes_per_elem: int = 2
    out_bytes_per_elem: int = 2
    fused_post_elems: float = 0.0   # elementwise ops fused after the GEMM
    name: str = ""

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k


def _align(x: int, a: int) -> int:
    return max(a, -(-x // a) * a)


def choose_block(spec: GemmSpec, cfg: HwConfig) -> Tuple[int, int, int]:
    """Stencil selection: largest (bm,bn,bk), multiples of the PE geometry,
    whose A+B+C working set fits the VMEM block budget."""
    budget = cfg.vmem_block_budget
    bm = min(_align(spec.m, cfg.mxu_rows), 8 * cfg.mxu_rows)
    bn = min(_align(spec.n, cfg.mxu_cols), 8 * cfg.mxu_cols)
    bk = min(_align(spec.k, 128), 16 * 128)

    def ws(bm, bn, bk):
        return (bm * bk * spec.a_bytes_per_elem
                + bk * bn * spec.b_bytes_per_elem
                + bm * bn * 4)  # accumulator f32

    # shrink the largest dim until the working set fits
    while ws(bm, bn, bk) > budget:
        if bk >= bm and bk >= bn and bk > 128:
            bk = max(128, bk // 2)
        elif bm >= bn and bm > cfg.mxu_rows:
            bm = max(cfg.mxu_rows, bm // 2)
        elif bn > cfg.mxu_cols:
            bn = max(cfg.mxu_cols, bn // 2)
        else:
            break
    return bm, bn, bk


class Mxu:
    """One chip's MXU complex (all ``n_mxu`` arrays operate as a unit on a
    block, matching XLA's single-kernel dispatch)."""

    def __init__(self, env: Environment, cfg: HwConfig, vmem: VMem,
                 tracer: Tracer, name: str = "mxu"):
        self.env = env
        self.cfg = cfg
        self.vmem = vmem
        self.tracer = tracer
        self.name = name

    # -- per-block stage costs ---------------------------------------------
    def _mac_cycles(self, bm: int, bn: int, bk: int) -> float:
        cfg = self.cfg
        # systolic: rows x cols MACs per array per cycle; ragged edges
        # waste lanes (ceil to hardware geometry)
        eff_m = -(-bm // cfg.mxu_rows) * cfg.mxu_rows
        eff_n = -(-bn // cfg.mxu_cols) * cfg.mxu_cols
        cycles = (eff_m * eff_n * bk) / (cfg.macs)
        if not cfg.mxu_fill_overlap:
            cycles += cfg.mxu_rows + bn  # array fill + drain
        return cycles

    def run(self, spec: GemmSpec) -> Generator:
        """Execute one GEMM through the 4-stage pipeline. Yields until done."""
        env, cfg = self.env, self.cfg
        bm, bn, bk = choose_block(spec, cfg)
        n_blocks_m = -(-spec.m // bm)
        n_blocks_n = -(-spec.n // bn)
        n_blocks_k = -(-spec.k // bk)
        total_blocks = n_blocks_m * n_blocks_n * n_blocks_k

        q_load = Store(env, capacity=cfg.pipeline_depth)
        q_mac = Store(env, capacity=cfg.pipeline_depth)
        q_post = Store(env, capacity=cfg.pipeline_depth)
        done = env.event()

        def gen_blocks():
            for im in range(n_blocks_m):
                m = min(bm, spec.m - im * bm)
                for i_n in range(n_blocks_n):
                    n = min(bn, spec.n - i_n * bn)
                    for ik in range(n_blocks_k):
                        k = min(bk, spec.k - ik * bk)
                        yield (m, n, k, ik == n_blocks_k - 1)

        def load_proc():
            for blk in gen_blocks():
                m, n, k, last_k = blk
                nbytes = (m * k * spec.a_bytes_per_elem
                          + k * n * spec.b_bytes_per_elem)
                yield from self.vmem.transfer(nbytes)
                yield q_load.put(blk)

        def mac_proc():
            for _ in range(total_blocks):
                blk = yield q_load.get()
                m, n, k, last_k = blk
                cycles = self._mac_cycles(m, n, k)
                t0 = env.now
                yield env.timeout(cycles * cfg.cycle_ns)
                # Table-2 activity: processed MACs (vs ideal macs*cycles)
                self.tracer.emit(self.name, "ops", t0, env.now,
                                 m * n * k)
                if last_k:
                    yield q_mac.put((m, n))

        def post_proc():
            out_blocks = n_blocks_m * n_blocks_n
            per_block_fused = (spec.fused_post_elems / max(out_blocks, 1))
            for _ in range(out_blocks):
                m, n = yield q_mac.get()
                if per_block_fused > 0:
                    cycles = per_block_fused / self.cfg.vpu_flops_per_cycle
                    t0 = env.now
                    yield env.timeout(cycles * cfg.cycle_ns)
                    self.tracer.emit(self.name + ".post", "ops", t0, env.now,
                                     per_block_fused)
                yield q_post.put((m, n))

        def store_proc():
            out_blocks = n_blocks_m * n_blocks_n
            for i in range(out_blocks):
                m, n = yield q_post.get()
                yield from self.vmem.transfer(m * n * spec.out_bytes_per_elem)
            done.succeed()

        env.process(load_proc(), name=f"{self.name}.load")
        env.process(mac_proc(), name=f"{self.name}.mac")
        env.process(post_proc(), name=f"{self.name}.post")
        env.process(store_proc(), name=f"{self.name}.store")
        yield done

    # -- analytic reference (used by tests / the vectorized engine) -------
    def ideal_time_ns(self, spec: GemmSpec) -> float:
        bm, bn, bk = choose_block(spec, self.cfg)
        n_m, n_n, n_k = -(-spec.m // bm), -(-spec.n // bn), -(-spec.k // bk)
        mac = 0.0
        for im in range(n_m):
            m = min(bm, spec.m - im * bm)
            for i_n in range(n_n):
                n = min(bn, spec.n - i_n * bn)
                for ik in range(n_k):
                    k = min(bk, spec.k - ik * bk)
                    mac += self._mac_cycles(m, n, k)
        return mac * self.cfg.cycle_ns
