"""Sweep-campaign subsystem: batched design-space exploration (paper §4).

VPU-EM's purpose is evaluating NPU perf/power *at scale* across a large
design-parameter space. This package turns each ad-hoc point-by-point
sweep script into a declarative **campaign**:

1. **Spec** (``spec.py``) — model workloads x hardware preset x parameter
   grid (DVFS points, HBM bandwidth, MXU geometry, tile count, ...),
   loadable from JSON (builtin specs live in ``repro/configs/sweeps/``).
2. **Pre-screen** (``prescreen.py``) — the whole grid is evaluated in one
   ``jax.vmap``/XLA call per structural cell via the analytic scheduler
   (``core.vectorized.schedule_many_stats``), yielding makespan + an
   analytic Power-EM proxy for every point.
3. **Select** (``pareto.py``) — the Pareto-interesting points (time x
   energy front, plus extremes) are chosen for refinement.
4. **Refine** (``refine.py``/``runner.py``) — only the selected points
   re-run in detail (Power-EM included) on the refinement engine the
   spec picks: the ground-truth event engine, or ``core.fastsim``'s
   interval replay with steady-state layer extrapolation
   (``refine.engine="fast"|"auto"`` — >=10x points/sec on full-model
   LM points, byte-identical records whenever it replays). Execution
   goes through a pluggable ``repro.exec`` backend (inline / local
   process pool / resumable filesystem job spool) behind a
   content-hashed on-disk result cache (``cache.py``) so repeated —
   and interrupted — campaigns are incremental. A per-point JSONL
   journal records status, wall time, worker id, and cache-hit
   counters.

CLI: ``python -m repro.sweep run <spec.json | builtin-name>
[--backend inline|pool|spool]``; workers attach with
``python -m repro.exec worker <spool>``; cache maintenance with
``python -m repro.sweep cache``.

Attribute access is lazy (PEP 562): refinement worker processes import
``repro.sweep.refine`` without paying for jax/XLA initialization.
"""
from typing import TYPE_CHECKING

__all__ = [
    "ANALYTIC_AXES",
    "CampaignResult",
    "GridPoint",
    "RefineSpec",
    "ResultCache",
    "SweepSpec",
    "builtin_spec_names",
    "load_builtin_spec",
    "load_spec",
    "pareto_front",
    "run_campaign",
    "select_points",
]

_EXPORTS = {
    "ANALYTIC_AXES": "spec",
    "GridPoint": "spec",
    "RefineSpec": "spec",
    "SweepSpec": "spec",
    "builtin_spec_names": "spec",
    "load_builtin_spec": "spec",
    "load_spec": "spec",
    "ResultCache": "cache",
    "pareto_front": "pareto",
    "select_points": "pareto",
    "CampaignResult": "runner",
    "run_campaign": "runner",
}

if TYPE_CHECKING:  # pragma: no cover
    from .cache import ResultCache
    from .pareto import pareto_front, select_points
    from .runner import CampaignResult, run_campaign
    from .spec import (ANALYTIC_AXES, GridPoint, RefineSpec, SweepSpec,
                       builtin_spec_names, load_builtin_spec, load_spec)


def __getattr__(name: str):
    try:
        modname = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}") from None
    import importlib

    mod = importlib.import_module(f".{modname}", __name__)
    value = getattr(mod, name)
    globals()[name] = value
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
