"""Content-hashed on-disk result cache for event-engine refinements.

A refinement's inputs — workload name, full resolved ``HwConfig``, tile
count, compile options, Power-EM settings — are canonicalized to JSON and
hashed; the record is stored at ``<dir>/<hh>/<hash>.json``. Re-running a
campaign (or a bigger campaign that overlaps a previous grid) only pays
for the points it has never simulated. ``SCHEMA_VERSION`` is part of the
key: bump it when event-engine or Power-EM semantics change and every
cached record transparently invalidates.

Robustness: a worker killed mid-write on a filesystem without atomic
rename can leave a truncated/corrupt entry. ``get`` treats any
unreadable entry as a miss and deletes it — it never raises. Entries
carry their schema version inline (``_schema``, stripped on read) so
``stats``/``prune`` can report and clear stale generations, and each
campaign appends its hit/miss counters to ``<dir>/stats.jsonl`` so the
CLI (``python -m repro.sweep cache``) can report a lifetime hit rate.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from typing import Any, Dict, Optional

__all__ = ["ResultCache", "SCHEMA_VERSION", "content_key",
           "atomic_write_json"]

SCHEMA_VERSION = 1

STATS_FILE = "stats.jsonl"


def content_key(payload: Dict[str, Any]) -> str:
    """Canonical sha256 of a refinement-input payload."""
    blob = json.dumps({"schema": SCHEMA_VERSION, **payload},
                      sort_keys=True, default=float)
    return hashlib.sha256(blob.encode()).hexdigest()


def atomic_write_json(path: str, obj: Dict[str, Any], *,
                      sort_keys: bool = False) -> str:
    """All-or-nothing JSON write: stage a temp file in the destination
    directory, publish with ``os.replace`` — readers never observe a
    torn file. The shared primitive behind the result cache and the job
    spool."""
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, sort_keys=sort_keys, default=float)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


class ResultCache:
    """Tiny sharded JSON store; safe under concurrent writers (atomic
    rename, last-writer-wins — all writers produce identical content for
    a given key by construction)."""

    def __init__(self, root: str):
        self.root = root
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".json")

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Fetch a record; corrupt/truncated entries are deleted and
        reported as a miss — this never raises."""
        p = self._path(key)
        try:
            with open(p) as f:
                rec = json.load(f)
            if not isinstance(rec, dict):
                raise json.JSONDecodeError("not a record", "", 0)
        except FileNotFoundError:
            self.misses += 1
            return None
        except (json.JSONDecodeError, UnicodeDecodeError, OSError):
            # killed worker mid-write (non-atomic fs), disk hiccup, ...:
            # drop the entry and re-simulate
            try:
                os.unlink(p)
            except OSError:
                pass
            self.misses += 1
            return None
        rec.pop("_schema", None)
        self.hits += 1
        return rec

    def put(self, key: str, record: Dict[str, Any]) -> str:
        return atomic_write_json(self._path(key),
                                 {"_schema": SCHEMA_VERSION, **record})

    # -- introspection / maintenance --------------------------------------

    def _entries(self):
        if not os.path.isdir(self.root):
            return
        for shard in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, shard)
            if not os.path.isdir(d):
                continue
            for f in sorted(os.listdir(d)):
                if f.endswith(".json"):
                    yield os.path.join(d, f)

    def stats(self) -> Dict[str, Any]:
        """Entry count, total bytes, and per-schema-generation counts
        (``None`` = unreadable/legacy entries with no schema tag)."""
        n = 0
        nbytes = 0
        by_schema: Dict[Optional[int], int] = {}
        for p in self._entries():
            n += 1
            try:
                nbytes += os.path.getsize(p)
                with open(p) as f:
                    schema = json.load(f).get("_schema")
            except (OSError, json.JSONDecodeError, UnicodeDecodeError,
                    AttributeError):
                schema = None
            by_schema[schema] = by_schema.get(schema, 0) + 1
        return {"entries": n, "bytes": nbytes, "by_schema": by_schema,
                "schema_version": SCHEMA_VERSION}

    def prune(self, *, keep_schema: int = SCHEMA_VERSION) -> int:
        """Delete entries from other schema generations (including
        unreadable/untagged ones); returns the number removed."""
        removed = 0
        for p in self._entries():
            try:
                with open(p) as f:
                    schema = json.load(f).get("_schema")
            except (OSError, json.JSONDecodeError, UnicodeDecodeError,
                    AttributeError):
                schema = None
            if schema != keep_schema:
                try:
                    os.unlink(p)
                    removed += 1
                except OSError:
                    pass
        return removed

    def log_stats(self, campaign: str = "") -> None:
        """Append this process's hit/miss counters (one JSON line,
        O_APPEND-safe) for lifetime hit-rate reporting."""
        if self.hits == 0 and self.misses == 0:
            return
        os.makedirs(self.root, exist_ok=True)
        line = json.dumps({"t": time.time(), "campaign": campaign,
                           "hits": self.hits, "misses": self.misses})
        with open(os.path.join(self.root, STATS_FILE), "a") as f:
            f.write(line + "\n")

    def lifetime_stats(self) -> Dict[str, Any]:
        """Aggregate hit/miss counters across every logged campaign."""
        hits = misses = runs = 0
        p = os.path.join(self.root, STATS_FILE)
        try:
            with open(p) as f:
                for raw in f:
                    raw = raw.strip()
                    if not raw:
                        continue
                    try:
                        d = json.loads(raw)
                    except json.JSONDecodeError:
                        continue
                    hits += int(d.get("hits", 0))
                    misses += int(d.get("misses", 0))
                    runs += 1
        except FileNotFoundError:
            pass
        total = hits + misses
        return {"runs": runs, "hits": hits, "misses": misses,
                "hit_rate": (hits / total) if total else None}

    def __len__(self) -> int:
        return sum(1 for _ in self._entries())
