"""Content-hashed on-disk result cache for event-engine refinements.

A refinement's inputs — workload name, full resolved ``HwConfig``, tile
count, compile options, Power-EM settings — are canonicalized to JSON and
hashed; the record is stored at ``<dir>/<hh>/<hash>.json``. Re-running a
campaign (or a bigger campaign that overlaps a previous grid) only pays
for the points it has never simulated. ``SCHEMA_VERSION`` is part of the
key: bump it when event-engine or Power-EM semantics change and every
cached record transparently invalidates.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional

__all__ = ["ResultCache", "SCHEMA_VERSION", "content_key"]

SCHEMA_VERSION = 1


def content_key(payload: Dict[str, Any]) -> str:
    """Canonical sha256 of a refinement-input payload."""
    blob = json.dumps({"schema": SCHEMA_VERSION, **payload},
                      sort_keys=True, default=float)
    return hashlib.sha256(blob.encode()).hexdigest()


class ResultCache:
    """Tiny sharded JSON store; safe under concurrent writers (atomic
    rename, last-writer-wins — all writers produce identical content for
    a given key by construction)."""

    def __init__(self, root: str):
        self.root = root
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], key + ".json")

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        p = self._path(key)
        try:
            with open(p) as f:
                rec = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            self.misses += 1
            return None
        self.hits += 1
        return rec

    def put(self, key: str, record: Dict[str, Any]) -> str:
        p = self._path(key)
        os.makedirs(os.path.dirname(p), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(p), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(record, f, default=float)
            os.replace(tmp, p)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return p

    def __len__(self) -> int:
        if not os.path.isdir(self.root):
            return 0
        n = 0
        for shard in os.listdir(self.root):
            d = os.path.join(self.root, shard)
            if os.path.isdir(d):
                n += sum(1 for f in os.listdir(d) if f.endswith(".json"))
        return n
