"""Pareto selection of refinement-worthy grid points.

The pre-screen gives every grid point an analytic (time, energy)
estimate; only the points that could be somebody's operating-point pick
deserve the expensive event-engine run: the Pareto front of
(minimize time, minimize energy), thinned to the refinement budget while
always keeping both extremes.
"""
from __future__ import annotations

from typing import List

import numpy as np

__all__ = ["pareto_front", "select_points"]


def pareto_front(objectives: np.ndarray) -> List[int]:
    """Indices of the non-dominated rows of a [K, M] matrix (all
    objectives minimized). O(K^2) — campaign grids are 1e2..1e4 points."""
    obj = np.asarray(objectives, dtype=float)
    if obj.ndim == 1:
        obj = obj[:, None]
    k = obj.shape[0]
    keep: List[int] = []
    for i in range(k):
        dominated = False
        for j in range(k):
            if j == i:
                continue
            if (obj[j] <= obj[i]).all() and (obj[j] < obj[i]).any():
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep


def select_points(objectives: np.ndarray, mode: str = "pareto",
                  max_points: int = 16) -> List[int]:
    """Refinement set for one cell's [K, M] analytic objective matrix.

    ``pareto``: non-dominated points, thinned by even stride along the
    first objective down to ``max_points`` (endpoints always kept).
    ``all`` / ``none``: everything / nothing.
    """
    k = int(np.asarray(objectives).shape[0])
    if mode == "all":
        return list(range(k))
    if mode == "none":
        return []
    if mode != "pareto":
        raise ValueError(f"unknown selection mode {mode!r}")
    front = pareto_front(objectives)
    if len(front) <= max_points:
        return sorted(front)
    obj = np.asarray(objectives, dtype=float)
    front = sorted(front, key=lambda i: (obj[i, 0], i))
    # even stride over the time-sorted front, endpoints pinned
    pick_pos = np.linspace(0, len(front) - 1, max_points).round().astype(int)
    return sorted({front[p] for p in pick_pos})
