"""Vectorized analytic pre-screen of one structural cell.

Compiles the cell's task graph once, then evaluates the whole analytic
sub-grid (all swept parameter vectors) in a single ``jax.vmap``/XLA call
via ``core.vectorized.schedule_many_stats``. Per point, the busy-time
vector feeds the analytic Power-EM proxy so the Pareto selection has a
real (time, energy) plane to work with — all without ever stepping the
event engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict

import numpy as np

from ..core.vectorized import (ENG_DMA, ENG_ICI, ENG_MXU, ENG_VPU,
                               N_ENGINE_CLASSES, from_tasks, params_of,
                               schedule_many_stats)
from ..graph.compiler import CompileOptions, compile_ops
from ..graph.workloads import resolve_workload
from ..power.powerem import analytic_power_w
from .spec import SweepCell

__all__ = ["CellPrescreen", "prescreen_cell"]


@dataclass
class CellPrescreen:
    cell: SweepCell
    time_ns: np.ndarray          # [K] analytic makespans
    avg_w: np.ndarray            # [K] analytic chip power proxy
    energy_j: np.ndarray         # [K]
    util: np.ndarray             # [K, 4] per-engine-class utilization
    n_tasks: int
    spilled_layers: int
    total_flops: float
    hbm_bytes: float             # compiled HBM traffic (weights + spills)
    wall_s: float                # compile + batched schedule wall time


# engine-class utilization -> power-tree module families
_CLASS_FAMILIES = {
    ENG_MXU: ("mxu",),
    ENG_VPU: ("vpu",),
    ENG_DMA: ("hbm", "dma"),
    ENG_ICI: ("ici", "noc"),
}


def prescreen_cell(cell: SweepCell) -> CellPrescreen:
    """One compile + ONE batched XLA schedule call for the whole cell."""
    t0 = time.time()
    spec = cell.spec
    cfg0 = cell.base_cfg()
    ops = resolve_workload(cell.workload)()
    cw = compile_ops(ops, cfg0,
                     CompileOptions(n_tiles=cell.n_tiles,
                                    **spec.compile_opts))
    arrays = from_tasks(cw.tasks)
    cfgs = [p.cfg(spec) for p in cell.points]
    pm = np.stack([params_of(c) for c in cfgs])
    makespans, busy = schedule_many_stats(arrays, pm)

    # busy time is summed over all engine instances of a class; normalize
    # by instance count so utilization stays in [0, 1]
    n_units = np.ones(N_ENGINE_CLASSES)
    for c in range(N_ENGINE_CLASSES):
        units = np.unique(arrays.engine_unit[arrays.engine_class == c])
        n_units[c] = max(len(units), 1)
    util = np.clip(busy / (np.maximum(makespans, 1e-9)[:, None] * n_units),
                   0.0, 1.0)

    avg_w = np.empty(len(cell.points))
    for i, cfg in enumerate(cfgs):
        fam_util: Dict[str, float] = {}
        for c, fams in _CLASS_FAMILIES.items():
            for fam in fams:
                fam_util[fam] = float(util[i, c])
        fam_util["vmem"] = max(fam_util["mxu"], fam_util["vpu"])
        avg_w[i] = analytic_power_w(cfg, fam_util, n_tiles=cell.n_tiles,
                                    freq_ghz=cfg.clock_ghz,
                                    temp_c=spec.refine.temp_c)
    energy = avg_w * makespans * 1e-9
    return CellPrescreen(cell=cell, time_ns=makespans, avg_w=avg_w,
                         energy_j=energy, util=util, n_tasks=len(cw.tasks),
                         spilled_layers=cw.spilled_layers,
                         total_flops=cw.total_flops,
                         hbm_bytes=cw.hbm_bytes,
                         wall_s=time.time() - t0)
