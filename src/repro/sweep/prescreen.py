"""Vectorized analytic pre-screen of one structural cell.

Compiles the cell's task graph once, then evaluates the whole analytic
sub-grid (all swept parameter vectors) in a single ``jax.vmap``/XLA call
via ``core.vectorized.schedule_many_stats``. Per point, the busy-time
vector feeds the analytic Power-EM proxy so the Pareto selection has a
real (time, energy) plane to work with — all without ever stepping the
event engine.

Full-model workloads (``graph.workloads.model_parts``) take the
**layer-replication fast path**: instead of compiling and scanning
``layers`` copies of the layer graph, the pre-screen compiles ONE layer
body and the model head, schedules each once, and composes the stats in
closed form (``model = layers * body + head`` — the
``core.vectorized.schedule_stats`` ``repeats`` contract). A ``memo``
dict shared across a campaign's cells dedupes the part compiles, so a
sweep axis over layer counts re-uses the same body screen — the event
engine still refines the full replicated op list.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import numpy as np

from ..core.vectorized import (ENG_DMA, ENG_ICI, ENG_MXU, ENG_VPU,
                               N_ENGINE_CLASSES, TaskArrays, from_tasks,
                               params_of, schedule_many_stats)
from ..graph.compiler import CompileOptions, compile_ops
from ..graph.workloads import model_parts, resolve_workload
from ..power.powerem import analytic_power_w
from .spec import SweepCell

__all__ = ["CellPrescreen", "prescreen_cell"]


@dataclass
class CellPrescreen:
    cell: SweepCell
    time_ns: np.ndarray          # [K] analytic makespans
    avg_w: np.ndarray            # [K] analytic chip power proxy
    energy_j: np.ndarray         # [K]
    util: np.ndarray             # [K, 4] per-engine-class utilization
    n_tasks: int
    spilled_layers: int
    total_flops: float
    hbm_bytes: float             # compiled HBM traffic (weights + spills)
    wall_s: float                # compile + batched schedule wall time


# engine-class utilization -> power-tree module families
_CLASS_FAMILIES = {
    ENG_MXU: ("mxu",),
    ENG_VPU: ("vpu",),
    ENG_DMA: ("hbm", "dma"),
    ENG_ICI: ("ici", "noc"),
}


def _class_units(arrays: TaskArrays) -> np.ndarray:
    """Engine instances per class (for utilization normalization)."""
    n_units = np.ones(N_ENGINE_CLASSES)
    for c in range(N_ENGINE_CLASSES):
        units = np.unique(arrays.engine_unit[arrays.engine_class == c])
        n_units[c] = max(len(units), 1)
    return n_units


@dataclass
class _PartScreen:
    """One compiled + batch-scheduled part graph (a layer body, a model
    head, or a whole single-graph workload)."""

    time_ns: np.ndarray          # [K]
    busy: np.ndarray             # [K, N_ENGINE_CLASSES]
    n_units: np.ndarray          # [N_ENGINE_CLASSES]
    n_tasks: int
    spilled: int
    total_flops: float
    hbm_bytes: float


def _screen_ops(ops, cell: SweepCell, opts: CompileOptions,
                pm: np.ndarray) -> _PartScreen:
    cw = compile_ops(ops, cell.base_cfg(), opts)
    arrays = from_tasks(cw.tasks)
    mk, busy = schedule_many_stats(arrays, pm)
    return _PartScreen(time_ns=mk, busy=busy, n_units=_class_units(arrays),
                       n_tasks=len(cw.tasks), spilled=cw.spilled_layers,
                       total_flops=cw.total_flops, hbm_bytes=cw.hbm_bytes)


def prescreen_cell(cell: SweepCell,
                   memo: Optional[Dict[Any, _PartScreen]] = None
                   ) -> CellPrescreen:
    """One compile + ONE batched XLA schedule call for the whole cell
    (two for full-model cells on a part-memo miss: body + head).

    ``memo`` (optional, shared across the cells of one campaign run)
    caches part screens keyed by part identity x n_tiles x structural
    overrides x the analytic parameter matrix, so e.g. a ``layers`` axis
    compiles each distinct layer body once for the whole sweep.
    """
    t0 = time.time()
    spec = cell.spec
    opts = CompileOptions(n_tiles=cell.n_tiles, **spec.compile_opts)
    cfgs = [p.cfg(spec) for p in cell.points]
    pm = np.stack([params_of(c) for c in cfgs])
    parts = model_parts(cell.workload)
    if parts is None:
        scr = _screen_ops(resolve_workload(cell.workload)(), cell, opts, pm)
        makespans, busy, n_units = scr.time_ns, scr.busy, scr.n_units
        n_tasks, spilled = scr.n_tasks, scr.spilled
        total_flops, hbm_bytes = scr.total_flops, scr.hbm_bytes
    else:
        def part(key: str, build) -> _PartScreen:
            if memo is None:
                return _screen_ops(build(), cell, opts, pm)
            mkey: Tuple = (key, cell.n_tiles,
                           tuple(sorted(cell.structural.items())),
                           pm.tobytes())
            if mkey not in memo:
                memo[mkey] = _screen_ops(build(), cell, opts, pm)
            return memo[mkey]

        body = part(parts.body_key, parts.body)
        head = part(parts.head_key, parts.head)
        L = parts.layers
        # closed-form layer replication: model = L x body + head (the
        # schedule_stats ``repeats`` contract; tests/test_invariants.py
        # pins prescreen == composed single-layer results)
        makespans = L * body.time_ns + head.time_ns
        busy = L * body.busy + head.busy
        n_units = np.maximum(body.n_units, head.n_units)
        n_tasks = L * body.n_tasks + head.n_tasks
        spilled = L * body.spilled + head.spilled
        total_flops = L * body.total_flops + head.total_flops
        hbm_bytes = L * body.hbm_bytes + head.hbm_bytes

    # busy time is summed over all engine instances of a class; normalize
    # by instance count so utilization stays in [0, 1]
    util = np.clip(busy / (np.maximum(makespans, 1e-9)[:, None] * n_units),
                   0.0, 1.0)

    avg_w = np.empty(len(cell.points))
    for i, cfg in enumerate(cfgs):
        fam_util: Dict[str, float] = {}
        for c, fams in _CLASS_FAMILIES.items():
            for fam in fams:
                fam_util[fam] = float(util[i, c])
        fam_util["vmem"] = max(fam_util["mxu"], fam_util["vpu"])
        avg_w[i] = analytic_power_w(cfg, fam_util, n_tiles=cell.n_tiles,
                                    freq_ghz=cfg.clock_ghz,
                                    temp_c=spec.refine.temp_c)
    energy = avg_w * makespans * 1e-9
    return CellPrescreen(cell=cell, time_ns=makespans, avg_w=avg_w,
                         energy_j=energy, util=util, n_tasks=n_tasks,
                         spilled_layers=spilled,
                         total_flops=total_flops,
                         hbm_bytes=hbm_bytes,
                         wall_s=time.time() - t0)
