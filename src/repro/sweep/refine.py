"""Ground-truth refinement of one grid point (event engine + Power-EM).

Kept in its own module with **no jax imports anywhere on its import
path** so parallel refinement workers (``spawn`` context) start in
milliseconds instead of re-initializing XLA per process.
"""
from __future__ import annotations

from typing import Any, Dict

from ..graph.compiler import CompileOptions, compile_ops
from ..graph.workloads import resolve_workload
from ..hw.chip import System
from ..hw.presets import from_dict
from ..power.powerem import PowerEM

__all__ = ["refine_point", "refine_payload"]


def refine_payload(*, workload: str, n_tiles: int, hw: Dict[str, Any],
                   compile_opts: Dict[str, Any], pti_ns: float,
                   temp_c: float, keep_series: bool) -> Dict[str, Any]:
    """The cache-keyed, process-picklable input of one refinement."""
    return {"workload": workload, "n_tiles": n_tiles, "hw": hw,
            "compile_opts": compile_opts, "pti_ns": pti_ns,
            "temp_c": temp_c, "keep_series": keep_series}


def refine_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Compile + event-simulate + Power-EM one hardware point."""
    cfg = from_dict(payload["hw"])
    nt = payload["n_tiles"]
    ops = resolve_workload(payload["workload"])()
    cw = compile_ops(ops, cfg,
                     CompileOptions(n_tiles=nt, **payload["compile_opts"]))
    sysm = System(cfg, n_tiles=nt)
    rep = sysm.run_workload(cw.tasks)
    pem = PowerEM(cfg, n_tiles=nt, freq_ghz=cfg.clock_ghz,
                  temp_c=payload["temp_c"])
    prep = pem.analyze(sysm.tracer, pti_ns=payload["pti_ns"])
    t = rep.makespan_ns
    e = prep.energy_j()
    rec = {
        "time_ns": t,
        "inf_per_s": 1e9 / t if t > 0 else 0.0,
        "avg_w": prep.avg_w,
        "peak_w": prep.peak_w,
        "energy_j": e,
        "inf_per_j": (1.0 / e) if e > 0 else 0.0,
        "volt": pem.tree.char.vf.f2v(cfg.clock_ghz, payload["temp_c"]),
        "n_tasks": rep.n_tasks,
        "spilled_layers": cw.spilled_layers,
        "total_flops": cw.total_flops,
    }
    if payload.get("keep_series"):
        rec["series_w"] = prep.series
        rec["pti_ns"] = prep.pti_ns
    return rec
