"""Ground-truth refinement of one grid point (event engine + Power-EM).

Kept in its own module with **no jax imports anywhere on its import
path** so parallel refinement workers (``spawn`` context) start in
milliseconds instead of re-initializing XLA per process.

Since ISSUE 5 a refinement payload carries an ``engine`` field:

* ``"event"`` — the classic path: compile, walk the full task list on
  the generator-driven event engine, Power-EM the tracer.
* ``"fast"``  — ``core.fastsim``: exact interval replay with
  steady-state layer extrapolation for full-model LM workloads (replay
  a reduced-layer twin, verify periodicity, synthesize the rest in
  arrays), exact full replay otherwise. Records are byte-identical to
  ``"event"`` whenever fastsim replays (it *is* the event engine then);
  extrapolated points agree to float-rounding noise.
* ``"auto"``  — ``"fast"`` for layered full-model workloads with at
  least ``fastsim.FAST_MIN_LAYERS`` layers (where extrapolation pays),
  ``"event"`` for everything else.

The field is part of the payload, so it travels through every
``repro.exec`` backend unchanged and lands in the result-cache content
key — switching engines never serves a stale record.

Since ISSUE 6 a payload may instead carry ``kind: "serve"``: a
serving-fleet cell (``serve.fleet.simulate_serve_point`` — trace-driven
continuous batching over analytic step costs). The kind field routes it
here and keys the cache, so serve cells flow through every backend, the
journal, and the result cache exactly like classic refinements.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

from ..core import fastsim
from ..graph.compiler import CompileOptions, CompiledWorkload, compile_ops
from ..graph.workloads import lm_workload_name, parse_lm_name, \
    resolve_workload
from ..hw.chip import System
from ..hw.presets import HwConfig, from_dict
from ..power.powerem import PowerEM

__all__ = ["refine_point", "refine_payload", "resolve_engine",
           "crosscheck_point", "ENGINES"]

ENGINES = ("event", "fast", "auto")


def refine_payload(*, workload: str, n_tiles: int, hw: Dict[str, Any],
                   compile_opts: Dict[str, Any], pti_ns: float,
                   temp_c: float, keep_series: bool,
                   engine: str = "event") -> Dict[str, Any]:
    """The cache-keyed, process-picklable input of one refinement."""
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    return {"workload": workload, "n_tiles": n_tiles, "hw": hw,
            "compile_opts": compile_opts, "pti_ns": pti_ns,
            "temp_c": temp_c, "keep_series": keep_series, "engine": engine}


def resolve_engine(engine: str, workload: str) -> str:
    """Collapse ``auto`` to a concrete engine for one workload."""
    if engine != "auto":
        return engine
    try:
        p = parse_lm_name(workload)
    except KeyError:
        p = None
    if p and p["layers"] and p["layers"] >= fastsim.FAST_MIN_LAYERS:
        return "fast"
    return "event"


def _compile(payload: Dict[str, Any]) -> Tuple[HwConfig, int,
                                               CompiledWorkload]:
    cfg = from_dict(payload["hw"])
    nt = payload["n_tiles"]
    ops = resolve_workload(payload["workload"])()
    cw = compile_ops(ops, cfg,
                     CompileOptions(n_tiles=nt, **payload["compile_opts"]))
    return cfg, nt, cw


def _reduced_workloads(workload: str) -> List[str]:
    """Reduced-layer replay-twin names, shallow first (the warmup
    transient varies with phase AND problem size, so a shallow attempt
    that fails its lock-in check retries deeper); empty when the
    workload is not an extrapolation candidate."""
    try:
        p = parse_lm_name(workload)
    except KeyError:
        return []
    if not p or not p["layers"] or p["layers"] < fastsim.FAST_MIN_LAYERS:
        return []
    depths = [fastsim.FAST_REPLAY_LAYERS_BY_PHASE.get(
        p["phase"], fastsim.FAST_REPLAY_LAYERS)]
    if fastsim.FAST_REPLAY_LAYERS not in depths:
        depths.append(fastsim.FAST_REPLAY_LAYERS)
    return [lm_workload_name(
        p["arch"], seq=p["seq"], batch=p["batch"], tp=p["tp"],
        phase=p["phase"], kv_len=p["kv_len"], ep=p["ep"],
        layers=r, dp=p["dp"], pod=p["pod"])
        for r in depths if r < p["layers"]]


def _simulate_fast(payload: Dict[str, Any]) -> Tuple[
        HwConfig, int, CompiledWorkload, "fastsim.FastRun"]:
    cfg, nt, cw = _compile(payload)
    opts = CompileOptions(n_tiles=nt, **payload["compile_opts"])
    reduced = [compile_ops(resolve_workload(n)(), cfg, opts)
               for n in _reduced_workloads(payload["workload"])]
    run = fastsim.simulate_fast(cw, cfg, n_tiles=nt, reduced=reduced)
    return cfg, nt, cw, run


def _record(cfg: HwConfig, nt: int, cw: CompiledWorkload, *,
            makespan_ns: float, n_tasks: int, prep, pem,
            payload: Dict[str, Any]) -> Dict[str, Any]:
    t = makespan_ns
    e = prep.energy_j()
    rec = {
        "time_ns": t,
        "inf_per_s": 1e9 / t if t > 0 else 0.0,
        "avg_w": prep.avg_w,
        "peak_w": prep.peak_w,
        "energy_j": e,
        "inf_per_j": (1.0 / e) if e > 0 else 0.0,
        "volt": pem.tree.char.vf.f2v(cfg.clock_ghz, payload["temp_c"]),
        "n_tasks": n_tasks,
        "spilled_layers": cw.spilled_layers,
        "total_flops": cw.total_flops,
    }
    if payload.get("keep_series"):
        rec["series_w"] = prep.series
        rec["pti_ns"] = prep.pti_ns
    return rec


def refine_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Compile + simulate + Power-EM one hardware point.

    ``payload["kind"]`` routes whole refinement families first
    (``"serve"`` -> the fleet simulator); within the classic family,
    ``payload["engine"]`` routes between the event engine and the
    ``core.fastsim`` interval-replay engine (see module docstring).
    """
    if payload.get("kind") == "serve":
        from ..serve.fleet import simulate_serve_point
        return simulate_serve_point(payload)
    engine = resolve_engine(payload.get("engine", "event"),
                            payload["workload"])
    cfg = from_dict(payload["hw"])
    nt = payload["n_tiles"]
    pem = PowerEM(cfg, n_tiles=nt, freq_ghz=cfg.clock_ghz,
                  temp_c=payload["temp_c"])
    if engine == "fast":
        cfg, nt, cw, run = _simulate_fast(payload)
        prep = pem.analyze(run.samples, pti_ns=payload["pti_ns"])
        return _record(cfg, nt, cw, makespan_ns=run.makespan_ns,
                       n_tasks=len(cw.tasks), prep=prep, pem=pem,
                       payload=payload)
    cfg, nt, cw = _compile(payload)
    sysm = System(cfg, n_tiles=nt)
    rep = sysm.run_workload(cw.tasks)
    prep = pem.analyze(sysm.tracer, pti_ns=payload["pti_ns"])
    return _record(cfg, nt, cw, makespan_ns=rep.makespan_ns,
                   n_tasks=rep.n_tasks, prep=prep, pem=pem, payload=payload)


def crosscheck_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Run one point on BOTH engines and quantify the disagreement.

    Returns per-task interval deltas and record-level deltas; the fast
    engine's contract is ``max_interval_diff_ns == 0.0`` whenever it
    replayed (``extrapolated=False``) and float-rounding noise
    otherwise. Used by tests and ``python -m repro.sweep crosscheck``.

    Each engine simulates exactly once: records are assembled from the
    already-computed interval/sample arrays (bit-identical to what
    ``refine_point`` would produce — the event path's Power-EM consumes
    the same ``SampleArrays`` export). Also reports the array-lowered
    ``list_schedule`` relaxation as the analytic estimate.
    """
    import numpy as np

    cfg, nt, cw, run = _simulate_fast(payload)
    if run.extrapolated:
        ev_start, ev_end, ev_sa = fastsim.replay_intervals(cw.tasks, cfg,
                                                           n_tiles=nt)
    else:
        # the fallback already IS a full event replay of these tasks
        ev_start, ev_end, ev_sa = run.start, run.end, run.samples
    dstart = float(np.abs(run.start - ev_start).max(initial=0.0))
    dend = float(np.abs(run.end - ev_end).max(initial=0.0))
    pem = PowerEM(cfg, n_tiles=nt, freq_ghz=cfg.clock_ghz,
                  temp_c=payload["temp_c"])
    rec_fa = _record(cfg, nt, cw, makespan_ns=run.makespan_ns,
                     n_tasks=len(cw.tasks), pem=pem, payload=payload,
                     prep=pem.analyze(run.samples,
                                      pti_ns=payload["pti_ns"]))
    rec_ev = _record(cfg, nt, cw, makespan_ns=ev_sa.makespan(),
                     n_tasks=len(cw.tasks), pem=pem, payload=payload,
                     prep=pem.analyze(ev_sa, pti_ns=payload["pti_ns"]))
    num_keys = [k for k, v in rec_ev.items() if isinstance(v, float)]
    rec_diff = {k: abs(rec_fa[k] - rec_ev[k]) /
                (abs(rec_ev[k]) if rec_ev[k] else 1.0) for k in num_keys}
    _, _, analytic_mk = fastsim.list_schedule(fastsim.lower(cw, cfg))
    return {
        "workload": payload["workload"],
        "extrapolated": run.extrapolated,
        "replayed_tasks": run.replayed_tasks,
        "n_tasks": len(cw.tasks),
        "max_interval_diff_ns": max(dstart, dend),
        "makespan_diff_ns": abs(run.makespan_ns - ev_sa.makespan()),
        "record_rel_diff": rec_diff,
        "analytic_makespan_ns": analytic_mk,
        "analytic_ratio": (ev_sa.makespan() / analytic_mk
                           if analytic_mk > 0 else 0.0),
        "detail": run.detail,
    }
