"""Ground-truth refinement of one grid point (event engine + Power-EM).

Kept in its own module with **no jax imports anywhere on its import
path** so parallel refinement workers (``spawn`` context) start in
milliseconds instead of re-initializing XLA per process.

Since ISSUE 5 a refinement payload carries an ``engine`` field:

* ``"event"`` — the classic path: compile, walk the full task list on
  the generator-driven event engine, Power-EM the tracer.
* ``"fast"``  — ``core.fastsim``: exact interval replay with
  steady-state layer extrapolation for full-model LM workloads (replay
  a reduced-layer twin, verify periodicity, synthesize the rest in
  arrays), exact full replay otherwise. Records are byte-identical to
  ``"event"`` whenever fastsim replays (it *is* the event engine then);
  extrapolated points agree to float-rounding noise.
* ``"auto"``  — ``"fast"`` for layered full-model workloads with at
  least ``fastsim.FAST_MIN_LAYERS`` layers (where extrapolation pays),
  ``"event"`` for everything else.

The field is part of the payload, so it travels through every
``repro.exec`` backend unchanged and lands in the result-cache content
key — switching engines never serves a stale record.

Since ISSUE 6 a payload may instead carry ``kind: "serve"``: a
serving-fleet cell (``serve.fleet.simulate_serve_point`` — trace-driven
continuous batching over analytic step costs). The kind field routes it
here and keys the cache, so serve cells flow through every backend, the
journal, and the result cache exactly like classic refinements.

Since ISSUE 8 a payload may carry ``kind: "batch"``: many classic
fast-engine points refined as one job (``refine_batch``), grouped by
structural class so points differing only along latency-rescaling
hardware axes share compiles, event-engine twin replays, and — when the
dead-axis analysis proves the records identical — the records
themselves (``core.batchsim``). The batch record is expanded back into
per-point cache entries and journal events by ``exec.backend``, so
downstream consumers never see the batching.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core import batchsim, fastsim
from ..graph import ingest
from ..graph.compiler import CompileOptions, CompiledWorkload, compile_ops
from ..graph.workloads import lm_workload_name, parse_lm_name, \
    resolve_workload
from ..hw.chip import System
from ..hw.presets import HwConfig, from_dict
from ..obs.metrics import REGISTRY
from ..power.powerem import PowerEM
from .cache import content_key
from .spec import ANALYTIC_AXES

__all__ = ["refine_point", "refine_payload", "resolve_engine",
           "crosscheck_point", "ENGINES", "batch_payload", "plan_batches",
           "refine_batch"]

ENGINES = ("event", "fast", "auto")


def refine_payload(*, workload: str, n_tiles: int, hw: Dict[str, Any],
                   compile_opts: Dict[str, Any], pti_ns: float,
                   temp_c: float, keep_series: bool,
                   engine: str = "event") -> Dict[str, Any]:
    """The cache-keyed, process-picklable input of one refinement."""
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    return {"workload": workload, "n_tiles": n_tiles, "hw": hw,
            "compile_opts": compile_opts, "pti_ns": pti_ns,
            "temp_c": temp_c, "keep_series": keep_series, "engine": engine}


def resolve_engine(engine: str, workload: str) -> str:
    """Collapse ``auto`` to a concrete engine for one workload."""
    if engine != "auto":
        return engine
    h = ingest.parse_hlo_name(workload)
    if h is not None:
        layers = _hlo_layers(h)
        return "fast" if layers >= fastsim.FAST_MIN_LAYERS else "event"
    try:
        p = parse_lm_name(workload)
    except KeyError:
        p = None
    if p and p["layers"] and p["layers"] >= fastsim.FAST_MIN_LAYERS:
        return "fast"
    return "event"


def _hlo_layers(parsed: Dict[str, Any]) -> int:
    """Layer-block count of a parsed ``hlo/...`` name (0 when the
    fixture is unknown — resolution will fail loudly later anyway)."""
    if parsed["layers_keep"] is not None:
        return parsed["layers_keep"]
    try:
        return int(ingest.fixture_meta(parsed["fixture"]).get("layers", 0))
    except KeyError:
        return 0


def _compile(payload: Dict[str, Any]) -> Tuple[HwConfig, int,
                                               CompiledWorkload]:
    cfg = from_dict(payload["hw"])
    nt = payload["n_tiles"]
    ops = resolve_workload(payload["workload"])()
    cw = compile_ops(ops, cfg,
                     CompileOptions(n_tiles=nt, **payload["compile_opts"]))
    return cfg, nt, cw


def _reduced_workloads(workload: str) -> List[str]:
    """Reduced-layer replay-twin names, shallow first (the warmup
    transient varies with phase AND problem size, so a shallow attempt
    that fails its lock-in check retries deeper); empty when the
    workload is not an extrapolation candidate."""
    h = ingest.parse_hlo_name(workload)
    if h is not None:
        if h["layers_keep"] is not None:      # already a reduced twin
            return []
        layers = _hlo_layers(h)
        if layers < fastsim.FAST_MIN_LAYERS:
            return []
        phase = ""
        try:
            phase = ingest.fixture_meta(h["fixture"]).get("phase", "")
        except KeyError:
            pass
        depths = [fastsim.FAST_REPLAY_LAYERS_BY_PHASE.get(
            phase, fastsim.FAST_REPLAY_LAYERS)]
        if fastsim.FAST_REPLAY_LAYERS not in depths:
            depths.append(fastsim.FAST_REPLAY_LAYERS)
        return [ingest.hlo_workload_name(h["fixture"], layers=r)
                for r in depths if r < layers]
    try:
        p = parse_lm_name(workload)
    except KeyError:
        return []
    if not p or not p["layers"] or p["layers"] < fastsim.FAST_MIN_LAYERS:
        return []
    depths = [fastsim.FAST_REPLAY_LAYERS_BY_PHASE.get(
        p["phase"], fastsim.FAST_REPLAY_LAYERS)]
    if fastsim.FAST_REPLAY_LAYERS not in depths:
        depths.append(fastsim.FAST_REPLAY_LAYERS)
    return [lm_workload_name(
        p["arch"], seq=p["seq"], batch=p["batch"], tp=p["tp"],
        phase=p["phase"], kv_len=p["kv_len"], ep=p["ep"],
        layers=r, dp=p["dp"], pod=p["pod"])
        for r in depths if r < p["layers"]]


def _simulate_fast(payload: Dict[str, Any]) -> Tuple[
        HwConfig, int, CompiledWorkload, "fastsim.FastRun"]:
    cfg, nt, cw = _compile(payload)
    opts = CompileOptions(n_tiles=nt, **payload["compile_opts"])
    reduced = [compile_ops(resolve_workload(n)(), cfg, opts)
               for n in _reduced_workloads(payload["workload"])]
    run = fastsim.simulate_fast(cw, cfg, n_tiles=nt, reduced=reduced)
    return cfg, nt, cw, run


def _record(cfg: HwConfig, nt: int, cw: CompiledWorkload, *,
            makespan_ns: float, n_tasks: int, prep, pem,
            payload: Dict[str, Any]) -> Dict[str, Any]:
    t = makespan_ns
    e = prep.energy_j()
    rec = {
        "time_ns": t,
        "inf_per_s": 1e9 / t if t > 0 else 0.0,
        "avg_w": prep.avg_w,
        "peak_w": prep.peak_w,
        "energy_j": e,
        "inf_per_j": (1.0 / e) if e > 0 else 0.0,
        "volt": pem.tree.char.vf.f2v(cfg.clock_ghz, payload["temp_c"]),
        "n_tasks": n_tasks,
        "spilled_layers": cw.spilled_layers,
        "total_flops": cw.total_flops,
    }
    if payload.get("keep_series"):
        rec["series_w"] = prep.series
        rec["pti_ns"] = prep.pti_ns
    return rec


def refine_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Compile + simulate + Power-EM one hardware point.

    ``payload["kind"]`` routes whole refinement families first
    (``"serve"`` -> the fleet simulator); within the classic family,
    ``payload["engine"]`` routes between the event engine and the
    ``core.fastsim`` interval-replay engine (see module docstring).
    """
    if payload.get("kind") == "serve":
        from ..serve.fleet import simulate_serve_point
        return simulate_serve_point(payload)
    if payload.get("kind") == "batch":
        return refine_batch(payload)
    engine = resolve_engine(payload.get("engine", "event"),
                            payload["workload"])
    cfg = from_dict(payload["hw"])
    nt = payload["n_tiles"]
    pem = PowerEM(cfg, n_tiles=nt, freq_ghz=cfg.clock_ghz,
                  temp_c=payload["temp_c"])
    if engine == "fast":
        cfg, nt, cw, run = _simulate_fast(payload)
        prep = pem.analyze(run.samples, pti_ns=payload["pti_ns"])
        return _record(cfg, nt, cw, makespan_ns=run.makespan_ns,
                       n_tasks=len(cw.tasks), prep=prep, pem=pem,
                       payload=payload)
    cfg, nt, cw = _compile(payload)
    sysm = System(cfg, n_tiles=nt)
    rep = sysm.run_workload(cw.tasks)
    prep = pem.analyze(sysm.tracer, pti_ns=payload["pti_ns"])
    return _record(cfg, nt, cw, makespan_ns=rep.makespan_ns,
                   n_tasks=rep.n_tasks, prep=prep, pem=pem, payload=payload)


# ---------------------------------------------------------------------------
# batched cross-point refinement (``core.batchsim``)


def batch_payload(items: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Wrap classic refinement payloads into one batch-job payload.

    The wrapper travels through every backend like any other payload
    (``kind: "batch"`` routes it in ``refine_point``); the result is a
    batch record — per-item records plus their content keys — which
    ``exec.backend`` expands into per-point cache entries and journal
    events, so batching is invisible downstream.
    """
    if not items:
        raise ValueError("batch_payload needs at least one item")
    for it in items:
        if it.get("kind") is not None:
            raise ValueError("only classic refinement payloads batch "
                             f"(got kind={it.get('kind')!r})")
    return {"kind": "batch", "items": [dict(it) for it in items]}


def _class_key(payload: Dict[str, Any]) -> str:
    """Structural-class planning key: everything in the payload except
    the analytic hw axes. Two payloads with equal keys compile to the
    same task graph (the compiler never reads the analytic fields), so
    they are grouped without compiling — ``stack_tables``' structural
    check and the duration/relaxation fan-out defense backstop the
    claim at refinement time."""
    hw = {k: v for k, v in payload["hw"].items() if k not in ANALYTIC_AXES}
    rest = {k: v for k, v in payload.items() if k != "hw"}
    return json.dumps({"hw": hw, "rest": rest}, sort_keys=True,
                      default=float)


def plan_batches(payloads: List[Dict[str, Any]], batch: int
                 ) -> List[Tuple[Dict[str, Any], List[int]]]:
    """Group a refinement work list into dispatchable jobs.

    Returns ``[(job_payload, positions), ...]`` where ``positions``
    index into ``payloads`` (record order is reconstructed from them).
    Fast-engine classic points are grouped by structural class and
    greedily packed — whole classes when they fit — into batch jobs of
    at most ``batch`` points; serve/event/auto-event points and lone
    leftovers stay single-point jobs. Deterministic: classes are
    ordered by their first member's position and in-class points keep
    work-list (grid) order, so every backend sees the same jobs in the
    same order regardless of how the caller discovered the misses.
    """
    if batch < 2:
        raise ValueError(f"plan_batches needs batch >= 2, got {batch}")
    classes: Dict[str, List[int]] = {}
    singles: List[int] = []
    for i, p in enumerate(payloads):
        if p.get("kind") is None and resolve_engine(
                p.get("engine", "event"), p["workload"]) == "fast":
            classes.setdefault(_class_key(p), []).append(i)
        else:
            singles.append(i)
    jobs: List[Tuple[Dict[str, Any], List[int]]] = []
    cur: List[int] = []

    def flush() -> None:
        if len(cur) == 1:
            jobs.append((payloads[cur[0]], [cur[0]]))
        elif cur:
            jobs.append((batch_payload([payloads[i] for i in cur]),
                         list(cur)))
        cur.clear()

    for key in sorted(classes, key=lambda k: classes[k][0]):
        members = classes[key]
        for c0 in range(0, len(members), batch):
            chunk = members[c0:c0 + batch]
            if len(cur) + len(chunk) > batch:
                flush()
            cur.extend(chunk)
    flush()
    for i in singles:
        jobs.append((payloads[i], [i]))
    jobs.sort(key=lambda j: min(j[1]))
    return jobs


def _refine_class(cls_items: List[Dict[str, Any]], members: List[int],
                  records: List[Optional[Dict[str, Any]]],
                  memo: Dict[Tuple, Tuple]) -> None:
    """Refine one structural class (>= 2 fast-engine points) sharing
    one compile, one stacked relaxation, and — per live-axis subgroup —
    one twin replay, one splice, one Power-EM pass, one record."""
    it0 = cls_items[0]
    cfg0, nt, cw = _compile(it0)
    opts = CompileOptions(n_tiles=nt, **it0["compile_opts"])
    opts_json = json.dumps(it0["compile_opts"], sort_keys=True,
                           default=float)
    twin_names = _reduced_workloads(it0["workload"])
    twins = [compile_ops(resolve_workload(n)(), cfg0, opts)
             for n in twin_names]
    twin_ix = {id(t): i for i, t in enumerate(twins)}
    twin_dead = [batchsim.dead_axes(t) for t in twins]
    dead = batchsim.dead_axes(cw)
    cfgs = [from_dict(it["hw"]) for it in cls_items]
    # batched lowering + one stacked list-scheduling relaxation for the
    # whole class — the batch-scale analogue of the analytic pre-screen,
    # and half of the record-sharing defense below
    dur = batchsim.batch_durations(cw, cfgs)
    bt = batchsim.BatchTaskTable(table=fastsim.lower(cw, cfgs[0]),
                                 duration=dur, n_points=len(cls_items))
    b_start, b_end, _ = batchsim.list_schedule_batched(bt)
    groups: Dict[str, List[int]] = {}
    for j, it in enumerate(cls_items):
        groups.setdefault(batchsim.live_key(it["hw"], dead), []).append(j)
    if REGISTRY.enabled:
        REGISTRY.counter("batch.classes").inc()
        REGISTRY.histogram("batch.class_size",
                           bounds=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0)
                           ).observe(len(cls_items))
        REGISTRY.histogram("batch.groups_per_class",
                           bounds=(1.0, 2.0, 4.0, 8.0, 16.0)
                           ).observe(len(groups))
    for gkey in sorted(groups, key=lambda k: groups[k][0]):
        g = groups[gkey]
        head = g[0]
        # record-sharing defense: a member may ride the head's record
        # only when it is provably simulation-identical — bitwise-equal
        # analytic durations AND stacked-relaxation intervals. A
        # mismatch means the dead-axis proof missed something for this
        # graph; those members refine individually instead.
        shared = [j for j in g
                  if np.array_equal(dur[j], dur[head])
                  and np.array_equal(b_start[j], b_start[head])
                  and np.array_equal(b_end[j], b_end[head])]
        solo = [j for j in g if j not in set(shared)]
        cfg_h = cfgs[head]
        hw_h = cls_items[head]["hw"]

        def verify(rcw: CompiledWorkload, _cfg: HwConfig = cfg_h,
                   _hw: Dict[str, Any] = hw_h):
            # one event-engine twin replay per (twin, live-config) —
            # shared across subgroups AND classes of this batch job
            # (the `layers` axis reuses the same shallow twins)
            ti = twin_ix[id(rcw)]
            k = (twin_names[ti], nt, opts_json,
                 batchsim.live_key(_hw, twin_dead[ti]))
            hit = memo.get(k)
            if hit is not None:
                if REGISTRY.enabled:
                    REGISTRY.counter("batch.replay_memo",
                                     result="hit").inc()
                return hit
            res = fastsim.verify_replay(rcw, _cfg, n_tiles=nt)
            memo[k] = res
            if REGISTRY.enabled:
                REGISTRY.counter("batch.replay_memo", result="miss").inc()
            return res

        run = fastsim.simulate_fast(cw, cfg_h, n_tiles=nt, reduced=twins,
                                    verify=verify)
        pem = PowerEM(cfg_h, n_tiles=nt, freq_ghz=cfg_h.clock_ghz,
                      temp_c=it0["temp_c"])
        prep = pem.analyze(run.samples, pti_ns=it0["pti_ns"])
        rec = _record(cfg_h, nt, cw, makespan_ns=run.makespan_ns,
                      n_tasks=len(cw.tasks), prep=prep, pem=pem,
                      payload=cls_items[head])
        for j in shared:
            records[members[j]] = rec
        for j in solo:
            records[members[j]] = refine_point(cls_items[j])
        if REGISTRY.enabled:
            REGISTRY.counter("batch.points", path="shared").inc(len(shared))
            if solo:
                REGISTRY.counter("batch.points",
                                 path="fallback").inc(len(solo))


def refine_batch(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Refine a ``kind: "batch"`` job: every item, grouped for sharing.

    Items are grouped by structural class; classes of one point — and
    anything not on the fast engine — fall back to ``refine_point``
    per item, **bitwise** identical to unbatched refinement. Returns a
    batch record ``{"kind": "batch", "records": [...], "keys": [...]}``
    with records in item order and each item's own content key, so the
    exec layer can expand it into per-point cache/journal entries.
    """
    items = payload["items"]
    if not items:
        raise ValueError("batch payload has no items")
    records: List[Optional[Dict[str, Any]]] = [None] * len(items)
    classes: Dict[str, List[int]] = {}
    for i, it in enumerate(items):
        classes.setdefault(_class_key(it), []).append(i)
    memo: Dict[Tuple, Tuple] = {}     # twin replays, shared job-wide
    for key in sorted(classes, key=lambda k: classes[k][0]):
        members = classes[key]
        it0 = items[members[0]]
        eng = resolve_engine(it0.get("engine", "event"), it0["workload"])
        if len(members) == 1 or eng != "fast" or \
                it0.get("kind") is not None:
            for m in members:
                records[m] = refine_point(items[m])
            if REGISTRY.enabled:
                REGISTRY.counter("batch.points",
                                 path="fallback").inc(len(members))
            continue
        _refine_class([items[m] for m in members], members, records, memo)
    return {"kind": "batch", "records": records,
            "keys": [content_key(it) for it in items]}


def crosscheck_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Run one point on BOTH engines and quantify the disagreement.

    Returns per-task interval deltas and record-level deltas; the fast
    engine's contract is ``max_interval_diff_ns == 0.0`` whenever it
    replayed (``extrapolated=False``) and float-rounding noise
    otherwise. Used by tests and ``python -m repro.sweep crosscheck``.

    Each engine simulates exactly once: records are assembled from the
    already-computed interval/sample arrays (bit-identical to what
    ``refine_point`` would produce — the event path's Power-EM consumes
    the same ``SampleArrays`` export). Also reports the array-lowered
    ``list_schedule`` relaxation as the analytic estimate.
    """
    import numpy as np

    cfg, nt, cw, run = _simulate_fast(payload)
    if run.extrapolated:
        ev_start, ev_end, ev_sa = fastsim.replay_intervals(cw.tasks, cfg,
                                                           n_tiles=nt)
    else:
        # the fallback already IS a full event replay of these tasks
        ev_start, ev_end, ev_sa = run.start, run.end, run.samples
    dstart = float(np.abs(run.start - ev_start).max(initial=0.0))
    dend = float(np.abs(run.end - ev_end).max(initial=0.0))
    pem = PowerEM(cfg, n_tiles=nt, freq_ghz=cfg.clock_ghz,
                  temp_c=payload["temp_c"])
    rec_fa = _record(cfg, nt, cw, makespan_ns=run.makespan_ns,
                     n_tasks=len(cw.tasks), pem=pem, payload=payload,
                     prep=pem.analyze(run.samples,
                                      pti_ns=payload["pti_ns"]))
    rec_ev = _record(cfg, nt, cw, makespan_ns=ev_sa.makespan(),
                     n_tasks=len(cw.tasks), pem=pem, payload=payload,
                     prep=pem.analyze(ev_sa, pti_ns=payload["pti_ns"]))
    num_keys = [k for k, v in rec_ev.items() if isinstance(v, float)]
    rec_diff = {k: abs(rec_fa[k] - rec_ev[k]) /
                (abs(rec_ev[k]) if rec_ev[k] else 1.0) for k in num_keys}
    _, _, analytic_mk = fastsim.list_schedule(fastsim.lower(cw, cfg))
    return {
        "workload": payload["workload"],
        "extrapolated": run.extrapolated,
        "replayed_tasks": run.replayed_tasks,
        "n_tasks": len(cw.tasks),
        "max_interval_diff_ns": max(dstart, dend),
        "makespan_diff_ns": abs(run.makespan_ns - ev_sa.makespan()),
        "record_rel_diff": rec_diff,
        "analytic_makespan_ns": analytic_mk,
        "analytic_ratio": (ev_sa.makespan() / analytic_mk
                           if analytic_mk > 0 else 0.0),
        "detail": run.detail,
    }
