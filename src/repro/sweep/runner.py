"""Campaign runner: pre-screen -> select -> cached parallel refinement.

``run_campaign`` is the one entrypoint every sweep benchmark drives:

* expands the spec into structural cells,
* pre-screens each cell's full analytic sub-grid in one batched XLA call,
* selects the Pareto-interesting points per cell,
* refines only those on the ground-truth event engine + Power-EM — in
  parallel ``spawn`` worker processes (the refinement import path is
  jax-free, see ``refine.py``) behind a content-hashed on-disk cache,
* returns uniform JSON-ready campaign records that ``benchmarks/report``
  renders and downstream analyses (DVFS policy picks, scaling summaries)
  post-process.
"""
from __future__ import annotations

import dataclasses
import json
import multiprocessing as mp
import os
import time
import warnings
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from ..hw.presets import to_dict
from .cache import ResultCache, content_key
from .pareto import select_points
from .prescreen import prescreen_cell
from .refine import refine_payload, refine_point
from .spec import SweepSpec

__all__ = ["CampaignResult", "run_campaign", "save_result", "load_result"]

RESULT_SCHEMA = 1


@dataclass
class CampaignResult:
    spec: Dict[str, Any]
    records: List[Dict[str, Any]]
    summary: Dict[str, Any]
    schema: int = RESULT_SCHEMA

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @property
    def refined(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["refined"]]

    def best(self, key: str = "time_ns") -> Optional[Dict[str, Any]]:
        refined = self.refined
        if not refined:
            return None
        return min(refined, key=lambda r: r[key])


def save_result(res: CampaignResult, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(res.to_dict(), f, indent=1, default=float)
    return path


def load_result(path: str) -> CampaignResult:
    with open(path) as f:
        d = json.load(f)
    return CampaignResult(spec=d["spec"], records=d["records"],
                          summary=d["summary"],
                          schema=d.get("schema", RESULT_SCHEMA))


def _log(progress: Optional[Callable[[str], None]], msg: str) -> None:
    if progress:
        progress(msg)


def _mp_method() -> str:
    """Worker start method. ``fork`` where available: refinement workers
    never touch jax (see refine.py), fork skips the __main__ re-import
    spawn needs and starts in ~ms. Override with SWEEP_MP_CONTEXT."""
    env = os.environ.get("SWEEP_MP_CONTEXT")
    if env:
        return env
    return "fork" if "fork" in mp.get_all_start_methods() else "spawn"


def run_campaign(spec: SweepSpec, *, workers: Optional[int] = 0,
                 use_cache: bool = True,
                 cache_dir: Optional[str] = None,
                 progress: Optional[Callable[[str], None]] = None
                 ) -> CampaignResult:
    """Execute one campaign.

    ``workers=0`` refines inline (deterministic, test-friendly);
    ``workers=None`` uses one process per core; ``workers=N`` caps the
    pool. The cache (``cache_dir`` or ``spec.cache_dir``) makes repeated
    campaigns incremental; pass ``use_cache=False`` to force re-runs.
    """
    t_start = time.time()
    cells = spec.cells()
    cdir = cache_dir or spec.cache_dir
    cache = ResultCache(cdir) if (use_cache and cdir) else None

    # -- phase 1: batched analytic pre-screen (one XLA call per cell) ----
    t0 = time.time()
    screens = []
    for cell in cells:
        scr = prescreen_cell(cell)
        screens.append(scr)
        _log(progress, f"prescreen {cell.label}: {len(cell.points)} points "
             f"in one XLA call ({scr.wall_s:.2f}s)")
    prescreen_s = time.time() - t0

    # -- phase 2: Pareto selection per cell ------------------------------
    records: List[Dict[str, Any]] = []
    todo: List[Dict[str, Any]] = []        # refinement payload per record
    todo_idx: List[int] = []               # record index per payload
    for scr in screens:
        cell = scr.cell
        obj = np.stack([scr.time_ns, scr.energy_j], axis=1)
        picked = set(select_points(obj, mode=spec.refine.mode,
                                   max_points=spec.refine.max_points))
        for i, pt in enumerate(cell.points):
            cfg = pt.cfg(spec)
            rec: Dict[str, Any] = {
                "point_id": pt.point_id(),
                "campaign": spec.name,
                "workload": pt.workload,
                "n_tiles": pt.n_tiles,
                "overrides": dict(pt.overrides),
                "hw_name": cfg.name,
                "analytic_time_ns": float(scr.time_ns[i]),
                "analytic_inf_per_s": float(1e9 / scr.time_ns[i])
                if scr.time_ns[i] > 0 else 0.0,
                "analytic_avg_w": float(scr.avg_w[i]),
                "analytic_energy_j": float(scr.energy_j[i]),
                "selected": i in picked,
                "refined": False,
                "cached": False,
            }
            if i in picked:
                payload = refine_payload(
                    workload=pt.workload, n_tiles=pt.n_tiles,
                    hw=to_dict(cfg), compile_opts=dict(spec.compile_opts),
                    pti_ns=spec.refine.pti_ns, temp_c=spec.refine.temp_c,
                    keep_series=spec.refine.keep_series)
                todo.append(payload)
                todo_idx.append(len(records))
            records.append(rec)
        _log(progress, f"select {cell.label}: {len(picked)}/"
             f"{len(cell.points)} points for event-engine refinement")

    # -- phase 3: cached, parallel event-engine refinement ---------------
    t0 = time.time()
    cache_hits = 0
    misses: List[int] = []                 # indices into todo
    results: List[Optional[Dict[str, Any]]] = [None] * len(todo)
    keys = [content_key(p) for p in todo]
    if cache is not None:
        for i, key in enumerate(keys):
            hit = cache.get(key)
            if hit is not None:
                results[i] = hit
                records[todo_idx[i]]["cached"] = True
                cache_hits += 1
            else:
                misses.append(i)
    else:
        misses = list(range(len(todo)))

    if misses:
        n_workers = workers if workers is not None else (os.cpu_count() or 1)
        fresh: Optional[List[Dict[str, Any]]] = None
        if n_workers and n_workers > 1 and len(misses) > 1:
            try:
                ctx = mp.get_context(_mp_method())
                with warnings.catch_warnings():
                    # jax warns about fork+threads; refinement workers
                    # never re-enter jax/XLA (refine.py is jax-free)
                    warnings.filterwarnings(
                        "ignore", message=".*os.fork.*",
                        category=RuntimeWarning)
                    with ProcessPoolExecutor(
                            max_workers=min(n_workers, len(misses)),
                            mp_context=ctx) as pool:
                        fresh = list(pool.map(refine_point,
                                              [todo[i] for i in misses]))
            except BrokenProcessPool:
                # e.g. spawn re-importing an unguarded __main__ —
                # refinement is pure, so just run inline
                _log(progress, "worker pool unavailable; refining inline")
                fresh = None
        if fresh is None:
            fresh = [refine_point(todo[i]) for i in misses]
        for i, rec in zip(misses, fresh):
            results[i] = rec
            if cache is not None:
                cache.put(keys[i], rec)
    refine_s = time.time() - t0

    deviations = []
    for i, res in enumerate(results):
        assert res is not None
        rec = records[todo_idx[i]]
        rec.update(res)
        rec["refined"] = True
        if rec["analytic_time_ns"] > 0:
            rec["deviation"] = rec["time_ns"] / rec["analytic_time_ns"]
            deviations.append(rec["deviation"])
    _log(progress, f"refine: {len(todo)} points "
         f"({cache_hits} cache hits, {len(misses)} simulated, "
         f"{refine_s:.2f}s)")

    summary = {
        "grid_points": len(records),
        "cells": len(cells),
        "prescreen_calls": len(cells),
        "refined": len(todo),
        "cache_hits": cache_hits,
        "simulated": len(misses),
        "prescreen_s": prescreen_s,
        "refine_s": refine_s,
        "wall_s": time.time() - t_start,
        "deviation_min": min(deviations) if deviations else None,
        "deviation_max": max(deviations) if deviations else None,
    }
    best = min((r for r in records if r["refined"]),
               key=lambda r: r["time_ns"], default=None)
    if best is not None:
        summary["best_time_point"] = {
            "point_id": best["point_id"], "workload": best["workload"],
            "overrides": best["overrides"], "time_ns": best["time_ns"]}
        beste = min((r for r in records if r["refined"]),
                    key=lambda r: r["energy_j"])
        summary["best_energy_point"] = {
            "point_id": beste["point_id"], "workload": beste["workload"],
            "overrides": beste["overrides"], "energy_j": beste["energy_j"]}
    return CampaignResult(spec=spec.to_dict(), records=records,
                          summary=summary)
