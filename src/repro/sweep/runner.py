"""Campaign runner: pre-screen -> select -> cached backend refinement.

``run_campaign`` is the one entrypoint every sweep benchmark drives:

* expands the spec into structural cells,
* pre-screens each cell's full analytic sub-grid in one batched XLA call,
* selects the Pareto-interesting points per cell,
* refines only those on the ground-truth event engine + Power-EM through
  a pluggable execution **backend** (``repro.exec``: inline / local
  process pool / resumable filesystem job spool) behind a content-hashed
  on-disk cache,
* journals per-point progress (status, wall time, worker id, cache-hit
  counters) to an append-only JSONL stream,
* returns uniform JSON-ready campaign records that ``benchmarks/report``
  renders and downstream analyses (DVFS policy picks, scaling summaries)
  post-process.

Records are canonicalized through a JSON round-trip before they enter a
result, so inline, pool, and spool backends — and cached re-runs —
produce byte-identical campaign records for the same spec.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Union

import numpy as np

# fresh records are canonicalized (JSON round-trip, sorted keys) so
# in-memory results match cache/spool-served ones byte-for-byte
from ..exec.backend import Backend, canonical as _canon, get_backend, \
    is_failure_record
from ..exec.journal import CampaignJournal
from ..hw.presets import to_dict
from ..obs.metrics import REGISTRY
from ..serve.fleet import serve_payload
from .cache import ResultCache, content_key
from .pareto import select_points
from .prescreen import prescreen_cell
from .refine import plan_batches, refine_payload
from .spec import SweepSpec

__all__ = ["CampaignResult", "run_campaign", "save_result", "load_result",
           "default_spool_dir", "annotate_hlo_crosscheck"]

RESULT_SCHEMA = 1


def _best(records: List[Dict[str, Any]], key: str
          ) -> Optional[Dict[str, Any]]:
    """Deterministic argmin over refined records: ties on the metric are
    broken by grid index, so reports are stable across runs/backends.
    Serving-fleet records are excluded — their metrics (fleet energy,
    request latency) are not comparable to per-inference ones; the
    summary ranks them separately (``best_goodput_point``)."""
    refined = [r for r in records
               if r.get("refined") and key in r and not r.get("serve")]
    if not refined:
        return None
    return min(refined,
               key=lambda r: (r[key], r.get("grid_index", len(records))))


@dataclass
class CampaignResult:
    spec: Dict[str, Any]
    records: List[Dict[str, Any]]
    summary: Dict[str, Any]
    schema: int = RESULT_SCHEMA

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @property
    def refined(self) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["refined"]]

    def best(self, key: str = "time_ns") -> Optional[Dict[str, Any]]:
        return _best(self.records, key)


def save_result(res: CampaignResult, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(res.to_dict(), f, indent=1, default=float)
    return path


def load_result(path: str) -> CampaignResult:
    with open(path) as f:
        d = json.load(f)
    return CampaignResult(spec=d["spec"], records=d["records"],
                          summary=d["summary"],
                          schema=d.get("schema", RESULT_SCHEMA))


def default_spool_dir(campaign: str, cache_dir: Optional[str]) -> str:
    """Deterministic spool location so an interrupted campaign and its
    re-invocation agree on where surviving jobs/results live."""
    root = os.path.dirname(cache_dir) if cache_dir else "."
    return os.path.join(root, "spool", campaign)


def _log(progress: Optional[Callable[[str], None]], msg: str) -> None:
    if progress:
        progress(msg)




def _resolve_backend(backend: Union[str, Backend, None],
                     workers: Optional[int], spec: SweepSpec,
                     cache_dir: Optional[str],
                     spool_dir: Optional[str]) -> Backend:
    if backend is not None and not isinstance(backend, str):
        return backend
    if backend is None:
        # legacy ``workers`` semantics: 0/1 inline, else local pool
        backend = "inline" if workers is not None and workers <= 1 else "pool"
    if backend == "spool" and not spool_dir:
        spool_dir = default_spool_dir(spec.name, cache_dir)
    return get_backend(backend, workers=workers, spool_dir=spool_dir)


def annotate_hlo_crosscheck(records: List[Dict[str, Any]]
                            ) -> Optional[Dict[str, Any]]:
    """Pair every ingested ``hlo/<fixture>`` record with its hand-built
    twin record at the same (overrides, n_tiles) point and attach the
    deviation ratios the differential harness asserts on.

    Each paired record gains ``hlo_twin`` (the twin workload name) and
    ``hlo_deviation`` — analytic-latency / FLOP / HBM-byte ratios
    (ingested over hand-built), a refined-latency ratio when both points
    were refined, the fixture's documented band from the manifest, and
    the in-band verdict. Returns the per-fixture summary (cells checked,
    in-band count, ratio extrema) or None when the campaign pairs
    nothing — ``run_campaign`` runs this after refinement on every
    campaign, so crosscheck results land in records/summary/golden
    fixtures uniformly across backends.
    """
    from ..graph import ingest

    def pt_key(workload: str, rec: Dict[str, Any]) -> str:
        return json.dumps([workload, rec["overrides"], rec["n_tiles"]],
                          sort_keys=True)

    by_key = {pt_key(r["workload"], r): r for r in records}
    summary: Dict[str, Any] = {}
    for rec in records:
        h = ingest.parse_hlo_name(rec["workload"])
        if h is None or h["layers_keep"] is not None:
            continue
        try:
            meta = ingest.fixture_meta(h["fixture"])
        except KeyError:
            continue                       # fixture gone: nothing to pair
        twin = by_key.get(pt_key(meta["twin"], rec))
        if twin is None:
            continue
        band = meta.get("band")

        def ratio(key: str) -> Optional[float]:
            a, b = rec.get(key), twin.get(key)
            if a is None or not b:
                return None
            return float(a) / float(b)

        dev: Dict[str, Any] = {
            "analytic_ratio": ratio("analytic_time_ns"),
            "flops_ratio": ratio("total_flops"),
            "hbm_ratio": ratio("hbm_bytes"),
            "band": band,
        }
        if rec.get("refined") and twin.get("refined"):
            dev["refined_ratio"] = ratio("time_ns")
        dev["in_band"] = (band is not None and dev["analytic_ratio"]
                          is not None and
                          band[0] <= dev["analytic_ratio"] <= band[1])
        rec["hlo_twin"] = meta["twin"]
        rec["hlo_deviation"] = dev
        s = summary.setdefault(h["fixture"], {
            "twin": meta["twin"], "band": band, "cells": 0, "in_band": 0,
            "analytic_ratio_min": None, "analytic_ratio_max": None})
        s["cells"] += 1
        s["in_band"] += int(dev["in_band"])
        r = dev["analytic_ratio"]
        if r is not None:
            s["analytic_ratio_min"] = (r if s["analytic_ratio_min"] is None
                                       else min(s["analytic_ratio_min"], r))
            s["analytic_ratio_max"] = (r if s["analytic_ratio_max"] is None
                                       else max(s["analytic_ratio_max"], r))
    return summary or None


def run_campaign(spec: SweepSpec, *, workers: Optional[int] = 0,
                 use_cache: bool = True,
                 cache_dir: Optional[str] = None,
                 progress: Optional[Callable[[str], None]] = None,
                 backend: Union[str, Backend, None] = None,
                 spool_dir: Optional[str] = None,
                 journal_path: Optional[str] = None,
                 allow_partial: bool = False) -> CampaignResult:
    """Execute one campaign.

    ``backend`` picks the refinement execution service: ``"inline"``
    (deterministic, test-friendly), ``"pool"`` (``workers`` local
    processes; None = one per core), ``"spool"`` (resumable filesystem
    job queue at ``spool_dir``, drained by ``workers`` spawned daemons
    plus any externally attached ``python -m repro.exec worker``), or a
    ready ``repro.exec`` Backend instance. When ``backend`` is None the
    legacy ``workers`` convention applies: 0/1 inline, else pool.

    The cache (``cache_dir`` or ``spec.cache_dir``) makes repeated and
    interrupted campaigns incremental; ``journal_path`` streams
    per-point status/wall-time/worker telemetry as JSONL.

    ``allow_partial=True`` is graceful degradation: a point whose
    refinement fails (or is quarantined as a poison job by the spool)
    becomes a ``status: "failed"`` record with the error attached
    instead of a ``BackendError`` aborting the whole campaign; the
    summary reports ``failed``/``coverage``/``failed_points`` so
    reports can annotate what's missing.
    """
    t_start = time.time()
    cells = spec.cells()
    cdir = cache_dir or spec.cache_dir
    cache = ResultCache(cdir) if (use_cache and cdir) else None
    bk = _resolve_backend(backend, workers, spec, cdir, spool_dir)
    journal = CampaignJournal(journal_path) if journal_path else None

    # -- phase 1: batched analytic pre-screen (one XLA call per cell) ----
    t0 = time.time()
    screens = []
    part_memo: Dict[Any, Any] = {}     # full-model body/head screens are
    #                                    shared across cells (layers axis)
    for cell in cells:
        scr = prescreen_cell(cell, memo=part_memo)
        screens.append(scr)
        _log(progress, f"prescreen {cell.label}: {len(cell.points)} points "
             f"in one XLA call ({scr.wall_s:.2f}s)")
    prescreen_s = time.time() - t0

    # -- phase 2: Pareto selection per cell ------------------------------
    records: List[Dict[str, Any]] = []
    todo: List[Dict[str, Any]] = []        # refinement payload per record
    todo_idx: List[int] = []               # record index per payload
    for scr in screens:
        cell = scr.cell
        obj = np.stack([scr.time_ns, scr.energy_j], axis=1)
        picked = set(select_points(obj, mode=spec.refine.mode,
                                   max_points=spec.refine.max_points))
        for i, pt in enumerate(cell.points):
            cfg = pt.cfg(spec)
            rec: Dict[str, Any] = {
                "point_id": pt.point_id(),
                "grid_index": len(records),
                "campaign": spec.name,
                "workload": pt.workload,
                "n_tiles": pt.n_tiles,
                "overrides": dict(pt.overrides),
                "hw_name": cfg.name,
                "analytic_time_ns": float(scr.time_ns[i]),
                "analytic_inf_per_s": float(1e9 / scr.time_ns[i])
                if scr.time_ns[i] > 0 else 0.0,
                "analytic_avg_w": float(scr.avg_w[i]),
                "analytic_energy_j": float(scr.energy_j[i]),
                # cell-level compiled-workload intensity: weights+spill
                # HBM traffic vs total flops — decode points sit far
                # below prefill points (memory-bound regime)
                "total_flops": scr.total_flops,
                "hbm_bytes": scr.hbm_bytes,
                "flops_per_byte": (scr.total_flops / scr.hbm_bytes
                                   if scr.hbm_bytes > 0 else 0.0),
                "selected": i in picked,
                "refined": False,
                "cached": False,
            }
            if i in picked:
                payload = refine_payload(
                    workload=pt.workload, n_tiles=pt.n_tiles,
                    hw=to_dict(cfg), compile_opts=dict(spec.compile_opts),
                    pti_ns=spec.refine.pti_ns, temp_c=spec.refine.temp_c,
                    keep_series=spec.refine.keep_series,
                    engine=spec.refine.engine)
                todo.append(payload)
                todo_idx.append(len(records))
            records.append(rec)
        _log(progress, f"select {cell.label}: {len(picked)}/"
             f"{len(cell.points)} points for event-engine refinement")

    # -- phase 2b: serving-fleet cells -----------------------------------
    # serve_grid points bypass the analytic pre-screen (their metric is
    # request-level, not step-level): every one becomes a `kind: "serve"`
    # refinement payload and flows through the same backend/cache/journal
    # machinery as classic points
    serve_pts = spec.serve_points()
    if serve_pts:
        cfg = spec.hw_config({})
        hw = to_dict(cfg)
        nt = spec.n_tiles[0]
        for sp in serve_pts:
            rec = {
                "point_id": sp.point_id(),
                "grid_index": len(records),
                "campaign": spec.name,
                "workload": sp.workload,
                "n_tiles": nt,
                "overrides": dict(sp.overrides),
                "hw_name": cfg.name,
                "selected": True,
                "refined": False,
                "cached": False,
            }
            todo.append(serve_payload(
                workload=sp.workload, n_tiles=nt, hw=hw,
                temp_c=spec.refine.temp_c,
                compile_opts=dict(spec.compile_opts), **sp.params))
            todo_idx.append(len(records))
            records.append(rec)
        _log(progress, f"serve: {len(serve_pts)} fleet cells queued "
             f"for trace-driven simulation")

    # -- phase 3: cached backend refinement ------------------------------
    t0 = time.time()
    keys = [content_key(p) for p in todo]
    if journal:
        journal.start(campaign=spec.name, backend=bk.name,
                      grid_points=len(records), to_refine=len(todo))
    cache_hits = 0
    misses: List[int] = []                 # indices into todo
    results: List[Optional[Dict[str, Any]]] = [None] * len(todo)
    if cache is not None:
        for i, key in enumerate(keys):
            hit = cache.get(key)
            if hit is not None:
                results[i] = hit
                records[todo_idx[i]]["cached"] = True
                cache_hits += 1
                if journal:
                    journal.point(
                        key, "cached",
                        point_id=records[todo_idx[i]]["point_id"])
            else:
                misses.append(i)
    else:
        misses = list(range(len(todo)))

    if misses:
        # keyword passed only when set, so minimal Backend stand-ins
        # (tests, external plugins) predating allow_partial keep working
        bk_extra = {"allow_partial": True} if allow_partial else {}
        batch_n = spec.refine.batch
        if batch_n > 1:
            # batched cross-point refinement: group fast-engine misses
            # by structural class into batch jobs (deterministic — grid
            # order in and out); batch records expand back to per-point
            # results here and to per-point cache/journal entries in
            # the backends
            jobs = plan_batches([todo[i] for i in misses], batch_n)
            job_payloads = [jp for jp, _ in jobs]
            job_keys = [content_key(jp) if jp.get("kind") == "batch"
                        else keys[misses[pos[0]]] for jp, pos in jobs]
            n_batched = sum(len(pos) for jp, pos in jobs
                            if jp.get("kind") == "batch")
            _log(progress,
                 f"refine: {len(misses)} points via {bk.name} backend "
                 f"({n_batched} batched into "
                 f"{sum(1 for jp, _ in jobs if jp.get('kind') == 'batch')}"
                 f" jobs of <= {batch_n}, "
                 f"{len(misses) - n_batched} single)")
            if REGISTRY.enabled:
                REGISTRY.counter("runner.batch_jobs",
                                 backend=bk.name).inc(len(jobs))
            fresh = bk.refine(job_payloads, keys=job_keys,
                              journal=journal, cache=cache,
                              progress=progress, **bk_extra)
            for (jp, pos), rec in zip(jobs, fresh):
                if rec.get("kind") == "batch":
                    for p_i, sub in zip(pos, rec["records"]):
                        results[misses[p_i]] = _canon(sub)
                elif is_failure_record(rec):
                    # a failed batch job degrades every point it carried
                    for p_i in pos:
                        results[misses[p_i]] = _canon(rec)
                else:
                    results[misses[pos[0]]] = _canon(rec)
        else:
            _log(progress,
                 f"refine: {len(misses)} points via {bk.name} backend")
            # the backend owns cache write-through (each record is
            # persisted as soon as it is refined, not after the batch)
            # — no second put
            fresh = bk.refine([todo[i] for i in misses],
                              keys=[keys[i] for i in misses],
                              journal=journal, cache=cache,
                              progress=progress, **bk_extra)
            for i, rec in zip(misses, fresh):
                results[i] = _canon(rec)
    refine_s = time.time() - t0
    if REGISTRY.enabled:
        REGISTRY.counter("runner.cache_hits", backend=bk.name
                         ).inc(cache_hits)
        REGISTRY.counter("runner.cache_misses", backend=bk.name
                         ).inc(len(misses))

    deviations = []
    failed_points: List[str] = []
    for i, res in enumerate(results):
        assert res is not None
        rec = records[todo_idx[i]]
        if is_failure_record(res):
            # graceful degradation: the point is terminal-but-failed;
            # `refined` stays False so _best/reports skip it, and the
            # diagnosis travels with the record
            rec["status"] = "failed"
            rec["failed"] = True
            rec["error"] = res.get("error", "?")
            failed_points.append(rec["point_id"])
            continue
        rec.update(res)
        rec["refined"] = True
        if rec.get("analytic_time_ns", 0) > 0:
            rec["deviation"] = rec["time_ns"] / rec["analytic_time_ns"]
            deviations.append(rec["deviation"])
    _log(progress, f"refine: {len(todo)} points "
         f"({cache_hits} cache hits, {len(misses)} simulated, "
         f"{len(failed_points)} failed, {refine_s:.2f}s)")

    hlo_xck = annotate_hlo_crosscheck(records)
    if hlo_xck:
        for fx, s in sorted(hlo_xck.items()):
            _log(progress, f"hlo crosscheck {fx}: {s['in_band']}/"
                 f"{s['cells']} cells in band {s['band']}")

    summary = {
        "grid_points": len(records),
        "serve_points": len(serve_pts),
        "cells": len(cells),
        "prescreen_calls": len(cells),
        "backend": bk.name,
        "refined": len(todo),
        "cache_hits": cache_hits,
        "simulated": len(misses),
        "prescreen_s": prescreen_s,
        "refine_s": refine_s,
        "wall_s": time.time() - t_start,
        "deviation_min": min(deviations) if deviations else None,
        "deviation_max": max(deviations) if deviations else None,
    }
    if failed_points:
        summary["failed"] = len(failed_points)
        summary["failed_points"] = failed_points
        summary["coverage"] = ((len(todo) - len(failed_points))
                               / len(todo) if todo else 1.0)
    if hlo_xck:
        summary["hlo_crosscheck"] = hlo_xck
    best = _best(records, "time_ns")
    if best is not None:
        summary["best_time_point"] = {
            "point_id": best["point_id"], "workload": best["workload"],
            "overrides": best["overrides"], "time_ns": best["time_ns"]}
        beste = _best(records, "energy_j")
        summary["best_energy_point"] = {
            "point_id": beste["point_id"], "workload": beste["workload"],
            "overrides": beste["overrides"], "energy_j": beste["energy_j"]}
    serve_recs = [r for r in records
                  if r.get("refined") and r.get("serve")]
    if serve_recs:
        bg = max(serve_recs,
                 key=lambda r: (r["goodput_rps"], -r["grid_index"]))
        summary["best_goodput_point"] = {
            "point_id": bg["point_id"], "workload": bg["workload"],
            "overrides": bg["overrides"],
            "goodput_rps": bg["goodput_rps"], "chips": bg["chips"],
            "energy_per_req_j": bg["energy_per_req_j"]}
    if cache is not None:
        cache.log_stats(campaign=spec.name)
    if journal:
        journal.end({k: summary[k] for k in
                     ("grid_points", "refined", "cache_hits", "simulated",
                      "backend", "wall_s")})
        # the same fold that powers `exec status --watch`: phase rates,
        # per-worker totals, ETA (0 — the campaign just finished)
        from ..obs.progress import CampaignProgress
        summary["progress"] = CampaignProgress.from_file(
            journal.path).summary()
    return CampaignResult(spec=spec.to_dict(), records=records,
                          summary=summary)
