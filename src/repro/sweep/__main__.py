"""Sweep-campaign CLI.

  python -m repro.sweep run <spec.json | builtin-name> [options]
  python -m repro.sweep list
  python -m repro.sweep show <builtin-name>
  python -m repro.sweep cache [dir] [--prune]
  python -m repro.sweep crosscheck <workload> [--n-tiles N] [--preset P]
  python -m repro.sweep crosscheck-hlo [spec] [--engine E] [--no-cache]

``run`` prints a per-phase progress log, a ``name,value`` CSV summary
block, and writes the campaign record JSON (default:
``benchmarks/artifacts/campaigns/<name>.json`` when run from the repo
root, else ``./<name>.campaign.json``) plus a per-point JSONL journal
next to it. ``--backend spool`` routes refinement through a resumable
filesystem job spool (see ``python -m repro.exec worker``): kill the
run, re-invoke it, and only never-finished points are re-simulated.
``cache`` reports entry count / size / lifetime hit-rate for a result
cache and ``--prune`` drops entries from older schema generations.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .runner import run_campaign, save_result
from .spec import builtin_spec_names, load_builtin_spec, load_spec

DEFAULT_CAMPAIGN_DIR = os.path.join("benchmarks", "artifacts", "campaigns")
DEFAULT_CACHE_DIR = os.path.join("benchmarks", "artifacts", "sweep_cache")


def _default_out(name: str) -> str:
    if os.path.isdir("benchmarks"):
        return os.path.join(DEFAULT_CAMPAIGN_DIR, f"{name}.json")
    return f"{name}.campaign.json"


def _load_spec(name: str):
    """Load + validate a spec; returns None after printing a clean
    one-line error (bad name/path, unknown field, bad axis...)."""
    try:
        return load_spec(name)
    except (FileNotFoundError, KeyError, ValueError) as e:
        msg = e.args[0] if e.args else e   # KeyError reprs its arg
        print(f"error: {msg}", file=sys.stderr)
        return None


def cmd_run(args: argparse.Namespace) -> int:
    spec = _load_spec(args.spec)
    if spec is None:
        return 2
    if args.refine_mode:
        spec.refine.mode = args.refine_mode
    if args.engine:
        spec.refine.engine = args.engine
    if args.refine_batch is not None:
        if args.refine_batch < 0:
            print(f"--refine-batch must be >= 0, got {args.refine_batch}")
            return 2
        spec.refine.batch = args.refine_batch
    cache_dir = None
    if not args.no_cache:
        cache_dir = args.cache_dir or spec.cache_dir or DEFAULT_CACHE_DIR
    out = args.out or _default_out(spec.name)
    journal = args.journal
    if journal is None:
        base = out[:-len(".json")] if out.endswith(".json") else out
        journal = base + ".journal.jsonl"
    res = run_campaign(spec, workers=args.workers,
                       use_cache=not args.no_cache, cache_dir=cache_dir,
                       backend=args.backend, spool_dir=args.spool_dir,
                       journal_path=journal,
                       allow_partial=args.allow_partial,
                       progress=lambda m: print(f"  [{spec.name}] {m}"))
    save_result(res, out)
    s = res.summary
    print(f"campaign,{spec.name},")
    print(f"grid_points,{s['grid_points']},{s['cells']} cells")
    print(f"prescreen_s,{s['prescreen_s']:.3g},one XLA call per cell")
    print(f"backend,{s['backend']},")
    print(f"refined,{s['refined']},{s['cache_hits']} cache hits / "
          f"{s['simulated']} simulated")
    if s.get("failed"):
        print(f"failed,{s['failed']},coverage {s['coverage']:.3f} "
              f"(--allow-partial degraded points)")
    print(f"refine_s,{s['refine_s']:.3g},")
    if s.get("deviation_max") is not None:
        print(f"deviation_range,{s['deviation_min']:.3g},"
              f"max {s['deviation_max']:.3g} (event/analytic)")
    if "best_time_point" in s:
        b = s["best_time_point"]
        print(f"best_time_ns,{b['time_ns']:.6g},"
              f"{b['workload']} {b['overrides']}")
    if "best_goodput_point" in s:
        b = s["best_goodput_point"]
        print(f"best_goodput_rps,{b['goodput_rps']:.6g},"
              f"{b['workload']} ({b['chips']} chips, "
              f"{b['energy_per_req_j']:.4g} J/req)")
    print(f"artifact,{out},")
    print(f"journal,{journal},")
    return 0


def cmd_list(_args: argparse.Namespace) -> int:
    """List builtin specs with workload count, grid size, and the spec's
    one-line ``description`` field — how new campaigns are discovered."""
    names = builtin_spec_names()
    if not names:
        print("no builtin specs found")
        return 1
    for n in names:
        spec = load_builtin_spec(n)
        print(f"{n:>20s}  {len(spec.workloads):3d} workloads  "
              f"{spec.grid_size:6d} points  {len(spec.cells()):4d} cells  "
              f"refine={spec.refine.mode:<7s} {spec.description}")
    return 0


def cmd_show(args: argparse.Namespace) -> int:
    spec = _load_spec(args.spec)
    if spec is None:
        return 2
    print(json.dumps(spec.to_dict(), indent=1))
    return 0


def cmd_cache(args: argparse.Namespace) -> int:
    from .cache import ResultCache, SCHEMA_VERSION

    cache = ResultCache(args.dir)
    st = cache.stats()
    print(f"cache_dir,{args.dir},")
    print(f"entries,{st['entries']},")
    print(f"bytes,{st['bytes']},")
    current = st["by_schema"].get(SCHEMA_VERSION, 0)
    stale = st["entries"] - current
    print(f"schema_current,{current},schema v{SCHEMA_VERSION}")
    print(f"schema_stale,{stale},older/untagged generations")
    life = cache.lifetime_stats()
    if life["runs"]:
        print(f"lifetime_hits,{life['hits']},over {life['runs']} campaigns")
        print(f"lifetime_misses,{life['misses']},")
        print(f"hit_rate,{life['hit_rate']:.3f},")
    if args.prune:
        removed = cache.prune()
        print(f"pruned,{removed},stale entries removed")
    return 0


def cmd_crosscheck(args: argparse.Namespace) -> int:
    """Run one point on BOTH refinement engines and print the deltas —
    the operational form of the fast engine's exactness contract."""
    from ..hw.presets import resolve_preset, to_dict
    from .refine import crosscheck_point, refine_payload

    try:
        # user-input resolution only: a deep KeyError inside the
        # simulation must surface as a traceback, not a usage error
        hw = to_dict(resolve_preset(args.preset))
        payload = refine_payload(
            workload=args.workload, n_tiles=args.n_tiles, hw=hw,
            compile_opts={}, pti_ns=args.pti_ns, temp_c=60.0,
            keep_series=False, engine="fast")
        from ..graph.workloads import resolve_workload
        resolve_workload(args.workload)
    except KeyError as e:
        print(f"error: {e.args[0] if e.args else e}", file=sys.stderr)
        return 2
    out = crosscheck_point(payload)
    print(f"workload,{out['workload']},")
    print(f"extrapolated,{out['extrapolated']},"
          f"{out['replayed_tasks']}/{out['n_tasks']} tasks replayed")
    print(f"max_interval_diff_ns,{out['max_interval_diff_ns']:.6g},")
    print(f"makespan_diff_ns,{out['makespan_diff_ns']:.6g},")
    print(f"analytic_makespan_ns,{out['analytic_makespan_ns']:.6g},"
          f"list_schedule estimate, event/analytic "
          f"{out['analytic_ratio']:.3g}")
    worst = max(out["record_rel_diff"].items(), key=lambda kv: kv[1])
    print(f"worst_record_rel_diff,{worst[1]:.6g},{worst[0]}")
    for k, v in sorted(out["detail"].items()):
        print(f"detail.{k},{v},")
    return 0


def cmd_crosscheck_hlo(args: argparse.Namespace) -> int:
    """Run the builtin ``hlo_crosscheck`` campaign — every captured-HLO
    fixture and its hand-built twin through the analytic pre-screen and
    refinement — and report per-fixture deviation ratios against the
    bands documented in ``src/repro/configs/hlo/manifest.json``.
    Exit 1 when any cell lands out of band."""
    spec = _load_spec(args.spec)
    if spec is None:
        return 2
    if args.engine:
        spec.refine.engine = args.engine
    cache_dir = None if args.no_cache else (
        args.cache_dir or spec.cache_dir or DEFAULT_CACHE_DIR)
    out = args.out or _default_out(spec.name)
    res = run_campaign(spec, workers=args.workers,
                       use_cache=not args.no_cache, cache_dir=cache_dir,
                       backend=args.backend,
                       progress=lambda m: print(f"  [{spec.name}] {m}"))
    save_result(res, out)
    xck = res.summary.get("hlo_crosscheck")
    if not xck:
        print("error: campaign paired no hlo/<fixture> records with "
              "twins — check the spec's workloads", file=sys.stderr)
        return 2
    print(f"campaign,{spec.name},")
    print(f"grid_points,{res.summary['grid_points']},"
          f"{res.summary['cells']} cells")
    print(f"refined,{res.summary['refined']},"
          f"{res.summary['cache_hits']} cache hits")
    ok = True
    for fx, s in sorted(xck.items()):
        in_band = s["in_band"] == s["cells"]
        ok = ok and in_band
        print(f"fixture,{fx},{s['in_band']}/{s['cells']} cells in band "
              f"{s['band']} vs {s['twin']}")
        print(f"analytic_ratio,{s['analytic_ratio_min']:.4g},"
              f"max {s['analytic_ratio_max']:.4g} (ingested/hand-built)")
    refined_ratios = [r["hlo_deviation"]["refined_ratio"]
                      for r in res.records
                      if "refined_ratio" in r.get("hlo_deviation", {})]
    if refined_ratios:
        print(f"refined_ratio,{min(refined_ratios):.4g},"
              f"max {max(refined_ratios):.4g} (both engines refined)")
    print(f"artifact,{out},")
    print(f"in_band,{str(ok).lower()},")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sweep",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("run", help="execute a campaign")
    rp.add_argument("spec", help="spec JSON path or builtin name")
    rp.add_argument("--backend", choices=("inline", "pool", "spool"),
                    default=None,
                    help="refinement execution service (default: inferred "
                         "from --workers: 0/1 inline, else pool)")
    rp.add_argument("--workers", type=int, default=None,
                    help="refinement worker processes "
                         "(default: one per core; 0 = inline; with "
                         "--backend spool: locally spawned spool workers, "
                         "0 = external workers only)")
    rp.add_argument("--spool-dir", default=None,
                    help="spool backend job directory (default: "
                         "<cache-root>/spool/<campaign>)")
    rp.add_argument("--journal", default=None,
                    help="per-point JSONL journal path "
                         "(default: <out>.journal.jsonl)")
    rp.add_argument("--no-cache", action="store_true",
                    help="ignore + don't write the result cache")
    rp.add_argument("--cache-dir", default=None)
    rp.add_argument("--out", default=None, help="campaign JSON output path")
    rp.add_argument("--refine-mode", choices=("pareto", "all", "none"),
                    default=None, help="override the spec's refine mode")
    rp.add_argument("--engine", choices=("event", "fast", "auto"),
                    default=None,
                    help="override the spec's refine engine (fast = "
                         "core.fastsim interval replay + steady-state "
                         "layer extrapolation)")
    rp.add_argument("--refine-batch", type=int, default=None,
                    help="override the spec's refine.batch: max points "
                         "per batched cross-point refinement job "
                         "(0/1 = per-point, the default)")
    rp.add_argument("--allow-partial", action="store_true",
                    help="graceful degradation: failed/quarantined "
                         "points become status:failed records with the "
                         "error attached instead of aborting the "
                         "campaign; the summary reports coverage")
    rp.set_defaults(fn=cmd_run)

    lp = sub.add_parser("list", help="list builtin campaign specs")
    lp.set_defaults(fn=cmd_list)

    sp = sub.add_parser("show", help="print a spec as JSON")
    sp.add_argument("spec")
    sp.set_defaults(fn=cmd_show)

    cp = sub.add_parser("cache", help="result-cache stats / maintenance")
    cp.add_argument("dir", nargs="?", default=DEFAULT_CACHE_DIR,
                    help=f"cache directory (default: {DEFAULT_CACHE_DIR})")
    cp.add_argument("--prune", action="store_true",
                    help="delete entries from other schema generations")
    cp.set_defaults(fn=cmd_cache)

    xp = sub.add_parser("crosscheck",
                        help="compare fast vs event refinement engines "
                             "on one workload point")
    xp.add_argument("workload", help="workload name, e.g. "
                    "lm/qwen3-32b/L32/s1024b8tp4pod8")
    xp.add_argument("--n-tiles", type=int, default=2)
    xp.add_argument("--preset", default="v5e")
    xp.add_argument("--pti-ns", type=float, default=100_000.0)
    xp.set_defaults(fn=cmd_crosscheck)

    hp = sub.add_parser(
        "crosscheck-hlo",
        help="run the builtin hlo_crosscheck campaign: captured HLO "
             "graphs vs their hand-built twins, deviation ratios "
             "checked against the fixture manifest's documented bands")
    hp.add_argument("spec", nargs="?", default="hlo_crosscheck",
                    help="spec JSON path or builtin name "
                         "(default: hlo_crosscheck)")
    hp.add_argument("--backend", choices=("inline", "pool", "spool"),
                    default=None)
    hp.add_argument("--workers", type=int, default=0)
    hp.add_argument("--no-cache", action="store_true")
    hp.add_argument("--cache-dir", default=None)
    hp.add_argument("--out", default=None)
    hp.add_argument("--engine", choices=("event", "fast", "auto"),
                    default=None,
                    help="override the spec's refine engine")
    hp.set_defaults(fn=cmd_crosscheck_hlo)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
