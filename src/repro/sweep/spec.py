"""Declarative sweep-campaign specs.

A ``SweepSpec`` is the JSON-serializable description of a campaign:
which workloads, which hardware preset, which parameter grid, and how to
refine. Axes split into two kinds:

* **analytic** axes (``ANALYTIC_AXES``) only move the parameter vector of
  the vectorized scheduler — every combination inside a structural cell
  is pre-screened in *one* XLA call without recompiling the task graph.
* **structural** axes (everything else: ``n_tiles``, VMEM capacity, DMA
  channel count, ...) change task-graph compilation or system topology,
  so each distinct combination forms its own cell (one compile + one
  batched pre-screen per cell).

Worked example — an LM campaign over inference phase and KV length::

    >>> from repro.sweep import SweepSpec, RefineSpec, run_campaign
    >>> spec = SweepSpec(
    ...     name="demo",
    ...     lm_grid={"arch": "qwen3-32b",
    ...              "phase": ["prefill", "decode"],
    ...              "seq": [512], "kv_len": [512, 2048],
    ...              "batch": [1, 8], "tp": [1, 2]},
    ...     preset="v5e",
    ...     axes={"clock_ghz": [0.6, 0.94], "hbm_gbps": [819.0, 1640.0]},
    ...     refine=RefineSpec(mode="pareto", max_points=2))
    >>> spec.workloads[:2]
    ['lm/qwen3-32b/s512b1tp1', 'lm/qwen3-32b/s512b1tp2']
    >>> spec.workloads[-1]
    'lm/qwen3-32b/decode/kv2048b8tp2'
    >>> spec.grid_size               # 12 workloads x 4 analytic points
    48
    >>> result = run_campaign(spec, workers=0)   # doctest: +SKIP

``lm_grid`` keys: ``arch`` (registry id), ``phase`` (subset of
``["prefill", "decode", "train"]``, default prefill), ``seq``
(prefill/train prompt lengths), ``kv_len`` (decode KV-cache lengths),
``batch``, ``tp`` (tensor-parallel degrees) and ``ep`` (MoE
expert-parallel degrees — ``ep > 1`` adds alltoall dispatch/combine
collectives and needs a MoE arch). Adding a ``layers`` key switches the
grid to **full-model** workloads (``graph.workloads.lm_model_ops``) and
unlocks the pod-shape axes: ``dp`` (data-parallel degrees; ``batch``
becomes the global batch, and ``phase="train"`` adds the DP gradient
all-reduce) and ``pod`` (chips per ICI domain — collectives whose ring
leaves the pod run at DCN speed). Every expanded workload is its own
structural cell, but full-model cells share their per-layer pre-screen
across the ``layers`` axis (the layer-replication fast path). Scalars
are accepted wherever a list is expected. Full field reference:
``docs/CAMPAIGNS.md``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..graph.workloads import (is_workload, lm_grid_names,
                               lm_workload_name, parse_lm_name)
from ..hw.presets import HwConfig, resolve_preset
from ..power.characterization import NOMINAL_TEMP_C
from ..serve.fleet import POLICIES
from ..serve.traffic import TRAFFIC_KINDS

__all__ = ["ANALYTIC_AXES", "RefineSpec", "SweepSpec", "GridPoint",
           "SweepCell", "ServePoint", "load_spec", "load_builtin_spec",
           "builtin_spec_names", "BUILTIN_SPEC_DIR"]

# HwConfig fields fully captured by core.vectorized.params_of — safe to
# sweep inside one compiled task graph (see module docstring).
ANALYTIC_AXES = frozenset({
    "clock_ghz", "hbm_gbps", "dma_desc_overhead_ns",
    "ici_link_gbps", "ici_latency_ns", "dcn_gbps", "dcn_latency_ns",
    "n_mxu", "mxu_rows", "mxu_cols",
    "vpu_lanes", "vpu_sublanes", "vpu_flops_per_lane",
    "vmem_ports", "vmem_port_bytes_per_cycle",
})

_HW_FIELDS = {f.name for f in dataclasses.fields(HwConfig)}

BUILTIN_SPEC_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "configs", "sweeps")


@dataclass
class RefineSpec:
    """How the pre-screened grid is refined (engine + budget + Power-EM).

    ``engine`` picks the refinement simulator per point: ``"event"``
    (the generator-driven event engine — ground truth), ``"fast"``
    (``core.fastsim`` interval replay with steady-state layer
    extrapolation; byte-identical to ``event`` whenever it replays) or
    ``"auto"`` (``fast`` for big layered full models, ``event``
    otherwise). The default honors ``REPRO_REFINE_ENGINE`` so CI can
    run whole campaign lanes on either engine; the value is part of
    every refinement payload and therefore of the result-cache key.

    ``batch`` > 1 turns on batched cross-point refinement
    (``sweep.refine.plan_batches`` / ``core.batchsim``): fast-engine
    points are grouped by structural class and dispatched as batch jobs
    of at most ``batch`` points, sharing compiles / twin replays /
    records within a job. 0 or 1 (default; ``REPRO_REFINE_BATCH``
    overrides) keeps the one-payload-per-point path. Records are
    identical either way — batching only changes how much work is
    shared — and individual points keep their own cache keys, so
    flipping ``batch`` never invalidates the cache.
    """

    mode: str = "pareto"          # pareto | all | none
    max_points: int = 16          # refinement budget per structural cell
    pti_ns: float = 10_000.0      # Power-EM trace interval
    temp_c: float = NOMINAL_TEMP_C
    keep_series: bool = False     # keep per-module PTI power series
    engine: str = field(default_factory=lambda: os.environ.get(
        "REPRO_REFINE_ENGINE", "event"))   # event | fast | auto
    batch: int = field(default_factory=lambda: int(os.environ.get(
        "REPRO_REFINE_BATCH", "0")))   # max points per batch job

    def __post_init__(self):
        if self.mode not in ("pareto", "all", "none"):
            raise ValueError(f"refine.mode must be pareto|all|none, "
                             f"got {self.mode!r}")
        if self.engine not in ("event", "fast", "auto"):
            raise ValueError(f"refine.engine must be event|fast|auto, "
                             f"got {self.engine!r}")
        if self.batch < 0:
            raise ValueError(f"refine.batch must be >= 0, "
                             f"got {self.batch}")


@dataclass
class SweepSpec:
    """One campaign: workloads x preset x grid (+ refinement policy)."""

    name: str
    workloads: List[str] = field(default_factory=list)
    preset: str = "paper_skew"
    base: Dict[str, Any] = field(default_factory=dict)
    axes: Dict[str, List[Any]] = field(default_factory=dict)
    n_tiles: List[int] = field(default_factory=lambda: [2])
    compile_opts: Dict[str, Any] = field(default_factory=dict)
    refine: RefineSpec = field(default_factory=RefineSpec)
    cache_dir: Optional[str] = None
    description: str = ""
    # LM workload grid: {"arch": ..., "phase": ["prefill"|"decode"],
    # "seq": [...], "kv_len": [...], "batch": [...], "tp": [...],
    # "ep": [...]} — expands into ``lm/<arch>/s<S>b<B>tp<T>[ep<E>]``
    # (prefill) / ``lm/<arch>/decode/kv<K>b<B>tp<T>[ep<E>]`` (decode)
    # workloads (each combination is its own structural cell)
    lm_grid: Optional[Dict[str, Any]] = None
    # serving-fleet grid (``serve.fleet``): one model deployment swept
    # over arrival rate x batch policy x traffic shape x pod shape.
    # Scalars: arch, layers, prompt, max_new, kv_capacity, n_requests,
    # seed, slo {ttft_ms, tpot_ms} (+ optional max_queue, burst_x,
    # dwell_s, trace_path). Axes (scalar or list): rate_rps, policy,
    # traffic, tp, ep, dp, pod, slots. Expands into ServePoints — each
    # refines through the ``kind: "serve"`` payload family, not the
    # pre-screen. Full reference: docs/CAMPAIGNS.md.
    serve_grid: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        if isinstance(self.refine, dict):
            self.refine = RefineSpec(**self.refine)
        if isinstance(self.n_tiles, int):
            self.n_tiles = [self.n_tiles]
        if self.lm_grid:
            g = {k: [v] if isinstance(v, (int, str)) else list(v)
                 for k, v in self.lm_grid.items()}   # scalar convenience
            archs = g.pop("arch", [None])
            if len(archs) != 1:
                raise ValueError(f"lm_grid takes exactly one arch, "
                                 f"got {archs}")
            arch = archs[0]
            phase = g.pop("phase", ["prefill"])
            bad_ph = [p for p in phase
                      if p not in ("prefill", "decode", "train")]
            if bad_ph:
                raise ValueError(f"lm_grid phase must be prefill|decode|"
                                 f"train, got {bad_ph}")
            seq = g.pop("seq", [])
            kv_len = g.pop("kv_len", [])
            ep = g.pop("ep", [1])
            layers = g.pop("layers", [])
            dp = g.pop("dp", [1])
            pod = g.pop("pod", [0])
            seq_phases = [p for p in phase if p != "decode"]
            missing = [k for k, need in
                       [("arch", arch is None), ("batch", "batch" not in g),
                        ("tp", "tp" not in g),
                        ("seq", bool(seq_phases) and not seq),
                        ("kv_len", "decode" in phase and not kv_len)]
                       if need]
            if missing:
                raise KeyError(
                    f"lm_grid needs arch/batch/tp, plus seq for prefill/"
                    f"train and kv_len for decode; missing {missing}")
            # dp/pod/train are pod-shape semantics of full-model
            # workloads; without a layers axis they would be silently
            # meaningless — reject them
            needs_layers = [k for k, bad in
                            [("dp", any(d > 1 for d in dp)),
                             ("pod", any(p > 0 for p in pod)),
                             ("phase=train", "train" in phase)] if bad]
            if needs_layers and not layers:
                raise KeyError(
                    f"lm_grid {needs_layers} need a 'layers' axis "
                    f"(full-model workloads)")
            if any(lyr < 1 for lyr in layers):
                raise ValueError(f"lm_grid layers must be >= 1, "
                                 f"got {layers}")
            # an axis whose phase is absent would silently vanish from
            # the grid — reject it like an unknown key
            stray = [k for k, vals, ok in
                     [("seq", seq, bool(seq_phases)),
                      ("kv_len", kv_len, "decode" in phase)]
                     if vals and not ok]
            if stray:
                raise KeyError(
                    f"lm_grid axis {stray} given but its phase is not in "
                    f"phase={phase}")
            names = lm_grid_names(arch, seq, g.pop("batch"), g.pop("tp"),
                                  phase=phase, kv_len=kv_len, ep=ep,
                                  layers=layers or [0], dp=dp, pod=pod)
            if g:
                raise KeyError(f"unknown lm_grid keys {sorted(g)}")
            # idempotent: to_dict/from_dict round-trips re-expand the
            # same names, so only append ones not already present
            self.workloads = list(self.workloads) + \
                [n for n in names if n not in self.workloads]
        if self.serve_grid:
            self.serve_points()       # validate eagerly: fail at load
        if not self.workloads and not self.serve_grid:
            raise ValueError("spec needs workloads (or a non-empty "
                             "lm_grid or serve_grid)")
        unknown = [w for w in self.workloads if not is_workload(w)]
        if unknown:
            raise KeyError(f"unknown workloads {unknown}; have builtin "
                           f"CNNs or 'lm/<arch>/s<seq>b<batch>tp<tp>'")
        bad = [a for a in list(self.axes) + list(self.base)
               if a not in _HW_FIELDS]
        if bad:
            raise KeyError(f"unknown HwConfig fields {bad}")
        for a, vals in self.axes.items():
            if not isinstance(vals, (list, tuple)) or not vals:
                raise ValueError(f"axis {a!r} needs a non-empty value list")
        # probe the preset early so a bad name fails at load, not mid-run
        resolve_preset(self.preset)

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SweepSpec":
        return cls(**d)

    # -- grid -------------------------------------------------------------
    @property
    def analytic_axes(self) -> Dict[str, List[Any]]:
        return {a: v for a, v in self.axes.items() if a in ANALYTIC_AXES}

    @property
    def structural_axes(self) -> Dict[str, List[Any]]:
        return {a: v for a, v in self.axes.items() if a not in ANALYTIC_AXES}

    @property
    def grid_size(self) -> int:
        n = len(self.workloads) * len(self.n_tiles)
        for vals in self.axes.values():
            n *= len(vals)
        if not self.workloads:
            n = 0
        return n + len(self.serve_points())

    def cells(self) -> List["SweepCell"]:
        """Structural cells, each carrying its analytic sub-grid."""
        s_axes = self.structural_axes
        a_axes = self.analytic_axes
        a_combos = [dict(zip(a_axes, vs))
                    for vs in itertools.product(*a_axes.values())] or [{}]
        out: List[SweepCell] = []
        for w in self.workloads:
            for nt in self.n_tiles:
                for svals in itertools.product(*s_axes.values()):
                    structural = dict(zip(s_axes, svals))
                    pts = [GridPoint(workload=w, n_tiles=nt,
                                     overrides={**structural, **a},
                                     structural=dict(structural))
                           for a in a_combos]
                    out.append(SweepCell(spec=self, workload=w, n_tiles=nt,
                                         structural=structural, points=pts))
        return out

    def hw_config(self, overrides: Dict[str, Any]) -> HwConfig:
        return resolve_preset(self.preset, **{**self.base, **overrides})

    # -- serving-fleet grid -----------------------------------------------
    def serve_points(self) -> List["ServePoint"]:
        """Expand (and validate) ``serve_grid`` into ServePoints.

        Grid order: tp-major, then ep, dp, pod, slots, policy, traffic,
        rate_rps innermost — fleet shape first, then scheduling policy,
        then load. Serving cells bypass the analytic pre-screen, so the
        spec's hw ``axes`` do not cross with them (``preset`` + ``base``
        define the chip); every ServePoint is one refinement payload.
        """
        if not self.serve_grid:
            return []
        g = dict(self.serve_grid)

        def axis(key: str, default: Any) -> List[Any]:
            v = g.pop(key, default)
            return [v] if isinstance(v, (int, float, str)) else list(v)

        try:
            arch = g.pop("arch")
            layers = int(g.pop("layers"))
            prompt = int(g.pop("prompt"))
            max_new = int(g.pop("max_new"))
            kv_capacity = int(g.pop("kv_capacity"))
            n_requests = int(g.pop("n_requests"))
            slo = dict(g.pop("slo"))
        except KeyError as e:
            raise KeyError(f"serve_grid needs arch/layers/prompt/max_new/"
                           f"kv_capacity/n_requests/slo; missing {e}")
        seed = int(g.pop("seed", 0))
        max_queue = int(g.pop("max_queue", 0))
        burst_x = float(g.pop("burst_x", 4.0))
        dwell_s = float(g.pop("dwell_s", 2.0))
        trace_path = g.pop("trace_path", None)
        tp = axis("tp", 1)
        ep = axis("ep", 1)
        dp = axis("dp", 1)
        pod = axis("pod", 0)
        slots = axis("slots", 8)
        policy = axis("policy", "continuous")
        traffic = axis("traffic", "poisson")
        rate = [float(r) for r in axis("rate_rps", None)
                if r is not None]
        if g:
            raise KeyError(f"unknown serve_grid keys {sorted(g)}")
        if not rate:
            raise KeyError("serve_grid needs a rate_rps axis")
        if layers < 1 or prompt < 1 or max_new < 1 or n_requests < 1:
            raise ValueError(
                f"serve_grid needs layers/prompt/max_new/n_requests "
                f">= 1, got {layers}/{prompt}/{max_new}/{n_requests}")
        if not {"ttft_ms", "tpot_ms"} <= set(slo):
            raise KeyError(f"serve_grid slo needs ttft_ms and tpot_ms, "
                           f"got {sorted(slo)}")
        bad_pol = [p for p in policy if p not in POLICIES]
        bad_tr = [t for t in traffic if t not in TRAFFIC_KINDS]
        if bad_pol or bad_tr:
            raise ValueError(f"serve_grid policy must be {POLICIES} and "
                             f"traffic {TRAFFIC_KINDS}; got "
                             f"{bad_pol + bad_tr}")
        if "jsonl" in traffic and not trace_path:
            raise KeyError("serve_grid traffic 'jsonl' needs trace_path")
        out: List[ServePoint] = []
        for t, e, d, pc in itertools.product(tp, ep, dp, pod):
            # arch/tp/ep/pod legality rides on the LM name validator
            # (registry arch, MoE-only ep, ...) — the cost model builds
            # exactly this name per step bucket
            parse_lm_name(lm_workload_name(
                arch, seq=prompt, batch=1, tp=t, ep=e,
                layers=layers, dp=1, pod=pc))
            for s, po, tr, r in itertools.product(slots, policy,
                                                  traffic, rate):
                tspec: Dict[str, Any] = {"kind": tr, "rate_rps": r,
                                         "n_requests": n_requests,
                                         "seed": seed}
                if tr == "bursty":
                    tspec.update(burst_x=burst_x, dwell_s=dwell_s)
                if tr == "jsonl":
                    tspec["path"] = trace_path
                name = (f"serve/{arch}/L{layers}/p{prompt}g{max_new}"
                        f"tp{t}" + (f"ep{e}" if e > 1 else "")
                        + f"dp{d}" + (f"pod{pc}" if pc else "")
                        + f"/s{s}kv{kv_capacity}/{po}/{tr}@r{r:g}")
                out.append(ServePoint(
                    workload=name,
                    params={"arch": arch, "layers": layers,
                            "prompt": prompt, "max_new": max_new,
                            "tp": t, "ep": e, "dp": d, "pod": pc,
                            "slots": int(s),
                            "kv_capacity": kv_capacity,
                            "policy": po, "max_queue": max_queue,
                            "traffic": tspec, "slo": slo},
                    overrides={"rate_rps": r, "policy": po,
                               "traffic": tr, "slots": int(s), "tp": t,
                               "ep": e, "dp": d, "pod": pc}))
        return out


@dataclass
class GridPoint:
    """One point of the campaign grid."""

    workload: str
    n_tiles: int
    overrides: Dict[str, Any]     # swept axis values (structural+analytic)
    structural: Dict[str, Any]

    def point_id(self) -> str:
        blob = json.dumps({"w": self.workload, "nt": self.n_tiles,
                           "ov": self.overrides}, sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]

    def cfg(self, spec: SweepSpec) -> HwConfig:
        return spec.hw_config(self.overrides)


@dataclass
class ServePoint:
    """One serving-fleet cell: a deployment under one traffic pattern.

    ``params`` carries everything ``serve.fleet.serve_payload`` needs
    beyond the spec-level plumbing (hw config, n_tiles, temp_c);
    ``overrides`` holds the swept axis values for the campaign record,
    mirroring ``GridPoint.overrides``.
    """

    workload: str
    params: Dict[str, Any]
    overrides: Dict[str, Any]

    def point_id(self) -> str:
        blob = json.dumps({"serve": self.params}, sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]


@dataclass
class SweepCell:
    """One structural cell: a shared task graph + its analytic sub-grid."""

    spec: SweepSpec
    workload: str
    n_tiles: int
    structural: Dict[str, Any]
    points: List[GridPoint]

    @property
    def label(self) -> str:
        s = ",".join(f"{k}={v}" for k, v in self.structural.items())
        return f"{self.workload}/t{self.n_tiles}" + (f"/{s}" if s else "")

    def base_cfg(self) -> HwConfig:
        """Cell compile config: base + structural overrides (analytic axes
        stay at their base values; they do not change the task graph)."""
        return self.spec.hw_config(self.structural)


# -- loading ---------------------------------------------------------------

def load_spec(path_or_name: str) -> SweepSpec:
    """Load a spec from a JSON file path, or by builtin name."""
    if os.path.exists(path_or_name):
        with open(path_or_name) as f:
            return SweepSpec.from_dict(json.load(f))
    return load_builtin_spec(path_or_name)


def load_builtin_spec(name: str) -> SweepSpec:
    p = os.path.join(BUILTIN_SPEC_DIR, f"{name}.json")
    if not os.path.exists(p):
        raise FileNotFoundError(
            f"no spec file and no builtin spec named {name!r}; "
            f"builtins: {builtin_spec_names()}")
    with open(p) as f:
        return SweepSpec.from_dict(json.load(f))


def builtin_spec_names() -> List[str]:
    if not os.path.isdir(BUILTIN_SPEC_DIR):
        return []
    return sorted(os.path.splitext(f)[0]
                  for f in os.listdir(BUILTIN_SPEC_DIR)
                  if f.endswith(".json"))
