"""Discrete-event simulation kernel for TPU-EM.

This is the paper's §3.1 substrate. SimPy is not available in this
environment, and the event engine is the core of the contribution, so it is
implemented natively with the same five primitives VPU-EM names:

  * ``Environment``  — testbench construction + simulation launch
  * ``Store``        — hardware FIFOs and queues           (resources.py)
  * ``Container``    — shared memory                        (resources.py)
  * ``Process``      — concurrent hardware modules / FSMs
  * ``Event``        — handshake signals (e.g. interrupts)

Design rules:
  - deterministic: the event queue orders by (time, priority, sequence id);
    no wall-clock, no RNG — identical inputs give identical traces.
  - two event levels (paper §3.1.1): *task-level* events are plain Events /
    Store handoffs between scheduler and engine processes; *sub-task* events
    are Timeouts inside an engine's pipeline-stage processes.
"""
from __future__ import annotations

import heapq
from typing import Any, Callable, Generator, Iterable, Optional

__all__ = [
    "Environment",
    "Event",
    "Timeout",
    "Process",
    "Condition",
    "AllOf",
    "AnyOf",
    "Interrupt",
    "SimulationEnd",
    "PENDING",
    "URGENT",
    "NORMAL",
]

# Sentinel for "event not yet triggered".
PENDING = object()

# Scheduling priorities (lower runs first at equal time).
URGENT = 0
NORMAL = 1


class Interrupt(Exception):
    """Thrown into a process by ``Process.interrupt`` (e.g. engine reset,
    straggler preemption)."""

    def __init__(self, cause: Any = None):
        super().__init__(cause)
        self.cause = cause


class SimulationEnd(Exception):
    """Raised internally to stop ``Environment.run(until=...)``."""


class Event:
    """A one-shot occurrence at a point in simulated time.

    Processes wait on events by ``yield``-ing them. An event carries a value
    (``succeed``) or an exception (``fail``).
    """

    __slots__ = ("env", "callbacks", "_value", "_ok", "_defused")

    def __init__(self, env: "Environment"):
        self.env = env
        self.callbacks: Optional[list] = []
        self._value: Any = PENDING
        self._ok: bool = True
        self._defused = False

    # -- state ------------------------------------------------------------
    @property
    def triggered(self) -> bool:
        return self._value is not PENDING

    @property
    def processed(self) -> bool:
        return self.callbacks is None

    @property
    def ok(self) -> bool:
        return self._ok

    @property
    def value(self) -> Any:
        if self._value is PENDING:
            raise RuntimeError(f"{self!r} not yet triggered")
        return self._value

    # -- triggering -------------------------------------------------------
    def succeed(self, value: Any = None, priority: int = NORMAL) -> "Event":
        if self._value is not PENDING:
            raise RuntimeError(f"{self!r} already triggered")
        self._ok = True
        self._value = value
        self.env._schedule(self, priority)
        return self

    def fail(self, exc: BaseException, priority: int = NORMAL) -> "Event":
        if self._value is not PENDING:
            raise RuntimeError(f"{self!r} already triggered")
        if not isinstance(exc, BaseException):
            raise TypeError("fail() needs an exception")
        self._ok = False
        self._value = exc
        self.env._schedule(self, priority)
        return self

    def trigger(self, event: "Event") -> None:
        """Mirror another (processed) event's outcome onto this one."""
        self._ok = event._ok
        self._value = event._value
        self.env._schedule(self)

    def __repr__(self):
        st = "pending" if self._value is PENDING else ("ok" if self._ok else "failed")
        return f"<{type(self).__name__} {st} at t={self.env.now}>"

    # -- composition ------------------------------------------------------
    def __and__(self, other: "Event") -> "Condition":
        return AllOf(self.env, [self, other])

    def __or__(self, other: "Event") -> "Condition":
        return AnyOf(self.env, [self, other])


class Timeout(Event):
    """Sub-task-level event: elapse of simulated time (pipeline-stage
    latency, transfer duration, ...). Scheduled immediately on creation."""

    __slots__ = ("delay",)

    def __init__(self, env: "Environment", delay: float, value: Any = None):
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        super().__init__(env)
        self.delay = delay
        self._ok = True
        self._value = value
        env._schedule(self, NORMAL, delay)


class Initialize(Event):
    """Starts a process when processed (URGENT so processes begin before any
    same-time Timeout fires)."""

    __slots__ = ()

    def __init__(self, env: "Environment", process: "Process"):
        super().__init__(env)
        self.callbacks.append(process._resume)
        self._ok = True
        self._value = None
        env._schedule(self, URGENT)


class Process(Event):
    """A concurrent hardware module / state machine driven by a generator.

    The generator yields ``Event``s; the process is itself an ``Event`` that
    triggers when the generator returns (value = return value).
    """

    __slots__ = ("_generator", "_target", "name")

    def __init__(self, env: "Environment", generator: Generator, name: str = ""):
        if not hasattr(generator, "throw"):
            raise TypeError(f"{generator!r} is not a generator")
        super().__init__(env)
        self._generator = generator
        self.name = name or getattr(generator, "__name__", "process")
        self._target: Optional[Event] = Initialize(env, self)

    @property
    def is_alive(self) -> bool:
        return self._value is PENDING

    def interrupt(self, cause: Any = None) -> None:
        """Throw ``Interrupt`` into the process at the current time."""
        if self._value is not PENDING:
            raise RuntimeError(f"{self} already terminated")
        if self._target is self.env.active_process:
            raise RuntimeError("a process cannot interrupt itself")
        # Deliver via a special immediate event so ordering stays in-queue.
        event = Event(self.env)
        event._ok = False
        event._value = Interrupt(cause)
        event._defused = True
        event.callbacks.append(self._resume)
        self.env._schedule(event, URGENT)
        # Disconnect from the event we were waiting on.
        if self._target is not None and self._target.callbacks is not None:
            try:
                self._target.callbacks.remove(self._resume)
            except ValueError:
                pass
        self._target = None

    def _resume(self, event: Event) -> None:
        env = self.env
        env._active_proc = self
        while True:
            try:
                if event._ok:
                    next_ev = self._generator.send(event._value)
                else:
                    event._defused = True
                    exc = event._value
                    next_ev = self._generator.throw(exc)
            except StopIteration as e:
                # Generator finished: trigger the process event.
                self._ok = True
                self._value = getattr(e, "value", None)
                env._schedule(self)
                break
            except BaseException as e:
                self._ok = False
                self._value = e
                self._defused = False
                env._schedule(self)
                break

            # Subscribe to the yielded event.
            if not isinstance(next_ev, Event):
                exc = TypeError(
                    f"process {self.name!r} yielded non-event {next_ev!r}"
                )
                self._generator.close()
                self._ok = False
                self._value = exc
                self._defused = False
                env._schedule(self)
                break
            if next_ev.callbacks is not None:
                # Not yet processed: wait for it.
                next_ev.callbacks.append(self._resume)
                self._target = next_ev
                break
            # Already processed: continue immediately with its outcome.
            event = next_ev

        env._active_proc = None


class Condition(Event):
    """Waits on several events; triggers per ``evaluate``."""

    __slots__ = ("_events", "_count", "_evaluate")

    def __init__(
        self,
        env: "Environment",
        evaluate: Callable[[list, int], bool],
        events: Iterable[Event],
    ):
        super().__init__(env)
        self._events = list(events)
        self._count = 0
        self._evaluate = evaluate
        for ev in self._events:
            if ev.env is not env:
                raise ValueError("events from different environments")
        if not self._events:
            self.succeed([])
            return
        for ev in self._events:
            if ev.callbacks is None:  # already processed
                self._check(ev)
            else:
                ev.callbacks.append(self._check)

    def _check(self, event: Event) -> None:
        if self._value is not PENDING:
            return
        self._count += 1
        if not event._ok:
            event._defused = True
            self.fail(event._value)
        elif self._evaluate(self._events, self._count):
            self.succeed([e._value for e in self._events if e._value is not PENDING])


class AllOf(Condition):
    __slots__ = ()

    def __init__(self, env, events):
        super().__init__(env, lambda evs, n: n >= len(evs), events)

    def _check(self, event: Event) -> None:
        # specialized: skip the evaluate() indirection and — at
        # success, when every event has triggered by definition — the
        # PENDING filter of the generic value-list rebuild
        if self._value is not PENDING:
            return
        self._count += 1
        if not event._ok:
            event._defused = True
            self.fail(event._value)
        elif self._count >= len(self._events):
            self.succeed([e._value for e in self._events])


class AnyOf(Condition):
    __slots__ = ()

    def __init__(self, env, events):
        super().__init__(env, lambda evs, n: n >= 1, events)


class Environment:
    """Simulation environment: event queue + clock + launch API.

    Time unit is abstract; TPU-EM uses **nanoseconds** throughout (hw models
    convert cycles→ns via their clock).
    """

    def __init__(self, initial_time: float = 0.0, *, stats: bool = False):
        self._now = float(initial_time)
        self._queue: list = []  # (time, priority, eid, event)
        self._eid = 0
        self._active_proc: Optional[Process] = None
        # kernel observability (obs.metrics): collected only when
        # ``stats`` is set — the default run loop stays untouched, which
        # is what keeps the off-by-default overhead contract (<5%)
        self.stats = stats
        self.events_processed = 0
        self.peak_heap = 0

    # -- clock ------------------------------------------------------------
    @property
    def now(self) -> float:
        return self._now

    @property
    def active_process(self) -> Optional[Process]:
        return self._active_proc

    # -- event factories ----------------------------------------------------
    def event(self) -> Event:
        return Event(self)

    def timeout(self, delay: float, value: Any = None) -> Timeout:
        return Timeout(self, delay, value)

    def process(self, generator: Generator, name: str = "") -> Process:
        return Process(self, generator, name)

    def all_of(self, events: Iterable[Event]) -> AllOf:
        return AllOf(self, events)

    def any_of(self, events: Iterable[Event]) -> AnyOf:
        return AnyOf(self, events)

    # -- scheduling ---------------------------------------------------------
    def _schedule(self, event: Event, priority: int = NORMAL,
                  delay: float = 0.0, _push=heapq.heappush):
        self._eid += 1
        _push(self._queue, (self._now + delay, priority, self._eid, event))

    def peek(self) -> float:
        """Time of the next scheduled event (inf if none)."""
        return self._queue[0][0] if self._queue else float("inf")

    def step(self) -> None:
        """Process the next event."""
        t, _, _, event = heapq.heappop(self._queue)
        self._now = t
        callbacks, event.callbacks = event.callbacks, None
        for cb in callbacks:
            cb(event)
        if not event._ok and not event._defused:
            # Nobody caught the failure.
            exc = event._value
            raise exc

    def run(self, until: Any = None) -> Any:
        """Run until time ``until``, until event ``until`` triggers, or until
        the queue drains."""
        stop_at = None
        stop_ev: Optional[Event] = None
        if until is not None:
            if isinstance(until, Event):
                stop_ev = until
                if stop_ev.processed:
                    if not stop_ev._ok:
                        raise stop_ev._value
                    return stop_ev._value
            else:
                stop_at = float(until)
                if stop_at < self._now:
                    raise ValueError(f"until={stop_at} < now={self._now}")
        # hot loop: step() inlined with the heap bound to locals — the
        # event kernel spends most of its cycles right here
        queue = self._queue
        pop = heapq.heappop
        if self.stats:
            return self._run_instrumented(queue, pop, stop_at, stop_ev)
        while queue:
            if stop_at is not None and queue[0][0] >= stop_at:
                self._now = stop_at
                return None
            t, _, _, event = pop(queue)
            self._now = t
            callbacks, event.callbacks = event.callbacks, None
            for cb in callbacks:
                cb(event)
            if not event._ok and not event._defused:
                raise event._value
            if stop_ev is not None and stop_ev.callbacks is None:
                if not stop_ev._ok:
                    raise stop_ev._value
                return stop_ev._value
        if stop_ev is not None:
            raise RuntimeError("queue drained before `until` event triggered")
        return None

    def _run_instrumented(self, queue: list, pop, stop_at, stop_ev) -> Any:
        """The same inlined run loop plus kernel telemetry: events
        processed and peak heap depth, accumulated in locals and flushed
        once at exit (so the enabled-path overhead is one int add and
        one compare per event)."""
        n = self.events_processed
        peak = self.peak_heap
        try:
            while queue:
                depth = len(queue)
                if depth > peak:
                    peak = depth
                if stop_at is not None and queue[0][0] >= stop_at:
                    self._now = stop_at
                    return None
                t, _, _, event = pop(queue)
                self._now = t
                n += 1
                callbacks, event.callbacks = event.callbacks, None
                for cb in callbacks:
                    cb(event)
                if not event._ok and not event._defused:
                    raise event._value
                if stop_ev is not None and stop_ev.callbacks is None:
                    if not stop_ev._ok:
                        raise stop_ev._value
                    return stop_ev._value
            if stop_ev is not None:
                raise RuntimeError(
                    "queue drained before `until` event triggered")
            return None
        finally:
            self.events_processed = n
            self.peak_heap = peak
