"""TPU-EM core: the paper's event-driven simulation kernel (§3.1).

``engine``     — Environment / Process / Event / Timeout / conditions
``resources``  — Store / PriorityStore / Container / Resource
``trace``      — activity sampling shared by perf + Power-EM
``vectorized`` — beyond-paper vmap-able analytic scheduler for sweeps
"""
from .engine import (
    AllOf,
    AnyOf,
    Condition,
    Environment,
    Event,
    Interrupt,
    Process,
    Timeout,
    NORMAL,
    URGENT,
)
from .resources import Container, PriorityItem, PriorityStore, Resource, Store
from .trace import ActivitySample, SampleArrays, TaskRecord, Tracer, pti_bins

__all__ = [
    "AllOf",
    "AnyOf",
    "Condition",
    "Environment",
    "Event",
    "Interrupt",
    "Process",
    "Timeout",
    "NORMAL",
    "URGENT",
    "Container",
    "PriorityItem",
    "PriorityStore",
    "Resource",
    "Store",
    "ActivitySample",
    "SampleArrays",
    "TaskRecord",
    "Tracer",
    "pti_bins",
]
