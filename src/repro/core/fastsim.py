"""Compiled interval-replay refinement: the campaign fast path.

The generator-driven event engine (``core.engine``) is ground truth, but
since the ``layers`` axis landed a full-model refinement walks
``layers x ops x n_tiles`` heap events while the analytic pre-screen
handles 13k points in seconds. This module closes that gap with three
pieces, all operating on **flat arrays** instead of Python object
graphs:

1. **Array lowering** (``lower``): a ``CompiledWorkload`` + ``HwConfig``
   become a ``TaskTable`` — engine ids, dense barrier waits/signals
   (``graph.compiler`` guarantees per-compile ids ``0..n-1``), and
   per-task latencies from the existing ``GemmSpec``/``VecSpec``/
   ``DmaDescriptor``/``CollectiveSpec`` cost models.
2. **List-scheduling sweep** (``list_schedule``): an event-free numpy
   relaxation over the static barrier DAG, respecting per-engine FIFO
   order. Durations are the analytic (contention-free) models, so this
   is a fast *estimate* — the event engine's sub-task pipelines and
   shared VMEM-port/HBM-bank contention make true intervals longer.
   Used for ordering/sanity, never for records.
3. **Steady-state interval replay** (``simulate_fast``): the exact
   path. Full-model LM workloads are ``layers`` identical ``L<i>.*``
   blocks; the event engine's schedule becomes periodic after a warmup
   layer (verified per run, never assumed). So: replay a *reduced*
   model (``FAST_REPLAY_LAYERS`` layers — its compiled prefix is
   task-for-task identical to the full model's), detect the periodic
   steady state by comparing consecutive layer blocks' task intervals
   and activity-sample windows, then extrapolate the remaining layers
   in O(1) each — synthesized intervals/samples are the steady block
   shifted by multiples of the measured period. When periodicity does
   not lock in (pattern diff beyond ``FAST_PATTERN_ATOL_NS``, irregular
   block structure, unexpected tail), it falls back to an **exact full
   replay** — event-engine intervals exported as arrays, bit-identical
   to ``engine="event"`` records.

Accuracy contract: replayed runs (the fallback, and every non-layered
workload) are *bitwise* equal to the event engine. Extrapolated runs
agree to float-rounding noise (measured ~1e-13 relative on makespan;
``sweep.refine.crosscheck_point`` quantifies it per point).

No jax anywhere on this import path — ``sweep.refine`` imports this
module from spawn-context worker processes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..graph.compiler import CompiledWorkload
from ..graph.tasks import Task
from ..hw.dma import Dma, DmaDescriptor
from ..hw.ici import CollectiveSpec, IciFabric
from ..hw.memory import Hbm
from ..hw.mxu import GemmSpec, Mxu
from ..hw.presets import HwConfig
from ..hw.vecunit import VecSpec, VecUnit
from ..obs.metrics import REGISTRY
from .trace import SampleArrays

__all__ = ["TaskTable", "lower", "list_schedule", "FastRun",
           "simulate_fast", "try_extrapolate", "replay_intervals",
           "BlockMatch", "VerifiedReplay", "match_blocks",
           "verify_replay", "splice",
           "FAST_REPLAY_LAYERS", "FAST_REPLAY_LAYERS_BY_PHASE",
           "FAST_MIN_LAYERS", "FAST_PATTERN_ATOL_NS"]

# reduced-model replay depth: warmup blocks, verified-steady interior
# blocks, and the final block + head (which see the head-weight
# prefetch exactly as the full model's last layer does). The warmup
# transient is phase-dependent: compute-bound prefill settles after one
# layer, while decode (DMA-paced, deep FIFO run-ahead) and train (3x
# op list) need two or three — measured via the lock-in check, which
# falls back to exact replay whenever a depth proves too shallow.
FAST_REPLAY_LAYERS = 6
FAST_REPLAY_LAYERS_BY_PHASE = {"prefill": 4, "decode": 6, "train": 6}
# extrapolate only when it pays (and leaves >= 1 block to insert)
FAST_MIN_LAYERS = 8
# steady-state lock-in tolerance on relative task/sample times. Float
# accumulation noise across layers measures ~1e-7 ns; a single HBM
# page-policy flip is >= 25 ns — so 1e-2 ns separates the two regimes
# by orders of magnitude on both sides.
FAST_PATTERN_ATOL_NS = 1e-2

_LAYER_RE = re.compile(r"^(?:dma\.)?L(\d+)\.")


# ---------------------------------------------------------------------------
# array lowering


@dataclass
class TaskTable:
    """Flat-array form of a compiled task graph."""

    n_tasks: int
    engines: List[str]            # engine-unit id -> name
    engine_id: np.ndarray         # [N] int32
    duration: np.ndarray          # [N] float64, analytic cost models
    # ragged waits: waits of task i are wait_bid/wait_need[wait_off[i]:
    # wait_off[i+1]] (dense barrier ids straight from the compiler)
    wait_off: np.ndarray          # [N+1] int32
    wait_bid: np.ndarray          # [W] int32
    wait_need: np.ndarray         # [W] int32
    signal_off: np.ndarray        # [N+1] int32
    signal_bid: np.ndarray        # [S] int32
    n_barriers: int
    layer: np.ndarray             # [N] int32, L<i> block id or -1


def _analytic_duration(payload: Any, cfg: HwConfig, *,
                       _memo: Dict[int, Any]) -> float:
    """Per-task latency from the existing hw cost models (``ideal_time_ns``
    is a pure function of config; the model objects are built once)."""
    models = _memo.get(id(cfg))
    if models is None:
        hbm = Hbm(None, cfg, None)
        models = (Mxu(None, cfg, None, None), VecUnit(None, cfg, None, None),
                  Dma(None, cfg, hbm, None, None), IciFabric(None, cfg, None))
        _memo[id(cfg)] = models
    mxu, vpu, dma, ici = models
    if isinstance(payload, GemmSpec):
        return mxu.ideal_time_ns(payload)
    if isinstance(payload, VecSpec):
        return vpu.ideal_time_ns(payload)
    if isinstance(payload, DmaDescriptor):
        return dma.ideal_time_ns(payload)
    if isinstance(payload, CollectiveSpec):
        return ici.ideal_time_ns(payload)
    raise TypeError(f"unknown payload {type(payload)}")


def layer_of(name: str) -> int:
    """``L<i>.*`` block id of a task/op name (handles the ``dma.``
    prefix), or -1 for non-layer (head/tail) tasks."""
    m = _LAYER_RE.match(name)
    return int(m.group(1)) if m else -1


def lower(cw: CompiledWorkload, cfg: HwConfig) -> TaskTable:
    """Lower a compiled workload to flat arrays (see module docstring)."""
    tasks = cw.tasks
    n = len(tasks)
    eng_ids: Dict[str, int] = {}
    engine_id = np.zeros(n, np.int32)
    duration = np.zeros(n, np.float64)
    layer = np.full(n, -1, np.int32)
    wait_off = np.zeros(n + 1, np.int32)
    signal_off = np.zeros(n + 1, np.int32)
    wb: List[int] = []
    wn: List[int] = []
    sb: List[int] = []
    memo: Dict[int, Any] = {}
    for i, t in enumerate(tasks):
        engine_id[i] = eng_ids.setdefault(t.engine, len(eng_ids))
        duration[i] = _analytic_duration(t.payload, cfg, _memo=memo)
        layer[i] = layer_of(t.name)
        for bid, need in t.waits:
            wb.append(bid)
            wn.append(need)
        for bid in t.signals:
            sb.append(bid)
        wait_off[i + 1] = len(wb)
        signal_off[i + 1] = len(sb)
    return TaskTable(n_tasks=n, engines=list(eng_ids), engine_id=engine_id,
                     duration=duration, wait_off=wait_off,
                     wait_bid=np.asarray(wb, np.int32),
                     wait_need=np.asarray(wn, np.int32),
                     signal_off=signal_off,
                     signal_bid=np.asarray(sb, np.int32),
                     n_barriers=cw.n_barriers, layer=layer)


def list_schedule(table: TaskTable) -> Tuple[np.ndarray, np.ndarray, float]:
    """Event-free list-scheduling relaxation over the lowered arrays.

    ``start[i] = max(engine_free[e_i], barrier-ready times of waits)``;
    barriers become ready when their ``need``-th signal (chronologically)
    lands. Respects per-engine FIFO order (the event engine pops its
    FIFO strictly in compile order). Returns ``(start, end, makespan)``
    under the analytic durations — a contention-free estimate.
    """
    n = table.n_tasks
    start = np.zeros(n, np.float64)
    end = np.zeros(n, np.float64)
    free = np.zeros(len(table.engines), np.float64)
    sig_times: List[List[float]] = [[] for _ in range(table.n_barriers)]
    eng = table.engine_id
    dur = table.duration
    woff, wbid, wneed = table.wait_off, table.wait_bid, table.wait_need
    soff, sbid = table.signal_off, table.signal_bid
    for i in range(n):
        t = free[eng[i]]
        for j in range(woff[i], woff[i + 1]):
            times = sig_times[wbid[j]]
            need = wneed[j]
            if len(times) < need:
                raise ValueError(
                    f"task {i} waits for signal {need} of barrier "
                    f"{wbid[j]}, only {len(times)} producers precede it")
            ready = float(np.partition(np.asarray(times), need - 1)[need - 1])
            if ready > t:
                t = ready
        start[i] = t
        e = t + dur[i]
        end[i] = e
        free[eng[i]] = e
        for j in range(soff[i], soff[i + 1]):
            sig_times[sbid[j]].append(e)
    return start, end, float(end.max()) if n else 0.0


# ---------------------------------------------------------------------------
# exact interval replay + steady-state extrapolation


@dataclass
class FastRun:
    """Result of one fast-engine simulation of a full task list."""

    tasks: List[Task]             # the FULL compiled task list
    start: np.ndarray             # [N] exact (or extrapolated) task starts
    end: np.ndarray               # [N] task ends
    samples: SampleArrays         # full activity-sample set
    makespan_ns: float
    extrapolated: bool
    replayed_tasks: int           # how many tasks were event-simulated
    detail: Dict[str, Any] = field(default_factory=dict)


def replay_intervals(tasks: Sequence[Task], cfg: HwConfig, *,
                     n_tiles: int) -> Tuple[np.ndarray, np.ndarray,
                                            SampleArrays]:
    """Run the event engine and export per-task intervals (task-list
    order) + the sample stream as arrays — the tracer interval export."""
    from ..hw.chip import System

    sysm = System(cfg, n_tiles=n_tiles)
    # run_workload minus the Report reduction (busy-time unions over
    # every module) — interval consumers reduce arrays themselves
    done = sysm.scheduler.run(tasks)
    sysm.env.run(until=done)
    # kernel/contention telemetry flows from fast-engine replays too
    sysm.emit_metrics()
    tid, _enq, st, en = sysm.tracer.task_arrays()
    pos = {t.tid: i for i, t in enumerate(tasks)}
    idx = np.fromiter((pos[t] for t in tid.tolist()), np.int64, len(tid))
    start = np.empty(len(tasks), np.float64)
    end = np.empty(len(tasks), np.float64)
    start[idx] = st
    end[idx] = en
    return start, end, sysm.tracer.sample_arrays()


def _payload_sig(p: Any) -> Tuple:
    """Structural payload identity: everything timing-relevant except
    the HBM base address (which advances layer to layer — periodicity
    of its *effect* is what the steady-state check verifies) and the
    embedded op name."""
    if isinstance(p, GemmSpec):
        return ("gemm", p.m, p.n, p.k, p.a_bytes_per_elem,
                p.b_bytes_per_elem, p.out_bytes_per_elem,
                p.fused_post_elems)
    if isinstance(p, VecSpec):
        return ("vec", p.n_elems, p.kind, p.bytes_in, p.bytes_out)
    if isinstance(p, DmaDescriptor):
        return ("dma", p.nbytes, p.src, p.dst, p.contiguous_run,
                p.compressed, p.broadcast)
    if isinstance(p, CollectiveSpec):
        return ("coll", p.op, p.payload_bytes, p.group_size, p.cross_pod)
    return ("other", repr(p))


_STRIP_RE = re.compile(r"^(dma\.)?L\d+\.")


def _strip_layer(name: str) -> str:
    return _STRIP_RE.sub(lambda m: m.group(1) or "", name)


def _block_slices(tasks: Sequence[Task]) -> Optional[Tuple[List[slice],
                                                           slice]]:
    """Split a task list into contiguous ``L<i>`` blocks + trailing tail.

    Returns ``None`` when the layer structure is irregular (non-layer
    tasks between blocks, non-ascending ids, ...) — caller falls back.
    """
    labels = [layer_of(t.name) for t in tasks]
    slices: List[slice] = []
    i, n = 0, len(tasks)
    expect = 0
    while i < n and labels[i] == expect:
        j = i
        while j < n and labels[j] == expect:
            j += 1
        slices.append(slice(i, j))
        i = j
        expect += 1
    if any(lb != -1 for lb in labels[i:]):
        return None          # layer tasks after the tail started
    if not slices:
        return None
    return slices, slice(i, n)


def _block_sig(tasks: Sequence[Task], sl: slice) -> Tuple:
    return tuple((_strip_layer(t.name), t.engine, _payload_sig(t.payload))
                 for t in tasks[sl])


def _ici_duration(spec: CollectiveSpec, cfg: HwConfig) -> float:
    """Closed-form collective latency — ``IciFabric.run`` executes one
    timeout of exactly ``ideal_time_ns`` (the ici engine serializes its
    FIFO, so collectives never contend in-engine), except that a
    zero-byte payload short-circuits to 0."""
    if spec.phases() == 0 or spec.payload_bytes <= 0:
        return 0.0
    return IciFabric(None, cfg, None).ideal_time_ns(spec)


def _full_replay(tasks: Sequence[Task], cfg: HwConfig, n_tiles: int,
                 reason: str) -> FastRun:
    start, end, sa = replay_intervals(tasks, cfg, n_tiles=n_tiles)
    return FastRun(tasks=list(tasks), start=start, end=end, samples=sa,
                   makespan_ns=sa.makespan(), extrapolated=False,
                   replayed_tasks=len(tasks), detail={"fallback": reason})


@dataclass
class BlockMatch:
    """Config-independent structural match of a full model against one
    reduced twin: the full model's block layout plus the tail payloads
    that must be patched in closed form after splicing. Pure graph
    structure — valid for every hardware config the pair compiles under
    (``graph.compiler`` output is invariant along the analytic axes)."""

    f_blocks: List[slice]         # full model's L<i> block slices
    f_tail: slice                 # full model's trailing (head) tasks
    n_extra: int                  # layers to synthesize (L - R)
    patches: List[Tuple[int, CollectiveSpec]]   # tail pos -> payload
    layers: int                   # L
    reduced_layers: int           # R


@dataclass
class VerifiedReplay:
    """One reduced twin replayed on the event engine and steady-state
    verified at one hardware config — the shareable unit of the batched
    refinement path (``core.batchsim``): every full model whose blocks
    structurally match this twin splices from the same verified replay,
    so a batch of campaign points pays for the event engine once."""

    n_tasks: int                  # twin task count (FastRun accounting)
    start: np.ndarray             # [n_tasks] exact twin task starts
    end: np.ndarray
    samples: SampleArrays         # twin activity-sample stream
    blocks: List[slice]           # twin L<i> block slices
    tail: slice
    q: int                        # steady block index (last interior)
    delta: float                  # measured steady-state period (ns)
    drift: float                  # task-pattern lock-in drift (ns)
    sdrift: float                 # sample-window lock-in drift (ns)
    win: np.ndarray               # bool mask: the captured steady window
    w1: float                     # window end (the period cut)


def match_blocks(full: CompiledWorkload, reduced: CompiledWorkload
                 ) -> Tuple[Optional[BlockMatch], str]:
    """Structural half of an extrapolation attempt (no simulation).

    Verifies both task lists split into regular ``L<i>`` blocks, every
    block carries the same structural signature, and the tails agree up
    to closed-form-patchable collectives. Returns ``(match, "")`` or
    ``(None, reason)``.
    """
    tasks = full.tasks
    fb = _block_slices(tasks)
    rb = _block_slices(reduced.tasks)
    if fb is None or rb is None:
        return None, "irregular layer blocks"
    f_blocks, f_tail = fb
    r_blocks, r_tail = rb
    L, R = len(f_blocks), len(r_blocks)
    n_extra = L - R
    if R < 4 or n_extra < 1 or L < FAST_MIN_LAYERS:
        return None, f"too few layers (L={L}, R={R})"

    # -- structural identity: every block matches, tails match ------------
    sig = _block_sig(reduced.tasks, r_blocks[0])
    if any(_block_sig(reduced.tasks, s) != sig for s in r_blocks[1:]) or \
       any(_block_sig(tasks, s) != sig for s in f_blocks):
        return None, "layer blocks differ"
    r_tail_tasks = reduced.tasks[r_tail]
    f_tail_tasks = tasks[f_tail]
    if len(r_tail_tasks) != len(f_tail_tasks):
        return None, "tail length differs"
    patches: List[Tuple[int, CollectiveSpec]] = []   # tail pos -> payload
    for k, (rt, ft) in enumerate(zip(r_tail_tasks, f_tail_tasks)):
        if _strip_layer(rt.name) != _strip_layer(ft.name) or \
           rt.engine != ft.engine:
            return None, "tail names differ"
        if _payload_sig(rt.payload) != _payload_sig(ft.payload):
            # layer-count-dependent tail payloads (the train-phase DP
            # gradient all-reduce scales with `layers`) are patchable in
            # closed form — but only with nothing scheduled after them
            if not (isinstance(ft.payload, CollectiveSpec)
                    and k == len(f_tail_tasks) - 1):
                return None, "unpatchable tail payload"
            patches.append((k, ft.payload))
    return BlockMatch(f_blocks=f_blocks, f_tail=f_tail, n_extra=n_extra,
                      patches=patches, layers=L, reduced_layers=R), ""


def verify_replay(reduced: CompiledWorkload, cfg: HwConfig, *,
                  n_tiles: int) -> Tuple[Optional[VerifiedReplay], str]:
    """Replay one reduced twin exactly and verify its steady state.

    Depends only on ``(reduced, cfg, n_tiles)`` — never on the full
    model — so the result is memoizable and shareable across every
    campaign point whose graph matches the twin (``match_blocks``) at a
    config that replays identically (``batchsim.dead_axes``).
    """
    rb = _block_slices(reduced.tasks)
    if rb is None:
        return None, "irregular layer blocks"
    r_blocks, r_tail = rb
    R = len(r_blocks)
    if R < 4:
        return None, f"too few replay layers (R={R})"

    # -- exact replay of the reduced model --------------------------------
    r_start, r_end, r_sa = replay_intervals(reduced.tasks, cfg,
                                            n_tiles=n_tiles)
    anchors = np.array([r_start[s.start] for s in r_blocks])
    q = R - 2                      # steady block (last interior one)
    delta = float(anchors[q] - anchors[q - 1])
    if delta <= 0:
        return None, "non-positive period"

    # -- steady-state lock-in: task patterns ------------------------------
    def pat(b: int) -> np.ndarray:
        s = r_blocks[b]
        return np.stack([r_start[s] - anchors[b], r_end[s] - anchors[b]])

    drift = float(np.abs(pat(q) - pat(q - 1)).max())
    if drift > FAST_PATTERN_ATOL_NS:
        return None, f"task pattern drift {drift:.3g} ns"

    # -- steady-state lock-in: activity-sample windows ---------------------
    # The period cut must not sit on a sample start: block anchors are
    # exactly where next-layer DMA prefetches launch, so an anchor-
    # aligned cut flips boundary samples between windows on ~1e-7 ns
    # accumulation noise. Place the cut mid-way through the largest gap
    # in sample starts (mod period) instead.
    a_prev = float(anchors[q - 1])
    region = (r_sa.t0 >= a_prev) & (r_sa.t0 < a_prev + delta)
    rel = np.sort(np.mod(r_sa.t0[region] - a_prev, delta))
    if len(rel) == 0:
        off = delta / 2.0
    else:
        gaps = np.diff(np.concatenate([rel, rel[:1] + delta]))
        gi = int(np.argmax(gaps))
        off = float(np.mod(rel[gi] + gaps[gi] / 2.0, delta))
    cut = float(anchors[q]) + off             # end of the captured window
    w0, w1 = cut - delta, cut
    win = (r_sa.t0 >= w0) & (r_sa.t0 < w1)
    prev = (r_sa.t0 >= w0 - delta) & (r_sa.t0 < w0)
    if int(win.sum()) != int(prev.sum()):
        return None, "sample window size drift"
    # Windows are compared in *canonical* order: same-time emissions on
    # different modules may swap raw emission order layer to layer (heap
    # ties resolve by global event id), which is timing-irrelevant —
    # PTI binning is per module.

    def canon(mask: np.ndarray, t_ref: float):
        rel0 = r_sa.t0[mask] - t_ref
        rel1 = r_sa.t1[mask] - t_ref
        order = np.lexsort((r_sa.amount[mask], np.round(rel1, 3),
                            np.round(rel0, 3), r_sa.kind_id[mask],
                            r_sa.module_id[mask]))
        return (r_sa.module_id[mask][order], r_sa.kind_id[mask][order],
                r_sa.amount[mask][order], rel0[order], rel1[order])

    cw_mid, cw_kid, cw_amt, cw_t0, cw_t1 = canon(win, w0)
    cp_mid, cp_kid, cp_amt, cp_t0, cp_t1 = canon(prev, w0 - delta)
    if not (np.array_equal(cw_mid, cp_mid) and np.array_equal(cw_kid, cp_kid)
            and np.array_equal(cw_amt, cp_amt)):
        return None, "sample pattern drift"
    sdrift = max(float(np.abs(cw_t0 - cp_t0).max(initial=0)),
                 float(np.abs(cw_t1 - cp_t1).max(initial=0)))
    if sdrift > FAST_PATTERN_ATOL_NS:
        return None, f"sample time drift {sdrift:.3g} ns"
    return VerifiedReplay(n_tasks=len(reduced.tasks), start=r_start,
                          end=r_end, samples=r_sa, blocks=r_blocks,
                          tail=r_tail, q=q, delta=delta, drift=drift,
                          sdrift=sdrift, win=win, w1=w1), ""


def splice(full: CompiledWorkload, match: BlockMatch, vr: VerifiedReplay,
           cfg: HwConfig) -> Tuple[Optional[FastRun], str]:
    """Synthesize the full model's intervals/samples from a verified
    twin replay (O(1) per extra layer). Never mutates ``vr`` — the same
    verified replay splices any number of campaign points."""
    tasks = full.tasks
    f_blocks, f_tail = match.f_blocks, match.f_tail
    n_extra, patches = match.n_extra, match.patches
    r_blocks, r_tail = vr.blocks, vr.tail
    r_start, r_end, r_sa = vr.start, vr.end, vr.samples
    q, delta, win, w1 = vr.q, vr.delta, vr.win, vr.w1

    # -- splice task intervals --------------------------------------------
    n_full = len(tasks)
    start = np.empty(n_full, np.float64)
    end = np.empty(n_full, np.float64)
    shift_after = n_extra * delta
    for i, s in enumerate(f_blocks):
        if i <= q:
            src = r_blocks[i]
            off = 0.0
        elif i <= q + n_extra:
            src = r_blocks[q]
            off = (i - q) * delta
        else:
            src = r_blocks[i - n_extra]
            off = shift_after
        start[s] = r_start[src] + off
        end[s] = r_end[src] + off
    start[f_tail] = r_start[r_tail] + shift_after
    end[f_tail] = r_end[r_tail] + shift_after

    # -- splice samples ----------------------------------------------------
    pre = r_sa.t0 < w1
    post = ~pre
    parts_t0 = [r_sa.t0[pre]]
    parts_t1 = [r_sa.t1[pre]]
    parts_mid = [r_sa.module_id[pre]]
    parts_kid = [r_sa.kind_id[pre]]
    parts_amt = [r_sa.amount[pre]]
    for j in range(1, n_extra + 1):
        parts_t0.append(r_sa.t0[win] + j * delta)
        parts_t1.append(r_sa.t1[win] + j * delta)
        parts_mid.append(r_sa.module_id[win])
        parts_kid.append(r_sa.kind_id[win])
        parts_amt.append(r_sa.amount[win])
    parts_t0.append(r_sa.t0[post] + shift_after)
    parts_t1.append(r_sa.t1[post] + shift_after)
    parts_mid.append(r_sa.module_id[post])
    parts_kid.append(r_sa.kind_id[post])
    parts_amt.append(r_sa.amount[post])
    sa = SampleArrays(modules=list(r_sa.modules), kinds=list(r_sa.kinds),
                      module_id=np.concatenate(parts_mid),
                      kind_id=np.concatenate(parts_kid),
                      t0=np.concatenate(parts_t0),
                      t1=np.concatenate(parts_t1),
                      amount=np.concatenate(parts_amt))

    # -- patch layer-count-dependent tail collectives ----------------------
    for k, payload in patches:
        ti = f_tail.start + k
        old_end = end[ti]
        end[ti] = start[ti] + _ici_duration(payload, cfg)
        if payload.phases() == 0 or payload.payload_bytes <= 0:
            continue       # instant collective: no sample on either engine
        mod = "ici.dcn" if payload.cross_pod else "ici"
        if mod not in sa.modules:
            return None, "tail sample patch failed (module missing)"
        mid = sa.modules.index(mod)
        rows = np.nonzero((sa.module_id == mid) & (sa.t0 == start[ti])
                          & (sa.t1 == old_end))[0]
        if len(rows) != 1:
            # ambiguous or missing sample: patching would leave the
            # record internally inconsistent — make the caller fall
            # back to exact replay instead
            return None, "tail sample patch failed (no unique row)"
        sa.t1[rows[0]] = end[ti]
        sa.amount[rows[0]] = payload.link_bytes()

    # event-engine semantics: makespan is the last sample's t1
    return FastRun(tasks=list(tasks), start=start, end=end, samples=sa,
                   makespan_ns=sa.makespan(),
                   extrapolated=True,
                   replayed_tasks=vr.n_tasks,
                   detail={"layers": match.layers,
                           "replayed_layers": match.reduced_layers,
                           "period_ns": delta, "task_drift_ns": vr.drift,
                           "sample_drift_ns": vr.sdrift,
                           "patched_tail": len(patches)}), ""


def try_extrapolate(full: CompiledWorkload, cfg: HwConfig, *,
                    n_tiles: int, reduced: CompiledWorkload
                    ) -> Tuple[Optional[FastRun], str]:
    """One steady-state extrapolation attempt against one reduced twin.

    Composition of the three reusable stages — ``match_blocks`` (pure
    structure), ``verify_replay`` (one event-engine twin replay +
    steady-state lock-in), ``splice`` (O(1)/layer synthesis). Returns
    ``(run, "")`` on lock-in, ``(None, reason)`` otherwise — the caller
    decides whether to try a deeper twin or fall back to an exact full
    replay (``simulate_fast`` runs that ladder).
    """
    match, reason = match_blocks(full, reduced)
    if match is None:
        return None, reason
    vr, reason = verify_replay(reduced, cfg, n_tiles=n_tiles)
    if vr is None:
        return None, reason
    return splice(full, match, vr, cfg)


def _reason_class(reasons: Sequence[str], extrapolate: bool) -> str:
    """Low-cardinality metric label for a fallback: the deepest attempt's
    reason with point-specific detail (numbers, parens) stripped."""
    if not extrapolate:
        return "disabled"
    if not reasons:
        return "no_reduced_workload"
    head = re.split(r"[(\d]", reasons[-1])[0].strip()
    return head.replace(" ", "_") or "unknown"


def simulate_fast(full: CompiledWorkload, cfg: HwConfig, *, n_tiles: int,
                  reduced: Sequence[CompiledWorkload] = (),
                  extrapolate: bool = True,
                  verify: Optional[Callable[[CompiledWorkload],
                                            Tuple[Optional[VerifiedReplay],
                                                  str]]] = None) -> FastRun:
    """Fast-engine simulation of ``full``.

    ``reduced`` is a ladder of compiled reduced-layer twins (same
    workload at increasing ``FAST_REPLAY_LAYERS_BY_PHASE`` depths — the
    warmup transient varies with phase and problem size, so a shallow
    attempt that fails lock-in retries deeper). Without candidates, or
    when every attempt fails its steady-state checks, this is an exact
    full replay, bit-identical to the event engine.

    ``verify`` overrides how a twin gets its ``VerifiedReplay`` — the
    batched refinement path (``core.batchsim``) passes a memoizing
    closure here so one event-engine twin replay serves every campaign
    point in a structural class. Default: fresh ``verify_replay`` per
    attempt (identical behavior, replay just isn't shared).
    """
    if verify is None:
        def verify(rcw: CompiledWorkload):
            return verify_replay(rcw, cfg, n_tiles=n_tiles)
    reasons: List[str] = []
    if extrapolate:
        for rw in reduced:
            run = None
            match, reason = match_blocks(full, rw)
            if match is not None:
                vr, reason = verify(rw)
                if vr is not None:
                    run, reason = splice(full, match, vr, cfg)
            if run is not None:
                if reasons:
                    run.detail["retried"] = reasons
                if REGISTRY.enabled:
                    REGISTRY.counter("fastsim.extrapolated").inc()
                    REGISTRY.histogram("fastsim.retry_depth",
                                       bounds=(0.0, 1.0, 2.0, 4.0)
                                       ).observe(len(reasons))
                return run
            reasons.append(reason)
    fallback = ("; ".join(reasons) if reasons else
                ("extrapolation disabled" if not extrapolate
                 else "no reduced workload"))
    if REGISTRY.enabled:
        REGISTRY.counter("fastsim.full_replay",
                         reason=_reason_class(reasons, extrapolate)).inc()
        if reasons:
            REGISTRY.histogram("fastsim.retry_depth",
                               bounds=(0.0, 1.0, 2.0, 4.0)
                               ).observe(len(reasons))
    return _full_replay(full.tasks, cfg, n_tiles, fallback)
