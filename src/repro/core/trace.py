"""Activity tracing — the data spine between performance and power models.

Every hardware model emits ``ActivitySample`` records into a shared
``Tracer`` while it processes events. The same records serve three consumers
(paper §3.3/§5.1):

  1. performance reports (per-engine busy time, utilization, timelines),
  2. Power-EM PTI (power-trace-interval) activity aggregation,
  3. test assertions (determinism, pipelining overlap).

Samples are intervals, not instants: ``(module, kind, t0, t1, amount)``.
``amount`` is in the module's native activity unit (bytes for DMA/NOC/memory,
ops for MXU/vector unit — exactly the paper's Table 2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["ActivitySample", "Tracer", "TaskRecord"]


@dataclass(frozen=True)
class ActivitySample:
    module: str       # hierarchical name, e.g. "pod0.chip3.mxu0"
    kind: str         # "ops" | "bytes" | "busy"
    t0: float         # ns
    t1: float         # ns
    amount: float     # native units over [t0, t1]

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclass(frozen=True)
class TaskRecord:
    """Task-level event record (scheduler view)."""

    task: str
    engine: str
    t_enqueue: float
    t_start: float
    t_end: float
    meta: Tuple[Tuple[str, object], ...] = ()


@dataclass
class Tracer:
    enabled: bool = True
    samples: List[ActivitySample] = field(default_factory=list)
    tasks: List[TaskRecord] = field(default_factory=list)

    # -- emission ------------------------------------------------------------
    def emit(self, module: str, kind: str, t0: float, t1: float, amount: float) -> None:
        if self.enabled:
            if t1 < t0:
                raise ValueError(f"sample ends before it starts: {t0}..{t1}")
            self.samples.append(ActivitySample(module, kind, t0, t1, amount))

    def emit_task(self, rec: TaskRecord) -> None:
        if self.enabled:
            self.tasks.append(rec)

    # -- queries ---------------------------------------------------------------
    def modules(self) -> List[str]:
        return sorted({s.module for s in self.samples})

    def by_module(self, module: str, kind: Optional[str] = None) -> List[ActivitySample]:
        return [
            s
            for s in self.samples
            if s.module == module and (kind is None or s.kind == kind)
        ]

    def busy_time(self, module: str) -> float:
        """Union length of the module's busy intervals (overlap-safe)."""
        ivals = sorted((s.t0, s.t1) for s in self.samples if s.module == module)
        total, cur0, cur1 = 0.0, None, None
        for t0, t1 in ivals:
            if cur1 is None or t0 > cur1:
                if cur1 is not None:
                    total += cur1 - cur0
                cur0, cur1 = t0, t1
            else:
                cur1 = max(cur1, t1)
        if cur1 is not None:
            total += cur1 - cur0
        return total

    def total_amount(self, module: str, kind: str) -> float:
        return sum(s.amount for s in self.samples if s.module == module and s.kind == kind)

    def makespan(self) -> float:
        return max((s.t1 for s in self.samples), default=0.0)

    # -- PTI binning (Power-EM §5.1) ------------------------------------------
    def pti_activity(
        self,
        module_prefix: str,
        kind: str,
        pti: float,
        t_end: Optional[float] = None,
    ) -> List[float]:
        """Per-interval activity amounts for modules under ``module_prefix``.

        A sample spanning several intervals contributes pro-rata (its rate is
        assumed uniform over [t0, t1]) — this is how Power-EM captures
        activity *temporally* as well as spatially.
        """
        if pti <= 0:
            raise ValueError("pti must be > 0")
        horizon = t_end if t_end is not None else self.makespan()
        n = max(1, math.ceil(horizon / pti)) if horizon > 0 else 1
        bins = [0.0] * n
        for s in self.samples:
            if not s.module.startswith(module_prefix) or s.kind != kind:
                continue
            if s.duration == 0:
                idx = min(int(s.t0 / pti), n - 1)
                bins[idx] += s.amount
                continue
            rate = s.amount / s.duration
            b0 = int(s.t0 / pti)
            b1 = min(int(math.ceil(s.t1 / pti)), n)
            for b in range(b0, b1):
                lo = max(s.t0, b * pti)
                hi = min(s.t1, (b + 1) * pti)
                if hi > lo:
                    bins[b] += rate * (hi - lo)
        return bins

    def clear(self) -> None:
        self.samples.clear()
        self.tasks.clear()


def to_chrome_trace(tracer: "Tracer") -> dict:
    """Export the activity + task timeline as a Chrome/Perfetto trace
    (chrome://tracing 'traceEvents' JSON). Engines become pids/tids;
    task-level records and sub-task activity samples become complete
    events — load the file in Perfetto to see the paper's Fig-8-style
    pipeline/concurrency picture interactively."""
    events = []
    pids = {}

    def pid_of(module: str) -> int:
        root = module.split(".")[0]
        if root not in pids:
            pids[root] = len(pids) + 1
            events.append({"ph": "M", "pid": pids[root], "name":
                           "process_name", "args": {"name": root}})
        return pids[root]

    for rec in tracer.tasks:
        events.append({
            "ph": "X", "name": rec.task, "cat": "task",
            "pid": pid_of(rec.engine), "tid": rec.engine,
            "ts": rec.t_start / 1e3,              # us
            "dur": max(rec.t_end - rec.t_start, 1e-3) / 1e3,
            "args": {"queued_us": (rec.t_start - rec.t_enqueue) / 1e3},
        })
    for s in tracer.samples:
        events.append({
            "ph": "X", "name": f"{s.kind}={s.amount:.3g}", "cat": "activity",
            "pid": pid_of(s.module), "tid": s.module,
            "ts": s.t0 / 1e3, "dur": max(s.duration, 1e-3) / 1e3,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}
