"""Activity tracing — the data spine between performance and power models.

Every hardware model emits ``ActivitySample`` records into a shared
``Tracer`` while it processes events. The same records serve three consumers
(paper §3.3/§5.1):

  1. performance reports (per-engine busy time, utilization, timelines),
  2. Power-EM PTI (power-trace-interval) activity aggregation,
  3. test assertions (determinism, pipelining overlap).

Samples are intervals, not instants: ``(module, kind, t0, t1, amount)``.
``amount`` is in the module's native activity unit (bytes for DMA/NOC/memory,
ops for MXU/vector unit — exactly the paper's Table 2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = ["ActivitySample", "Tracer", "TaskRecord", "SampleArrays"]


@dataclass(frozen=True, slots=True)
class ActivitySample:
    module: str       # hierarchical name, e.g. "pod0.chip3.mxu0"
    kind: str         # "ops" | "bytes" | "busy"
    t0: float         # ns
    t1: float         # ns
    amount: float     # native units over [t0, t1]

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclass(frozen=True, slots=True)
class TaskRecord:
    """Task-level event record (scheduler view)."""

    task: str
    engine: str
    t_enqueue: float
    t_start: float
    t_end: float
    meta: Tuple[Tuple[str, object], ...] = ()
    tid: int = -1     # graph.tasks.Task.tid, for array alignment


@dataclass
class SampleArrays:
    """Column-major view of an activity-sample stream.

    The array twin of ``Tracer.samples``: one row per sample, module
    names interned to ids, row order preserved (PTI binning accumulates
    in row order, so loop- and array-based consumers agree bitwise).
    Produced by ``Tracer.sample_arrays`` after an event simulation, or
    synthesized directly by ``core.fastsim`` when it extrapolates a
    steady state instead of replaying it.
    """

    modules: List[str]          # id -> module name
    kinds: List[str]            # id -> kind name
    module_id: np.ndarray       # [M] int32
    kind_id: np.ndarray         # [M] int32
    t0: np.ndarray              # [M] float64
    t1: np.ndarray              # [M] float64
    amount: np.ndarray          # [M] float64

    def __len__(self) -> int:
        return int(self.module_id.shape[0])

    def makespan(self) -> float:
        return float(self.t1.max()) if len(self) else 0.0

    def module_ids_with_prefix(self, prefix: str) -> List[int]:
        return [i for i, m in enumerate(self.modules)
                if m.startswith(prefix)]


@dataclass
class Tracer:
    enabled: bool = True
    samples: List[ActivitySample] = field(default_factory=list)
    tasks: List[TaskRecord] = field(default_factory=list)

    # -- emission ------------------------------------------------------------
    def emit(self, module: str, kind: str, t0: float, t1: float, amount: float) -> None:
        if self.enabled:
            if t1 < t0:
                raise ValueError(f"sample ends before it starts: {t0}..{t1}")
            self.samples.append(ActivitySample(module, kind, t0, t1, amount))

    def emit_task(self, rec: TaskRecord) -> None:
        if self.enabled:
            self.tasks.append(rec)

    # -- queries ---------------------------------------------------------------
    def modules(self) -> List[str]:
        return sorted({s.module for s in self.samples})

    def by_module(self, module: str, kind: Optional[str] = None) -> List[ActivitySample]:
        return [
            s
            for s in self.samples
            if s.module == module and (kind is None or s.kind == kind)
        ]

    def busy_time(self, module: str) -> float:
        """Union length of the module's busy intervals (overlap-safe)."""
        ivals = sorted((s.t0, s.t1) for s in self.samples if s.module == module)
        total, cur0, cur1 = 0.0, None, None
        for t0, t1 in ivals:
            if cur1 is None or t0 > cur1:
                if cur1 is not None:
                    total += cur1 - cur0
                cur0, cur1 = t0, t1
            else:
                cur1 = max(cur1, t1)
        if cur1 is not None:
            total += cur1 - cur0
        return total

    def total_amount(self, module: str, kind: str) -> float:
        return sum(s.amount for s in self.samples if s.module == module and s.kind == kind)

    def makespan(self) -> float:
        return max((s.t1 for s in self.samples), default=0.0)

    # -- PTI binning (Power-EM §5.1) ------------------------------------------
    def pti_activity(
        self,
        module_prefix: str,
        kind: str,
        pti: float,
        t_end: Optional[float] = None,
    ) -> List[float]:
        """Per-interval activity amounts for modules under ``module_prefix``.

        A sample spanning several intervals contributes pro-rata (its rate is
        assumed uniform over [t0, t1]) — this is how Power-EM captures
        activity *temporally* as well as spatially.
        """
        if pti <= 0:
            raise ValueError("pti must be > 0")
        horizon = t_end if t_end is not None else self.makespan()
        n = max(1, math.ceil(horizon / pti)) if horizon > 0 else 1
        bins = [0.0] * n
        for s in self.samples:
            if not s.module.startswith(module_prefix) or s.kind != kind:
                continue
            if s.duration == 0:
                idx = min(int(s.t0 / pti), n - 1)
                bins[idx] += s.amount
                continue
            rate = s.amount / s.duration
            b0 = int(s.t0 / pti)
            b1 = min(int(math.ceil(s.t1 / pti)), n)
            for b in range(b0, b1):
                lo = max(s.t0, b * pti)
                hi = min(s.t1, (b + 1) * pti)
                if hi > lo:
                    bins[b] += rate * (hi - lo)
        return bins

    def clear(self) -> None:
        self.samples.clear()
        self.tasks.clear()

    # -- array export (core.fastsim / vectorized Power-EM) --------------------
    def sample_arrays(self) -> "SampleArrays":
        """Lower the sample list to ``SampleArrays`` (row order kept)."""
        mod_ids: Dict[str, int] = {}
        kind_ids: Dict[str, int] = {}
        n = len(self.samples)
        mid = np.empty(n, np.int32)
        kid = np.empty(n, np.int32)
        t0 = np.empty(n, np.float64)
        t1 = np.empty(n, np.float64)
        amt = np.empty(n, np.float64)
        for i, s in enumerate(self.samples):
            mid[i] = mod_ids.setdefault(s.module, len(mod_ids))
            kid[i] = kind_ids.setdefault(s.kind, len(kind_ids))
            t0[i] = s.t0
            t1[i] = s.t1
            amt[i] = s.amount
        return SampleArrays(modules=list(mod_ids), kinds=list(kind_ids),
                            module_id=mid, kind_id=kid, t0=t0, t1=t1,
                            amount=amt)

    def task_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        """``(tid, t_enqueue, t_start, t_end)`` arrays in record order."""
        n = len(self.tasks)
        tid = np.empty(n, np.int64)
        enq = np.empty(n, np.float64)
        st = np.empty(n, np.float64)
        en = np.empty(n, np.float64)
        for i, r in enumerate(self.tasks):
            tid[i], enq[i], st[i], en[i] = r.tid, r.t_enqueue, r.t_start, \
                r.t_end
        return tid, enq, st, en


def pti_bins(sa: SampleArrays, module_ids: Iterable[int], kind: str,
             pti: float, t_end: Optional[float] = None) -> np.ndarray:
    """Array twin of ``Tracer.pti_activity`` — bitwise-identical bins.

    Every sample expands to its covered bins via ``np.repeat`` (so
    contributions accumulate in sample order, exactly like the Python
    loop) and lands with one ``np.add.at``. The per-contribution
    arithmetic replicates the loop's expressions operation for
    operation, which is what lets the vectorized Power-EM produce
    byte-identical records.
    """
    if pti <= 0:
        raise ValueError("pti must be > 0")
    horizon = t_end if t_end is not None else sa.makespan()
    n = max(1, math.ceil(horizon / pti)) if horizon > 0 else 1
    bins = np.zeros(n, np.float64)
    ids = list(module_ids)
    if not ids or kind not in sa.kinds:
        return bins
    sel = np.isin(sa.module_id, np.asarray(ids, np.int32)) & \
        (sa.kind_id == sa.kinds.index(kind))
    if not sel.any():
        return bins
    t0, t1, amt = sa.t0[sel], sa.t1[sel], sa.amount[sel]
    dur = t1 - t0
    zero = dur == 0.0
    # int(x / pti) truncates the IEEE quotient — replicate exactly
    b0 = (t0 / pti).astype(np.int64)
    b1 = np.minimum(np.ceil(t1 / pti).astype(np.int64), n)
    # zero-duration samples land whole in one clamped bin
    b0 = np.where(zero, np.minimum(b0, n - 1), b0)
    nb = np.where(zero, 1, np.maximum(b1 - b0, 0))
    total = int(nb.sum())
    if total == 0:
        return bins
    row = np.repeat(np.arange(len(nb)), nb)
    k = np.arange(total) - np.repeat(np.cumsum(nb) - nb, nb)
    b = b0[row] + k
    rate = np.where(zero, 0.0, amt / np.where(zero, 1.0, dur))
    lo = np.maximum(t0[row], b * pti)
    hi = np.minimum(t1[row], (b + 1) * pti)
    contrib = np.where(zero[row], amt[row],
                       np.where(hi > lo, rate[row] * (hi - lo), 0.0))
    np.add.at(bins, b, contrib)
    return bins


def to_chrome_trace(tracer: "Tracer") -> dict:
    """Export the activity + task timeline as a Chrome/Perfetto trace
    (chrome://tracing 'traceEvents' JSON). Engines become pids/tids;
    task-level records and sub-task activity samples become complete
    events — load the file in Perfetto to see the paper's Fig-8-style
    pipeline/concurrency picture interactively."""
    events = []
    pids = {}

    def pid_of(module: str) -> int:
        root = module.split(".")[0]
        if root not in pids:
            pids[root] = len(pids) + 1
            events.append({"ph": "M", "pid": pids[root], "name":
                           "process_name", "args": {"name": root}})
        return pids[root]

    for rec in tracer.tasks:
        events.append({
            "ph": "X", "name": rec.task, "cat": "task",
            "pid": pid_of(rec.engine), "tid": rec.engine,
            "ts": rec.t_start / 1e3,              # us
            "dur": max(rec.t_end - rec.t_start, 1e-3) / 1e3,
            "args": {"queued_us": (rec.t_start - rec.t_enqueue) / 1e3},
        })
    for s in tracer.samples:
        events.append({
            "ph": "X", "name": f"{s.kind}={s.amount:.3g}", "cat": "activity",
            "pid": pid_of(s.module), "tid": s.module,
            "ts": s.t0 / 1e3, "dur": max(s.duration, 1e-3) / 1e3,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}
