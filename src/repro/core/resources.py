"""Shared-resource primitives for the TPU-EM event kernel.

These mirror the SimPy classes the paper names (§3.1.3):

  * ``Store``          — hardware FIFOs / task queues (bounded, FIFO order)
  * ``PriorityStore``  — arbitration-ordered queues (NOC routers)
  * ``Container``      — shared memory capacity (VMEM/CB allocation)
  * ``Resource``       — mutually exclusive ports (memory ports, DMA channels)

All requests are Events; a process interacts by ``yield store.put(x)`` /
``item = yield store.get()`` etc. Requests resolve strictly FIFO (or by
priority) so model behaviour is deterministic.
"""
from __future__ import annotations

import heapq
import operator
from typing import Any, List

from .engine import Environment, Event, URGENT

_BY_KEY = operator.attrgetter("key")

__all__ = [
    "Store",
    "PriorityStore",
    "PriorityItem",
    "Container",
    "Resource",
]


class _Request(Event):
    __slots__ = ("item", "amount", "key")

    def __init__(self, env: Environment):
        super().__init__(env)


class Store:
    """Bounded FIFO of Python objects — the paper's hardware FIFO/queue."""

    __slots__ = ("env", "capacity", "name", "items", "_putq", "_getq",
                 "_seq", "_drainer")

    def __init__(self, env: Environment, capacity: float = float("inf"), name: str = ""):
        if capacity <= 0:
            raise ValueError("capacity must be > 0")
        self.env = env
        self.capacity = capacity
        self.name = name
        self.items: List[Any] = []
        self._putq: List[_Request] = []
        self._getq: List[_Request] = []

    # -- public API ---------------------------------------------------------
    def put(self, item: Any) -> Event:
        req = _Request(self.env)
        req.item = item
        self._putq.append(req)
        self._dispatch()
        return req

    def get(self) -> Event:
        req = _Request(self.env)
        self._getq.append(req)
        self._dispatch()
        return req

    @property
    def level(self) -> int:
        return len(self.items)

    # -- internals ------------------------------------------------------------
    def _do_put(self, req: _Request) -> bool:
        if len(self.items) < self.capacity:
            self.items.append(req.item)
            req.succeed(priority=URGENT)
            return True
        return False

    def _do_get(self, req: _Request) -> bool:
        if self.items:
            req.succeed(self.items.pop(0), priority=URGENT)
            return True
        return False

    def _dispatch(self) -> None:
        # Alternate until no progress: a completed get may unblock a put.
        progress = True
        while progress:
            progress = False
            while self._putq and self._do_put(self._putq[0]):
                self._putq.pop(0)
                progress = True
            while self._getq and self._do_get(self._getq[0]):
                self._getq.pop(0)
                progress = True


class PriorityItem:
    """Orderable wrapper: lower ``priority`` is served first; FIFO at ties."""

    __slots__ = ("priority", "item")

    def __init__(self, priority: float, item: Any):
        self.priority = priority
        self.item = item

    def __lt__(self, other: "PriorityItem") -> bool:
        return self.priority < other.priority

    def __repr__(self):
        return f"PriorityItem({self.priority}, {self.item!r})"


class PriorityStore(Store):
    """Store whose ``get`` returns the lowest-priority item (router arbiter)."""

    __slots__ = ()

    def __init__(self, env: Environment, capacity: float = float("inf"), name: str = ""):
        super().__init__(env, capacity, name)
        self._seq = 0

    def _do_put(self, req: _Request) -> bool:
        if len(self.items) < self.capacity:
            self._seq += 1
            heapq.heappush(self.items, (req.item, self._seq))
            req.succeed(priority=URGENT)
            return True
        return False

    def _do_get(self, req: _Request) -> bool:
        if self.items:
            item, _ = heapq.heappop(self.items)
            req.succeed(item, priority=URGENT)
            return True
        return False

    @property
    def level(self) -> int:
        return len(self.items)


class Container:
    """Continuous shared capacity (bytes of CB/VMEM, DMA credits...).

    ``put(n)`` adds, ``get(n)`` removes; both block until satisfiable.
    Strict FIFO per direction (no barging) for determinism.
    """

    __slots__ = ("env", "capacity", "name", "_level", "_putq", "_getq")

    def __init__(
        self,
        env: Environment,
        capacity: float = float("inf"),
        init: float = 0.0,
        name: str = "",
    ):
        if capacity <= 0:
            raise ValueError("capacity must be > 0")
        if not 0 <= init <= capacity:
            raise ValueError("0 <= init <= capacity violated")
        self.env = env
        self.capacity = capacity
        self.name = name
        self._level = float(init)
        self._putq: List[_Request] = []
        self._getq: List[_Request] = []

    @property
    def level(self) -> float:
        return self._level

    def put(self, amount: float) -> Event:
        if amount <= 0:
            raise ValueError("amount must be > 0")
        req = _Request(self.env)
        req.amount = amount
        self._putq.append(req)
        self._dispatch()
        return req

    def get(self, amount: float) -> Event:
        if amount <= 0:
            raise ValueError("amount must be > 0")
        req = _Request(self.env)
        req.amount = amount
        self._getq.append(req)
        self._dispatch()
        return req

    def _dispatch(self) -> None:
        progress = True
        while progress:
            progress = False
            if self._putq and self._level + self._putq[0].amount <= self.capacity:
                req = self._putq.pop(0)
                self._level += req.amount
                req.succeed(priority=URGENT)
                progress = True
            if self._getq and self._level >= self._getq[0].amount:
                req = self._getq.pop(0)
                self._level -= req.amount
                req.succeed(priority=URGENT)
                progress = True


class Resource:
    """N interchangeable servers (memory ports, DMA channels, ICI links).

    ``yield res.request()`` acquires, ``res.release(req)`` frees. Also usable
    as a context helper:

        req = res.request()
        yield req
        ...
        res.release(req)
    """

    __slots__ = ("env", "capacity", "name", "users", "_queue", "_seq",
                 "n_requests", "n_stalls")

    def __init__(self, env: Environment, capacity: int = 1, name: str = ""):
        if capacity <= 0:
            raise ValueError("capacity must be > 0")
        self.env = env
        self.capacity = capacity
        self.name = name
        self.users: List[_Request] = []
        self._queue: List[_Request] = []
        self._seq = 0
        # contention telemetry (obs.metrics): requests seen / requests
        # that could not be granted immediately. Two int adds per
        # request — requests are orders of magnitude rarer than kernel
        # events, so this stays always-on (and deterministic).
        self.n_requests = 0
        self.n_stalls = 0

    @property
    def count(self) -> int:
        return len(self.users)

    def request(self, priority: float = 0.0) -> Event:
        req = _Request(self.env)
        # FIFO within a priority class via a per-resource sequence
        # number — NEVER id(req): grant order among equal-priority
        # contenders must be identical run to run, or simulations (and
        # the byte-identical-records backend contract) go
        # nondeterministic with memory layout
        self._seq += 1
        key = req.key = (priority, self._seq)
        q = self._queue
        # seq grows monotonically, so appends are already in order
        # unless this request carries a lower priority value
        if q and key < q[-1].key:
            q.append(req)
            q.sort(key=_BY_KEY)
        else:
            q.append(req)
        self._dispatch()
        self.n_requests += 1
        if not req.triggered:
            self.n_stalls += 1
        return req

    def release(self, req: _Request) -> None:
        if req in self.users:
            self.users.remove(req)
        else:  # cancel a queued request
            try:
                self._queue.remove(req)
            except ValueError:
                pass
        self._dispatch()

    def _dispatch(self) -> None:
        while self._queue and len(self.users) < self.capacity:
            req = self._queue.pop(0)
            self.users.append(req)
            req.succeed(priority=URGENT)
