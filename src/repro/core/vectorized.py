"""Beyond-paper: JAX-vectorized analytic scheduler for parameter sweeps.

The paper's stated purpose is exploring a *large design-parameter space*.
The Python event kernel is the reference model; this module compiles the
same task graph into arrays and runs a **list-scheduling recurrence** under
``jax.lax.scan`` — ``vmap`` over hardware-parameter vectors then evaluates
hundreds of configs in one XLA call (used by the Fig 5-7/9 style sweeps to
pre-screen; the event engine re-runs the interesting points in detail).

Durations are the engines' analytic models (no pipeline/contention
micro-behavior); the event engine remains ground truth and tests bound the
deviation between the two.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..graph.tasks import Task
from ..hw.dma import DmaDescriptor
from ..hw.ici import CollectiveSpec
from ..hw.mxu import GemmSpec
from ..hw.presets import HwConfig
from ..hw.vecunit import VecSpec

__all__ = ["TaskArrays", "from_tasks", "params_of", "schedule",
           "schedule_many", "schedule_stats", "schedule_many_stats",
           "PARAM_NAMES", "N_ENGINE_CLASSES"]

MAX_DEPS = 8

# engine classes for the duration model
ENG_MXU, ENG_VPU, ENG_DMA, ENG_ICI = 0, 1, 2, 3
N_ENGINE_CLASSES = 4

PARAM_NAMES = ("macs", "clock_ghz", "vpu_flops_per_cycle", "hbm_gbps",
               "dma_overhead_ns", "ici_link_gbps", "ici_latency_ns",
               "dcn_gbps", "dcn_latency_ns", "mxu_rows", "vmem_bytes_per_ns",
               "task_overhead_ns", "mxu_cols")


@dataclass
class TaskArrays:
    engine_class: np.ndarray    # [N] int32 in {MXU, VPU, DMA, ICI}
    engine_unit: np.ndarray     # [N] int32 physical engine instance id
    n_units: int
    flops: np.ndarray           # [N]
    elems: np.ndarray
    bytes_: np.ndarray
    io_bytes: np.ndarray        # VMEM load/store traffic of compute tasks
    gemm_m: np.ndarray          # GEMM dims for ragged-edge efficiency
    gemm_n: np.ndarray
    coll_phases: np.ndarray
    coll_bytes: np.ndarray      # per-phase link bytes
    cross_pod: np.ndarray       # [N] bool
    deps: np.ndarray            # [N, MAX_DEPS] int32, -1 padded


# TaskArrays is a jax pytree (n_units static) so the batched-stats
# kernel below is a single module-level jit: task graphs with the same
# SHAPE — e.g. every layer body of an LM campaign, whatever its seq/
# batch/TP values — share one XLA compilation instead of recompiling
# per call.
jax.tree_util.register_pytree_node(
    TaskArrays,
    lambda a: ((a.engine_class, a.engine_unit, a.flops, a.elems, a.bytes_,
                a.io_bytes, a.gemm_m, a.gemm_n, a.coll_phases,
                a.coll_bytes, a.cross_pod, a.deps), a.n_units),
    lambda aux, c: TaskArrays(c[0], c[1], aux, *c[2:]))


def params_of(cfg: HwConfig, mxu_eff: float = 0.0) -> np.ndarray:
    del mxu_eff  # kept for API compat; efficiency is per-task now
    return np.array([
        cfg.macs, cfg.clock_ghz, cfg.vpu_flops_per_cycle, cfg.hbm_gbps,
        cfg.dma_desc_overhead_ns, cfg.ici_link_gbps, cfg.ici_latency_ns,
        cfg.dcn_gbps, cfg.dcn_latency_ns, cfg.mxu_rows,
        cfg.vmem_ports * cfg.vmem_port_bytes_per_cycle * cfg.clock_ghz,
        # per-task pipeline setup: fill/drain + FIFO/barrier hop
        # (calibrated vs the event engine on the small-op CNN workloads)
        (cfg.mxu_rows + 64) * cfg.cycle_ns + 450.0,
        cfg.mxu_cols,
    ], dtype=np.float64)


def from_tasks(tasks: Sequence[Task]) -> TaskArrays:
    """Task list (with barrier deps) -> dense arrays. Dependencies resolve
    each wait barrier to its producer task indices (capped at MAX_DEPS,
    keeping the latest producers — the binding ones under FIFO order)."""
    producers: Dict[int, List[int]] = {}
    unit_ids: Dict[str, int] = {}
    n = len(tasks)
    eng_cls = np.zeros(n, np.int32)
    eng_unit = np.zeros(n, np.int32)
    flops = np.zeros(n)
    elems = np.zeros(n)
    bytes_ = np.zeros(n)
    io_bytes = np.zeros(n)
    gemm_m = np.zeros(n)
    gemm_n = np.zeros(n)
    phases = np.zeros(n)
    cbytes = np.zeros(n)
    cross = np.zeros(n, bool)
    deps = np.full((n, MAX_DEPS), -1, np.int32)

    for i, t in enumerate(tasks):
        if t.engine not in unit_ids:
            unit_ids[t.engine] = len(unit_ids)
        eng_unit[i] = unit_ids[t.engine]
        p = t.payload
        if isinstance(p, GemmSpec):
            eng_cls[i] = ENG_MXU
            flops[i] = p.flops
            gemm_m[i], gemm_n[i] = p.m, p.n
            # pipeline overlaps the three streams; the largest paces
            io_bytes[i] = max(p.m * p.k * p.a_bytes_per_elem,
                              p.k * p.n * p.b_bytes_per_elem,
                              p.m * p.n * p.out_bytes_per_elem)
        elif isinstance(p, VecSpec):
            eng_cls[i] = ENG_VPU
            elems[i] = p.n_elems
            io_bytes[i] = (p.bytes_in or 2 * p.n_elems) + \
                (p.bytes_out or 2 * p.n_elems)
        elif isinstance(p, DmaDescriptor):
            eng_cls[i] = ENG_DMA
            bytes_[i] = p.nbytes
        elif isinstance(p, CollectiveSpec):
            eng_cls[i] = ENG_ICI
            phases[i] = p.phases()
            cbytes[i] = p.payload_bytes / max(p.group_size, 1)
            cross[i] = p.cross_pod
        else:
            raise TypeError(f"unknown payload {type(p)}")
        dlist: List[int] = []
        for bid, _need in t.waits:
            dlist.extend(producers.get(bid, []))
        for j, d in enumerate(dlist[-MAX_DEPS:]):
            deps[i, j] = d
        for bid in t.signals:
            producers.setdefault(bid, []).append(i)

    return TaskArrays(eng_cls, eng_unit, len(unit_ids), flops, elems, bytes_,
                      io_bytes, gemm_m, gemm_n, phases, cbytes, cross, deps)


def _durations(a: TaskArrays, p: jnp.ndarray) -> jnp.ndarray:
    (macs, f, vpu_rate, hbm, dma_oh, link, lat, dcn, dcn_lat, rows,
     vmem_bw, t_oh, cols) = (p[i] for i in range(13))
    # ragged-edge efficiency: the systolic array pads M,N to its geometry
    m = jnp.maximum(a.gemm_m, 1.0)
    nn = jnp.maximum(a.gemm_n, 1.0)
    pad = (jnp.ceil(m / rows) * rows * jnp.ceil(nn / cols) * cols) / (m * nn)
    # compute engines are bounded by max(math, VMEM streaming) + setup —
    # mirrors the event models' load/exec/store pipeline shape
    io_mxu = (a.io_bytes / vmem_bw)
    d_mxu = jnp.maximum(a.flops * pad / (2.0 * macs * f), io_mxu) + t_oh
    d_vpu = jnp.maximum(a.elems / (vpu_rate * f), a.io_bytes / vmem_bw) + t_oh
    d_dma = dma_oh + a.bytes_ / hbm
    bw = jnp.where(a.cross_pod, dcn, link)
    latv = jnp.where(a.cross_pod, dcn_lat, lat)
    d_ici = a.coll_phases * (latv + a.coll_bytes / bw)
    cls = a.engine_class
    return jnp.where(
        cls == ENG_MXU, d_mxu,
        jnp.where(cls == ENG_VPU, d_vpu,
                  jnp.where(cls == ENG_DMA, d_dma, d_ici)))


def schedule(arrays: TaskArrays, params: jnp.ndarray) -> jnp.ndarray:
    """List-schedule makespan under one parameter vector (jit-able)."""
    dur = _durations(arrays, jnp.asarray(params))
    deps = jnp.asarray(arrays.deps)
    unit = jnp.asarray(arrays.engine_unit)
    n = dur.shape[0]
    n_units = arrays.n_units

    def step(carry, xs):
        done, free = carry                     # [N] task end, [U] engine free
        i, d, dp, u = xs
        dep_done = jnp.where(dp >= 0, done[jnp.maximum(dp, 0)], 0.0)
        start = jnp.maximum(jnp.max(dep_done), free[u])
        end = start + d
        done = done.at[i].set(end)
        free = free.at[u].set(end)
        return (done, free), end

    idx = jnp.arange(n)
    (done, _), ends = jax.lax.scan(
        step,
        (jnp.zeros(n), jnp.zeros(n_units)),
        (idx, dur, deps, unit))
    return jnp.max(ends)


@jax.jit
def _schedule_many_impl(arrays: TaskArrays,
                        param_matrix: jnp.ndarray) -> jnp.ndarray:
    return jax.vmap(lambda p: schedule(arrays, p))(param_matrix)


def schedule_many(arrays: TaskArrays, param_matrix: np.ndarray) -> np.ndarray:
    """vmap over K parameter vectors -> K makespans in one XLA call."""
    return np.asarray(_schedule_many_impl(arrays,
                                          jnp.asarray(param_matrix)))


def schedule_stats(arrays: TaskArrays, params: jnp.ndarray, *,
                   repeats: int = 1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Makespan + per-engine-class busy time under one parameter vector.

    The busy vector (``[N_ENGINE_CLASSES]``, summed task durations per
    class) is what the sweep pre-screen feeds the analytic Power-EM proxy:
    utilization(class) = busy / makespan, no event simulation needed.

    ``repeats`` is the **layer-replication fast path**: a workload made
    of ``repeats`` sequentially dependent copies of this task graph (a
    full multi-layer LM: every layer re-streams its weights, so copy
    i+1 starts after copy i) has makespan ``repeats * makespan(1)`` and
    busy ``repeats * busy(1)`` in closed form under the list-scheduling
    model — no per-layer loop, no longer scan. Cross-copy prefetch
    overlap at layer seams is ignored; the event engine (which always
    walks the full replicated graph) bounds that error via the campaign
    ``deviation`` column.
    """
    dur = _durations(arrays, jnp.asarray(params))
    cls = jnp.asarray(arrays.engine_class)
    busy = jnp.zeros(N_ENGINE_CLASSES).at[cls].add(dur)
    r = float(repeats)
    return schedule(arrays, params) * r, busy * r


@functools.partial(jax.jit, static_argnames=("repeats",))
def _schedule_many_stats_impl(arrays: TaskArrays, param_matrix: jnp.ndarray,
                              repeats: int
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return jax.vmap(lambda p: schedule_stats(arrays, p,
                                             repeats=repeats))(param_matrix)


def schedule_many_stats(arrays: TaskArrays, param_matrix: np.ndarray, *,
                        repeats: int = 1
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """vmap over K parameter vectors -> (K makespans, [K, 4] busy times)
    in one XLA call — the sweep campaign's batched pre-screen.
    ``repeats`` applies the closed-form layer replication of
    ``schedule_stats`` to every parameter vector. Same-shaped task
    graphs share one XLA compilation (TaskArrays is a pytree)."""
    mk, busy = _schedule_many_stats_impl(arrays, jnp.asarray(param_matrix),
                                         int(repeats))
    return np.asarray(mk), np.asarray(busy)
