"""Batched cross-point refinement: one simulation, many campaign cells.

A campaign sweeps *hardware* axes (clock, HBM bandwidth, link rates —
``sweep.spec.ANALYTIC_AXES``) over a fixed set of workloads. Points that
differ only along those axes compile to **the same task graph**: the
compiler never reads the analytic config fields, so engines, barrier
waits/signals and op payloads are identical and only the per-task
analytic latencies change. This module exploits that three ways:

1. **Structural hashing** (``structural_hash``): a process-stable
   content hash of everything in a lowered ``TaskTable`` *except* the
   latencies — engine ids, dense barrier waits/signals, structural
   payload signatures (``fastsim._payload_sig``). Points with equal
   hashes form a *structural class*: isomorphic graphs differing only
   along latency-rescaling axes. Task names are deliberately excluded,
   so graphs that are isomorphic under renaming (e.g. two batch sizes
   whose per-chip op shapes coincide) share a class too.
2. **Table stacking** (``stack_tables`` + ``list_schedule_batched``):
   one ``BatchTaskTable`` holds the shared structure plus a ``[P, N]``
   duration matrix, and the list-scheduling relaxation runs once for
   all P points with numpy inner ops over the point axis — mirroring
   how ``core.vectorized.schedule_many_stats`` batches the analytic
   pre-screen. Per point it is bitwise-equal to ``fastsim.
   list_schedule`` (locked by tests).
3. **Dead-axis analysis** (``dead_axes`` / ``live_key``): which
   analytic axes can change *nothing* about a point's exact record —
   neither the event-engine replay nor the Power-EM pass reads them
   for this graph. Points in a class that also agree on every *live*
   axis share one event-engine twin replay, one splice, one Power-EM
   pass, and one (bitwise-identical) record. ``sweep.refine.
   refine_batch`` drives that sharing; ``fastsim.simulate_fast``'s
   ``verify=`` hook is where the shared ``VerifiedReplay`` enters.

Like ``fastsim``, this import path is jax-free — it runs inside
spawn-context worker processes.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Any, Dict, FrozenSet, List, Sequence, Tuple

import numpy as np

from ..graph.compiler import CompiledWorkload
from ..hw.ici import CollectiveSpec
from ..hw.presets import HwConfig
from .fastsim import TaskTable, _analytic_duration, _payload_sig

__all__ = ["structural_hash", "dead_axes", "live_key", "batch_durations",
           "BatchTaskTable", "stack_tables", "list_schedule_batched"]


# ---------------------------------------------------------------------------
# structural hashing


def structural_hash(cw: CompiledWorkload, *, n_tiles: int = 0) -> str:
    """Content hash of a compiled workload's *structure*.

    Covers everything the event engine's schedule shape depends on
    except per-task latencies: engine ids, dense barrier waits/signals
    (per-compile, dense from 0 — compiler contract), and structural
    payload signatures. Excludes task names (isomorphism under
    renaming) and any memory address (``_payload_sig`` already strips
    the per-layer HBM base). Stable across processes: built purely
    from ints/strings/bools, no ``id()``, no dict iteration order.
    """
    h = hashlib.sha256()
    h.update(json.dumps({"n_tiles": n_tiles, "n_barriers": cw.n_barriers,
                         "n_tasks": len(cw.tasks)},
                        sort_keys=True).encode())
    for t in cw.tasks:
        sig = (t.engine, _payload_sig(t.payload),
               tuple((int(b), int(nd)) for b, nd in t.waits),
               tuple(int(b) for b in t.signals))
        h.update(repr(sig).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# dead-axis analysis


def dead_axes(cw: CompiledWorkload) -> FrozenSet[str]:
    """Analytic axes that provably cannot affect this graph's record.

    An axis is *dead* when neither the event-engine replay nor the
    Power-EM pass reads it for any payload in ``cw``:

    - ``dcn_gbps`` / ``dcn_latency_ns`` are only read by
      ``IciFabric.ideal_time_ns`` for ``cross_pod`` collectives — dead
      whenever no collective leaves the pod.
    - ``ici_latency_ns`` is only read for (non-cross-pod) collectives —
      dead when the graph has no collectives at all.
    - ``ici_link_gbps`` is **never** dead: even with no collectives,
      Power-EM sizes the ici/noc power-tree nodes by the link rate, so
      two configs differing there produce different power records.

    Dead axes define record-sharing groups: class members agreeing on
    every live axis get one shared simulation and bitwise-identical
    records (``live_key``).
    """
    has_coll = False
    has_cross = False
    for t in cw.tasks:
        p = t.payload
        if isinstance(p, CollectiveSpec):
            has_coll = True
            if p.cross_pod:
                has_cross = True
                break
    dead: set = set()
    if not has_cross:
        dead.update(("dcn_gbps", "dcn_latency_ns"))
    if not has_coll:
        dead.add("ici_latency_ns")
    return frozenset(dead)


def live_key(hw: Dict[str, Any], dead: FrozenSet[str]) -> str:
    """Canonical key of a point's hw config restricted to live axes —
    class members with equal live keys share one exact simulation."""
    return json.dumps({k: v for k, v in hw.items() if k not in dead},
                      sort_keys=True, default=float)


# ---------------------------------------------------------------------------
# batched lowering + list scheduling


def batch_durations(cw: CompiledWorkload, cfgs: Sequence[HwConfig]
                    ) -> np.ndarray:
    """Per-task analytic latencies for P configs at once: ``[P, N]``.

    Row p is bitwise-equal to ``lower(cw, cfgs[p]).duration`` — same
    cost-model objects, same call per task — but payload-signature
    memoization collapses the per-task model calls to one per distinct
    payload shape (full-model LMs repeat each shape ``layers`` times).
    """
    n = len(cw.tasks)
    out = np.zeros((len(cfgs), n), np.float64)
    for p, cfg in enumerate(cfgs):
        memo: Dict[int, Any] = {}
        by_sig: Dict[Tuple, float] = {}
        row = out[p]
        for i, t in enumerate(cw.tasks):
            sig = _payload_sig(t.payload)
            d = by_sig.get(sig)
            if d is None:
                d = _analytic_duration(t.payload, cfg, _memo=memo)
                by_sig[sig] = d
            row[i] = d
    return out


@dataclass
class BatchTaskTable:
    """One structural class's shared graph + per-point latencies."""

    table: TaskTable              # structure (duration column ignored)
    duration: np.ndarray          # [P, N] float64
    n_points: int


def stack_tables(tables: Sequence[TaskTable]) -> BatchTaskTable:
    """Stack structurally identical ``TaskTable``s along the point axis.

    Raises ``ValueError`` when any structural field differs — the
    defense behind the structural hash (hash collisions across truly
    distinct graphs would be caught here, not silently mis-batched).
    """
    if not tables:
        raise ValueError("stack_tables needs at least one table")
    base = tables[0]
    for t in tables[1:]:
        if (t.n_tasks != base.n_tasks or t.engines != base.engines
                or t.n_barriers != base.n_barriers
                or not np.array_equal(t.engine_id, base.engine_id)
                or not np.array_equal(t.wait_off, base.wait_off)
                or not np.array_equal(t.wait_bid, base.wait_bid)
                or not np.array_equal(t.wait_need, base.wait_need)
                or not np.array_equal(t.signal_off, base.signal_off)
                or not np.array_equal(t.signal_bid, base.signal_bid)
                or not np.array_equal(t.layer, base.layer)):
            raise ValueError("tables are not structurally identical")
    dur = np.stack([t.duration for t in tables])
    return BatchTaskTable(table=base, duration=dur, n_points=len(tables))


def list_schedule_batched(bt: BatchTaskTable
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``fastsim.list_schedule`` vectorized over the point axis.

    The task loop stays scalar (the barrier DAG is shared), but every
    inner op — engine-free times, barrier ``need``-th-signal selection,
    readiness max — runs on ``[P]`` vectors. Returns ``(start [P, N],
    end [P, N], makespan [P])``; each point's row is bitwise-equal to
    the scalar schedule of that point's own table (locked by tests).
    """
    tb = bt.table
    n, P = tb.n_tasks, bt.n_points
    start = np.zeros((P, n), np.float64)
    end = np.zeros((P, n), np.float64)
    free = np.zeros((P, len(tb.engines)), np.float64)
    # per-barrier signal times, each entry a [P] vector
    sig_times: List[List[np.ndarray]] = [[] for _ in range(tb.n_barriers)]
    eng = tb.engine_id
    dur = bt.duration
    woff, wbid, wneed = tb.wait_off, tb.wait_bid, tb.wait_need
    soff, sbid = tb.signal_off, tb.signal_bid
    for i in range(n):
        t = free[:, eng[i]].copy()
        for j in range(woff[i], woff[i + 1]):
            times = sig_times[wbid[j]]
            need = wneed[j]
            if len(times) < need:
                raise ValueError(
                    f"task {i} waits for signal {need} of barrier "
                    f"{wbid[j]}, only {len(times)} producers precede it")
            # need-th chronological signal, independently per point
            ready = np.partition(np.stack(times), need - 1, axis=0)[need - 1]
            np.maximum(t, ready, out=t)
        start[:, i] = t
        e = t + dur[:, i]
        end[:, i] = e
        free[:, eng[i]] = e
        for j in range(soff[i], soff[i + 1]):
            sig_times[sbid[j]].append(e)
    mk = end.max(axis=1) if n else np.zeros(P, np.float64)
    return start, end, mk
