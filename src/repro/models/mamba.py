"""Mamba-style selective SSM (diagonal state) for the Hymba hybrid heads.

Training/prefill uses ``jax.lax.associative_scan`` over the sequence —
O(S log S) depth, fully parallel, which is what makes the hybrid arch
eligible for ``long_500k``. The inner channel dim is sharded over the
'model' mesh axis (logical axis ``ssm_inner``), bounding the scan's
[B, S, di, n] state tensor per chip.

Decode is the O(1) recurrent update on (conv_state, ssm_state).

Shapes: x_in [B, S, di]; A_log [di, n]; W_x projects di -> (dt_rank + 2n);
conv is depthwise causal, width K.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain

__all__ = ["selective_scan", "mamba_mix", "mamba_decode_mix", "MambaState"]


class MambaState(NamedTuple):
    conv: jax.Array   # [B, di, K-1] last inputs (for causal depthwise conv)
    ssm: jax.Array    # [B, di, n]   diagonal SSM state


def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           carry: Optional[jax.Array] = None):
    """x [B,S,di], w [di,K] -> y [B,S,di]; optional left context carry."""
    B, S, di = x.shape
    K = w.shape[-1]
    if carry is None:
        pad = jnp.zeros((B, K - 1, di), x.dtype)
    else:
        pad = carry.transpose(0, 2, 1).astype(x.dtype)      # [B,K-1,di]
    xp = jnp.concatenate([pad, x], axis=1)                   # [B,S+K-1,di]
    # sum_k w[:,k] * x[t-K+1+k] — K is tiny (4): unrolled adds, no conv op.
    y = sum(xp[:, k : k + S] * w[None, None, :, k] for k in range(K))
    new_carry = xp[:, S:, :].transpose(0, 2, 1)              # [B,di,K-1]
    return y, new_carry


def selective_scan(a: jax.Array, bu: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + bu_t along axis 1. a, bu [B, S, di, n] (f32)."""

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bu), axis=1)
    return h


def _ssm_inner(x_conv, dt, Bm, Cm, A, D, state: Optional[jax.Array]):
    """Shared SSM math. x_conv [B,S,di], dt [B,S,di], Bm/Cm [B,S,n].

    Returns y [B,S,di] (f32) and final state [B,di,n].
    """
    a = jnp.exp(dt[..., None] * A[None, None])              # [B,S,di,n]
    bu = (dt * x_conv)[..., None] * Bm[:, :, None, :]       # [B,S,di,n]
    if state is not None:
        # fold carried state into the first step: h_0' = a_0*h_prev + bu_0
        bu = bu.at[:, 0].add(a[:, 0] * state)
    h = selective_scan(a, bu)                               # [B,S,di,n]
    y = jnp.einsum("bsdn,bsn->bsd", h, Cm) + D[None, None] * x_conv
    return y, h[:, -1]


def mamba_mix(
    x_in: jax.Array,
    z: jax.Array,
    conv_w: jax.Array,
    w_x: jax.Array,
    w_dt: jax.Array,
    b_dt: jax.Array,
    a_log: jax.Array,
    d_skip: jax.Array,
    *,
    n_state: int,
    dt_rank: int,
    state: Optional[MambaState] = None,
    return_state: bool = False,
):
    """Full Mamba mixing on a pre-projected pair (x_in, z) [B,S,di].

    Caller provides in/out projections; this is the conv + selective-scan +
    gate core so train/prefill/decode share one numeric path.
    """
    B, S, di = x_in.shape
    xc, conv_carry = _causal_depthwise_conv(
        x_in, conv_w, None if state is None else state.conv
    )
    xc = jax.nn.silu(xc.astype(jnp.float32))
    xc = constrain(xc, "batch", None, "ssm_inner")
    proj = jnp.einsum("bsd,dr->bsr", xc.astype(x_in.dtype), w_x)
    proj = proj.astype(jnp.float32)
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + n_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, w_dt.astype(jnp.float32))
        + b_dt.astype(jnp.float32)
    )
    dt = constrain(dt, "batch", None, "ssm_inner")
    A = -jnp.exp(a_log.astype(jnp.float32))                 # [di,n]
    y, ssm_final = _ssm_inner(
        xc, dt, Bm, Cm, A, d_skip.astype(jnp.float32),
        None if state is None else state.ssm.astype(jnp.float32),
    )
    out = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_in.dtype)
    out = constrain(out, "batch", None, "ssm_inner")
    if return_state:
        return out, MambaState(conv=conv_carry, ssm=ssm_final)
    return out


def mamba_decode_mix(
    x_in: jax.Array,
    z: jax.Array,
    conv_w: jax.Array,
    w_x: jax.Array,
    w_dt: jax.Array,
    b_dt: jax.Array,
    a_log: jax.Array,
    d_skip: jax.Array,
    *,
    n_state: int,
    dt_rank: int,
    state: MambaState,
) -> Tuple[jax.Array, MambaState]:
    """One-token step: x_in, z [B,1,di]. O(1) state update."""
    B, _, di = x_in.shape
    K = conv_w.shape[-1]
    # conv: append new token to carry, take one output step
    hist = jnp.concatenate(
        [state.conv.astype(x_in.dtype), x_in.transpose(0, 2, 1)], axis=-1
    )  # [B,di,K]
    xc = jnp.einsum("bdk,dk->bd", hist, conv_w)[:, None]    # [B,1,di]
    new_conv = hist[..., 1:]
    xc = jax.nn.silu(xc.astype(jnp.float32))
    proj = jnp.einsum("bsd,dr->bsr", xc.astype(x_in.dtype), w_x).astype(
        jnp.float32
    )
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + n_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, w_dt.astype(jnp.float32))
        + b_dt.astype(jnp.float32)
    )
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dt[0 if False else ...][..., None] * A[None, None])[:, 0]
    bu = ((dt * xc)[..., None] * Bm[:, :, None, :])[:, 0]   # [B,di,n]
    h = a * state.ssm.astype(jnp.float32) + bu
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + d_skip.astype(jnp.float32) * xc[:, 0]
    out = (y[:, None] * jax.nn.silu(z.astype(jnp.float32))).astype(x_in.dtype)
    return out, MambaState(conv=new_conv, ssm=h)
