"""Mixture-of-Experts FFN: top-k routing with capacity, two implementations.

``moe_dense``  — reference oracle: every expert computed for every token,
                 masked by routing weights. O(E·T·d·f) compute — used by CPU
                 smoke tests and as the numeric ground truth for the EP path.

``moe_ep``     — production expert-parallel path (shard_map): tokens are
                 bucketed by destination shard with a sort (NO one-hot
                 dispatch einsums — those cost 2·T·E·C·d FLOPs, more than
                 the experts themselves), exchanged with all_to_all over the
                 'model' axis, run through the local experts as one batched
                 einsum, and returned. Capacity-dropped tokens fall back to
                 the residual (standard token-dropping semantics).

Routing: softmax over experts, top-k, renormalized gates (Qwen3-MoE style;
Phi-3.5's sparsemixer is approximated by the same renormalized top-k —
recorded in DESIGN.md §assumption-changes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import ShardingRules, active_rules

__all__ = ["moe_dense", "moe_ep", "moe_ffn", "router_topk"]


def router_topk(x, w_router, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [T,d] -> (gates [T,k] fp32 renormalized, ids [T,k] int32, probs)."""
    logits = jnp.einsum("td,de->te", x, w_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, ids.astype(jnp.int32), probs


def _expert_ffn(x, wg, wi, wo):
    """Batched-expert SwiGLU: x [E,C,d], weights [E,d,f]/[E,f,d] -> [E,C,d]."""
    g = jnp.einsum("ecd,edf->ecf", x, wg)
    u = jnp.einsum("ecd,edf->ecf", x, wi)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wo)


def moe_dense(x, w_router, we_gate, we_up, we_down, *, k: int) -> jax.Array:
    """Oracle: compute all experts, combine by gates. x [T,d]."""
    T, d = x.shape
    E = w_router.shape[-1]
    gates, ids, _ = router_topk(x, w_router, k)
    # combine weight per (token, expert): [T,E]
    comb = jnp.zeros((T, E), jnp.float32)
    comb = jnp.take_along_axis(
        comb, ids, axis=1
    )  # dummy to keep shapes clear; build via scatter below
    comb = jnp.zeros((T, E), jnp.float32).at[jnp.arange(T)[:, None], ids].add(gates)
    ys = _expert_ffn(
        jnp.broadcast_to(x, (E,) + x.shape), we_gate, we_up, we_down
    )  # [E,T,d]
    return jnp.einsum("te,etd->td", comb.astype(x.dtype), ys)


def _bucket_by(dest, n_buckets: int, cap: int, src_ids):
    """Sort-based bucketing: returns (slot_src [n_buckets*cap] int32 index
    into src arrays, valid [n_buckets*cap] bool). dest [N] in [0,n_buckets)."""
    N = dest.shape[0]
    order = jnp.argsort(dest)                    # stable
    sdest = dest[order]
    # rank of each element within its destination bucket
    first = jnp.searchsorted(sdest, jnp.arange(n_buckets), side="left")
    rank = jnp.arange(N) - first[sdest]
    keep = rank < cap
    slot = sdest * cap + jnp.minimum(rank, cap - 1)
    # scatter src index into slots; dropped entries never written
    slot_src = jnp.full((n_buckets * cap,), -1, jnp.int32)
    slot_src = slot_src.at[jnp.where(keep, slot, n_buckets * cap)].set(
        src_ids[order].astype(jnp.int32), mode="drop"
    )
    return slot_src, slot_src >= 0


def _moe_ep_local(x, w_router, we_gate, we_up, we_down, *, k, n_experts,
                  capacity_factor, axis_name):
    """Per-shard body (inside shard_map). x [T_loc, d]; experts [E_loc,...]."""
    T, d = x.shape
    E_loc = we_gate.shape[0]
    Pn = n_experts // E_loc                      # peers along the EP axis
    gates, ids, _ = router_topk(x, w_router, k)  # [T,k]
    flat_ids = ids.reshape(-1)                   # [T*k]
    flat_gate = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    dest = flat_ids // E_loc                     # owning peer
    cap = int(max(8, -(-(T * k * capacity_factor) // Pn)))
    cap = -(-cap // 8) * 8
    slot_src, valid = _bucket_by(dest, Pn, cap, jnp.arange(T * k, dtype=jnp.int32))

    gather_tok = jnp.where(valid, flat_tok[slot_src], 0)
    send_x = jnp.where(valid[:, None], x[gather_tok], 0).reshape(Pn, cap, d)
    send_eid = jnp.where(valid, flat_ids[slot_src] % E_loc, -1).reshape(Pn, cap)

    if axis_name is not None:
        recv_x = jax.lax.all_to_all(send_x, axis_name, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid, axis_name, 0, 0, tiled=False)
    else:                                        # single-shard EP (tests)
        recv_x, recv_eid = send_x, send_eid
    recv_x = recv_x.reshape(Pn * cap, d)
    recv_eid = recv_eid.reshape(Pn * cap)

    # second bucketing: group received tokens by local expert
    C2 = -(-(Pn * cap) // E_loc)
    C2 = -(-C2 // 8) * 8
    eid_ok = jnp.where(recv_eid >= 0, recv_eid, E_loc)  # invalid -> overflow bucket
    slot2, valid2 = _bucket_by(eid_ok, E_loc + 1, C2,
                               jnp.arange(Pn * cap, dtype=jnp.int32))
    slot2 = slot2[: E_loc * C2]
    valid2 = valid2[: E_loc * C2]
    xe = jnp.where(valid2[:, None], recv_x[jnp.where(valid2, slot2, 0)], 0)
    xe = xe.reshape(E_loc, C2, d)

    ye = _expert_ffn(xe, we_gate, we_up, we_down)  # [E_loc, C2, d]

    # return to recv-slot order, then all_to_all back
    y_recv = jnp.zeros((Pn * cap, d), ye.dtype)
    y_recv = y_recv.at[jnp.where(valid2, slot2, Pn * cap)].set(
        ye.reshape(E_loc * C2, d), mode="drop"
    )
    y_send = y_recv.reshape(Pn, cap, d)
    if axis_name is not None:
        y_back = jax.lax.all_to_all(y_send, axis_name, 0, 0, tiled=False)
    else:
        y_back = y_send
    y_back = y_back.reshape(Pn * cap, d)

    # combine at source: out[tok] += gate * y  (dropped slots contribute 0)
    contrib = y_back * jnp.where(valid, flat_gate[slot_src], 0.0)[:, None].astype(
        y_back.dtype
    )
    out = jnp.zeros((T, d), y_back.dtype)
    out = out.at[jnp.where(valid, gather_tok, T)].add(contrib, mode="drop")
    return out


def moe_ep(x, w_router, we_gate, we_up, we_down, *, k, n_experts,
           capacity_factor, rules: ShardingRules) -> jax.Array:
    """Expert-parallel MoE over the 'model' mesh axis. x [B,S,d] global."""
    B, S, d = x.shape
    mesh = rules.mesh
    ep = rules.ep_axis
    batch_ax = rules.table.get("batch")
    x_spec = P(batch_ax, ep, None)               # tokens split over EP axis too
    other = tuple(a for a in mesh.axis_names if a != ep)

    body = functools.partial(
        _moe_ep_local,
        k=k,
        n_experts=n_experts,
        capacity_factor=capacity_factor,
        axis_name=ep,
    )
    fn = jax.shard_map(
        lambda xx, wr, wg, wu, wd: body(
            xx.reshape(-1, d), wr, wg, wu, wd
        ).reshape(xx.shape),
        mesh=mesh,
        in_specs=(x_spec, P(), P(ep), P(ep), P(ep)),
        out_specs=x_spec,
        check_vma=False,
    )
    return fn(x, w_router, we_gate, we_up, we_down)


def moe_onehot(x, w_router, we_gate, we_up, we_down, *, k, n_experts,
               capacity_factor) -> jax.Array:
    """One-hot einsum dispatch (GSPMD expert parallelism, no shard_map).

    Token count T is small here (decode), so the O(T·E·C·d) dispatch einsums
    are cheap; experts stay sharded over 'model' via the 'expert' logical
    axis and GSPMD partitions the batched-expert einsums + inserts the
    combine all-reduce. Used when the token dim cannot be split across the
    EP axis (e.g. one-token decode).
    """
    T, d = x.shape
    E = n_experts
    gates, ids, _ = router_topk(x, w_router, k)              # [T,k]
    cap = int(max(4, -(-(T * k * capacity_factor) // E)))
    # rank of each (token, slot) within its expert: counts of earlier
    # assignments to the same expert (over flattened [T*k] order)
    flat_ids = ids.reshape(-1)                               # [T*k]
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)    # [T*k, E]
    rank = jnp.cumsum(onehot, axis=0) - onehot               # exclusive
    rank = jnp.sum(rank * onehot, axis=-1)                   # [T*k]
    keep = rank < cap
    # dispatch [T*k, E, C]
    disp = (jax.nn.one_hot(flat_ids, E, dtype=x.dtype)[:, :, None]
            * jax.nn.one_hot(jnp.where(keep, rank, cap), cap + 1,
                             dtype=x.dtype)[:, None, :cap])
    comb = disp * gates.reshape(-1)[:, None, None].astype(x.dtype)
    x_rep = x[jnp.repeat(jnp.arange(T), k)]                  # [T*k, d]
    xe = jnp.einsum("sec,sd->ecd", disp, x_rep)              # [E,C,d]
    xe = constrain_expert(xe)
    ye = _expert_ffn(xe, we_gate, we_up, we_down)            # [E,C,d]
    ye = constrain_expert(ye)
    y = jnp.einsum("sec,ecd->sd", comb, ye)                  # [T*k, d]
    return y.reshape(T, k, d).sum(axis=1)


def constrain_expert(xe):
    from ..distributed.sharding import constrain
    return constrain(xe, "expert", None, None)


def moe_ffn(x, w_router, we_gate, we_up, we_down, *, k, n_experts,
            capacity_factor) -> jax.Array:
    """Dispatch on active sharding rules: sort-based shard_map EP for bulk
    token streams, one-hot GSPMD EP when the token dim cannot split over
    the EP axis (decode), dense oracle otherwise. x [B,S,d] -> [B,S,d].
    """
    rules = active_rules()
    B, S, d = x.shape
    if rules is not None and rules.moe_impl == "ep" and rules.ep_axis is not None:
        ep_size = rules.mesh.shape[rules.ep_axis]
        if S % ep_size == 0:
            return moe_ep(
                x, w_router, we_gate, we_up, we_down,
                k=k, n_experts=n_experts, capacity_factor=capacity_factor,
                rules=rules,
            )
        y = moe_onehot(
            x.reshape(-1, d), w_router, we_gate, we_up, we_down,
            k=k, n_experts=n_experts, capacity_factor=capacity_factor,
        )
        return y.reshape(B, S, d)
    y = moe_dense(
        x.reshape(-1, d), w_router, we_gate, we_up, we_down, k=k
    )
    return y.reshape(B, S, d)
