"""Model zoo: shared layers + block library + segment-based assembly."""
from .model import Model, Segment, build_model, plan_segments

__all__ = ["Model", "Segment", "build_model", "plan_segments"]
