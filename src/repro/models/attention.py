"""Attention for train/prefill/decode, memory-bounded and GSPMD-shardable.

Three code paths:

  * ``attention``        — train/prefill. Scans over query chunks so scores
    never materialize beyond [B, Sc, KV, G, Skv]; sliding-window attention is
    *banded* (keys dynamically sliced to window+chunk) so SWA FLOPs are
    O(S·w), not O(S²). GQA is a grouped einsum (no kv repeat).
  * ``cross_attention``  — q from text, kv from (small) image-token set.
  * ``decode_attention`` — one new token against a KV cache whose sequence
    dim is sharded over the 'model' mesh axis: the softmax max/sum and the
    PV contraction reduce over that dim, which GSPMD lowers to the
    flash-decoding collective pattern (small all-reduces), never an
    all-gather of the cache.

Shapes: q [B,S,H,hd], k/v [B,Skv,KV,hd], cache k/v [B,Smax,KV,hd].
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain

__all__ = ["attention", "cross_attention", "decode_attention"]

NEG_INF = -1e30


def _grouped_scores(q, k, scale):
    """q [B,Sq,KV,G,hd] · k [B,Sk,KV,hd] -> [B,KV,G,Sq,Sk] (fp32)."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale


def _softmax_apply(scores, v):
    """scores [B,KV,G,Sq,Sk] (masked, fp32) · v [B,Sk,KV,hd] -> [B,Sq,KV,G,hd]."""
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    n_sink: int = 0,
    q_chunk: int = 512,
    scale: Optional[float] = None,
    remat_chunk: bool = True,
) -> jax.Array:
    """Chunked attention. Returns [B,S,H,hd].

    window>0: causal sliding window (banded key slice). n_sink>0: the first
    ``n_sink`` positions are always attended (Hymba meta tokens).

    remat_chunk: checkpoint each q-chunk so the [Sc, Skv] scores/masks are
    recomputed in backward instead of being stacked as map residuals —
    without this, the stacked f32 scores + pred masks are ~70% of the
    per-chip HBM traffic of a train step (measured on the smollm-135m
    dry-run artifact; §Perf iteration 1).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)

    n_chunks = max(1, S // q_chunk)
    while S % n_chunks:
        n_chunks -= 1
    Sc = S // n_chunks

    banded = causal and window > 0 and (window + Sc) < S
    band = -(-(window + Sc) // 128) * 128 if banded else S  # key-slice length

    qs = qg.reshape(B, n_chunks, Sc, KV, G, hd).swapaxes(0, 1)  # [n,B,Sc,KV,G,hd]
    col_full = jnp.arange(S)

    def chunk(i, qc):
        row = i * Sc + jnp.arange(Sc)                      # [Sc] global rows
        if banded:
            start = jnp.clip(i * Sc + Sc - band, 0, S - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            col = start + jnp.arange(band)
        else:
            kc, vc, col = k, v, col_full
        scores = _grouped_scores(qc, kc, scale)            # [B,KV,G,Sc,Skv]
        if causal:
            ok = col[None, :] <= row[:, None]
            if window > 0:
                ok &= col[None, :] > (row[:, None] - window)
            if n_sink > 0:
                ok |= col[None, :] < n_sink
                ok &= col[None, :] <= row[:, None]
            scores = jnp.where(ok[None, None, None], scores, NEG_INF)
        if banded and n_sink > 0:
            # sink keys live outside the band: handled by caller via concat.
            pass
        out = _softmax_apply(scores, vc)                   # [B,Sc,KV,G,hd]
        return out

    if remat_chunk:
        chunk = jax.checkpoint(
            chunk, policy=jax.checkpoint_policies.nothing_saveable)
    if n_chunks == 1:
        out = chunk(jnp.int32(0), qs[0])[None]
    else:
        out = jax.lax.map(lambda xs: chunk(xs[0], xs[1]),
                          (jnp.arange(n_chunks), qs))
    out = out.swapaxes(0, 1).reshape(B, S, H, hd)
    return constrain(out, "batch", "act_seq", "heads", None)


def sink_banded_attention(
    q, k, v, *, window: int, n_sink: int, q_chunk: int = 512, scale=None
) -> jax.Array:
    """SWA + always-attend sinks, keeping the banded key slice. Computes the
    band part and the sink part separately and merges with a joint softmax
    (two-piece logsumexp), so FLOPs stay O(S·(w+sink))."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    if S <= (window + q_chunk) or n_sink == 0:
        return attention(q, k, v, causal=True, window=window, n_sink=n_sink,
                         q_chunk=q_chunk, scale=scale)
    qg = q.reshape(B, S, KV, G, hd)
    n_chunks = max(1, S // q_chunk)
    while S % n_chunks:
        n_chunks -= 1
    Sc = S // n_chunks
    band = -(-(window + Sc) // 128) * 128
    band = min(band, S)
    k_sink, v_sink = k[:, :n_sink], v[:, :n_sink]
    qs = qg.reshape(B, n_chunks, Sc, KV, G, hd).swapaxes(0, 1)

    def chunk(i, qc):
        row = i * Sc + jnp.arange(Sc)
        start = jnp.clip(i * Sc + Sc - band, 0, S - band)
        kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        col = start + jnp.arange(band)
        sb = _grouped_scores(qc, kc, scale)
        ok = (col[None, :] <= row[:, None]) & (col[None, :] > row[:, None] - window)
        # avoid double counting sink columns that fall inside the band
        ok &= col[None, :] >= n_sink
        sb = jnp.where(ok[None, None, None], sb, NEG_INF)
        ss = _grouped_scores(qc, k_sink, scale)            # [B,KV,G,Sc,n_sink]
        ok_s = (jnp.arange(n_sink)[None, :] <= row[:, None])
        ss = jnp.where(ok_s[None, None, None], ss, NEG_INF)
        joint = jnp.concatenate([ss, sb], axis=-1)
        probs = jax.nn.softmax(joint, axis=-1).astype(v.dtype)
        ps, pb = probs[..., :n_sink], probs[..., n_sink:]
        out = jnp.einsum("bkgqs,bskh->bqkgh", ps, v_sink)
        out += jnp.einsum("bkgqs,bskh->bqkgh", pb, vc)
        return out

    out = jax.lax.map(lambda xs: chunk(xs[0], xs[1]), (jnp.arange(n_chunks), qs))
    out = out.swapaxes(0, 1).reshape(B, S, H, hd)
    return constrain(out, "batch", "act_seq", "heads", None)


def cross_attention(q, k_img, v_img, *, scale=None) -> jax.Array:
    """q [B,S,H,hd] x image kv [B,I,KV,hd] (no mask, I is small)."""
    B, S, H, hd = q.shape
    KV = k_img.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, KV, G, hd)
    scores = _grouped_scores(qg, k_img, scale)
    out = _softmax_apply(scores, v_img)
    return out.reshape(B, S, H, hd)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    valid: jax.Array,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """One-token decode: q [B,1,H,hd] vs cache [B,Smax,KV,hd] (kv_seq-sharded).

    ``valid`` [Smax] bool marks live cache slots (caller encodes causal /
    ring-buffer semantics). Softmax + PV reduce over the sharded Smax dim ->
    flash-decoding collectives under GSPMD (all-reduce of max/sum), never an
    all-gather of the cache.
    """
    B, _, H, hd = q.shape
    Smax, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, 1, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache)
    return out.reshape(B, 1, H, hd)
