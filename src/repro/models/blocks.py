"""Layer blocks: parameter templates + train/prefill/decode forward paths.

Each block *kind* is a ``Block`` record with four functions sharing one
numeric core, so the smoke tests (train path) validate the same math the
serving paths use:

  template(cfg)                   -> pytree of PT
  apply(cfg, p, x, ctx)           -> x                     (train / no-cache)
  prefill(cfg, p, x, ctx)         -> (x, cache_slice)
  decode(cfg, p, x, cache, ctx)   -> (x, new_cache_slice)
  cache_template(cfg, B, ctx)     -> pytree of PT (cache shapes/axes/dtypes)

Blocks are assembled into models by ``model.py`` as *segments* (scanned
stacks of identical blocks, or single unrolled blocks where the arch is
non-uniform: Hymba's 3 global-attention layers, xLSTM's sLSTM positions).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed.sharding import constrain
from .attention import attention, cross_attention, decode_attention, sink_banded_attention
from .layers import PT, apply_rope, rms_norm, swiglu
from .mamba import MambaState, mamba_decode_mix, mamba_mix
from .moe import moe_ffn
from .ssm import (
    mlstm_chunked,
    mlstm_decode_step,
    slstm_decode_step,
    slstm_scan,
)

__all__ = ["Block", "BlockCtx", "BLOCKS", "stackify", "rope_at"]


@dataclass(frozen=True)
class BlockCtx:
    """Per-segment static + per-call dynamic context."""

    rope: Optional[Tuple[jax.Array, jax.Array]] = None  # cos/sin [S, hd/2]
    window: int = 0            # 0 = full attention
    n_sink: int = 0            # always-attended prefix (Hymba meta tokens)
    causal: bool = True
    img: Optional[jax.Array] = None     # [B, I, d] image embeddings (VLM)
    pos: Optional[jax.Array] = None     # scalar int32 decode position
    smax: int = 0              # cache capacity (decode)
    q_chunk: int = 512


@dataclass(frozen=True)
class Block:
    kind: str
    template: Callable[[ArchConfig], Any]
    apply: Callable[..., jax.Array]
    prefill: Callable[..., Tuple[jax.Array, Any]]
    decode: Callable[..., Tuple[jax.Array, Any]]
    cache_template: Callable[[ArchConfig, int, BlockCtx], Any]


def stackify(tmpl, n: int):
    """Add a leading 'stack' dim of size n to every PT in a template tree."""
    return jax.tree_util.tree_map(
        lambda t: replace(t, shape=(n,) + t.shape, axes=("stack",) + t.axes),
        tmpl,
        is_leaf=lambda x: isinstance(x, PT),
    )


def rope_at(pos: jax.Array, head_dim: int, theta: float):
    """cos/sin [1, hd/2] at a single (traced) position."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32) * freqs
    return jnp.cos(ang)[None], jnp.sin(ang)[None]


def _res_scale(cfg: ArchConfig) -> float:
    # MiniCPM depth-scaled residuals: scale_depth / sqrt(n_layers).
    return cfg.scale_depth / math.sqrt(cfg.n_layers) if cfg.scale_depth > 0 else 1.0


# ---------------------------------------------------------------------------
# attention (+ dense-FFN / MoE-FFN) block — dense, moe, encoder families
# ---------------------------------------------------------------------------

def _attn_template(cfg: ArchConfig) -> Dict[str, Any]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p: Dict[str, Any] = {
        "ln1": PT((d,), (None,), init="ones"),
        "wq": PT((d, H, hd), ("embed", "heads", None), fan_in=d),
        "wk": PT((d, KV, hd), ("embed", "kv_heads", None), fan_in=d),
        "wv": PT((d, KV, hd), ("embed", "kv_heads", None), fan_in=d),
        "wo": PT((H, hd, d), ("heads", None, "embed"), fan_in=H * hd),
        "ln2": PT((d,), (None,), init="ones"),
    }
    if cfg.qkv_bias:
        p["bq"] = PT((H, hd), ("heads", None), init="zeros")
        p["bk"] = PT((KV, hd), ("kv_heads", None), init="zeros")
        p["bv"] = PT((KV, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = PT((hd,), (None,), init="ones")
        p["k_norm"] = PT((hd,), (None,), init="ones")
    if cfg.is_moe:
        E, f = cfg.n_experts, cfg.d_ff
        p["router"] = PT((d, E), ("embed", None))
        p["we_gate"] = PT((E, d, f), ("expert", "embed", None))
        p["we_up"] = PT((E, d, f), ("expert", "embed", None))
        p["we_down"] = PT((E, f, d), ("expert", None, "embed"))
    else:
        f = cfg.d_ff
        p["wg"] = PT((d, f), ("embed", "ff"))
        p["wi"] = PT((d, f), ("embed", "ff"))
        p["wo2"] = PT((f, d), ("ff", "embed"))
    return p


def _qkv(cfg: ArchConfig, p, h, rope):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "act_seq", "heads", None)
    return q, k, v


def _ffn(cfg: ArchConfig, p, x, res):
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    h2 = constrain(h2, "batch", "act_seq", None)
    if cfg.is_moe:
        f = moe_ffn(
            h2, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            k=cfg.experts_per_token, n_experts=cfg.n_experts,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        f = swiglu(h2, p["wg"], p["wi"], p["wo2"])
    x = x + f * res
    return constrain(x, "batch", "act_seq", None)


def _attn_apply(cfg: ArchConfig, p, x, ctx: BlockCtx) -> jax.Array:
    res = _res_scale(cfg)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h = constrain(h, "batch", "act_seq", None)
    q, k, v = _qkv(cfg, p, h, ctx.rope)
    if ctx.window > 0 and ctx.n_sink > 0:
        o = sink_banded_attention(q, k, v, window=ctx.window,
                                  n_sink=ctx.n_sink, q_chunk=ctx.q_chunk)
    else:
        o = attention(q, k, v, causal=ctx.causal, window=ctx.window,
                      q_chunk=ctx.q_chunk)
    # named for selective remat: policy 'save-attn' keeps this [B,S,H,hd]
    # tensor so backward never re-runs the O(S^2) score pipeline
    from jax.ad_checkpoint import checkpoint_name
    o = checkpoint_name(o, "attn_out")
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    x = x + o * res
    return _ffn(cfg, p, x, res)


def _attn_cache_len(cfg: ArchConfig, ctx: BlockCtx) -> int:
    if ctx.window > 0:
        return ctx.n_sink + ctx.window
    return ctx.smax


def _attn_cache_template(cfg: ArchConfig, B: int, ctx: BlockCtx):
    KV, hd = cfg.n_kv_heads, cfg.hd
    W = _attn_cache_len(cfg, ctx)
    seq_ax = "kv_seq" if ctx.window == 0 else None
    spec = PT((B, W, KV, hd), ("batch", seq_ax, "kv_heads", None), init="zeros")
    return {"k": spec, "v": spec}


def _attn_prefill(cfg: ArchConfig, p, x, ctx: BlockCtx):
    """Apply + build the cache slice from this layer's K/V."""
    res = _res_scale(cfg)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h = constrain(h, "batch", "act_seq", None)
    q, k, v = _qkv(cfg, p, h, ctx.rope)
    if ctx.window > 0 and ctx.n_sink > 0:
        o = sink_banded_attention(q, k, v, window=ctx.window,
                                  n_sink=ctx.n_sink, q_chunk=ctx.q_chunk)
    else:
        o = attention(q, k, v, causal=ctx.causal, window=ctx.window,
                      q_chunk=ctx.q_chunk)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    x = _ffn(cfg, p, x + o * res, res)
    _, cache = _pack_attn_cache(cfg, k, v, ctx)
    seq_ax = "kv_seq" if ctx.window == 0 else None
    cache = {
        "k": constrain(cache["k"], "batch", seq_ax, "kv_heads", None),
        "v": constrain(cache["v"], "batch", seq_ax, "kv_heads", None),
    }
    return x, cache


def _attn_decode(cfg: ArchConfig, p, x, cache, ctx: BlockCtx):
    """x [B,1,d]; cache {k,v [B,W,KV,hd]}; ctx.pos = absolute position."""
    res = _res_scale(cfg)
    pos = ctx.pos
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    rope = rope_at(pos, cfg.hd, cfg.rope_theta) if ctx.rope is not None else None
    q, k, v = _qkv(cfg, p, h, rope)
    # decode shards the CACHE over 'model' (flash-decoding); q must keep
    # heads replicated or GSPMD all-gathers the cache slice every layer
    q = constrain(q, "batch", None, None, None)
    W = cache["k"].shape[1]
    if ctx.window == 0:
        slot = pos
        valid = jnp.arange(W) <= pos
    else:
        ns = ctx.n_sink
        slot = jnp.where(pos < ns, pos, ns + (pos - ns) % ctx.window)
        valid = (jnp.arange(W) <= pos) | (pos >= W)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    o = decode_attention(q, ck, cv, valid)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    x = x + o * res
    x = _ffn_decode(cfg, p, x, res)
    return x, {"k": ck, "v": cv}


def _ffn_decode(cfg: ArchConfig, p, x, res):
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        f = moe_ffn(
            h2, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            k=cfg.experts_per_token, n_experts=cfg.n_experts,
            capacity_factor=cfg.capacity_factor,
        )
    else:
        f = swiglu(h2, p["wg"], p["wi"], p["wo2"])
    return x + f * res


ATTN_BLOCK = Block(
    kind="attn",
    template=_attn_template,
    apply=_attn_apply,
    prefill=_attn_prefill,
    decode=_attn_decode,
    cache_template=_attn_cache_template,
)


# ---------------------------------------------------------------------------
# cross-attention block (Llama-3.2-Vision): q from text, kv from image tokens
# ---------------------------------------------------------------------------

def _cross_template(cfg: ArchConfig) -> Dict[str, Any]:
    d, H, KV, hd, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    return {
        "ln1": PT((d,), (None,), init="ones"),
        "wq": PT((d, H, hd), ("embed", "heads", None), fan_in=d),
        "wk": PT((d, KV, hd), ("embed", "kv_heads", None), fan_in=d),
        "wv": PT((d, KV, hd), ("embed", "kv_heads", None), fan_in=d),
        "wo": PT((H, hd, d), ("heads", None, "embed"), fan_in=H * hd),
        "q_norm": PT((hd,), (None,), init="ones"),
        "k_norm": PT((hd,), (None,), init="ones"),
        "gate_attn": PT((), (), init="zeros"),
        "ln2": PT((d,), (None,), init="ones"),
        "wg": PT((d, f), ("embed", "ff")),
        "wi": PT((d, f), ("embed", "ff")),
        "wo2": PT((f, d), ("ff", "embed")),
        "gate_ffn": PT((), (), init="zeros"),
    }


def _img_kv(p, img, eps):
    k = jnp.einsum("bid,dkh->bikh", img, p["wk"])
    v = jnp.einsum("bid,dkh->bikh", img, p["wv"])
    k = rms_norm(k, p["k_norm"], eps)
    return k, v


def _cross_core(cfg, p, x, k_img, v_img):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = constrain(q, "batch", "act_seq", "heads", None)
    o = cross_attention(q, k_img, v_img)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    x = x + jnp.tanh(p["gate_attn"]) * o
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    f = swiglu(h2, p["wg"], p["wi"], p["wo2"])
    x = x + jnp.tanh(p["gate_ffn"]) * f
    return constrain(x, "batch", "act_seq", None)


def _cross_apply(cfg, p, x, ctx: BlockCtx):
    k_img, v_img = _img_kv(p, ctx.img, cfg.norm_eps)
    return _cross_core(cfg, p, x, k_img, v_img)


def _cross_prefill(cfg, p, x, ctx: BlockCtx):
    k_img, v_img = _img_kv(p, ctx.img, cfg.norm_eps)
    return _cross_core(cfg, p, x, k_img, v_img), {"k": k_img, "v": v_img}


def _cross_decode(cfg, p, x, cache, ctx: BlockCtx):
    return _cross_core(cfg, p, x, cache["k"], cache["v"]), cache


def _cross_cache_template(cfg: ArchConfig, B: int, ctx: BlockCtx):
    KV, hd, I = cfg.n_kv_heads, cfg.hd, cfg.n_image_tokens
    spec = PT((B, I, KV, hd), ("batch", None, "kv_heads", None), init="zeros")
    return {"k": spec, "v": spec}


CROSS_BLOCK = Block(
    kind="cross",
    template=_cross_template,
    apply=_cross_apply,
    prefill=_cross_prefill,
    decode=_cross_decode,
    cache_template=_cross_cache_template,
)


# ---------------------------------------------------------------------------
# hybrid block (Hymba): parallel attention + Mamba heads on the same input,
# outputs normalized and fused, then dense FFN.
# ---------------------------------------------------------------------------

def _dt_rank(cfg: ArchConfig) -> int:
    return max(1, -(-cfg.d_model // 16))


def _hybrid_template(cfg: ArchConfig) -> Dict[str, Any]:
    d, H, KV, hd, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_ff
    di = cfg.ssm_expand * d
    n, K, dtr = cfg.ssm_state, cfg.ssm_conv, _dt_rank(cfg)
    return {
        "ln1": PT((d,), (None,), init="ones"),
        # attention branch
        "wq": PT((d, H, hd), ("embed", "heads", None), fan_in=d),
        "wk": PT((d, KV, hd), ("embed", "kv_heads", None), fan_in=d),
        "wv": PT((d, KV, hd), ("embed", "kv_heads", None), fan_in=d),
        "wo": PT((H, hd, d), ("heads", None, "embed"), fan_in=H * hd),
        "norm_attn": PT((d,), (None,), init="ones"),
        # mamba branch
        "w_in": PT((d, 2 * di), ("embed", "ssm_inner")),
        "conv_w": PT((di, K), ("ssm_inner", None), init="small"),
        "w_x": PT((di, dtr + 2 * n), ("ssm_inner", None)),
        "w_dt": PT((dtr, di), (None, "ssm_inner")),
        "b_dt": PT((di,), ("ssm_inner",), init="small"),
        "a_log": PT((di, n), ("ssm_inner", None), init="small"),
        "d_skip": PT((di,), ("ssm_inner",), init="ones"),
        "wo_m": PT((di, d), ("ssm_inner", "embed")),
        "norm_ssm": PT((d,), (None,), init="ones"),
        # fusion + FFN
        "ln2": PT((d,), (None,), init="ones"),
        "wg": PT((d, f), ("embed", "ff")),
        "wi": PT((d, f), ("embed", "ff")),
        "wo2": PT((f, d), ("ff", "embed")),
    }


def _hybrid_mamba(cfg, p, h, state=None, return_state=False, decode=False):
    di = cfg.ssm_expand * cfg.d_model
    xz = jnp.einsum("bsd,de->bse", h, p["w_in"])
    xz = constrain(xz, "batch", None, "ssm_inner")
    x_in, z = jnp.split(xz, 2, axis=-1)
    kw = dict(n_state=cfg.ssm_state, dt_rank=_dt_rank(cfg))
    if decode:
        y, st = mamba_decode_mix(
            x_in, z, p["conv_w"], p["w_x"], p["w_dt"], p["b_dt"],
            p["a_log"], p["d_skip"], state=state, **kw)
        out = jnp.einsum("bsd,de->bse", y, p["wo_m"])
        return out, st
    if return_state:
        y, st = mamba_mix(
            x_in, z, p["conv_w"], p["w_x"], p["w_dt"], p["b_dt"],
            p["a_log"], p["d_skip"], state=state, return_state=True, **kw)
        out = jnp.einsum("bsd,de->bse", y, p["wo_m"])
        return out, st
    y = mamba_mix(x_in, z, p["conv_w"], p["w_x"], p["w_dt"], p["b_dt"],
                  p["a_log"], p["d_skip"], state=state, **kw)
    return jnp.einsum("bsd,de->bse", y, p["wo_m"])


def _hybrid_fuse(cfg, p, x, o_attn, o_ssm):
    fused = 0.5 * (rms_norm(o_attn, p["norm_attn"], cfg.norm_eps)
                   + rms_norm(o_ssm, p["norm_ssm"], cfg.norm_eps))
    x = x + fused
    x = constrain(x, "batch", "act_seq", None)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    f = swiglu(h2, p["wg"], p["wi"], p["wo2"])
    return constrain(x + f, "batch", "act_seq", None)


def _hybrid_apply(cfg, p, x, ctx: BlockCtx):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h = constrain(h, "batch", "act_seq", None)
    q, k, v = _qkv(cfg, p, h, ctx.rope)
    if ctx.window > 0 and ctx.n_sink > 0:
        o = sink_banded_attention(q, k, v, window=ctx.window,
                                  n_sink=ctx.n_sink, q_chunk=ctx.q_chunk)
    else:
        o = attention(q, k, v, causal=True, window=ctx.window,
                      q_chunk=ctx.q_chunk)
    o_attn = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    o_ssm = _hybrid_mamba(cfg, p, h)
    return _hybrid_fuse(cfg, p, x, o_attn, o_ssm)


def _hybrid_cache_template(cfg: ArchConfig, B: int, ctx: BlockCtx):
    di = cfg.ssm_expand * cfg.d_model
    c = _attn_cache_template(cfg, B, ctx)
    c["conv"] = PT((B, di, cfg.ssm_conv - 1), ("batch", "ssm_inner", None),
                   init="zeros", dtype="float32")
    c["ssm"] = PT((B, di, cfg.ssm_state), ("batch", "ssm_inner", None),
                  init="zeros", dtype="float32")
    return c


def _hybrid_prefill(cfg, p, x, ctx: BlockCtx):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    h = constrain(h, "batch", "act_seq", None)
    q, k, v = _qkv(cfg, p, h, ctx.rope)
    if ctx.window > 0 and ctx.n_sink > 0:
        o = sink_banded_attention(q, k, v, window=ctx.window,
                                  n_sink=ctx.n_sink, q_chunk=ctx.q_chunk)
    else:
        o = attention(q, k, v, causal=True, window=ctx.window,
                      q_chunk=ctx.q_chunk)
    o_attn = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    o_ssm, st = _hybrid_mamba(cfg, p, h, return_state=True)
    xo = _hybrid_fuse(cfg, p, x, o_attn, o_ssm)

    # attention cache (same ring layout as ATTN_BLOCK.prefill)
    _, attn_cache = _pack_attn_cache(cfg, k, v, ctx)
    cache = dict(attn_cache)
    cache["conv"] = st.conv.astype(jnp.float32)
    cache["ssm"] = st.ssm.astype(jnp.float32)
    return xo, cache


def _pack_attn_cache(cfg, k, v, ctx: BlockCtx):
    B, S = k.shape[0], k.shape[1]
    W = _attn_cache_len(cfg, ctx)
    KV, hd = cfg.n_kv_heads, cfg.hd
    ck = jnp.zeros((B, W, KV, hd), k.dtype)
    cv = jnp.zeros((B, W, KV, hd), v.dtype)
    if ctx.window == 0:
        n = min(S, W)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k[:, :n], 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v[:, :n], 0, axis=1)
    else:
        ns = ctx.n_sink
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k[:, :ns], 0, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v[:, :ns], 0, axis=1)
        tail = min(ctx.window, S - ns)
        start = (S - tail - ns) % ctx.window
        idx = ns + (start + jnp.arange(tail)) % ctx.window
        ck = ck.at[:, idx].set(k[:, S - tail:])
        cv = cv.at[:, idx].set(v[:, S - tail:])
    return None, {"k": ck, "v": cv}


def _hybrid_decode(cfg, p, x, cache, ctx: BlockCtx):
    pos = ctx.pos
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    rope = rope_at(pos, cfg.hd, cfg.rope_theta) if ctx.rope is not None else None
    q, k, v = _qkv(cfg, p, h, rope)
    q = constrain(q, "batch", None, None, None)
    W = cache["k"].shape[1]
    if ctx.window == 0:
        slot = pos
        valid = jnp.arange(W) <= pos
    else:
        ns = ctx.n_sink
        slot = jnp.where(pos < ns, pos, ns + (pos - ns) % ctx.window)
        valid = (jnp.arange(W) <= pos) | (pos >= W)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    o = decode_attention(q, ck, cv, valid)
    o_attn = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    st = MambaState(conv=cache["conv"], ssm=cache["ssm"])
    o_ssm, st = _hybrid_mamba(cfg, p, h, state=st, decode=True)
    xo = _hybrid_fuse(cfg, p, x, o_attn, o_ssm)
    return xo, {"k": ck, "v": cv, "conv": st.conv, "ssm": st.ssm}


HYBRID_BLOCK = Block(
    kind="hybrid",
    template=_hybrid_template,
    apply=_hybrid_apply,
    prefill=_hybrid_prefill,
    decode=_hybrid_decode,
    cache_template=_hybrid_cache_template,
)


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM) — cell is the whole layer (no separate FFN)
# ---------------------------------------------------------------------------

def _mlstm_template(cfg: ArchConfig) -> Dict[str, Any]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "ln": PT((d,), (None,), init="ones"),
        "wq": PT((d, H, hd), ("embed", "heads", None), fan_in=d),
        "wk": PT((d, H, hd), ("embed", "heads", None), fan_in=d),
        "wv": PT((d, H, hd), ("embed", "heads", None), fan_in=d),
        "w_if": PT((d, H, 2), ("embed", "heads", None), init="small"),
        "b_if": PT((H, 2), ("heads", None), init="zeros"),
        "wz": PT((d, d), ("embed", None)),
        "norm_cell": PT((d,), (None,), init="ones"),
        "wo": PT((d, d), (None, "embed")),
    }


def _mlstm_io(cfg, p, x):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    h = constrain(h, "batch", "act_seq", None)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    gates = jnp.einsum("bsd,dhg->bshg", h, p["w_if"]) + p["b_if"]
    z = jnp.einsum("bsd,de->bse", h, p["wz"])
    return q, k, v, gates[..., 0], gates[..., 1], z


def _mlstm_out(cfg, p, x, hc, z):
    B, S = z.shape[0], z.shape[1]
    hc = rms_norm(hc.reshape(B, S, cfg.d_model), p["norm_cell"], cfg.norm_eps)
    out = hc * jax.nn.silu(z.astype(jnp.float32)).astype(hc.dtype)
    out = jnp.einsum("bsd,de->bse", out, p["wo"])
    return constrain(x + out, "batch", "act_seq", None)


def _mlstm_apply(cfg, p, x, ctx: BlockCtx):
    q, k, v, ig, fg, z = _mlstm_io(cfg, p, x)
    hc = mlstm_chunked(q, k, v, ig, fg)
    return _mlstm_out(cfg, p, x, hc, z)


def _mlstm_cache_template(cfg: ArchConfig, B: int, ctx: BlockCtx):
    H, hd = cfg.n_heads, cfg.hd
    return {
        "C": PT((B, H, hd, hd), ("batch", "heads", None, None),
                init="zeros", dtype="float32"),
        "n": PT((B, H, hd), ("batch", "heads", None),
                init="zeros", dtype="float32"),
        "m": PT((B, H), ("batch", "heads"), init="neg_inf", dtype="float32"),
    }


def _mlstm_prefill(cfg, p, x, ctx: BlockCtx):
    q, k, v, ig, fg, z = _mlstm_io(cfg, p, x)
    hc, (C, n, m) = mlstm_chunked(q, k, v, ig, fg, return_state=True)
    return _mlstm_out(cfg, p, x, hc, z), {"C": C, "n": n, "m": m}


def _mlstm_decode(cfg, p, x, cache, ctx: BlockCtx):
    q, k, v, ig, fg, z = _mlstm_io(cfg, p, x)
    hc, (C, n, m) = mlstm_decode_step(
        q, k, v, ig, fg, (cache["C"], cache["n"], cache["m"])
    )
    return _mlstm_out(cfg, p, x, hc, z), {"C": C, "n": n, "m": m}


MLSTM_BLOCK = Block(
    kind="mlstm",
    template=_mlstm_template,
    apply=_mlstm_apply,
    prefill=_mlstm_prefill,
    decode=_mlstm_decode,
    cache_template=_mlstm_cache_template,
)


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential scalar-memory cell + gated FFN
# ---------------------------------------------------------------------------

def _slstm_template(cfg: ArchConfig) -> Dict[str, Any]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    f2 = 2 * d
    return {
        "ln": PT((d,), (None,), init="ones"),
        "w_gates": PT((d, H, 4, hd), ("embed", "heads", None, None), fan_in=d),
        "b_gates": PT((H, 4, hd), ("heads", None, None), init="zeros"),
        "r_gates": PT((H, hd, 4, hd), ("heads", None, None, None), init="small"),
        "norm_cell": PT((d,), (None,), init="ones"),
        "wo": PT((d, d), (None, "embed")),
        "ln2": PT((d,), (None,), init="ones"),
        "wg": PT((d, f2), ("embed", "ff")),
        "wi": PT((d, f2), ("embed", "ff")),
        "wo2": PT((f2, d), ("ff", "embed")),
    }


def _slstm_gates(cfg, p, x):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gx = jnp.einsum("bsd,dhgk->bshgk", h, p["w_gates"]) + p["b_gates"]
    return gx


def _slstm_post(cfg, p, x, hs):
    B, S = x.shape[0], x.shape[1]
    hc = rms_norm(hs.reshape(B, S, cfg.d_model), p["norm_cell"], cfg.norm_eps)
    x = x + jnp.einsum("bsd,de->bse", hc, p["wo"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    f = swiglu(h2, p["wg"], p["wi"], p["wo2"])
    return constrain(x + f, "batch", "act_seq", None)


def _slstm_apply(cfg, p, x, ctx: BlockCtx):
    gx = _slstm_gates(cfg, p, x)
    hs, _ = slstm_scan(gx, p["r_gates"])
    return _slstm_post(cfg, p, x, hs)


def _slstm_cache_template(cfg: ArchConfig, B: int, ctx: BlockCtx):
    H, hd = cfg.n_heads, cfg.hd
    v = PT((B, H, hd), ("batch", "heads", None), init="zeros", dtype="float32")
    return {"c": v, "n": PT((B, H, hd), ("batch", "heads", None), init="ones",
                            dtype="float32"),
            "h": v, "m": PT((B, H, hd), ("batch", "heads", None),
                            init="neg_inf", dtype="float32")}


def _slstm_prefill(cfg, p, x, ctx: BlockCtx):
    gx = _slstm_gates(cfg, p, x)
    hs, (c, n, h, m) = slstm_scan(gx, p["r_gates"])
    return _slstm_post(cfg, p, x, hs), {"c": c, "n": n, "h": h, "m": m}


def _slstm_decode(cfg, p, x, cache, ctx: BlockCtx):
    gx = _slstm_gates(cfg, p, x)
    hs, (c, n, h, m) = slstm_decode_step(
        gx, p["r_gates"], (cache["c"], cache["n"], cache["h"], cache["m"])
    )
    return _slstm_post(cfg, p, x, hs), {"c": c, "n": n, "h": h, "m": m}


SLSTM_BLOCK = Block(
    kind="slstm",
    template=_slstm_template,
    apply=_slstm_apply,
    prefill=_slstm_prefill,
    decode=_slstm_decode,
    cache_template=_slstm_cache_template,
)


BLOCKS: Dict[str, Block] = {
    b.kind: b for b in (ATTN_BLOCK, CROSS_BLOCK, HYBRID_BLOCK, MLSTM_BLOCK,
                        SLSTM_BLOCK)
}
