"""Shared layer primitives + the parameter-template machinery.

Every model family declares its parameters as a tree of ``PT`` (param
template) records — one source of truth from which we derive:

  * ``init_params``   — PRNG materialization (smoke tests, examples)
  * ``abstract_params`` — ShapeDtypeStructs (dry-run, no allocation)
  * ``param_pspecs``  — PartitionSpecs from logical axes (in_shardings)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import ShardingRules, constrain

__all__ = [
    "PT",
    "init_params",
    "abstract_params",
    "param_pspecs",
    "rms_norm",
    "rope_table",
    "apply_rope",
    "swiglu",
    "cross_entropy_chunked",
]


@dataclass(frozen=True)
class PT:
    """Parameter/state template: shape + logical axes + init law (+dtype)."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | small | neg_inf
    fan_in: int = 0            # 0 -> last-but-one dim (normal init scale)
    dtype: str = ""            # "" = caller default (cache states: "float32")

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")

    def resolve_dtype(self, default):
        return jnp.dtype(self.dtype) if self.dtype else default


def _is_template(x: Any) -> bool:
    return isinstance(x, PT)


def _map_templates(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_template)


def init_params(template, key: jax.Array, dtype=jnp.bfloat16):
    leaves = [t for t in jax.tree_util.tree_leaves(tree=template, is_leaf=_is_template)]
    keys = list(jax.random.split(key, max(len(leaves), 1)))
    it = iter(keys)

    def make(t: PT):
        k = next(it)
        dt = t.resolve_dtype(dtype)
        if t.init == "zeros":
            return jnp.zeros(t.shape, dt)
        if t.init == "ones":
            return jnp.ones(t.shape, dt)
        if t.init == "neg_inf":
            return jnp.full(t.shape, -1e30, dt)
        fan = t.fan_in or (t.shape[-2] if len(t.shape) >= 2 else t.shape[-1])
        scale = 1.0 / math.sqrt(max(fan, 1))
        if t.init == "small":
            scale *= 0.1
        return (jax.random.normal(k, t.shape, jnp.float32) * scale).astype(dt)

    return _map_templates(make, template)


def abstract_params(template, dtype=jnp.bfloat16):
    return _map_templates(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.resolve_dtype(dtype)), template
    )


def param_pspecs(template, rules: ShardingRules):
    """PT -> PartitionSpec, leaving any non-divisible dim unsharded (the
    same guard ``constrain`` applies to activations)."""

    def one(t: PT):
        parts = []
        for dim, name in zip(t.shape, t.axes):
            phys = rules.table.get(name) if name is not None else None
            if phys is not None:
                n = rules.axis_size(name)
                if n <= 1 or dim % n != 0:
                    phys = None
            parts.append(phys)
        while parts and parts[-1] is None:
            parts.pop()
        from jax.sharding import PartitionSpec as P

        return P(*parts)

    return _map_templates(one, template)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def rope_table(seq_len: int, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables [seq_len, head_dim/2], float32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, H, hd]; cos/sin [S, hd/2] (broadcast over batch/heads)."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


def swiglu(x: jax.Array, wg: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    """SwiGLU MLP: (silu(x@wg) * (x@wi)) @ wo, TP-sharded on the hidden dim."""
    g = jnp.einsum("...d,df->...f", x, wg)
    u = jnp.einsum("...d,df->...f", x, wi)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    if h.ndim == 3:
        h = constrain(h, "batch", "act_seq", "ff")
    return jnp.einsum("...f,fd->...d", h, wo)


def cross_entropy_chunked(
    h: jax.Array,
    lm_head: jax.Array,
    labels: jax.Array,
    *,
    logit_scale: float = 1.0,
    n_chunks: int = 8,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Memory-bounded CE: scan over sequence chunks so [B,Sc,V] logits never
    materialize for the full sequence (V up to 152k makes full logits the
    single biggest activation otherwise). lm_head [d, V] is vocab-TP-sharded;
    the logsumexp reduces over the sharded V dim (GSPMD inserts the
    all-reduce).
    """
    B, S, d = h.shape
    while S % n_chunks:
        n_chunks -= 1
    Sc = S // n_chunks
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    # slice chunks inside the scan body (dynamic_slice reads only the
    # chunk) — the previous reshape+swapaxes materialized a transposed
    # f32 copy of the whole hidden stream (~10% of per-chip HBM traffic
    # on the vision-90b train cell; §Perf)
    def body(carry, i):
        hx = jax.lax.dynamic_slice_in_dim(h, i * Sc, Sc, axis=1)
        lx = jax.lax.dynamic_slice_in_dim(labels, i * Sc, Sc, axis=1)
        mx = jax.lax.dynamic_slice_in_dim(mask, i * Sc, Sc,
                                          axis=1).astype(jnp.float32)
        logits = jnp.einsum("bsd,dv->bsv", hx, lm_head).astype(jnp.float32)
        logits = logits * logit_scale
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: reduces over the
        # vocab-sharded dim with a partial-sum + all-reduce (no all-gather).
        onehot = jax.nn.one_hot(lx, logits.shape[-1], dtype=logits.dtype)
        tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
        nll = (lse - tgt) * mx
        return (carry[0] + nll.sum(), carry[1] + mx.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32),) * 2,
        jnp.arange(n_chunks, dtype=jnp.int32))
    return tot / jnp.maximum(cnt, 1.0)
