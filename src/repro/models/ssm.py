"""xLSTM cells: mLSTM (matrix memory, chunk-parallel) + sLSTM (scalar memory).

mLSTM training/prefill uses the **chunkwise-parallel form**: within a chunk
of length L the contribution is a masked [L, L] decay-weighted attention
matrix; across chunks a small ``lax.scan`` carries the stabilized state
(C [dk, dv], n [dk], m scalar per head). This keeps FLOPs O(S·L·d) and
memory O(B·H·L²) instead of O(S²) — the property that makes xLSTM eligible
for the ``long_500k`` shape.

All gate math is float32 and log-space stabilized (running max ``m``),
matching the xLSTM paper's numerics. Decode is the O(1) recurrent step.

Shapes: q, k [B, S, H, dk], v [B, S, H, dv], gate preacts [B, S, H].
State: C [B, H, dk, dv], n [B, H, dk], m [B, H]  (stored pre-scaled by
exp(-m), i.e. "hatted").
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "mlstm_chunked",
    "mlstm_decode_step",
    "mlstm_state_init",
    "slstm_scan",
    "slstm_decode_step",
    "slstm_state_init",
]


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


def mlstm_state_init(B: int, H: int, dk: int, dv: int, dtype=jnp.float32):
    return (
        jnp.zeros((B, H, dk, dv), dtype),
        jnp.zeros((B, H, dk), dtype),
        jnp.full((B, H), -1e30, dtype),
    )


def mlstm_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,
    f_pre: jax.Array,
    state: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
    *,
    chunk: int = 256,
    return_state: bool = False,
):
    """Chunk-parallel mLSTM. Returns h [B, S, H, dv] (and final state)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    while S % L:
        L -= 1
    Nc = S // L

    # [B,S,H,*] -> [Nc, B, H, L, *] for the chunk scan
    def to_chunks(x):
        x = x.reshape(B, Nc, L, H, -1).transpose(1, 0, 3, 2, 4)
        return x

    qf = to_chunks(q).astype(jnp.float32)
    kf = to_chunks(k).astype(jnp.float32) / jnp.sqrt(jnp.float32(dk))
    vf = to_chunks(v).astype(jnp.float32)
    lf = _logsigmoid(to_chunks(f_pre[..., None]).astype(jnp.float32))[..., 0]
    li = to_chunks(i_pre[..., None]).astype(jnp.float32)[..., 0]  # [Nc,B,H,L]

    if state is None:
        C0, n0, m0 = mlstm_state_init(B, H, dk, dv)
    else:
        C0, n0, m0 = (s.astype(jnp.float32) for s in state)

    tri = jnp.tril(jnp.ones((L, L), jnp.bool_))            # s <= t

    def chunk_step(carry, xs):
        Ch, nh, m = carry                                  # hatted state
        qc, kc, vc, lfc, lic = xs                          # [B,H,L,*]
        b = jnp.cumsum(lfc, axis=-1)                       # [B,H,L] inclusive
        btot = b[..., -1:]
        G = jax.lax.cummax(lic - b, axis=lic.ndim - 1)     # [B,H,L]
        m_t = b + jnp.maximum(m[..., None], G)             # stabilizer per t
        # intra-chunk decay matrix D[t,s] = exp(b_t - b_s + li_s - m_t), s<=t
        logD = b[..., :, None] - b[..., None, :] + lic[..., None, :] \
            - m_t[..., :, None]
        logD = jnp.where(tri, logD, -jnp.inf)
        D = jnp.exp(logD)                                  # [B,H,L,L]
        Sqk = jnp.einsum("bhtd,bhsd->bhts", qc, kc)        # [B,H,L,L]
        E = Sqk * D
        num = jnp.einsum("bhts,bhsv->bhtv", E, vc)         # intra numerator
        den = jnp.sum(E, axis=-1)                          # [B,H,L]
        # inter-chunk (carry) contribution
        a = jnp.exp(b + m[..., None] - m_t)                # [B,H,L]
        num = num + a[..., None] * jnp.einsum("bhtd,bhdv->bhtv", qc, Ch)
        den = den + a * jnp.einsum("bhtd,bhd->bht", qc, nh)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to chunk end
        m_new = btot[..., 0] + jnp.maximum(m, G[..., -1])
        g = jnp.exp(btot - b + lic - m_new[..., None])     # [B,H,L]
        decay = jnp.exp(btot[..., 0] + m - m_new)          # [B,H]
        C_new = decay[..., None, None] * Ch + jnp.einsum(
            "bhs,bhsd,bhsv->bhdv", g, kc, vc
        )
        n_new = decay[..., None] * nh + jnp.einsum("bhs,bhsd->bhd", g, kc)
        return (C_new, n_new, m_new), h

    (Cf, nf, mf), hs = jax.lax.scan(
        chunk_step, (C0, n0, m0), (qf, kf, vf, lf, li)
    )
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv).astype(v.dtype)
    if return_state:
        return h, (Cf, nf, mf)
    return h


def mlstm_decode_step(q, k, v, i_pre, f_pre, state):
    """One-token recurrent mLSTM step. q,k,v [B,1,H,d*]; gates [B,1,H]."""
    B, _, H, dk = q.shape
    Ch, nh, m = (s.astype(jnp.float32) for s in state)
    qf = q[:, 0].astype(jnp.float32)                       # [B,H,dk]
    kf = k[:, 0].astype(jnp.float32) / jnp.sqrt(jnp.float32(dk))
    vf = v[:, 0].astype(jnp.float32)
    lf = _logsigmoid(f_pre[:, 0].astype(jnp.float32))      # [B,H]
    li = i_pre[:, 0].astype(jnp.float32)
    m_new = jnp.maximum(lf + m, li)
    fs = jnp.exp(lf + m - m_new)
    is_ = jnp.exp(li - m_new)
    C_new = fs[..., None, None] * Ch + is_[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n_new = fs[..., None] * nh + is_[..., None] * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, C_new)
    den = jnp.einsum("bhd,bhd->bh", qf, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h[:, None].astype(v.dtype), (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM — scalar memory, exponential gating, strictly sequential (the paper
# notes sLSTM is not parallelizable; we scan over time).
# ---------------------------------------------------------------------------

def slstm_state_init(B: int, H: int, hd: int, dtype=jnp.float32):
    return (
        jnp.zeros((B, H, hd), dtype),   # c
        jnp.ones((B, H, hd), dtype),    # n
        jnp.zeros((B, H, hd), dtype),   # h
        jnp.full((B, H, hd), -1e30, dtype),  # m
    )


def _slstm_cell(state, gates_x, R):
    """gates_x [B,H,4,hd] (input contribution); R [H,hd,4,hd] recurrent."""
    c, n, h, m = state
    pre = gates_x + jnp.einsum("bhd,hdgk->bhgk", h, R)
    zi, fi, ii, oi = (pre[:, :, g] for g in range(4))
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    m_new = jnp.maximum(fi + m, ii)
    fs = jnp.exp(fi + m - m_new)
    is_ = jnp.exp(ii - m_new)
    c_new = fs * c + is_ * z
    n_new = fs * n + is_
    h_new = o * (c_new / jnp.maximum(n_new, 1e-9))
    return (c_new, n_new, h_new, m_new), h_new


def slstm_scan(gates_x: jax.Array, R: jax.Array, state=None):
    """gates_x [B,S,H,4,hd] -> h [B,S,H,hd] (float32 internally)."""
    B, S, H, _, hd = gates_x.shape
    if state is None:
        state = slstm_state_init(B, H, hd)
    gx = gates_x.astype(jnp.float32).transpose(1, 0, 2, 3, 4)   # [S,B,H,4,hd]
    Rf = R.astype(jnp.float32)
    state, hs = jax.lax.scan(lambda s, g: _slstm_cell(s, g, Rf), state, gx)
    return hs.transpose(1, 0, 2, 3).astype(gates_x.dtype), state


def slstm_decode_step(gates_x: jax.Array, R: jax.Array, state):
    """gates_x [B,1,H,4,hd] one step."""
    state, h = _slstm_cell(state, gates_x[:, 0].astype(jnp.float32),
                           R.astype(jnp.float32))
    return h[:, None].astype(gates_x.dtype), state
