"""Model assembly: configs -> segments -> full train/prefill/decode programs.

An architecture is a list of **segments**: a scanned stack of identical
blocks (``jax.lax.scan`` over stacked params for O(1) compile scaling) or a
single unrolled block where the arch is non-uniform:

  dense / moe / audio   [attn x L]
  vlm                   [vlm_group x G]           (nested scan: 4 self + 1 cross)
  ssm (xLSTM)           [mlstm runs] + [slstm singles] at cfg.slstm_layers
  hybrid (Hymba)        [SWA-hybrid runs] + [global-attn hybrid singles]

The same block numerics serve train, prefill and decode (kv/ssm/cell cache).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..distributed.sharding import constrain
from .blocks import BLOCKS, BlockCtx, stackify
from .layers import (
    PT,
    abstract_params,
    cross_entropy_chunked,
    init_params,
    param_pspecs,
    rms_norm,
    rope_table,
)

__all__ = ["Model", "Segment", "plan_segments", "build_model"]


@dataclass(frozen=True)
class Segment:
    kind: str                  # block kind, or "vlm_group"
    n: int                     # number of layers in this segment
    scanned: bool
    window: int = 0
    n_sink: int = 0
    causal: bool = True
    inner: int = 0             # vlm_group: self layers per group


def _runs(total: int, singles: Tuple[int, ...]):
    """Split [0, total) into (is_single, start, length) runs."""
    out = []
    i = 0
    singles = sorted(singles)
    for s in singles:
        if s > i:
            out.append((False, i, s - i))
        out.append((True, s, 1))
        i = s + 1
    if i < total:
        out.append((False, i, total - i))
    return out


def plan_segments(cfg: ArchConfig) -> List[Segment]:
    if cfg.family in ("dense", "moe"):
        return [Segment("attn", cfg.n_layers, True, window=cfg.sliding_window)]
    if cfg.family == "audio":
        return [Segment("attn", cfg.n_layers, True, causal=False)]
    if cfg.family == "vlm":
        g = cfg.n_layers // (cfg.cross_attn_every + 1)
        return [Segment("vlm_group", g, True, inner=cfg.cross_attn_every)]
    if cfg.family == "ssm":
        segs = []
        for single, start, n in _runs(cfg.n_layers, cfg.slstm_layers):
            segs.append(Segment("slstm" if single else "mlstm", n, not single))
        return segs
    if cfg.family == "hybrid":
        segs = []
        for single, start, n in _runs(cfg.n_layers, cfg.global_attn_layers):
            if single:
                segs.append(Segment("hybrid", 1, False, window=0,
                                    n_sink=0))
            else:
                segs.append(Segment("hybrid", n, True,
                                    window=cfg.sliding_window,
                                    n_sink=cfg.n_meta_tokens))
        return segs
    raise ValueError(f"unknown family {cfg.family}")


def _uses_rope(cfg: ArchConfig) -> bool:
    return cfg.family not in ("ssm", "audio")


class Model:
    """One architecture's full program set, built from its ArchConfig."""

    def __init__(self, cfg: ArchConfig, *, remat: bool = True,
                 remat_policy: str = "full", ce_chunks: int = 8,
                 q_chunk: int = 512):
        self.cfg = cfg
        self.segments = plan_segments(cfg)
        self.remat = remat
        # 'full' = nothing saveable (paper-faithful baseline);
        # 'save-attn' = keep the named attention outputs (skips the O(S^2)
        # score recompute in backward — §Perf iteration; costs
        # L*B*S*H*hd*2 bytes of HBM, use where that fits)
        self.remat_policy = remat_policy
        self.ce_chunks = ce_chunks
        self.q_chunk = q_chunk

    # ------------------------------------------------------------------
    # parameter templates
    # ------------------------------------------------------------------
    def template(self) -> Dict[str, Any]:
        cfg = self.cfg
        d = cfg.d_model
        t: Dict[str, Any] = {}
        if cfg.family == "audio":
            # frontend stub: frames arrive at d_model; learned input norm
            t["in_norm"] = PT((d,), (None,), init="ones")
        else:
            t["embed"] = PT((cfg.padded_vocab, d), (None, "embed"),
                            fan_in=d)
        if cfg.n_meta_tokens:
            t["meta"] = PT((cfg.n_meta_tokens, d), (None, None), init="small")
        segs = []
        for seg in self.segments:
            segs.append(self._seg_template(seg))
        t["segments"] = segs
        t["final_norm"] = PT((d,), (None,), init="ones")
        if not cfg.tie_embeddings:
            t["head"] = PT((d, cfg.padded_vocab), ("embed", "vocab"),
                           fan_in=d)
        return t

    def _seg_template(self, seg: Segment):
        cfg = self.cfg
        if seg.kind == "vlm_group":
            grp = {
                "self": stackify(stackify(BLOCKS["attn"].template(cfg),
                                          seg.inner), seg.n),
                "cross": stackify(BLOCKS["cross"].template(cfg), seg.n),
            }
            return grp
        tmpl = BLOCKS[seg.kind].template(cfg)
        return stackify(tmpl, seg.n) if seg.scanned else tmpl

    def init(self, key: jax.Array, dtype=jnp.bfloat16):
        return init_params(self.template(), key, dtype)

    def abstract(self, dtype=jnp.bfloat16):
        return abstract_params(self.template(), dtype)

    def pspecs(self, rules):
        return param_pspecs(self.template(), rules)

    # ------------------------------------------------------------------
    # batch templates (inputs)
    # ------------------------------------------------------------------
    def batch_template(self, shape: ShapeSpec) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = PT((B, S), ("batch", None), init="zeros", dtype="int32")
        if shape.kind == "train":
            b = {"labels": PT((B, S), ("batch", None), init="zeros",
                              dtype="int32")}
            if cfg.family == "audio":
                b["frames"] = PT((B, S, cfg.d_model), ("batch", None, None))
            else:
                b["tokens"] = tok
            if cfg.family == "vlm":
                b["images"] = PT((B, cfg.n_image_tokens, cfg.d_model),
                                 ("batch", None, None))
            return b
        if shape.kind == "prefill":
            b = {}
            if cfg.family == "audio":
                b["frames"] = PT((B, S, cfg.d_model), ("batch", None, None))
            else:
                b["tokens"] = tok
            if cfg.family == "vlm":
                b["images"] = PT((B, cfg.n_image_tokens, cfg.d_model),
                                 ("batch", None, None))
            return b
        # decode: one new token; the big inputs are the cache
        return {"tokens": PT((B, 1), ("batch", None), init="zeros",
                             dtype="int32")}

    # ------------------------------------------------------------------
    # cache templates
    # ------------------------------------------------------------------
    def cache_template(self, B: int, smax: int) -> Dict[str, Any]:
        cfg = self.cfg
        smax_tot = smax + cfg.n_meta_tokens
        segs = []
        for seg in self.segments:
            ctx = self._ctx(seg, smax=smax_tot)
            if seg.kind == "vlm_group":
                grp = {
                    "self": stackify(stackify(
                        BLOCKS["attn"].cache_template(cfg, B, ctx), seg.inner),
                        seg.n),
                    "cross": stackify(
                        BLOCKS["cross"].cache_template(cfg, B, ctx), seg.n),
                }
                segs.append(grp)
            else:
                c = BLOCKS[seg.kind].cache_template(cfg, B, ctx)
                segs.append(stackify(c, seg.n) if seg.scanned else c)
        return {"pos": PT((), (), init="zeros", dtype="int32"),
                "segments": segs}

    def abstract_cache(self, B: int, smax: int, dtype=jnp.bfloat16):
        return abstract_params(self.cache_template(B, smax), dtype)

    def init_cache(self, B: int, smax: int, dtype=jnp.bfloat16):
        # caches are all zeros/ones/neg_inf inits — key is unused
        return init_params(self.cache_template(B, smax),
                           jax.random.PRNGKey(0), dtype)

    def cache_pspecs(self, B: int, smax: int, rules):
        return param_pspecs(self.cache_template(B, smax), rules)

    # ------------------------------------------------------------------
    # forward paths
    # ------------------------------------------------------------------
    def _ctx(self, seg: Segment, rope=None, img=None, pos=None,
             smax: int = 0) -> BlockCtx:
        return BlockCtx(rope=rope, window=seg.window, n_sink=seg.n_sink,
                        causal=seg.causal, img=img, pos=pos, smax=smax,
                        q_chunk=self.q_chunk)

    def _embed(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            x = batch["frames"].astype(params["in_norm"].dtype)
            x = rms_norm(x, params["in_norm"], cfg.norm_eps)
            # fixed sinusoidal positions (frontend stub has none)
            S, d = x.shape[1], x.shape[2]
            pos = jnp.arange(S, dtype=jnp.float32)[:, None]
            div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-math.log(1e4) / d))
            pe = jnp.zeros((S, d), jnp.float32)
            pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
            pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
            x = x + pe.astype(x.dtype)[None]
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
            if cfg.scale_emb != 1.0:
                x = x * cfg.scale_emb
        if cfg.n_meta_tokens:
            B = x.shape[0]
            meta = jnp.broadcast_to(params["meta"][None],
                                    (B,) + params["meta"].shape)
            x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
        return constrain(x, "batch", "act_seq", None)

    def _rope_for(self, S: int):
        if not _uses_rope(self.cfg):
            return None
        return rope_table(S, self.cfg.hd, self.cfg.rope_theta)

    def _maybe_remat(self, fn):
        if not self.remat:
            return fn
        if self.remat_policy == "save-attn":
            policy = jax.checkpoint_policies.save_only_these_names(
                "attn_out")
        else:
            policy = jax.checkpoint_policies.nothing_saveable
        return jax.checkpoint(fn, policy=policy)

    def forward(self, params, batch, *, for_train: bool = True) -> jax.Array:
        """Embedding -> all segments -> final norm. Returns [B, S(+M), d]."""
        cfg = self.cfg
        x = self._embed(params, batch)
        S = x.shape[1]
        rope = self._rope_for(S)
        img = batch.get("images")
        if img is not None:
            img = img.astype(x.dtype)
        for seg, p in zip(self.segments, params["segments"]):
            ctx = self._ctx(seg, rope=rope, img=img)
            x = self._apply_segment(seg, p, x, ctx, remat=for_train)
        return rms_norm(x, params["final_norm"], cfg.norm_eps)

    def _apply_segment(self, seg: Segment, p, x, ctx: BlockCtx, remat: bool):
        cfg = self.cfg
        if seg.kind == "vlm_group":
            attn, cross = BLOCKS["attn"], BLOCKS["cross"]

            def group(xc, gp):
                def one(xc2, lp):
                    return attn.apply(cfg, lp, xc2, ctx), None
                body = self._maybe_remat(one) if remat else one
                xc, _ = jax.lax.scan(body, xc, gp["self"])
                xc = cross.apply(cfg, gp["cross"], xc, ctx)
                return xc, None

            gbody = self._maybe_remat(group) if remat else group
            x, _ = jax.lax.scan(gbody, x, p)
            return x
        blk = BLOCKS[seg.kind]
        if not seg.scanned:
            fn = (self._maybe_remat(lambda xc, lp: blk.apply(cfg, lp, xc, ctx))
                  if remat else (lambda xc, lp: blk.apply(cfg, lp, xc, ctx)))
            return fn(x, p)

        def body(xc, lp):
            return blk.apply(cfg, lp, xc, ctx), None

        body = self._maybe_remat(body) if remat else body
        x, _ = jax.lax.scan(body, x, p)
        return x

    # -- training loss --------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        h = self.forward(params, batch, for_train=True)
        if cfg.n_meta_tokens:
            h = h[:, cfg.n_meta_tokens:]
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        scale = (1.0 / (cfg.d_model / cfg.dim_model_base)
                 if cfg.dim_model_base else 1.0)
        return cross_entropy_chunked(h, head, batch["labels"],
                                     logit_scale=scale,
                                     n_chunks=self.ce_chunks)

    # -- serving ----------------------------------------------------------
    def _logits(self, params, h_last: jax.Array) -> jax.Array:
        cfg = self.cfg
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        scale = (1.0 / (cfg.d_model / cfg.dim_model_base)
                 if cfg.dim_model_base else 1.0)
        logits = jnp.einsum("bd,dv->bv", h_last, head).astype(jnp.float32)
        # keep logits vocab-sharded: without this constraint GSPMD chooses
        # to all-gather the (d x V) head in f32 per decode step (~200MB for
        # 150k vocabs) — found via TPU-EM replay of the compiled program
        logits = constrain(logits, "batch", "vocab")
        return logits * scale

    def prefill(self, params, batch, smax: int):
        """Process the prompt; returns (last-token logits [B,V], cache)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        S = x.shape[1]
        rope = self._rope_for(S)
        img = batch.get("images")
        if img is not None:
            img = img.astype(x.dtype)
        smax_tot = smax + cfg.n_meta_tokens
        caches = []
        for seg, p in zip(self.segments, params["segments"]):
            ctx = self._ctx(seg, rope=rope, img=img, smax=smax_tot)
            x, c = self._prefill_segment(seg, p, x, ctx)
            caches.append(c)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, h[:, -1])
        # pos counts REAL sequence tokens (meta prefix excluded); decode adds
        # the meta offset back when computing absolute cache slots.
        cache = {"pos": jnp.asarray(S - cfg.n_meta_tokens, jnp.int32),
                 "segments": caches}
        return logits, cache

    def _prefill_segment(self, seg: Segment, p, x, ctx: BlockCtx):
        cfg = self.cfg
        if seg.kind == "vlm_group":
            attn, cross = BLOCKS["attn"], BLOCKS["cross"]

            def group(xc, gp):
                def one(xc2, lp):
                    return attn.prefill(cfg, lp, xc2, ctx)
                xc, cs = jax.lax.scan(one, xc, gp["self"])
                xc, cc = cross.prefill(cfg, gp["cross"], xc, ctx)
                return xc, {"self": cs, "cross": cc}

            return jax.lax.scan(group, x, p)
        blk = BLOCKS[seg.kind]
        if not seg.scanned:
            return blk.prefill(cfg, p, x, ctx)

        def body(xc, lp):
            return blk.prefill(cfg, lp, xc, ctx)

        return jax.lax.scan(body, x, p)

    def decode_step(self, params, cache, tokens: jax.Array):
        """One decode step. tokens [B,1] -> (logits [B,V], new cache)."""
        cfg = self.cfg
        pos = cache["pos"] + cfg.n_meta_tokens  # absolute slot incl. meta
        x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.scale_emb != 1.0:
            x = x * cfg.scale_emb
        x = constrain(x, "batch", None, None)
        rope_flag = self._rope_for(1)  # non-None => blocks compute rope_at(pos)
        new_caches = []
        for seg, p, c in zip(self.segments, params["segments"],
                             cache["segments"]):
            ctx = self._ctx(seg, rope=rope_flag, pos=pos)
            x, nc = self._decode_segment(seg, p, x, c, ctx)
            new_caches.append(nc)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, h[:, 0])
        return logits, {"pos": cache["pos"] + 1, "segments": new_caches}

    def _decode_segment(self, seg: Segment, p, x, c, ctx: BlockCtx):
        cfg = self.cfg
        if seg.kind == "vlm_group":
            attn, cross = BLOCKS["attn"], BLOCKS["cross"]

            def group(xc, gpc):
                gp, gc = gpc

                def one(xc2, lpc):
                    lp, lc = lpc
                    return attn.decode(cfg, lp, xc2, lc, ctx)

                xc, cs = jax.lax.scan(one, xc, (gp["self"], gc["self"]))
                xc, cc = cross.decode(cfg, gp["cross"], xc, gc["cross"], ctx)
                return xc, {"self": cs, "cross": cc}

            return jax.lax.scan(group, x, (p, c))
        blk = BLOCKS[seg.kind]
        if not seg.scanned:
            return blk.decode(cfg, p, x, c, ctx)

        def body(xc, lpc):
            lp, lc = lpc
            return blk.decode(cfg, lp, xc, lc, ctx)

        return jax.lax.scan(body, x, (p, c))


def build_model(cfg: ArchConfig, **kw) -> Model:
    return Model(cfg, **kw)
