"""Gradient compression for the cross-pod (DCN) all-reduce.

Int8 block-quantization with **error feedback**: each step the residual
between the true gradient and its quantized form is carried into the next
step's gradient, so the compression bias vanishes in expectation (standard
EF-SGD result). On a real multi-pod deployment this wraps the inter-pod
gradient segment (the intra-pod ICI reduce-scatter stays full-precision);
TPU-EM models it as a 4x reduction of DCN collective bytes.

Numerics are validated in tests (quantization error bound, EF convergence
on a quadratic).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_compress_grads",
           "ef_init", "compression_ratio"]

BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, n


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8. Returns (q int8 [nb, BLOCK], scale f32 [nb])."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def ef_init(params) -> Dict:
    """Error-feedback residual accumulator (fp32, param-sharded)."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def ef_compress_grads(grads, ef_state):
    """g' = Q(g + e);  e' = (g + e) - g'. Applied leaf-wise."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s, g.shape, jnp.float32)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree_util.tree_map(one, grads, ef_state)
    is2 = lambda x: isinstance(x, tuple) and len(x) == 2
    new_g = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is2)
    new_e = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is2)
    return new_g, new_e


def compression_ratio(dtype=jnp.bfloat16) -> float:
    """Bytes ratio vs uncompressed (int8 payload + per-block f32 scale)."""
    raw = jnp.dtype(dtype).itemsize
    return (1.0 + 4.0 / BLOCK) / raw
