"""Training step factory: loss -> grads -> (optional EF-compressed) update.

``make_train_step(model, rules, ...)`` returns a pure ``train_step(state,
batch)`` suitable for ``jax.jit`` with ``in_shardings`` from
``state_pspecs``/``batch_pspecs`` and donated state. Supports:

  * gradient accumulation over microbatches (``lax.scan``, f32 accumulators)
  * global-norm clipping
  * int8 error-feedback gradient compression (cross-pod DCN modeling)
  * cosine / WSD schedules (MiniCPM uses WSD per its paper)
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..distributed.sharding import ShardingRules, use_rules
from ..models.layers import param_pspecs
from ..models.model import Model
from . import compress as compress_mod
from .optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule, wsd_schedule

__all__ = ["make_train_step", "init_state", "state_pspecs", "batch_pspecs",
           "schedule_for"]


def schedule_for(cfg: ArchConfig, peak_lr: float = 3e-4, warmup: int = 2000,
                 total: int = 100_000) -> Callable:
    if cfg.name.startswith("minicpm"):
        return wsd_schedule(peak_lr, warmup, total)
    return cosine_schedule(peak_lr, warmup, total)


def init_state(model: Model, key: jax.Array, *, dtype=jnp.bfloat16,
               compress: bool = False) -> Dict:
    params = model.init(key, dtype)
    state = {"params": params, "opt": adamw_init(params)}
    if compress:
        state["ef"] = compress_mod.ef_init(params)
    return state


def abstract_state(model: Model, *, dtype=jnp.bfloat16,
                   compress: bool = False) -> Dict:
    params = model.abstract(dtype)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {
        "params": params,
        "opt": {
            "m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        },
    }
    if compress:
        state["ef"] = jax.tree_util.tree_map(f32, params)
    return state


def state_pspecs(model: Model, rules: ShardingRules, *,
                 compress: bool = False) -> Dict:
    from jax.sharding import PartitionSpec as P

    ps = model.pspecs(rules)
    state = {"params": ps, "opt": {"m": ps, "v": ps, "step": P()}}
    if compress:
        state["ef"] = ps
    return state


def batch_pspecs(model: Model, shape: ShapeSpec, rules: ShardingRules):
    return param_pspecs(model.batch_template(shape), rules)


def make_train_step(
    model: Model,
    rules: Optional[ShardingRules],
    *,
    lr_schedule: Optional[Callable] = None,
    clip_norm: float = 1.0,
    weight_decay: float = 0.1,
    microbatches: int = 1,
    compress: bool = False,
) -> Callable:
    lr_schedule = lr_schedule or schedule_for(model.cfg)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        with use_rules(rules):
            params = state["params"]
            if microbatches > 1:
                def split(x):
                    return x.reshape((microbatches, x.shape[0] // microbatches)
                                     + x.shape[1:])

                mbs = jax.tree_util.tree_map(split, batch)
                acc0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def mb_step(carry, mb):
                    loss_acc, gacc = carry
                    loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                    gacc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                    return (loss_acc + loss, gacc), None

                (loss, gacc), _ = jax.lax.scan(
                    mb_step, (jnp.zeros((), jnp.float32), acc0), mbs)
                loss = loss / microbatches
                grads = jax.tree_util.tree_map(
                    lambda g, p: (g / microbatches).astype(p.dtype),
                    gacc, params)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)

            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            new_state = dict(state)
            if compress:
                grads, new_ef = compress_mod.ef_compress_grads(
                    grads, state["ef"])
                new_state["ef"] = new_ef
            lr = lr_schedule(state["opt"]["step"])
            new_params, new_opt = adamw_update(
                params, grads, state["opt"], lr,
                weight_decay=weight_decay)
            new_state["params"] = new_params
            new_state["opt"] = new_opt
            metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
            return new_state, metrics

    return train_step
