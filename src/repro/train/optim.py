"""Optimizer: AdamW with fp32 moments + LR schedules (cosine, WSD).

Moments live in float32 and inherit the parameter sharding (params are
FSDP-sharded over 'data' via the 'embed' logical axis and TP-sharded over
'model', so optimizer state is ZeRO-style sharded with no extra machinery —
GSPMD keeps the update fully local).

WSD (warmup-stable-decay) is the MiniCPM schedule: linear warmup, long
stable plateau, short exponential-ish decay tail.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "adamw_init",
    "adamw_update",
    "global_norm",
    "clip_by_global_norm",
    "cosine_schedule",
    "wsd_schedule",
]


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree
    ), norm


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params,
    grads,
    opt_state: Dict,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Dict, Dict]:
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    out = jax.tree_util.tree_map(upd, params, grads,
                                 opt_state["m"], opt_state["v"])
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_params = jax.tree_util.tree_map(lambda t3: t3[0], out, is_leaf=is3)
    new_m = jax.tree_util.tree_map(lambda t3: t3[1], out, is_leaf=is3)
    new_v = jax.tree_util.tree_map(lambda t3: t3[2], out, is_leaf=is3)
    return new_params, {"m": new_m, "v": new_v, "step": step}


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, peak_lr * cos)

    return lr


def wsd_schedule(peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor_frac: float = 0.01) -> Callable:
    """MiniCPM warmup-stable-decay: plateau at peak, exp decay tail."""
    decay_steps = max(int(total * decay_frac), 1)
    stable_end = total - decay_steps

    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        d = jnp.clip((s - stable_end) / decay_steps, 0.0, 1.0)
        tail = peak_lr * jnp.exp(jnp.log(floor_frac) * d)
        return jnp.where(s < warmup, warm,
                         jnp.where(s < stable_end, peak_lr, tail))

    return lr
