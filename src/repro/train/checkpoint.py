"""Sharded checkpointing with elastic re-mesh restore.

Format: one ``.npy`` per leaf (path-keyed), plus ``index.json`` carrying the
tree structure, dtypes, the training step and the data-pipeline cursor.
``restore`` takes the *target* sharding (mesh may differ from the one that
saved — elastic rescale): leaves are ``device_put`` with the new
NamedSharding, which is exactly the re-shard.

Fault-tolerance runbook implemented here + train driver:
  * save every N steps (async thread), keep last K
  * on restart: newest complete checkpoint wins (atomic "DONE" marker)
  * data cursor restored -> bit-identical batch stream resumes
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, state, *,
                    data_cursor: int = 0, meta: Optional[Dict] = None) -> str:
    ckpt = os.path.join(directory, f"step_{step:08d}")
    tmp = ckpt + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    index = {"step": step, "data_cursor": data_cursor,
             "meta": meta or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype == jax.numpy.bfloat16:
            arr = arr.view(np.uint16)  # np.save can't serialize ml_dtypes
        np.save(os.path.join(tmp, fname), arr)
        index["leaves"][key] = {"file": fname, "dtype": logical_dtype,
                                "shape": list(arr.shape)}
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    with open(os.path.join(tmp, "DONE"), "w") as f:
        f.write("ok")
    if os.path.exists(ckpt):
        shutil.rmtree(ckpt)
    os.rename(tmp, ckpt)
    return ckpt


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "DONE")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target_state,
                       shardings=None) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``target_state``; re-shard onto
    ``shardings`` (same tree) if given — this is the elastic re-mesh path."""
    ckpt = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt, "index.json")) as f:
        index = json.load(f)
    flat_target = _flatten(target_state)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, rec in index["leaves"].items():
        arr = np.load(os.path.join(ckpt, rec["file"]))
        if rec["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        tgt = flat_target.get(key)
        if tgt is not None and tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(
                f"checkpoint leaf {key} shape {arr.shape} != target "
                f"{tuple(tgt.shape)} — incompatible architecture")
        sh = flat_shard.get(key)
        loaded[key] = (jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
    # rebuild tree in target structure
    treedef = jax.tree_util.tree_structure(target_state)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(target_state)[0]
    ]
    leaves = [loaded[p] for p in paths]
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, index["data_cursor"], index.get("meta", {})


class CheckpointManager:
    """Async save-every-N with keep-last-K retention."""

    def __init__(self, directory: str, *, save_every: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.save_every = save_every
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, state, *, data_cursor: int = 0,
                   meta: Optional[Dict] = None) -> bool:
        if step % self.save_every:
            return False
        self.wait()
        # device_get on the main thread (jax arrays are not thread-safe to
        # donate concurrently with the train step)
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            save_checkpoint(self.directory, step, host_state,
                            data_cursor=data_cursor, meta=meta)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, "DONE"))
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
