"""Deterministic synthetic data pipeline with a checkpointable cursor.

``batch_at(step)`` is a pure function of (seed, step): after a restart the
pipeline resumes from the checkpointed step with bit-identical batches —
the fault-tolerance property the checkpoint tests assert. Shards are
device_put with the batch sharding when rules are provided.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec

__all__ = ["SyntheticData"]


class SyntheticData:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, seed: int = 0,
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.B = batch_override or shape.global_batch
        self.S = seq_override or shape.seq_len

    def batch_at(self, step: int) -> Dict[str, Any]:
        cfg = self.cfg
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S = self.B, self.S
        batch: Dict[str, Any] = {}
        if cfg.family == "audio":
            batch["frames"] = jnp.asarray(
                rng.standard_normal((B, S, cfg.d_model), np.float32))
        else:
            toks = rng.integers(0, cfg.vocab_size, (B, S + 1), dtype=np.int32)
            batch["tokens"] = jnp.asarray(toks[:, :S])
        if cfg.family == "vlm":
            batch["images"] = jnp.asarray(
                rng.standard_normal((B, cfg.n_image_tokens, cfg.d_model),
                                    np.float32).astype(np.float32))
        if self.shape.kind == "train":
            if cfg.family == "audio":
                batch["labels"] = jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32))
            else:
                batch["labels"] = jnp.asarray(toks[:, 1:])
        return batch
