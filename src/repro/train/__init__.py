"""Training substrate: optimizer, step factory, data, checkpointing."""
from .checkpoint import CheckpointManager, latest_step, restore_checkpoint, \
    save_checkpoint
from .data import SyntheticData
from .loop import abstract_state, batch_pspecs, init_state, make_train_step, \
    schedule_for, state_pspecs
from .optim import adamw_init, adamw_update, cosine_schedule, wsd_schedule

__all__ = [
    "CheckpointManager",
    "SyntheticData",
    "abstract_state",
    "adamw_init",
    "adamw_update",
    "batch_pspecs",
    "cosine_schedule",
    "init_state",
    "latest_step",
    "make_train_step",
    "restore_checkpoint",
    "save_checkpoint",
    "schedule_for",
    "state_pspecs",
    "wsd_schedule",
]
