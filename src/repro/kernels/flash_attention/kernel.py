"""Flash attention Pallas TPU kernel (online-softmax, causal, GQA).

Motivation (from the dry-run artifacts): the jnp attention path
materializes [*, Sq, Sk] scores in HBM — for smollm-135m/train_4k that is
~0.9 TB of per-chip HBM traffic per step, the dominant memory-roofline
term. This kernel keeps the running (m, l, acc) statistics in VMEM scratch
across the sequential k-block grid dimension, so score traffic never
leaves VMEM — the classic flash-attention scheme re-blocked for the MXU:
block shapes are multiples of 128 lanes, accumulation in f32.

Layout: q [BH, Sq, hd], k/v [BKV, Sk, hd] (heads flattened into batch;
GQA mapping done by the BlockSpec index maps: q-head i reads kv-head
(i % H) // G of batch i // H).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention"]

NEG = -1e30


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref,
                           acc_ref, m_ref, l_ref, *,
                           scale: float, causal: bool,
                           block_q: int, block_k: int, n_k: int):
    jq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0].astype(jnp.float32)            # [bk, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        rows = jq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = jk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows, s, NEG)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # guard: fully-masked rows keep p = 0 (not exp(0))
    p = jnp.where(s <= NEG / 2, 0.0, jnp.exp(s - m_new[:, None]))
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(jk == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,               # [BH, Sq, hd]
    k: jax.Array,               # [BKV, Sk, hd]
    v: jax.Array,
    *,
    n_q_heads_per_kv: int = 1,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    G = n_q_heads_per_kv
    assert BH == BKV * G, (BH, BKV, G)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    n_q = -(-Sq // block_q)
    n_k = -(-Sk // block_k)

    kernel = functools.partial(
        flash_attention_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, jq, jk: (i, jq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, jq, jk: (i // G, jk, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, jq, jk: (i // G, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda i, jq, jk: (i, jq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
