"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, n_q_heads_per_kv: int = 1, causal: bool = True,
                  scale: Optional[float] = None) -> jax.Array:
    """q [BH, Sq, hd], k/v [BKV, Sk, hd] -> [BH, Sq, hd] (f32 math)."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    G = n_q_heads_per_kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, vv.astype(jnp.float32)).astype(
        q.dtype)
