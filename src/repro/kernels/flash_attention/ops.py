"""Jit'd public wrapper: model-layout adapter for the flash kernel.

``flash_mha(q, k, v)`` takes the model's [B, S, H, hd] / [B, S, KV, hd]
layout, flattens heads into the batch dim, dispatches to the Pallas kernel
(interpret-mode on CPU; compiled on TPU) and restores the layout.
"""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention

__all__ = ["flash_mha"]


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, block_q: int = 128, block_k: int = 128,
              interpret: bool = True) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    # [B,S,H,hd] -> [B*H, S, hd] with q-heads grouped per kv head so the
    # kernel's i//G kv indexing lines up
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, v.shape[1], hd)
    o = flash_attention(qf, kf, vf, n_q_heads_per_kv=G, causal=causal,
                        block_q=block_q, block_k=block_k,
                        interpret=interpret)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
