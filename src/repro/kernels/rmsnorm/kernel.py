"""Fused RMSNorm Pallas kernel: one HBM round-trip instead of three.

The jnp path (square -> mean -> rsqrt -> scale) leaves 3-4 materialized
intermediates at [rows, d]; fused, the row block stays in VMEM. Row blocks
x full feature dim (d is at most 8192 = 32 KiB/row at f32 — comfortably
VMEM-resident at block_rows=256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_kernel", "fused_rmsnorm"]


def rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def fused_rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                  block_rows: int = 256, interpret: bool = True) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    grid = (-(-rows // br),)
    out = pl.pallas_call(
        functools.partial(rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
