"""Jit'd wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import fused_rmsnorm

__all__ = ["rmsnorm"]

rmsnorm = jax.jit(functools.partial(fused_rmsnorm),
                  static_argnames=("eps", "block_rows", "interpret"))
