"""Pure-jnp oracle for the SSM scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssm_scan_ref"]


def ssm_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t over axis 0; h_{-1} = 0."""

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=0)
    return h.astype(a.dtype)
