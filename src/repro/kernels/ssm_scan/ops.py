"""Jit'd wrapper for the SSM scan kernel (batched over leading dims)."""
from __future__ import annotations

import functools

import jax

from .kernel import ssm_scan

__all__ = ["ssm_scan_batched"]


@functools.partial(jax.jit, static_argnames=("block_t", "block_c",
                                             "interpret"))
def ssm_scan_batched(a: jax.Array, b: jax.Array, *, block_t: int = 128,
                     block_c: int = 512, interpret: bool = True) -> jax.Array:
    """a, b [B, S, C] (or [S, C]) -> h, scanning axis -2."""
    if a.ndim == 2:
        return ssm_scan(a, b, block_t=block_t, block_c=block_c,
                        interpret=interpret)
    B = a.shape[0]
    flat_a = a.reshape((-1,) + a.shape[-2:])
    flat_b = b.reshape((-1,) + b.shape[-2:])
    out = jax.vmap(lambda x, y: ssm_scan(x, y, block_t=block_t,
                                         block_c=block_c,
                                         interpret=interpret))(flat_a, flat_b)
    return out.reshape(a.shape)
