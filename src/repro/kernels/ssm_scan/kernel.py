"""Chunked diagonal-SSM scan Pallas kernel.

Computes h_t = a_t * h_{t-1} + b_t along time for [S, C] channel-diagonal
state (the Mamba/mLSTM-style recurrence core). The grid is
(channel blocks, time blocks) with time minor-most: TPU executes the grid
sequentially, so a VMEM scratch row carries the running state across time
blocks while each block's work is fully vectorized over channels — the
VMEM-resident re-blocking of a GPU-style scan kernel (no warp shuffles on
TPU; the systolic/vector units want [time x channel] tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssm_scan_kernel", "ssm_scan"]


def ssm_scan_kernel(a_ref, b_ref, o_ref, h_ref, *, block_t: int):
    jt = pl.program_id(1)

    @pl.when(jt == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)        # [bt, bc]
    b = b_ref[...].astype(jnp.float32)
    h0 = h_ref[...]                            # [bc]

    # within-block scan (sequential over bt, vectorized over channels);
    # bt is small (e.g. 128) so the loop unrolls into vector ops
    def step(h, ab):
        at, bt_ = ab
        h = at * h + bt_
        return h, h

    hT, hs = jax.lax.scan(step, h0, (a, b))
    o_ref[...] = hs.astype(o_ref.dtype)
    h_ref[...] = hT


def ssm_scan(a: jax.Array, b: jax.Array, *, block_t: int = 128,
             block_c: int = 512, interpret: bool = True) -> jax.Array:
    """a, b [S, C] -> h [S, C] with h_t = a_t*h_{t-1} + b_t (h_{-1} = 0)."""
    S, C = a.shape
    bt = min(block_t, S)
    bc = min(block_c, C)
    grid = (-(-C // bc), -(-S // bt))
    return pl.pallas_call(
        functools.partial(ssm_scan_kernel, block_t=bt),
        grid=grid,
        in_specs=[pl.BlockSpec((bt, bc), lambda jc, jt: (jt, jc)),
                  pl.BlockSpec((bt, bc), lambda jc, jt: (jt, jc))],
        out_specs=pl.BlockSpec((bt, bc), lambda jc, jt: (jt, jc)),
        out_shape=jax.ShapeDtypeStruct((S, C), a.dtype),
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(a, b)
