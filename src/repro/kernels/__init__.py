"""Pallas TPU kernels for the perf hot-spots the dry-run artifacts expose.

flash_attention — online-softmax attention; removes the dominant HBM
    score traffic of the jnp path (memory-roofline win for train/prefill).
rmsnorm — fused norm (one HBM round trip).
ssm_scan — chunked diagonal linear recurrence (Mamba/mLSTM core), carried
    through VMEM scratch across the sequential time grid.

Kernels target TPU (pl.pallas_call + BlockSpec); CPU validation runs them
in interpret mode against the ref.py oracles (tests/test_kernels.py sweeps
shapes and dtypes).
"""
from .flash_attention.ops import flash_mha
from .rmsnorm.kernel import fused_rmsnorm
from .ssm_scan.ops import ssm_scan_batched

__all__ = ["flash_mha", "fused_rmsnorm", "ssm_scan_batched"]
