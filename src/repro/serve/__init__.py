"""Serving substrate: prefill/decode programs + batched engine."""
from .engine import Request, ServeEngine, make_decode_fn, make_prefill_fn

__all__ = ["Request", "ServeEngine", "make_decode_fn", "make_prefill_fn"]
