"""Serving substrate.

Two halves with different import weights:

* ``serve.engine`` — the real thing: jitted prefill/decode programs and
  the batched ``ServeEngine`` (jax; the correctness reference).
* ``serve.traffic`` / ``serve.fleet`` — the modeled thing: synthetic
  arrival traces and the event-based fleet simulator that serving
  campaigns refine through ``sweep.refine`` worker processes.

The engine symbols are re-exported lazily (PEP 562): importing
``repro.serve.fleet`` from a spawn-context refinement worker must not
drag jax in (the jax-free-import contract of ``sweep.refine``).
"""
from typing import TYPE_CHECKING

__all__ = ["Request", "ServeEngine", "make_decode_fn", "make_prefill_fn"]

if TYPE_CHECKING:  # pragma: no cover - static analysis only
    from .engine import (Request, ServeEngine, make_decode_fn,
                         make_prefill_fn)


def __getattr__(name):
    if name in __all__:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
