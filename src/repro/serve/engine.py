"""Serving: prefill/decode step factories + a batched engine.

``make_prefill_fn`` / ``make_decode_fn`` produce the exact programs the
dry-run lowers for the ``prefill_32k`` / ``decode_32k`` / ``long_500k``
cells. The ``ServeEngine`` adds the operational layer a deployment needs:
request queue, continuous batching into fixed decode slots, greedy/top-k
sampling, and **straggler mitigation** — a request that exceeds its decode
deadline is evicted and re-queued (bounded retries), so one stuck stream
cannot head-of-line-block the batch.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import ShardingRules, use_rules
from ..models.model import Model

__all__ = ["make_prefill_fn", "make_decode_fn", "ServeEngine", "Request"]


def make_prefill_fn(model: Model, rules: Optional[ShardingRules],
                    smax: int) -> Callable:
    def prefill(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch, smax)

    return prefill


def make_decode_fn(model: Model, rules: Optional[ShardingRules]) -> Callable:
    def decode(params, cache, tokens):
        with use_rules(rules):
            return model.decode_step(params, cache, tokens)

    return decode


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32
    max_new: int
    generated: List[int] = field(default_factory=list)
    retries: int = 0
    deadline_steps: Optional[int] = None  # straggler budget per request
    steps_used: int = 0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


class ServeEngine:
    """Single-slot-group batched decoder (greedy sampling).

    Not a throughput-optimal server — it is the *correctness* reference for
    the serving programs plus the scheduling/straggler logic, which TPU-EM
    simulates at pod scale.
    """

    def __init__(self, model: Model, params, *, smax: int,
                 rules: Optional[ShardingRules] = None,
                 max_retries: int = 1, jit: bool = True):
        self.model = model
        self.params = params
        self.smax = smax
        self.rules = rules
        self.max_retries = max_retries
        pf, dc = make_prefill_fn(model, rules, smax), make_decode_fn(model, rules)
        self.prefill_fn = jax.jit(pf) if jit else pf
        self.decode_fn = jax.jit(dc, donate_argnums=(1,)) if jit else dc
        self.queue: Deque[Request] = deque()
        self.completed: Dict[int, Request] = {}
        self.evicted: List[int] = []
        self.evicted_partial: Dict[int, Request] = {}
        self._rid = 0

    def submit(self, prompt: np.ndarray, max_new: int = 16,
               deadline_steps: Optional[int] = None) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, np.asarray(prompt, np.int32),
                                  max_new, deadline_steps=deadline_steps))
        return self._rid

    def _prefill_batch(self, reqs: List[Request]):
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad (simple)
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self.prefill_fn(self.params, batch)
        return logits, cache

    def run(self, batch_size: int = 4) -> Dict[int, List[int]]:
        """Drain the queue; returns {rid: generated tokens}.

        Permanently-evicted stragglers (retry budget exhausted) keep
        their rid in ``self.evicted`` AND contribute whatever they
        generated to the returned mapping — a stalled stream's partial
        output is still an answer the caller paid for.
        """
        while self.queue:
            reqs = [self.queue.popleft() for _ in
                    range(min(batch_size, len(self.queue)))]
            logits, cache = self._prefill_batch(reqs)
            next_tok = np.asarray(jnp.argmax(logits, -1), np.int32)
            live = list(range(len(reqs)))
            while live:
                for i in list(live):
                    r = reqs[i]
                    r.generated.append(int(next_tok[i]))
                    r.steps_used += 1
                    if r.done:
                        live.remove(i)
                        self.completed[r.rid] = r
                    elif (r.deadline_steps is not None
                          and r.steps_used >= r.deadline_steps):
                        # straggler: evict; re-queue with remaining budget
                        live.remove(i)
                        if r.retries < self.max_retries:
                            r.retries += 1
                            r.steps_used = 0
                            self.queue.append(r)
                        else:
                            self.evicted.append(r.rid)
                            self.evicted_partial[r.rid] = r
                if not live:
                    break
                logits, cache = self.decode_fn(
                    self.params, cache, jnp.asarray(next_tok)[:, None])
                next_tok = np.asarray(jnp.argmax(logits, -1), np.int32)
        out = {rid: r.generated for rid, r in self.completed.items()}
        out.update({rid: r.generated
                    for rid, r in self.evicted_partial.items()})
        return out
