"""Event-based serving-fleet simulator over the modeled pod.

The real ``serve.engine.ServeEngine`` runs actual jax decode steps and
stays the correctness reference; it cannot answer fleet questions
("what pod shape serves 40 req/s within SLO cheapest?") because one
request-level simulation at that fidelity costs minutes. This module
answers them by splitting the problem:

* **Step costs** come from the analytic model stack: one transformer
  layer body + model head per (phase, batch-bucket, context-bucket) are
  compiled (``graph.compiler``), lowered to arrays and list-scheduled
  (``core.fastsim.lower`` / ``list_schedule``), and scaled in closed
  form — ``step = layers x body + head`` — exactly the layer-
  replication contract the sweep pre-screen and the fast engine's
  steady-state extrapolation rely on. Buckets are powers of two, so a
  whole campaign cell touches a handful of compiles no matter how many
  requests flow through it.
* **Request dynamics** are a discrete-event loop per replica:
  continuous batching (new prefills interleaved into the in-flight
  decode batch each iteration, vLLM-style) or static batching (admit a
  batch, drain it to completion, repeat), over a fixed number of KV
  slots with admission control and mid-decode eviction when a sequence
  outgrows the KV budget. Requests are assigned to the ``dp`` fleet
  replicas round-robin at arrival.

With ~µs-scale Python bookkeeping per step, 100k+ requests per cell
simulate in seconds — cheap enough to grid arrival rate x batch policy
x pod shape like any other campaign axis.

Accounting per request: TTFT (first token latency, >= queue wait by
construction) and TPOT (steady decode interval). A cell rolls up into
one SLO record: TTFT/TPOT p50/p95/p99, goodput (completed requests
meeting both SLO bounds per second), slot occupancy, and fleet energy
— per-engine-class busy fractions feed ``power.powerem.pod_power_w``,
the same characterized power tree every other record uses.

Determinism contract: everything here is pure float math over a trace
that regenerates from its payload-embedded spec (``serve.traffic``), so
serve records are byte-identical across the inline/pool/spool backends
— the ``tests/test_golden.py`` contract. No jax anywhere on this import
path: ``sweep.refine`` dispatches serve payloads here from spawn-
context worker processes.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.fastsim import lower, list_schedule
from ..graph.compiler import CompileOptions, compile_ops
from ..graph.workloads import lm_workload_name, model_parts
from ..hw.presets import HwConfig, from_dict
from ..obs.metrics import REGISTRY
from ..power.powerem import pod_power_w
from .traffic import TraceRequest, make_trace

__all__ = ["StepCost", "ServeCostModel", "FleetParams", "FleetResult",
           "simulate_fleet", "simulate_serve_point", "serve_payload",
           "fleet_from_payload", "POLICIES", "SERVE_SCHEMA_VERSION"]

POLICIES = ("static", "continuous")
# bumped when serve-record semantics change: lives in the payload, so
# the result cache never serves a record computed under old semantics
# (v2: queue-depth-at-admission + queue-wait percentiles in records)
SERVE_SCHEMA_VERSION = 2

_PCTS = (50.0, 95.0, 99.0)
# per-step histogram bounds: batch/queue sizes are power-of-two-ish,
# occupancy is a fraction of the slot budget
_BATCH_BOUNDS = tuple(float(1 << i) for i in range(11))
_OCC_BOUNDS = (0.125, 0.25, 0.5, 0.75, 0.9, 1.0)


def _bucket(n: int) -> int:
    """Smallest power of two >= n (>=1): the step-cost quantization."""
    return 1 << max(int(n) - 1, 0).bit_length()


# ---------------------------------------------------------------------------
# step-cost model


@dataclass(frozen=True)
class StepCost:
    """One fleet iteration's cost: wall time + per-engine-class busy
    time (per engine unit, for utilization/power rollup)."""

    ns: float
    busy: Dict[str, float]        # mxu|vpu|dma|ici -> busy ns per unit


def _class_of(engine: str) -> Optional[str]:
    """Task-engine name -> engine class (mirrors the compiler's naming:
    ``tile<t>.mxu`` / ``tile<t>.vpu`` / ``dma`` / ``ici``)."""
    if engine.endswith(".mxu"):
        return "mxu"
    if engine.endswith(".vpu"):
        return "vpu"
    if engine in ("dma", "ici"):
        return engine
    return None


class ServeCostModel:
    """Analytic per-step costs for one serving replica.

    ``prefill_cost(batch, prompt)`` / ``decode_cost(batch, kv)`` compile
    lazily per (phase, bucketed batch, bucketed context) and memoize —
    the simulator calls them every iteration, the lattice stays tiny.
    The fleet simulator only duck-types these two methods, so tests
    drive ``simulate_fleet`` with synthetic constant-cost stubs.
    """

    def __init__(self, cfg: HwConfig, *, arch: str, layers: int,
                 tp: int = 1, ep: int = 1, pod: int = 0, n_tiles: int = 1,
                 compile_opts: Optional[Dict[str, Any]] = None):
        if layers < 1:
            raise ValueError(f"need layers >= 1, got {layers}")
        self.cfg = cfg
        self.arch = arch
        self.layers = layers
        self.tp = tp
        self.ep = ep
        self.pod = pod
        self.n_tiles = n_tiles
        self.compile_opts = dict(compile_opts or {})
        self._memo: Dict[Tuple[str, int, int], StepCost] = {}

    def _part_cost(self, ops) -> Tuple[float, Dict[str, float]]:
        cw = compile_ops(ops, self.cfg,
                         CompileOptions(n_tiles=self.n_tiles,
                                        **self.compile_opts))
        table = lower(cw, self.cfg)
        _, _, makespan = list_schedule(table)
        busy = {"mxu": 0.0, "vpu": 0.0, "dma": 0.0, "ici": 0.0}
        units = {"mxu": 0, "vpu": 0, "dma": 0, "ici": 0}
        for name in table.engines:
            c = _class_of(name)
            if c:
                units[c] += 1
        for eid, name in enumerate(table.engines):
            c = _class_of(name)
            if c:
                busy[c] += float(
                    table.duration[table.engine_id == eid].sum())
        for c in busy:
            busy[c] /= max(units[c], 1)
        return makespan, busy

    def _cost(self, phase: str, batch: int, ctx: int) -> StepCost:
        key = (phase, batch, ctx)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        name = lm_workload_name(
            self.arch, seq=ctx if phase == "prefill" else 0, batch=batch,
            tp=self.tp, phase=phase,
            kv_len=ctx if phase == "decode" else 0, ep=self.ep,
            layers=self.layers, dp=1, pod=self.pod)
        parts = model_parts(name)
        body_ns, body_busy = self._part_cost(parts.body())
        head_ns, head_busy = self._part_cost(parts.head())
        ns = self.layers * body_ns + head_ns
        busy = {c: self.layers * body_busy[c] + head_busy[c]
                for c in body_busy}
        cost = StepCost(ns=ns, busy=busy)
        self._memo[key] = cost
        return cost

    def prefill_cost(self, batch: int, prompt: int) -> StepCost:
        return self._cost("prefill", _bucket(batch), _bucket(prompt))

    def decode_cost(self, batch: int, kv: int) -> StepCost:
        return self._cost("decode", _bucket(batch), _bucket(kv))


# ---------------------------------------------------------------------------
# fleet event loop


@dataclass(frozen=True)
class FleetParams:
    """Serving-policy knobs of one fleet cell."""

    replicas: int = 1             # dp: independent model replicas
    slots: int = 8                # concurrent sequences per replica
    kv_capacity: int = 4096       # max prompt+generated tokens per slot
    policy: str = "continuous"    # static | continuous
    max_queue: int = 0            # reject beyond this backlog (0 = inf)

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {self.policy!r}")
        if self.replicas < 1 or self.slots < 1 or self.kv_capacity < 2:
            raise ValueError(f"bad fleet shape: replicas={self.replicas} "
                             f"slots={self.slots} "
                             f"kv_capacity={self.kv_capacity}")


@dataclass
class _Req:
    arrival_ns: float
    prompt: int
    max_new: int
    admit_ns: float = -1.0        # leaves the queue, takes a slot
    first_ns: float = -1.0        # first token lands (end of its step)
    done_ns: float = -1.0
    tokens: int = 0               # generated so far
    status: str = "queued"        # queued|active|done|evicted|rejected
    admit_depth: int = -1         # queue backlog left behind at admission
    replica: int = 0              # which dp replica served it


@dataclass
class FleetResult:
    """Per-request detail + per-replica aggregates of one simulation.

    ``record()`` flattens this into the JSON-safe SLO record that lands
    in campaign results; tests assert on the detail arrays directly.
    """

    requests: List[_Req]
    duration_ns: float            # fleet makespan (max over replicas)
    steps: int
    slot_ns: float                # sum over steps of occupied x step
    capacity_ns: float            # slots x per-replica duration, summed
    max_active: int               # peak concurrent sequences (1 replica)
    busy: Dict[str, float]        # engine-class busy ns, fleet total
    detail: Dict[str, Any] = field(default_factory=dict)

    def record(self, *, slo_ttft_ms: float,
               slo_tpot_ms: float) -> Dict[str, Any]:
        done = [r for r in self.requests if r.status == "done"]
        evicted = [r for r in self.requests if r.status == "evicted"]
        rejected = [r for r in self.requests if r.status == "rejected"]
        served = done + evicted       # got at least one token
        ttft = np.array([r.first_ns - r.arrival_ns
                         for r in served]) / 1e6
        tpot = np.array([(r.done_ns - r.first_ns) / (r.tokens - 1)
                         for r in served if r.tokens > 1]) / 1e6
        dur_s = self.duration_ns / 1e9
        arr_span = max(r.arrival_ns for r in self.requests) / 1e9
        good = [r for r in done
                if (r.first_ns - r.arrival_ns) / 1e6 <= slo_ttft_ms
                and (r.tokens < 2 or (r.done_ns - r.first_ns)
                     / (r.tokens - 1) / 1e6 <= slo_tpot_ms)]
        rec: Dict[str, Any] = {
            "requests": len(self.requests),
            "completed": len(done),
            "evicted": len(evicted),
            "rejected": len(rejected),
            "tokens_out": sum(r.tokens for r in self.requests),
            "duration_s": dur_s,
            "steps": self.steps,
            "max_active": self.max_active,
            "slot_occupancy": (self.slot_ns / self.capacity_ns
                               if self.capacity_ns > 0 else 0.0),
            "offered_rps": (len(self.requests) / arr_span
                            if arr_span > 0 else 0.0),
            "throughput_rps": len(done) / dur_s if dur_s > 0 else 0.0,
            "goodput_rps": len(good) / dur_s if dur_s > 0 else 0.0,
            "slo_attainment": (len(good) / len(self.requests)
                               if self.requests else 0.0),
        }
        # admission detail (serve schema v2): how deep the backlog ran
        # and how long requests queued before taking a slot
        admitted = [r for r in self.requests if r.admit_ns >= 0]
        depth = np.array([r.admit_depth for r in admitted], np.float64)
        qwait = np.array([r.admit_ns - r.arrival_ns
                          for r in admitted]) / 1e6
        for tag, arr in (("ttft", ttft), ("tpot", tpot),
                         ("admit_depth", depth), ("queue_wait", qwait)):
            unit = "" if tag == "admit_depth" else "_ms"
            for p, v in zip(_PCTS, np.percentile(arr, _PCTS)
                            if len(arr) else (0.0,) * len(_PCTS)):
                rec[f"{tag}_p{p:.0f}{unit}"] = float(v)
        return rec


def _drain(batch: List[_Req], t_end: float, kv_capacity: int,
           completed_into: List[_Req]) -> List[_Req]:
    """Post-step bookkeeping: finish / evict / keep each sequence."""
    live: List[_Req] = []
    for r in batch:
        if r.tokens >= r.max_new:
            r.status, r.done_ns = "done", t_end
        elif r.prompt + r.tokens >= kv_capacity:
            # out of KV budget mid-decode: slot freed, partial output
            # surfaces in the record (mirrors ServeEngine's eviction)
            r.status, r.done_ns = "evicted", t_end
        else:
            live.append(r)
            continue
        completed_into.append(r)
    return live


def _run_replica(reqs: List[_Req], costs, p: FleetParams,
                 busy: Dict[str, float], *, rep: int = 0,
                 timeline: Optional[List[Dict[str, Any]]] = None
                 ) -> Tuple[float, int, float, int]:
    """Simulate one replica over its (arrival-ordered) request stream.

    Returns ``(end_ns, steps, slot_ns, max_active)`` and accumulates
    engine-class busy time into ``busy``. Continuous policy admits into
    free slots every iteration (one fused prefill+decode step);
    static policy drains each admitted batch to completion first.
    When ``timeline`` is given, every step appends one dict (replica,
    window, batch composition, queue depth, resident KV tokens) — the
    Perfetto exporter's counter-track source.
    """
    queue: deque = deque()
    active: List[_Req] = []
    finished: List[_Req] = []
    t = 0.0
    i = 0
    steps = 0
    slot_ns = 0.0
    max_active = 0
    n = len(reqs)
    # hoisted per-step instruments: zero hot-loop cost while disabled
    reg = REGISTRY if REGISTRY.enabled else None
    if reg is not None:
        h_batch = reg.histogram("serve.batch_size",
                                bounds=_BATCH_BOUNDS, replica=str(rep))
        h_queue = reg.histogram("serve.queue_depth",
                                bounds=_BATCH_BOUNDS, replica=str(rep))
        h_occ = reg.histogram("serve.slot_occupancy",
                              bounds=_OCC_BOUNDS, replica=str(rep))

    def pull(now: float) -> None:
        nonlocal i
        while i < n and reqs[i].arrival_ns <= now:
            r = reqs[i]
            i += 1
            if r.prompt + 1 > p.kv_capacity or \
                    (p.max_queue and len(queue) >= p.max_queue):
                r.status = "rejected"
            else:
                queue.append(r)

    def step(admitted: List[_Req], decoding: List[_Req]) -> None:
        nonlocal t, steps, slot_ns, max_active, active
        cost = 0.0
        if admitted:
            c = costs.prefill_cost(len(admitted),
                                   max(r.prompt for r in admitted))
            cost += c.ns
            for k, v in c.busy.items():
                busy[k] += v
        if decoding:
            c = costs.decode_cost(len(decoding),
                                  max(r.prompt + r.tokens
                                      for r in decoding))
            cost += c.ns
            for k, v in c.busy.items():
                busy[k] += v
        t_end = t + cost
        steps += 1
        occ = len(admitted) + len(decoding)
        slot_ns += occ * cost
        max_active = max(max_active, occ)
        if reg is not None:
            h_batch.observe(occ)
            h_queue.observe(len(queue))
            h_occ.observe(occ / p.slots)
        if timeline is not None:
            timeline.append({
                "replica": rep, "t0": t, "t1": t_end,
                "prefill": len(admitted), "decode": len(decoding),
                "queue": len(queue),
                "kv_tokens": sum(r.prompt + r.tokens
                                 for r in admitted + decoding)})
        for r in admitted:
            r.status = "active"
            r.first_ns = t_end
            r.tokens = 1
        for r in decoding:
            r.tokens += 1
        active = _drain(decoding + admitted, t_end, p.kv_capacity,
                        finished)
        t = t_end

    while i < n or queue or active:
        pull(t)
        if not active and not queue:
            t = reqs[i].arrival_ns     # idle: jump to the next arrival
            continue
        admitted: List[_Req] = []
        while queue and len(active) + len(admitted) < p.slots:
            r = queue.popleft()
            r.admit_ns = t
            r.admit_depth = len(queue)   # backlog left behind
            admitted.append(r)
        if p.policy == "continuous":
            step(admitted, active)
        else:
            # static: prefill the batch, then decode it dry — no
            # admissions until every sequence finishes
            step(admitted, [])
            while active:
                step([], active)
    return t, steps, slot_ns, max_active


def simulate_fleet(trace: Sequence[TraceRequest], costs,
                   p: FleetParams, *,
                   timeline: Optional[List[Dict[str, Any]]] = None
                   ) -> FleetResult:
    """Run a trace through ``p.replicas`` round-robin-balanced replicas.

    ``costs`` duck-types ``prefill_cost(batch, prompt)`` /
    ``decode_cost(batch, kv)`` -> ``StepCost``. Pass a list as
    ``timeline`` to capture one entry per fleet step (see
    ``_run_replica``) for the Perfetto exporter.
    """
    if not trace:
        raise ValueError("empty trace")
    reqs = [_Req(r.arrival_ns, r.prompt_tokens, r.max_new) for r in trace]
    busy: Dict[str, float] = {"mxu": 0.0, "vpu": 0.0, "dma": 0.0,
                              "ici": 0.0}
    duration = 0.0
    steps = 0
    slot_ns = 0.0
    capacity_ns = 0.0
    max_active = 0
    for rep in range(p.replicas):
        shard = reqs[rep::p.replicas]
        if not shard:
            continue
        for r in shard:
            r.replica = rep
        end, st, sn, ma = _run_replica(shard, costs, p, busy, rep=rep,
                                       timeline=timeline)
        duration = max(duration, end)
        steps += st
        slot_ns += sn
        max_active = max(max_active, ma)
    capacity_ns = p.replicas * p.slots * duration
    res = FleetResult(requests=reqs, duration_ns=duration, steps=steps,
                      slot_ns=slot_ns, capacity_ns=capacity_ns,
                      max_active=max_active, busy=busy)
    if REGISTRY.enabled:
        by_status: Dict[str, int] = {}
        for r in reqs:
            by_status[r.status] = by_status.get(r.status, 0) + 1
        for status, cnt in sorted(by_status.items()):
            REGISTRY.counter("serve.requests", status=status).inc(cnt)
        REGISTRY.counter("serve.admissions").inc(
            sum(1 for r in reqs if r.admit_ns >= 0))
        REGISTRY.counter("serve.steps").inc(steps)
        REGISTRY.gauge("serve.max_active").set_max(max_active)
    return res


# ---------------------------------------------------------------------------
# campaign payload plumbing (the `kind: "serve"` refinement family)


def serve_payload(*, workload: str, arch: str, layers: int, prompt: int,
                  max_new: int, tp: int, ep: int, dp: int, pod: int,
                  slots: int, kv_capacity: int, policy: str,
                  traffic: Dict[str, Any], slo: Dict[str, float],
                  n_tiles: int, hw: Dict[str, Any], temp_c: float,
                  max_queue: int = 0,
                  compile_opts: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
    """The cache-keyed, process-picklable input of one serve cell.

    ``kind: "serve"`` is what ``sweep.refine.refine_point`` dispatches
    on, so these payloads flow through the inline/pool/spool backends,
    the result cache, and the journal exactly like classic refinement
    payloads."""
    return {"kind": "serve", "serve_schema": SERVE_SCHEMA_VERSION,
            "workload": workload, "arch": arch, "layers": layers,
            "prompt": prompt, "max_new": max_new, "tp": tp, "ep": ep,
            "dp": dp, "pod": pod, "slots": slots,
            "kv_capacity": kv_capacity, "policy": policy,
            "max_queue": max_queue, "traffic": dict(traffic),
            "slo": dict(slo), "n_tiles": n_tiles, "hw": hw,
            "temp_c": temp_c, "compile_opts": dict(compile_opts or {})}


def fleet_from_payload(payload: Dict[str, Any], *,
                       timeline: Optional[List[Dict[str, Any]]] = None
                       ) -> Tuple[FleetResult, FleetParams,
                                  ServeCostModel]:
    """Rebuild a serve cell from its payload and run the fleet loop.

    Shared by ``simulate_serve_point`` (records) and the Perfetto
    exporter (request-lifecycle spans + per-step counter tracks)."""
    cfg = from_dict(payload["hw"])
    trace = make_trace(payload["traffic"],
                       prompt_tokens=payload["prompt"],
                       max_new=payload["max_new"])
    costs = ServeCostModel(cfg, arch=payload["arch"],
                           layers=payload["layers"], tp=payload["tp"],
                           ep=payload["ep"], pod=payload["pod"],
                           n_tiles=payload["n_tiles"],
                           compile_opts=payload["compile_opts"])
    p = FleetParams(replicas=payload["dp"], slots=payload["slots"],
                    kv_capacity=payload["kv_capacity"],
                    policy=payload["policy"],
                    max_queue=payload.get("max_queue", 0))
    res = simulate_fleet(trace, costs, p, timeline=timeline)
    return res, p, costs


def simulate_serve_point(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Simulate one serve cell end to end: regenerate the trace, build
    the cost model, run the fleet, roll up the SLO record + fleet power.
    """
    cfg = from_dict(payload["hw"])
    res, p, costs = fleet_from_payload(payload)
    slo = payload["slo"]
    rec = res.record(slo_ttft_ms=slo["ttft_ms"],
                     slo_tpot_ms=slo["tpot_ms"])
    # fleet power: per-class busy fractions (fleet-total busy over
    # replicas x duration) through the characterized power tree, scaled
    # to every chip of the fleet (symmetric SPMD replicas)
    chips = payload["dp"] * payload["tp"] * payload["ep"]
    denom = max(p.replicas * res.duration_ns, 1e-9)
    util = {c: min(b / denom, 1.0) for c, b in res.busy.items()}
    fam_util = {"mxu": util["mxu"], "vpu": util["vpu"],
                "vmem": max(util["mxu"], util["vpu"]),
                "hbm": util["dma"], "dma": util["dma"],
                "ici": util["ici"], "noc": util["ici"]}
    avg_w = pod_power_w(cfg, fam_util, chips=chips,
                        n_tiles=payload["n_tiles"],
                        freq_ghz=cfg.clock_ghz, temp_c=payload["temp_c"])
    energy = avg_w * rec["duration_s"]
    rec.update({
        "serve": True,
        "chips": chips,
        "avg_w": avg_w,
        "energy_j": energy,
        "energy_per_req_j": (energy / rec["completed"]
                             if rec["completed"] else 0.0),
        "prefill_step_ns": costs.prefill_cost(
            payload["slots"], payload["prompt"]).ns,
        "decode_step_ns": costs.decode_cost(
            payload["slots"], payload["prompt"] + payload["max_new"]).ns,
    })
    return rec
