"""Synthetic request-arrival traces for serving-fleet campaigns.

Three sources, all producing the same flat ``TraceRequest`` stream:

* ``poisson_trace``  — memoryless open-loop traffic: exponential
  inter-arrival gaps at a constant offered rate.
* ``bursty_trace``   — a two-state Markov-modulated Poisson process
  (MMPP-2): the generator alternates between a *calm* and a *burst*
  regime (exponentially distributed dwell times); the burst regime
  offers ``burst_x`` times the calm rate while the long-run mean rate
  stays exactly ``rate_rps``. This is the diurnal-spike/retry-storm
  shape that separates continuous batching from static batching.
* ``load_trace_jsonl`` — replay a recorded trace (one JSON object per
  line) so real production arrival processes can drive the simulator.

Determinism contract: traces are pure functions of their parameters.
Randomness only ever flows through ``Generator.random()`` (raw PCG64
uniforms mapped through explicit inverse CDFs) — numpy guarantees that
stream bit-for-bit across versions, unlike the distribution helpers —
so a trace spec embedded in a refinement payload regenerates the exact
same trace on every backend and host, keeping serving campaign records
byte-identical (the ``tests/test_golden.py`` cross-backend contract).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any, Dict, List

import numpy as np

__all__ = ["TraceRequest", "poisson_trace", "bursty_trace",
           "load_trace_jsonl", "make_trace", "TRAFFIC_KINDS"]

TRAFFIC_KINDS = ("poisson", "bursty", "jsonl")


@dataclass(frozen=True)
class TraceRequest:
    """One request of an arrival trace (times in ns from trace start)."""

    arrival_ns: float
    prompt_tokens: int
    max_new: int


def _exp(rng: np.random.Generator, scale: float, n: int) -> np.ndarray:
    """Exponential draws via inverse CDF over raw uniforms (stable
    stream: ``Generator.random`` only)."""
    return -np.log1p(-rng.random(n)) * scale


def poisson_trace(*, rate_rps: float, n_requests: int, seed: int,
                  prompt_tokens: int, max_new: int) -> List[TraceRequest]:
    """Open-loop Poisson arrivals at ``rate_rps`` requests/second."""
    if rate_rps <= 0 or n_requests < 1:
        raise ValueError(f"need rate_rps > 0 and n_requests >= 1, got "
                         f"rate_rps={rate_rps}, n_requests={n_requests}")
    rng = np.random.default_rng(seed)
    gaps_ns = _exp(rng, 1e9 / rate_rps, n_requests)
    arrivals = np.cumsum(gaps_ns)
    return [TraceRequest(float(t), prompt_tokens, max_new)
            for t in arrivals]


def bursty_trace(*, rate_rps: float, n_requests: int, seed: int,
                 prompt_tokens: int, max_new: int, burst_x: float = 4.0,
                 dwell_s: float = 2.0) -> List[TraceRequest]:
    """MMPP-2 arrivals: calm/burst regimes with exponential dwell times.

    The two regimes spend equal expected time (``dwell_s`` each), the
    burst regime arrives ``burst_x`` times faster than the calm one,
    and the rates are normalized so the long-run offered rate is
    ``rate_rps``: ``calm = 2 * rate / (1 + burst_x)``.
    """
    if burst_x < 1.0:
        raise ValueError(f"burst_x must be >= 1, got {burst_x}")
    if rate_rps <= 0 or n_requests < 1 or dwell_s <= 0:
        raise ValueError(f"bad bursty-trace parameters: rate_rps="
                         f"{rate_rps}, n_requests={n_requests}, "
                         f"dwell_s={dwell_s}")
    rng = np.random.default_rng(seed)
    calm_rps = 2.0 * rate_rps / (1.0 + burst_x)
    rates = (calm_rps, calm_rps * burst_x)
    out: List[TraceRequest] = []
    t_ns = 0.0
    regime = 0                       # start calm; dwell draw flips it
    while len(out) < n_requests:
        dwell_ns = float(_exp(rng, dwell_s * 1e9, 1)[0])
        regime_end = t_ns + dwell_ns
        scale_ns = 1e9 / rates[regime]
        while len(out) < n_requests:
            t_next = t_ns + float(_exp(rng, scale_ns, 1)[0])
            if t_next > regime_end:
                break                # arrival falls in the next regime
            t_ns = t_next
            out.append(TraceRequest(t_ns, prompt_tokens, max_new))
        t_ns = regime_end
        regime = 1 - regime
    return out


def load_trace_jsonl(path: str) -> List[TraceRequest]:
    """Load a recorded trace: one JSON object per line with
    ``arrival_s`` (or ``arrival_ns``), ``prompt_tokens``, ``max_new``."""
    out: List[TraceRequest] = []
    with open(path) as f:
        for ln, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            d = json.loads(raw)
            if "arrival_ns" in d:
                t = float(d["arrival_ns"])
            elif "arrival_s" in d:
                t = float(d["arrival_s"]) * 1e9
            else:
                raise ValueError(f"{path}:{ln}: needs arrival_s or "
                                 f"arrival_ns")
            out.append(TraceRequest(t, int(d["prompt_tokens"]),
                                    int(d["max_new"])))
    if not out:
        raise ValueError(f"{path}: empty trace")
    return sorted(out, key=lambda r: r.arrival_ns)


def make_trace(spec: Dict[str, Any], *, prompt_tokens: int,
               max_new: int) -> List[TraceRequest]:
    """Build a trace from its payload-embedded spec dict.

    ``spec["kind"]`` selects the source (``poisson`` / ``bursty`` /
    ``jsonl``); the remaining keys are that source's parameters. This is
    the function refinement workers call, so everything that determines
    the trace must be inside ``spec`` (it is part of the result-cache
    content key).
    """
    kind = spec.get("kind", "poisson")
    if kind == "poisson":
        return poisson_trace(rate_rps=spec["rate_rps"],
                             n_requests=spec["n_requests"],
                             seed=spec.get("seed", 0),
                             prompt_tokens=prompt_tokens, max_new=max_new)
    if kind == "bursty":
        return bursty_trace(rate_rps=spec["rate_rps"],
                            n_requests=spec["n_requests"],
                            seed=spec.get("seed", 0),
                            prompt_tokens=prompt_tokens, max_new=max_new,
                            burst_x=spec.get("burst_x", 4.0),
                            dwell_s=spec.get("dwell_s", 2.0))
    if kind == "jsonl":
        return load_trace_jsonl(spec["path"])
    raise ValueError(f"unknown traffic kind {kind!r}; "
                     f"have {'|'.join(TRAFFIC_KINDS)}")
