"""Optimizer, schedules, gradient compression, checkpoint/restart, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import REGISTRY, SHAPES
from repro.models import build_model
from repro.train.checkpoint import (CheckpointManager, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.train.compress import (compression_ratio, dequantize_int8,
                                  ef_compress_grads, ef_init, quantize_int8)
from repro.train.data import SyntheticData
from repro.train.loop import init_state, make_train_step
from repro.train.optim import (adamw_init, adamw_update, cosine_schedule,
                               global_norm, wsd_schedule)


def test_adamw_converges_quadratic():
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (16, 16))
    params = {"w": jnp.zeros((16, 16))}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, jnp.asarray(0.05),
                                   weight_decay=0.0)
    assert float(loss(params)) < 0.01 * l0


def test_wsd_schedule_shape():
    lr = wsd_schedule(1e-3, warmup=100, total=1000, decay_frac=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-3)
    assert float(lr(jnp.asarray(500))) == pytest.approx(1e-3)  # stable
    assert float(lr(jnp.asarray(1000))) < 2e-5                 # decayed


def test_cosine_schedule_monotone_after_peak():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    vals = [float(lr(jnp.asarray(s))) for s in (10, 40, 70, 100)]
    assert vals == sorted(vals, reverse=True)


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                min_size=1, max_size=500))
@settings(max_examples=40, deadline=None)
def test_quantization_error_bound(vals):
    """Property: per-block int8 error <= scale/2 = max|block|/254."""
    x = jnp.asarray(np.asarray(vals, np.float32))
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape, jnp.float32)
    err = np.abs(np.asarray(deq - x))
    blocks = np.asarray(jnp.pad(x, (0, (-len(vals)) % 256)).reshape(-1, 256))
    bound = np.abs(blocks).max(-1) / 127.0 * 0.5 + 1e-7
    assert (err.reshape(-1) <= np.repeat(bound, 256)[:err.size] + 1e-6).all()


def test_error_feedback_convergence():
    """EF-compressed SGD matches uncompressed convergence on a quadratic."""
    key = jax.random.PRNGKey(1)
    target = {"w": jax.random.normal(key, (64, 64))}

    def loss(p):
        return 0.5 * jnp.sum((p["w"] - target["w"]) ** 2)

    def run(compressed):
        p = {"w": jnp.zeros((64, 64))}
        ef = ef_init(p)
        for _ in range(60):
            g = jax.grad(loss)(p)
            if compressed:
                g, ef = ef_compress_grads(g, ef)
            p = jax.tree_util.tree_map(lambda a, b: a - 0.1 * b, p, g)
        return float(loss(p))

    assert run(True) < 1.05 * run(False) + 1e-3


def test_compression_ratio():
    assert compression_ratio(jnp.bfloat16) < 0.55


def test_checkpoint_roundtrip_and_gc(tmp_path):
    cfg = REGISTRY["smollm-135m"].reduced()
    model = build_model(cfg, remat=False)
    state = init_state(model, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 10, state, data_cursor=10)
    save_checkpoint(d, 20, state, data_cursor=20)
    assert latest_step(d) == 20
    restored, cursor, _ = restore_checkpoint(d, 20, state)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert cursor == 20

    mgr = CheckpointManager(d, save_every=1, keep=2, async_save=False)
    for s in (30, 40, 50):
        mgr.maybe_save(s, state, data_cursor=s)
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_"))
    assert steps == [40, 50]


@pytest.mark.slow
def test_restart_resumes_bit_identical():
    """Fault-tolerance runbook: kill after step k, restore, continue ->
    identical final loss as the uninterrupted run."""
    cfg = REGISTRY["smollm-135m"].reduced()
    model = build_model(cfg, remat=False)
    data = SyntheticData(cfg, SHAPES["train_4k"], seed=3,
                         batch_override=2, seq_override=16)
    step_fn = make_train_step(model, None)

    def run(n, state=None, start=0):
        if state is None:
            state = init_state(model, jax.random.PRNGKey(0))
        losses = []
        for s in range(start, n):
            state, m = step_fn(state, data.batch_at(s))
            losses.append(float(m["loss"]))
        return state, losses

    _, straight = run(6)
    state3, part1 = run(3)
    # simulate restart: checkpoint via host round-trip
    host = jax.tree_util.tree_map(lambda x: np.asarray(x), state3)
    state3b = jax.tree_util.tree_map(jnp.asarray, host)
    _, part2 = run(6, state=state3b, start=3)
    np.testing.assert_allclose(straight, part1 + part2, rtol=1e-6)


def test_data_cursor_determinism():
    cfg = REGISTRY["qwen2-1.5b"].reduced()
    data = SyntheticData(cfg, SHAPES["train_4k"], seed=7,
                         batch_override=2, seq_override=16)
    b1 = data.batch_at(41)
    b2 = data.batch_at(41)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(data.batch_at(42)["tokens"]))


def test_train_step_with_compression_and_microbatches():
    cfg = REGISTRY["smollm-135m"].reduced()
    model = build_model(cfg, remat=False)
    state = init_state(model, jax.random.PRNGKey(0), compress=True)
    step_fn = make_train_step(model, None, microbatches=2, compress=True)
    data = SyntheticData(cfg, SHAPES["train_4k"], seed=0,
                         batch_override=4, seq_override=16)
    state, m = step_fn(state, data.batch_at(0))
    assert np.isfinite(float(m["loss"]))
    # error-feedback state is being populated
    efn = global_norm(state["ef"])
    assert float(efn) > 0
