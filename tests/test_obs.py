"""Observability subsystem tests (ISSUE 7).

Covers the three obs planes plus their satellites:

* metrics registry semantics (counters/gauges/histograms, labels,
  deterministic snapshots) and the instrumentation hooks in the event
  engine, fast engine, serving fleet, and exec backends;
* Perfetto exporter: golden JSON fixtures for a small event-sim point
  and a serve point (regenerate with ``--update-golden``), schema
  validation (pid/tid/ts/dur, monotone counter tracks), and the
  campaign-journal worker lanes;
* live progress: torn-line-safe journal tailing, the throughput/ETA
  fold, the ``exec status`` CLI, and the ``progress`` block in campaign
  summaries.
"""
import json
import os

import pytest

from repro.exec.journal import CampaignJournal, JournalView
from repro.hw.presets import resolve_preset, to_dict
from repro.obs.metrics import MetricsRegistry, REGISTRY, collecting, \
    render_table
from repro.obs.perfetto import trace_campaign_journal, trace_event_point, \
    trace_serve_point, write_trace
from repro.obs.progress import CampaignProgress, JournalFollower, \
    render_progress
from repro.serve.fleet import FleetParams, StepCost, serve_payload, \
    simulate_fleet, simulate_serve_point
from repro.serve.traffic import TraceRequest
from repro.sweep.refine import refine_payload

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _event_payload():
    return refine_payload(
        workload="tiny_yolo_v2", n_tiles=1,
        hw=to_dict(resolve_preset("v5e")), compile_opts={},
        pti_ns=100_000.0, temp_c=60.0, keep_series=False)


def _serve_payload():
    return serve_payload(
        workload="serve/golden", arch="qwen3-32b", layers=1, prompt=64,
        max_new=8, tp=1, ep=1, dp=2, pod=0, slots=4, kv_capacity=128,
        policy="continuous",
        traffic={"kind": "poisson", "rate_rps": 100.0, "n_requests": 24,
                 "seed": 3},
        slo={"ttft_ms": 500.0, "tpot_ms": 50.0},
        n_tiles=1, hw=to_dict(resolve_preset("v5e")), temp_c=60.0)


# ---------------------------------------------------------------------------
# metrics registry


def test_registry_instruments_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("jobs", state="done").inc()
    reg.counter("jobs", state="done").inc(2)
    reg.gauge("depth").set(3)
    reg.gauge("depth").set_max(1)          # keeps the high-water mark
    h = reg.histogram("wait", bounds=(1.0, 2.0))
    for v in (0.5, 1.5, 9.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["jobs{state=done}"] == 3
    assert snap["gauges"]["depth"] == 3
    hs = snap["histograms"]["wait"]
    assert hs["count"] == 3 and hs["overflow"] == 1
    assert hs["buckets"] == {"le_1": 1, "le_2": 1}
    assert hs["min"] == 0.5 and hs["max"] == 9.0
    # label order never matters: same instrument either way
    assert reg.counter("x", a=1, b=2) is reg.counter("x", b=2, a=1)
    assert any(line.startswith("counter,jobs{state=done},3")
               for line in render_table(snap))
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_global_registry_disabled_records_nothing():
    from repro.hw.chip import System
    from repro.graph.compiler import CompileOptions, compile_ops
    from repro.graph.workloads import resolve_workload

    assert not REGISTRY.enabled      # the off-by-default contract
    cfg = resolve_preset("v5e")
    cw = compile_ops(resolve_workload("tiny_yolo_v2")(), cfg,
                     CompileOptions(n_tiles=1))
    before = json.dumps(REGISTRY.snapshot(), sort_keys=True)
    System(cfg, n_tiles=1).run_workload(cw.tasks)
    assert json.dumps(REGISTRY.snapshot(), sort_keys=True) == before


def test_engine_metrics_flow_when_collecting():
    from repro.hw.chip import System
    from repro.graph.compiler import CompileOptions, compile_ops
    from repro.graph.workloads import resolve_workload

    cfg = resolve_preset("v5e")
    cw = compile_ops(resolve_workload("tiny_yolo_v2")(), cfg,
                     CompileOptions(n_tiles=1))
    with collecting() as reg:
        sysm = System(cfg, n_tiles=1)
        sysm.run_workload(cw.tasks)
        snap = reg.snapshot()
    c = snap["counters"]
    assert c["engine.events_processed"] == sysm.env.events_processed > 0
    assert c["engine.tasks_done"] == len(cw.tasks)
    assert snap["gauges"]["engine.peak_heap_depth"] >= 1
    assert any(k.startswith("engine.resource_requests") for k in c)


@pytest.mark.parametrize("maker", [_event_payload, _serve_payload],
                         ids=["event", "serve"])
def test_metrics_snapshot_deterministic(maker):
    """Equal inputs -> byte-identical snapshots, run after run."""
    from repro.sweep.refine import refine_point

    snaps = []
    for _ in range(2):
        with collecting() as reg:
            refine_point(maker())
            snaps.append(reg.snapshot_json())
    assert snaps[0] == snaps[1]
    assert json.loads(snaps[0])["counters"]      # actually instrumented


def test_fastsim_fallback_metrics():
    from repro.core.fastsim import simulate_fast
    from repro.graph.compiler import CompileOptions, compile_ops
    from repro.graph.workloads import resolve_workload

    cfg = resolve_preset("v5e")
    cw = compile_ops(resolve_workload("lm/qwen3-32b/s64b1tp1")(), cfg,
                     CompileOptions(n_tiles=1))
    with collecting() as reg:
        run = simulate_fast(cw, cfg, n_tiles=1, reduced=())
        c = reg.snapshot()["counters"]
    assert not run.extrapolated
    assert c["fastsim.full_replay{reason=no_reduced_workload}"] == 1
    # the replay routes through the instrumented engine too
    assert c["engine.events_processed"] > 0


# ---------------------------------------------------------------------------
# serving fleet: admit-depth satellite + instrumentation


class _FlatCosts:
    def prefill_cost(self, batch, prompt):
        return StepCost(ns=100.0, busy={"mxu": 50.0})

    def decode_cost(self, batch, kv):
        return StepCost(ns=10.0, busy={"mxu": 5.0})


def _burst_trace(n, spacing_ns=0.0):
    return [TraceRequest(arrival_ns=i * spacing_ns, prompt_tokens=8,
                         max_new=4) for i in range(n)]


def test_admit_depth_and_queue_wait_recorded():
    p = FleetParams(replicas=1, slots=2, kv_capacity=64,
                    policy="continuous")
    res = simulate_fleet(_burst_trace(8), _FlatCosts(), p)
    admitted = [r for r in res.requests if r.admit_ns >= 0]
    assert admitted and all(r.admit_depth >= 0 for r in admitted)
    # 8 simultaneous arrivals into 2 slots: the first batch leaves 6
    # queued behind it, so *some* request saw a deep backlog
    assert max(r.admit_depth for r in admitted) >= 4
    rec = res.record(slo_ttft_ms=1e9, slo_tpot_ms=1e9)
    for k in ("admit_depth_p50", "admit_depth_p95", "admit_depth_p99",
              "queue_wait_p50_ms", "queue_wait_p95_ms",
              "queue_wait_p99_ms"):
        assert k in rec
    assert rec["admit_depth_p99"] >= rec["admit_depth_p50"] >= 0
    assert rec["queue_wait_p99_ms"] >= 0


def test_fleet_metrics_and_timeline():
    p = FleetParams(replicas=2, slots=2, kv_capacity=64,
                    policy="continuous")
    timeline = []
    with collecting() as reg:
        simulate_fleet(_burst_trace(8), _FlatCosts(), p,
                       timeline=timeline)
        snap = reg.snapshot()
    assert snap["counters"]["serve.requests{status=done}"] == 8
    assert snap["counters"]["serve.admissions"] == 8
    assert snap["counters"]["serve.steps"] > 0
    assert any(k.startswith("serve.batch_size")
               for k in snap["histograms"])
    assert timeline and all(
        set(t) == {"replica", "t0", "t1", "prefill", "decode", "queue",
                   "kv_tokens"} for t in timeline)
    # per-replica step windows are time-ordered (the counter-track
    # monotonicity the Perfetto exporter relies on)
    for rep in (0, 1):
        ts = [t["t0"] for t in timeline if t["replica"] == rep]
        assert ts == sorted(ts)


def test_serve_schema_v2_record_keys():
    rec = simulate_serve_point(_serve_payload())
    assert "admit_depth_p50" in rec and "queue_wait_p95_ms" in rec
    assert _serve_payload()["serve_schema"] == 2


# ---------------------------------------------------------------------------
# Perfetto exporter


def _validate_trace(trace):
    assert set(trace) == {"traceEvents", "displayTimeUnit"}
    named_pids = set()
    last_counter = {}
    for ev in trace["traceEvents"]:
        assert isinstance(ev["pid"], int) and ev["pid"] >= 1
        assert isinstance(ev["name"], str) and ev["name"]
        if ev["ph"] == "M":
            assert ev["name"] == "process_name"
            named_pids.add(ev["pid"])
            continue
        assert ev["pid"] in named_pids    # metadata precedes use
        assert isinstance(ev["ts"], (int, float)) and ev["ts"] >= 0
        if ev["ph"] == "X":
            assert "tid" in ev and ev["dur"] > 0
        elif ev["ph"] == "C":
            assert all(isinstance(v, (int, float))
                       for v in ev["args"].values())
            k = (ev["pid"], ev["name"])
            assert ev["ts"] >= last_counter.get(k, -1.0), \
                f"counter track {k} not monotone"
            last_counter[k] = ev["ts"]
        else:
            assert ev["ph"] == "i"
    assert last_counter, "expected at least one counter track"


def _freeze_trace(trace):
    def rnd(o):
        if isinstance(o, float):
            return float(f"{o:.10g}")
        if isinstance(o, dict):
            return {k: rnd(v) for k, v in sorted(o.items())}
        if isinstance(o, list):
            return [rnd(v) for v in o]
        return o

    return rnd(json.loads(json.dumps(trace, default=float)))


@pytest.mark.parametrize("name,build", [
    ("perfetto_event_point", lambda: trace_event_point(_event_payload())),
    ("perfetto_serve_point", lambda: trace_serve_point(_serve_payload())),
])
def test_perfetto_golden(name, build, request):
    trace = build()
    _validate_trace(trace)
    frozen = _freeze_trace(trace)
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    if request.config.getoption("--update-golden"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(frozen, f, sort_keys=True)
            f.write("\n")
        return
    assert os.path.exists(path), (
        f"missing golden fixture {path}; generate it with "
        f"`python -m pytest tests/test_obs.py --update-golden`")
    with open(path) as f:
        golden = json.load(f)
    assert frozen == golden, (
        f"Perfetto trace for {name} drifted from tests/golden/; if the "
        f"change is intended, rerun with --update-golden and commit")


def _write_journal(path, *, end=True):
    j = CampaignJournal(path)
    j.start(campaign="camp", backend="spool", grid_points=4, to_refine=4)
    j.point("a" * 16, "done", worker="w1", wall_s=0.4)
    j.point("b" * 16, "cached")
    j.point("c" * 16, "done", worker="w2", wall_s=0.6)
    j.point("d" * 16, "failed", worker="w2", error="boom")
    if end:
        j.end({"wall_s": 2.0})
    return path


def test_perfetto_campaign_journal(tmp_path):
    path = _write_journal(str(tmp_path / "j.jsonl"))
    trace = trace_campaign_journal(path)
    evs = trace["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert {e["tid"] for e in spans} == {"w1", "w2"}   # worker lanes
    assert all(e["dur"] > 0 and e["ts"] >= 0 for e in spans)
    insts = [e for e in evs if e["ph"] == "i"]
    assert any(e["name"].startswith("cached:") for e in insts)
    assert any(e["name"].startswith("failed:") for e in insts)
    out = write_trace(trace, str(tmp_path / "t.json"))
    with open(out) as f:
        assert json.load(f) == json.loads(json.dumps(trace))


# ---------------------------------------------------------------------------
# journal hardening + live progress


def test_journal_view_warns_on_torn_lines(tmp_path):
    path = _write_journal(str(tmp_path / "j.jsonl"))
    with open(path, "a") as f:
        f.write('["not", "an", "object"]\n')
        f.write('{"ev": "point", "key": "trunc')    # killed mid-write
    view = JournalView.from_file(path)
    assert len(view.warnings) == 2
    assert all("skipped" in w for w in view.warnings)
    c = view.counts()                               # fold unaffected
    assert c["total"] == 4 and c["done"] == 2 and c["failed"] == 1
    assert view.all_done() is False


def test_journal_follower_consumes_complete_lines_only(tmp_path):
    path = str(tmp_path / "j.jsonl")
    j = CampaignJournal(path)
    j.start(campaign="c", backend="inline", grid_points=1, to_refine=1)
    fo = JournalFollower(path)
    assert [e["ev"] for e in fo.poll()] == ["start"]
    with open(path, "a") as f:                      # torn write...
        f.write('{"ev": "point", "key": "kk", "status": "do')
    assert fo.poll() == []                          # ...not consumed
    with open(path, "a") as f:                      # ...then finished
        f.write('ne", "t": 5.0}\n')
    evs = fo.poll()
    assert len(evs) == 1 and evs[0]["status"] == "done"
    assert fo.poll() == [] and not fo.warnings


def test_progress_fold_throughput_and_eta():
    prog = CampaignProgress()
    prog.feed({"ev": "start", "t": 100.0, "campaign": "c",
               "backend": "spool", "grid_points": 8, "to_refine": 6})
    prog.feed({"ev": "point", "t": 100.0, "key": "k0",
               "status": "cached"})
    for i, t in enumerate((105.0, 110.0)):
        prog.feed({"ev": "point", "t": t, "key": f"k{i + 1}",
                   "status": "done", "worker": "w1", "wall_s": 5.0})
    s = prog.summary()
    assert s["resolved"] == 3 and s["remaining"] == 3
    assert not s["finished"]
    assert s["sim_points_per_s"] == pytest.approx(0.2)   # 2 in 10s
    assert s["eta_s"] == pytest.approx(15.0)             # 3 / 0.2
    assert s["workers"]["w1"]["points"] == 2
    assert s["workers"]["w1"]["alive"] is True
    # liveness ages against an explicit clock (the --watch path)
    stale = prog.summary(now=110.0 + 10_000.0)
    assert stale["workers"]["w1"]["alive"] is False
    assert any("3/6 resolved" in ln for ln in render_progress(s))


def test_runner_summary_progress_block(tmp_path):
    from repro.sweep import RefineSpec, SweepSpec
    from repro.sweep.runner import run_campaign

    spec = SweepSpec(
        name="obs_progress_slice",
        workloads=["mobilenet_v2"],
        preset="paper_skew",
        axes={"clock_ghz": [0.5, 1.0]},
        n_tiles=[1],
        refine=RefineSpec(mode="all"))
    res = run_campaign(spec, backend="inline", use_cache=False,
                       journal_path=str(tmp_path / "j.jsonl"))
    prog = res.summary["progress"]
    assert prog["finished"] is True and prog["eta_s"] == 0.0
    assert prog["resolved"] == res.summary["refined"]
    assert prog["simulated"] == res.summary["simulated"]
    assert prog["backend"] == "inline"


# ---------------------------------------------------------------------------
# CLIs


def test_exec_status_cli_journal_and_spool(tmp_path, capsys):
    from repro.exec.__main__ import main as exec_main

    path = _write_journal(str(tmp_path / "j.jsonl"))
    assert exec_main(["status", path]) == 0
    out = capsys.readouterr().out
    assert "camp" in out and "resolved" in out

    spool_dir = str(tmp_path / "spool")
    from repro.exec.spool import Spool
    Spool(spool_dir).submit("k1", {"x": 1})
    assert exec_main(["status", spool_dir]) == 0
    assert "jobs,1" in capsys.readouterr().out


def test_exec_journal_cli_prints_warnings(tmp_path, capsys):
    from repro.exec.__main__ import main as exec_main

    path = _write_journal(str(tmp_path / "j.jsonl"))
    with open(path, "a") as f:
        f.write('{"torn')
    assert exec_main(["journal", path]) == 0
    cap = capsys.readouterr()
    assert "skipped" in cap.err and "total,4" in cap.out


def test_obs_trace_cli(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main

    # payload file -> serve exporter
    pfile = str(tmp_path / "point.json")
    with open(pfile, "w") as f:
        json.dump(_serve_payload(), f)
    out = str(tmp_path / "trace.json")
    assert obs_main(["trace", pfile, "-o", out]) == 0
    assert "serve-point" in capsys.readouterr().out
    with open(out) as f:
        _validate_trace(json.load(f))

    # journal -> worker lanes
    jpath = _write_journal(str(tmp_path / "j.jsonl"))
    out2 = str(tmp_path / "trace2.json")
    assert obs_main(["trace", jpath, "-o", out2]) == 0
    with open(out2) as f:
        assert json.load(f)["traceEvents"]
