"""Test-suite bootstrap.

* Puts ``src/`` on ``sys.path`` so ``PYTHONPATH=src`` is optional.
* Gates the optional ``hypothesis`` dependency: when the real package is
  missing (hermetic containers), installs the deterministic fallback from
  ``_hypothesis_stub`` so every module still collects and the property
  tests run on seeded examples.
"""
from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised in hermetic containers
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.install()


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="regenerate the frozen campaign records under tests/golden/ "
             "(tests/test_golden.py) instead of comparing against them")
