"""Stack-EM multi-context scheduling, power gating (paper §6.2 future work,
implemented), and a subprocess multi-device GSPMD guard."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import Tracer
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.stackem import StackContext, run_stack
from repro.graph.workloads import mobilenet_v2, tiny_yolo_v2
from repro.hw.presets import V5E, paper_skew
from repro.power.powerem import PowerEM


def _ctx(name, builder, period_ns, priority, cfg, n=3):
    cw = compile_ops(builder(), cfg, CompileOptions(n_tiles=1))
    return StackContext(name=name, tasks=cw.tasks, period_ns=period_ns,
                        n_requests=n, priority=priority)


def test_stackem_two_contexts_complete():
    cfg = paper_skew()
    rep = run_stack([
        _ctx("cam", mobilenet_v2, period_ns=1e6, priority=0, cfg=cfg),
        _ctx("det", tiny_yolo_v2, period_ns=2e6, priority=1, cfg=cfg),
    ], cfg)
    assert len(rep.latencies_ns["cam"]) == 3
    assert len(rep.latencies_ns["det"]) == 3
    assert all(l > 0 for l in rep.latencies_ns["cam"])


def test_stackem_contention_raises_latency():
    """A co-running heavy context inflates the light context's e2e latency
    — the software-stack effect Stack-EM exists to expose."""
    cfg = paper_skew()
    solo = run_stack([_ctx("cam", mobilenet_v2, 1e6, 0, cfg)], cfg)
    shared = run_stack([
        _ctx("cam", mobilenet_v2, 1e6, 1, cfg),
        _ctx("det", tiny_yolo_v2, 5e5, 0, cfg),   # higher priority hog
    ], cfg)
    assert shared.avg_latency_ms("cam") > solo.avg_latency_ms("cam")


def test_power_gating_saves_idle_energy():
    tr = Tracer()
    cfg = V5E
    # busy 1 PTI, then idle 8 PTIs
    rate = cfg.macs * cfg.clock_ghz
    tr.emit("tile0.mxu", "ops", 0, 1000, rate * 1000)
    pem = PowerEM(cfg)
    plain = pem.analyze(tr, pti_ns=1000, t_end_ns=9000)
    gated = pem.analyze(tr, pti_ns=1000, t_end_ns=9000, power_gating=True)
    assert gated.energy_j() < plain.energy_j()
    # active PTI unaffected
    assert gated.series["tile0.mxu"][0] == plain.series["tile0.mxu"][0]


MULTIDEV_SNIPPET = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.configs import REGISTRY, SHAPES
    from repro.launch.programs import build_program
    from repro.train.data import SyntheticData

    cfg = REGISTRY["qwen2-1.5b"].reduced()
    shape = SHAPES["train_4k"]
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    prog = build_program(cfg, shape, mesh)
    # run REAL values through the partitioned program on 8 fake devices
    # (jit bakes shardings, not shapes — a smaller batch recompiles fine)
    from repro.train.loop import init_state
    state = init_state(prog.model, jax.random.PRNGKey(0))
    data = SyntheticData(cfg, shape, batch_override=8, seq_override=64)
    fn = prog.jitted()
    state2, metrics = fn(state, data.batch_at(0))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), loss
    print("MULTIDEV_OK", loss)
""")


@pytest.mark.slow
def test_multidevice_gspmd_subprocess():
    """End-to-end GSPMD guard: a REAL partitioned train step on 8 host
    devices (subprocess because the device count locks at jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env)
    assert "MULTIDEV_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
