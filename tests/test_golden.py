"""Golden-record regression fixtures (ISSUE 4).

Small, deterministic slices of the three LM campaign families —
``lm_decode_kv``, ``moe_ep_grid``, and the full-model ``lm_full_pod`` —
are frozen as canonicalized record lists under ``tests/golden/``. The
tests assert:

* **cross-backend byte-identity** — inline, pool, and spool backends
  produce byte-for-byte identical campaign records for the same spec
  (the ``repro.exec`` Backend contract at the record level);
* **golden stability** — today's records still match the frozen
  fixtures, so any semantic drift in the op lists, compiler, analytic
  scheduler, event engine, or Power-EM shows up as a diff, not as a
  silently shifted campaign.

Regenerate after an INTENDED semantic change with::

    PYTHONPATH=src python -m pytest tests/test_golden.py --update-golden

and commit the diff under ``tests/golden/`` with the change that caused
it. Floats are rounded to 8 significant digits in the frozen form so
the comparison is robust to cross-platform last-ulp noise while still
catching any real modeling change.
"""
import json
import os
import threading
import time

import pytest

from repro.exec import SpoolBackend, get_backend, run_worker
from repro.sweep import RefineSpec, SweepSpec
from repro.sweep.runner import run_campaign

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _specs():
    """The three frozen campaign slices (tiny but structurally faithful:
    both phases, EP alltoalls, full-model layers/dp/pod axes)."""
    return {
        "lm_decode_kv_slice": SweepSpec(
            name="lm_decode_kv_slice",
            lm_grid={"arch": "qwen3-32b", "phase": ["prefill", "decode"],
                     "seq": [64], "kv_len": [64], "batch": [2],
                     "tp": [1, 2]},
            preset="v5e", axes={"clock_ghz": [0.6, 0.94]}, n_tiles=[2],
            refine=RefineSpec(mode="pareto", max_points=1,
                              pti_ns=50_000.0)),
        "moe_ep_grid_slice": SweepSpec(
            name="moe_ep_grid_slice",
            lm_grid={"arch": "qwen3-moe-30b-a3b", "seq": [64],
                     "batch": [1], "tp": [1], "ep": [1, 4]},
            preset="v5e", axes={"hbm_gbps": [409.0, 819.0]}, n_tiles=[2],
            refine=RefineSpec(mode="pareto", max_points=1,
                              pti_ns=50_000.0)),
        "lm_full_pod_slice": SweepSpec(
            name="lm_full_pod_slice",
            lm_grid={"arch": "qwen3-32b", "phase": ["prefill", "decode"],
                     "seq": [64], "kv_len": [64], "batch": [4], "tp": [2],
                     "dp": [2], "layers": [2], "pod": [2]},
            preset="v5e", axes={"clock_ghz": [0.6, 0.94]}, n_tiles=[2],
            refine=RefineSpec(mode="pareto", max_points=1,
                              pti_ns=50_000.0)),
        # serving-fleet cells (ISSUE 6): trace-driven continuous/static
        # batching over analytic step costs — locks the traffic
        # generators, the fleet event loop, and the SLO rollup across
        # backends and against the frozen records
        "serve_fleet_slice": SweepSpec(
            name="serve_fleet_slice",
            serve_grid={"arch": "qwen3-32b", "layers": 2, "prompt": 64,
                        "max_new": 8, "kv_capacity": 128, "tp": [2],
                        "dp": [1, 2], "pod": 0, "slots": 4,
                        "policy": ["static", "continuous"],
                        "traffic": ["poisson", "bursty"],
                        "rate_rps": [50.0], "n_requests": 60, "seed": 7,
                        "slo": {"ttft_ms": 500.0, "tpot_ms": 50.0}},
            preset="v5e", n_tiles=[2],
            refine=RefineSpec(mode="all")),
        # refine.batch=8: the whole slice dispatches as ONE batch job
        # (ISSUE 8) — two structural classes along the layers axis
        # sharing twin replays, a dead DCN axis inside each class
        # sharing records — so this fixture locks the structural
        # hash/dead-axis machinery across backends and against frozen
        # per-point records (which must be bitwise what per-point
        # refinement produces)
        "lm_batch_slice": SweepSpec(
            name="lm_batch_slice",
            lm_grid={"arch": "qwen3-32b", "seq": [64], "batch": [2],
                     "tp": [2], "layers": [8, 16], "pod": [2]},
            preset="v5e", axes={"dcn_gbps": [50.0, 100.0]}, n_tiles=[2],
            refine=RefineSpec(mode="all", pti_ns=50_000.0, engine="fast",
                              batch=8)),
        # captured-HLO ingestion (ISSUE 9): one ingested graph + its
        # hand-built twin (the run_campaign crosscheck annotation pairs
        # them into frozen hlo_deviation ratios) plus their L4 reduced
        # forms (the fast engine's exact-replay fallback path on
        # ingested graphs). engine="fast" is explicit so the frozen
        # records are lane-independent: the 28-layer pair extrapolates
        # deterministically, the L4 pair falls back to bitwise replay
        "hlo_crosscheck_slice": SweepSpec(
            name="hlo_crosscheck_slice",
            workloads=["hlo/qwen2_1_5b_prefill",
                       "lm/qwen2-1.5b/L28/s128b1tp1",
                       "hlo/qwen2_1_5b_prefill@L4",
                       "lm/qwen2-1.5b/L4/s128b1tp1"],
            preset="v5e", axes={"clock_ghz": [0.6, 0.94]}, n_tiles=[2],
            refine=RefineSpec(mode="pareto", max_points=1,
                              pti_ns=50_000.0, engine="fast")),
        # refine.engine="fast": 16-layer points actually take the
        # steady-state extrapolation path (ISSUE 5), so this slice locks
        # both the fast engine's determinism across backends and its
        # frozen record values
        "lm_fast_engine_slice": SweepSpec(
            name="lm_fast_engine_slice",
            lm_grid={"arch": "qwen3-32b", "phase": ["prefill", "decode"],
                     "seq": [64], "kv_len": [64], "batch": [4], "tp": [2],
                     "dp": [2], "layers": [16], "pod": [2]},
            preset="v5e", axes={"clock_ghz": [0.6, 0.94]}, n_tiles=[2],
            refine=RefineSpec(mode="pareto", max_points=1,
                              pti_ns=50_000.0, engine="fast")),
    }


def _freeze(records):
    """Canonical golden form, cross-platform-stable:

    * analytic fields (XLA f32 output: ``analytic_*``, ``deviation``)
      are rounded to 6 significant digits — inside f32 resolution, so
      vectorization differences between CPU targets cannot flip them;
    * everything else (event engine + Power-EM: pure-Python IEEE f64,
      bit-deterministic) keeps 10 significant digits.
    """
    def rnd(o, coarse=False):
        if isinstance(o, float):
            return float(f"{o:.6g}" if coarse else f"{o:.10g}")
        if isinstance(o, dict):
            return {k: rnd(v, coarse or k.startswith("analytic")
                           or k == "deviation")
                    for k, v in sorted(o.items())}
        if isinstance(o, list):
            return [rnd(v, coarse) for v in o]
        return o

    return rnd(json.loads(json.dumps(records, default=float)))


def _golden_path(name):
    return os.path.join(GOLDEN_DIR, f"{name}.json")


def _drain_in_thread(root):
    """In-process spool worker (no subprocess: fast-lane friendly)."""
    from repro.sweep.refine import refine_point

    stop = threading.Event()

    def loop():
        while not stop.is_set():
            if run_worker(root, worker="golden-w", hb_s=0.2,
                          refine_fn=refine_point) == 0:
                time.sleep(0.05)

    t = threading.Thread(target=loop, daemon=True)
    t.start()
    return t, stop


@pytest.mark.parametrize("name", sorted(_specs()))
def test_golden_records_and_backend_identity(name, tmp_path, request):
    """Inline/pool/spool records are byte-identical, and match the
    frozen fixture (or regenerate it under ``--update-golden``)."""
    spec = _specs()[name]
    inline = run_campaign(spec, backend="inline", use_cache=False)

    # cross-backend byte-identity on the raw (un-rounded) records
    pool = run_campaign(spec, backend=get_backend("pool", workers=2),
                        use_cache=False)
    root = str(tmp_path / "spool")
    t, stop = _drain_in_thread(root)
    try:
        spool = run_campaign(
            spec, backend=SpoolBackend(root, workers=0, poll_s=0.05,
                                       timeout_s=300),
            use_cache=False)
    finally:
        stop.set()
        t.join(timeout=10)
    blobs = {bk: json.dumps(res.records, sort_keys=True)
             for bk, res in [("inline", inline), ("pool", pool),
                             ("spool", spool)]}
    assert blobs["inline"] == blobs["pool"] == blobs["spool"]

    frozen = _freeze(inline.records)
    path = _golden_path(name)
    if request.config.getoption("--update-golden"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(frozen, f, indent=1, sort_keys=True)
            f.write("\n")
        return
    assert os.path.exists(path), (
        f"missing golden fixture {path}; generate it with "
        f"`python -m pytest tests/test_golden.py --update-golden`")
    with open(path) as f:
        golden = json.load(f)
    assert frozen == golden, (
        f"campaign records for {name} drifted from tests/golden/; if the "
        f"modeling change is intended, rerun with --update-golden and "
        f"commit the diff")
