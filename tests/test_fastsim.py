"""Fast refinement engine (``core.fastsim``) — exactness lockdown (ISSUE 5).

Four families:

1. **Replay exactness** — on randomized op lists (all op kinds,
   collectives with cross-pod placement, spill forced on/off, prefetch
   and compression toggled) the fast engine's full-replay path yields
   *bitwise* the event engine's makespan, per-task intervals, and
   Power-EM energy. The vectorized PTI binning is additionally pinned
   bitwise against the scalar ``Tracer.pti_activity`` reference.
2. **Steady-state extrapolation** — layered full-model points (all
   three phases, TP/DP/pod placements) extrapolate (no silent
   fallback) and agree with the full event simulation to float-rounding
   noise: intervals within 1e-3 ns, records within 1e-9 relative.
3. **Array lowering** — dense per-compile barrier ids, and the
   ``list_schedule`` relaxation respects the barrier DAG + per-engine
   FIFO order.
4. **Routing** — ``engine`` payload plumbing: auto resolution, cache-key
   separation, spec validation, byte-identical fast-vs-event records on
   replayed workloads end to end through ``refine_point``.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fastsim
from repro.core.trace import pti_bins
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import Op, resolve_workload
from repro.hw.chip import System
from repro.hw.presets import paper_skew, resolve_preset, to_dict
from repro.power.powerem import PowerEM, build_power_tree
from repro.sweep.cache import content_key
from repro.sweep.refine import (crosscheck_point, refine_payload,
                                refine_point, resolve_engine)
from repro.sweep.spec import RefineSpec

CFG = paper_skew()
V5E = resolve_preset("v5e")


# -- random op lists --------------------------------------------------------

def _op(i, kind, size, group, cross_pod, stream):
    if kind == "matmul":
        return Op(f"op{i}", "matmul", m=size, n=64, k=64,
                  in_bytes=size * 64, out_bytes=size * 64,
                  w_bytes=64 * 64, stream=stream)
    if kind == "eltwise":
        return Op(f"op{i}", "eltwise", elems=size * 64, vec_kind="add",
                  in_bytes=size * 64, out_bytes=size * 64, stream=stream)
    return Op(f"op{i}", kind, in_bytes=size * 256, out_bytes=size * 256,
              group=group, cross_pod=cross_pod)


op_lists = st.lists(
    st.tuples(st.sampled_from(["matmul", "eltwise", "allreduce",
                               "alltoall"]),
              st.sampled_from([8, 96, 700]),       # fits-VMEM .. spills
              st.sampled_from([2, 4]),             # collective group
              st.booleans(),                       # cross_pod
              st.booleans()),                      # force streaming
    min_size=1, max_size=8)


@settings(max_examples=10, deadline=None)
@given(op_lists,
       st.sampled_from([1, 2]),                    # n_tiles
       st.sampled_from([0.02, 0.5]),               # resident_fraction
       st.booleans(),                              # compression
       st.booleans())                              # weight_prefetch
def test_replay_bitwise_equals_event_engine(descr, nt, resident, comp,
                                            prefetch):
    """Full-replay fast path == event engine, bit for bit: intervals,
    makespan, per-PTI bins, and Power-EM series."""
    ops = [_op(i, *d) for i, d in enumerate(descr)]
    opts = CompileOptions(n_tiles=nt, resident_fraction=resident,
                          compression=comp, weight_prefetch=prefetch)
    cw = compile_ops(ops, CFG, opts)

    # reference: raw event engine
    sysm = System(CFG, n_tiles=nt)
    rep = sysm.run_workload(cw.tasks)
    recs = {}
    for r in sysm.tracer.tasks:
        recs[r.tid] = r

    # fast engine (no reduced twin -> exact full replay)
    run = fastsim.simulate_fast(cw, CFG, n_tiles=nt)
    assert not run.extrapolated
    assert run.makespan_ns == rep.makespan_ns
    for i, t in enumerate(cw.tasks):
        assert run.start[i] == recs[t.tid].t_start
        assert run.end[i] == recs[t.tid].t_end

    # vectorized PTI binning == the scalar Tracer reference, bitwise
    sa = run.samples
    pti = 500.0
    horizon = rep.makespan_ns
    for node in build_power_tree(CFG, nt).walk():
        ref = sysm.tracer.pti_activity(node.module_prefix,
                                       node.activity_kind, pti,
                                       t_end=horizon)
        vec = pti_bins(sa, sa.module_ids_with_prefix(node.module_prefix),
                       node.activity_kind, pti, t_end=horizon)
        assert ref == vec.tolist()

    # vectorized Power-EM over the arrays == Power-EM over the tracer
    pem = PowerEM(CFG, n_tiles=nt)
    a = pem.analyze(sysm.tracer, pti_ns=pti)
    b = pem.analyze(sa, pti_ns=pti)
    assert a.series == b.series and a.util == b.util
    assert a.energy_j() == b.energy_j()


def test_powerem_gating_path_still_works():
    ops = [_op(0, "matmul", 96, 2, False, False),
           _op(1, "eltwise", 96, 2, False, False)]
    cw = compile_ops(ops, CFG, CompileOptions(n_tiles=2))
    sysm = System(CFG, n_tiles=2)
    sysm.run_workload(cw.tasks)
    pem = PowerEM(CFG, n_tiles=2)
    plain = pem.analyze(sysm.tracer, pti_ns=200.0)
    gated = pem.analyze(sysm.tracer, pti_ns=200.0, power_gating=True)
    assert sum(gated.total_series) <= sum(plain.total_series)


# -- steady-state extrapolation --------------------------------------------

EXTRAP_POINTS = [
    "lm/qwen3-32b/L8/s64b2tp2pod2",
    "lm/qwen3-32b/L8/decode/kv128b2tp2pod2",
    "lm/qwen3-32b/L8/train/s64b2tp2dp2pod2",   # patched grad all-reduce
]


@pytest.mark.parametrize("workload", EXTRAP_POINTS)
def test_extrapolation_matches_event_engine(workload):
    """All three phases lock in (no silent fallback) and agree with the
    full event simulation to float-rounding noise."""
    out = crosscheck_point(refine_payload(
        workload=workload, n_tiles=2, hw=to_dict(V5E), compile_opts={},
        pti_ns=50_000.0, temp_c=60.0, keep_series=False, engine="fast"))
    assert out["extrapolated"], out["detail"]
    assert out["replayed_tasks"] < out["n_tasks"]
    assert out["max_interval_diff_ns"] < 1e-3
    assert out["makespan_diff_ns"] < 1e-3
    assert max(out["record_rel_diff"].values()) < 1e-9
    if "train" in workload:
        assert out["detail"]["patched_tail"] == 1


def test_fallback_is_exact_when_structure_mismatches():
    """A reduced twin that doesn't match the full model's block
    structure must fall back to full replay — still bit-exact."""
    cfg = V5E
    full = compile_ops(resolve_workload("lm/qwen3-32b/L8/s64b2tp2pod2")(),
                      cfg, CompileOptions(n_tiles=2))
    other = compile_ops(
        resolve_workload("lm/qwen3-32b/L4/decode/kv64b2tp2pod2")(),
        cfg, CompileOptions(n_tiles=2))
    run = fastsim.simulate_fast(full, cfg, n_tiles=2, reduced=[other])
    assert not run.extrapolated
    assert "fallback" in run.detail
    _, _, sa = fastsim.replay_intervals(full.tasks, cfg, n_tiles=2)
    assert run.makespan_ns == sa.makespan()


def test_fast_records_byte_equal_event_on_replayed_workloads():
    """End-to-end: a non-layered workload refined with engine="fast"
    produces the *identical* record dict as engine="event"."""
    base = dict(workload="lm/qwen3-32b/decode/kv64b2tp2", n_tiles=2,
                hw=to_dict(V5E), compile_opts={}, pti_ns=50_000.0,
                temp_c=60.0, keep_series=True)
    rec_ev = refine_point(refine_payload(**base, engine="event"))
    rec_fa = refine_point(refine_payload(**base, engine="fast"))
    assert rec_ev == rec_fa


# -- array lowering + list schedule ----------------------------------------

def test_compiler_barrier_ids_dense_and_per_compile():
    ops = [_op(i, k, 96, 2, False, False)
           for i, k in enumerate(["matmul", "allreduce", "eltwise"])]
    a = compile_ops(ops, CFG, CompileOptions(n_tiles=2))
    b = compile_ops(ops, CFG, CompileOptions(n_tiles=2))
    assert a.n_barriers == b.n_barriers      # no process-global watermark
    for cw in (a, b):
        used = {bid for t in cw.tasks for bid in t.signals}
        used |= {bid for t in cw.tasks for bid, _ in t.waits}
        assert used == set(range(cw.n_barriers))
    wa = [(t.waits, t.signals) for t in a.tasks]
    wb = [(t.waits, t.signals) for t in b.tasks]
    assert wa == wb                          # ids independent of history


def test_list_schedule_respects_dag_and_fifo():
    ops = [_op(i, k, s, 2, False, False) for i, (k, s) in enumerate(
        [("matmul", 96), ("eltwise", 96), ("allreduce", 8),
         ("matmul", 700)])]
    cw = compile_ops(ops, CFG, CompileOptions(n_tiles=2))
    table = fastsim.lower(cw, CFG)
    start, end, mk = fastsim.list_schedule(table)
    assert mk == end.max()
    # per-engine FIFO: tasks on one engine never overlap, in order
    for e in range(len(table.engines)):
        idx = np.nonzero(table.engine_id == e)[0]
        for a, b in zip(idx, idx[1:]):
            assert start[b] >= end[a]
    # barrier DAG: a waiter never starts before every producer whose
    # signal it needs could have fired
    producers = {}
    for i, t in enumerate(cw.tasks):
        for bid in t.signals:
            producers.setdefault(bid, []).append(i)
    for i, t in enumerate(cw.tasks):
        for bid, need in t.waits:
            ends = sorted(end[j] for j in producers[bid])
            assert start[i] >= ends[need - 1] - 1e-9
    # durations come from the analytic models: strictly positive
    assert (table.duration > 0).all()


def test_lowered_layer_labels():
    cw = compile_ops(resolve_workload("lm/qwen3-32b/L8/s64b2tp2pod2")(),
                     V5E, CompileOptions(n_tiles=2))
    table = fastsim.lower(cw, V5E)
    assert set(table.layer.tolist()) == set(range(8)) | {-1}
    assert table.n_barriers == cw.n_barriers


# -- engine routing ---------------------------------------------------------

def test_engine_routing_and_cache_keys():
    assert resolve_engine("event", "anything") == "event"
    assert resolve_engine("fast", "anything") == "fast"
    assert resolve_engine("auto", "mobilenet_v2") == "event"
    assert resolve_engine("auto", "lm/qwen3-32b/s64b1tp1") == "event"
    assert resolve_engine(
        "auto", "lm/qwen3-32b/L32/s1024b8tp4pod8") == "fast"
    assert resolve_engine(
        "auto", "lm/qwen3-32b/L2/s64b4tp2dp2pod2") == "event"

    base = dict(workload="mobilenet_v2", n_tiles=2, hw=to_dict(CFG),
                compile_opts={}, pti_ns=1e4, temp_c=60.0,
                keep_series=False)
    keys = {content_key(refine_payload(**base, engine=e))
            for e in ("event", "fast", "auto")}
    assert len(keys) == 3                    # engine is in the cache key

    with pytest.raises(ValueError):
        refine_payload(**base, engine="warp")
    with pytest.raises(ValueError):
        RefineSpec(engine="warp")


def test_refine_spec_engine_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_REFINE_ENGINE", "fast")
    assert RefineSpec().engine == "fast"
    monkeypatch.delenv("REPRO_REFINE_ENGINE")
    assert RefineSpec().engine == "event"
