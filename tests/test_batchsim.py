"""Batched cross-point refinement (``core.batchsim``) — differential
lockdown (ISSUE 8).

Five families:

1. **Differential harness** — batched refinement records are *bitwise*
   the per-point fast-engine records (all three phases, live and dead
   axes mixed), per-point fast records agree with the event engine
   within 1e-9 relative (transitively pinning batched == event), and a
   structural class that degenerates to one point takes the bitwise
   ``refine_point`` fallback.
2. **Batched core vs scalar core** — on randomized op lists,
   ``batch_durations`` rows and ``list_schedule_batched`` rows are
   bitwise the per-config ``lower``/``list_schedule`` outputs, and
   ``stack_tables`` rejects structurally different tables.
3. **Structural hash** — invariant along every analytic axis, stable
   across processes (no ``id()``/dict-order dependence), and across all
   builtin campaign workloads (``lm_full_pod``/``lm_decode_kv``/
   ``moe_ep_grid``) equal hashes only ever pair graphs that really are
   structurally identical (``stack_tables`` accepts them).
4. **Dead-axis analysis** — DCN axes dead exactly when no collective
   leaves the pod, ICI latency dead exactly when there are no
   collectives, link rate never dead (Power-EM reads it).
5. **Planning + plumbing** — ``plan_batches`` determinism/coverage/
   ordering, ``RefineSpec.batch`` validation + env default, and a mini
   campaign run batched vs unbatched: byte-identical records,
   per-point journal events, per-point cache entries that serve an
   unbatched rerun.
"""
import json
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import batchsim, fastsim
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import Op, resolve_workload
from repro.hw.presets import paper_skew, resolve_preset, to_dict
from repro.sweep.refine import (batch_payload, plan_batches, refine_batch,
                                refine_payload, refine_point)
from repro.sweep.spec import ANALYTIC_AXES, RefineSpec, load_builtin_spec

CFG = paper_skew()
V5E = resolve_preset("v5e")

# same three phases test_fastsim extrapolates (L8 >= FAST_MIN_LAYERS)
BATCH_POINTS = [
    "lm/qwen3-32b/L8/s64b2tp2pod2",
    "lm/qwen3-32b/L8/decode/kv128b2tp2pod2",
    "lm/qwen3-32b/L8/train/s64b2tp2dp2pod2",
]


def _payload(workload, **hw_over):
    hw = to_dict(V5E)
    hw.update(hw_over)
    return refine_payload(workload=workload, n_tiles=2, hw=hw,
                          compile_opts={}, pti_ns=50_000.0, temp_c=60.0,
                          keep_series=False, engine="fast")


# -- 1. differential harness ------------------------------------------------

@pytest.mark.parametrize("workload", BATCH_POINTS)
def test_batched_records_bitwise_equal_per_point(workload):
    """Per phase: a class mixing a dead axis (DCN at tp2/pod2) with a
    live one (clock) refines batched == per-point, bitwise."""
    items = [_payload(workload, dcn_gbps=d, clock_ghz=c)
             for c in (0.94, 1.2) for d in (50.0, 100.0)]
    solo = [refine_point(it) for it in items]
    out = refine_batch(batch_payload(items))
    assert out["kind"] == "batch"
    assert len(out["records"]) == len(out["keys"]) == len(items)
    for a, b in zip(solo, out["records"]):
        assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


@pytest.mark.parametrize("workload", BATCH_POINTS)
def test_batched_records_match_event_engine(workload):
    """Transitive 1e-9 contract vs ground truth: batched == per-point
    fast (bitwise, above), and here fast vs the raw event engine."""
    from repro.sweep.refine import crosscheck_point

    out = crosscheck_point(_payload(workload))
    assert out["extrapolated"], out["detail"]
    assert max(out["record_rel_diff"].values()) < 1e-9


def test_singleton_class_takes_bitwise_refine_point_fallback():
    """Two size-1 classes in one job: both records bitwise equal the
    per-point path (which they in fact took)."""
    items = [_payload(BATCH_POINTS[0]), _payload(BATCH_POINTS[1])]
    out = refine_batch(batch_payload(items))
    for it, rec in zip(items, out["records"]):
        assert rec == refine_point(it)


def test_batch_payload_validation():
    with pytest.raises(ValueError):
        batch_payload([])
    with pytest.raises(ValueError):
        batch_payload([{"kind": "serve", "workload": "x"}])


# -- 2. batched core vs scalar core -----------------------------------------

def _op(i, kind, size, group, cross_pod, stream):
    if kind == "matmul":
        return Op(f"op{i}", "matmul", m=size, n=64, k=64,
                  in_bytes=size * 64, out_bytes=size * 64,
                  w_bytes=64 * 64, stream=stream)
    if kind == "eltwise":
        return Op(f"op{i}", "eltwise", elems=size * 64, vec_kind="add",
                  in_bytes=size * 64, out_bytes=size * 64, stream=stream)
    return Op(f"op{i}", kind, in_bytes=size * 256, out_bytes=size * 256,
              group=group, cross_pod=cross_pod)


op_lists = st.lists(
    st.tuples(st.sampled_from(["matmul", "eltwise", "allreduce",
                               "alltoall"]),
              st.sampled_from([8, 96, 700]),
              st.sampled_from([2, 4]),
              st.booleans(),
              st.booleans()),
    min_size=1, max_size=8)


@settings(max_examples=10, deadline=None)
@given(op_lists, st.sampled_from([1, 2]))
def test_batched_schedule_bitwise_equals_scalar(descr, nt):
    """lower/list_schedule per config == batch_durations/
    list_schedule_batched rows, bit for bit, on random op lists."""
    ops = [_op(i, *d) for i, d in enumerate(descr)]
    cw = compile_ops(ops, CFG, CompileOptions(n_tiles=nt))
    cfgs = [CFG,
            CFG.replace(clock_ghz=CFG.clock_ghz * 1.5),
            CFG.replace(hbm_gbps=CFG.hbm_gbps * 0.5,
                        ici_link_gbps=CFG.ici_link_gbps * 2.0)]
    tables = [fastsim.lower(cw, c) for c in cfgs]
    dur = batchsim.batch_durations(cw, cfgs)
    bt = batchsim.stack_tables(tables)
    bs, be, bm = batchsim.list_schedule_batched(bt)
    for p, tb in enumerate(tables):
        assert np.array_equal(dur[p], tb.duration)
        s, e, mk = fastsim.list_schedule(tb)
        assert np.array_equal(bs[p], s)
        assert np.array_equal(be[p], e)
        assert bm[p] == mk


def test_stack_tables_rejects_structural_mismatch():
    a = compile_ops([_op(0, "matmul", 96, 2, False, False)], CFG,
                    CompileOptions(n_tiles=2))
    b = compile_ops([_op(0, "eltwise", 96, 2, False, False)], CFG,
                    CompileOptions(n_tiles=2))
    with pytest.raises(ValueError):
        batchsim.stack_tables([fastsim.lower(a, CFG),
                               fastsim.lower(b, CFG)])
    with pytest.raises(ValueError):
        batchsim.stack_tables([])


# -- 3. structural hash ------------------------------------------------------

def test_structural_hash_invariant_along_every_analytic_axis():
    cw = compile_ops(resolve_workload(BATCH_POINTS[0])(), V5E,
                     CompileOptions(n_tiles=2))
    base = batchsim.structural_hash(cw, n_tiles=2)
    hw = to_dict(V5E)
    for axis in sorted(ANALYTIC_AXES):
        assert axis in hw, axis
        over = dict(hw)
        over[axis] = (hw[axis] * 2 if isinstance(hw[axis], float)
                      else hw[axis] * 2)
        from repro.hw.presets import from_dict
        cw2 = compile_ops(resolve_workload(BATCH_POINTS[0])(),
                          from_dict(over), CompileOptions(n_tiles=2))
        assert batchsim.structural_hash(cw2, n_tiles=2) == base, axis
    # but not invariant to the graph itself or the tiling
    assert batchsim.structural_hash(cw, n_tiles=4) != base
    cw3 = compile_ops(resolve_workload(BATCH_POINTS[1])(), V5E,
                      CompileOptions(n_tiles=2))
    assert batchsim.structural_hash(cw3, n_tiles=2) != base


def test_structural_hash_never_collides_across_builtin_campaigns():
    """Across every workload of the three builtin campaigns, equal
    hashes only pair graphs that are *actually* structurally identical
    (their lowered tables stack) — renamed isomorphisms allowed, true
    collisions not."""
    names = []
    for spec_name in ("lm_full_pod", "lm_decode_kv", "moe_ep_grid"):
        names.extend(load_builtin_spec(spec_name).workloads)
    by_hash = {}
    for w in sorted(set(names)):
        cw = compile_ops(resolve_workload(w)(), V5E,
                         CompileOptions(n_tiles=2))
        h = batchsim.structural_hash(cw, n_tiles=2)
        by_hash.setdefault(h, []).append(cw)
    assert len(by_hash) > 1
    for h, cws in by_hash.items():
        if len(cws) == 1:
            continue
        # same hash -> stacking must succeed (structure identical)
        batchsim.stack_tables([fastsim.lower(c, V5E) for c in cws])


def test_structural_hash_stable_across_processes():
    cw = compile_ops(resolve_workload(BATCH_POINTS[0])(), V5E,
                     CompileOptions(n_tiles=2))
    here = batchsim.structural_hash(cw, n_tiles=2)
    code = (
        "from repro.core import batchsim\n"
        "from repro.graph.compiler import CompileOptions, compile_ops\n"
        "from repro.graph.workloads import resolve_workload\n"
        "from repro.hw.presets import resolve_preset\n"
        f"cw = compile_ops(resolve_workload({BATCH_POINTS[0]!r})(),\n"
        "                 resolve_preset('v5e'), CompileOptions(n_tiles=2))\n"
        "print(batchsim.structural_hash(cw, n_tiles=2))\n")
    out = subprocess.run([sys.executable, "-c", code], check=True,
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "PYTHONHASHSEED": "77"},
                         cwd=__file__.rsplit("/tests/", 1)[0])
    assert out.stdout.strip() == here


# -- 4. dead-axis analysis ---------------------------------------------------

def test_dead_axes_follow_collective_placement():
    def axes_of(workload):
        cw = compile_ops(resolve_workload(workload)(), V5E,
                         CompileOptions(n_tiles=2))
        return batchsim.dead_axes(cw)

    # tp4 ring on a 2-chip pod leaves the pod: DCN is live
    assert axes_of("lm/qwen3-32b/L8/s64b2tp4pod2") == frozenset()
    # tp2 ring inside a 2-chip pod: DCN dead, ICI latency live
    assert axes_of("lm/qwen3-32b/L8/s64b2tp2pod2") == frozenset(
        {"dcn_gbps", "dcn_latency_ns"})
    # tp1: no collectives at all -> ICI latency dead too
    assert axes_of("lm/qwen3-32b/L8/s64b2tp1") == frozenset(
        {"dcn_gbps", "dcn_latency_ns", "ici_latency_ns"})
    # link rate is never dead (Power-EM sizes the ici tree by it)
    for w in ("lm/qwen3-32b/L8/s64b2tp1", "lm/qwen3-32b/L8/s64b2tp2pod2"):
        assert "ici_link_gbps" not in axes_of(w)
        assert axes_of(w) <= ANALYTIC_AXES


def test_live_key_partitions_on_live_axes_only():
    hw = to_dict(V5E)
    dead = frozenset({"dcn_gbps", "dcn_latency_ns"})
    a = batchsim.live_key(hw, dead)
    hw2 = dict(hw, dcn_gbps=hw["dcn_gbps"] * 4)
    assert batchsim.live_key(hw2, dead) == a
    hw3 = dict(hw, clock_ghz=hw["clock_ghz"] * 2)
    assert batchsim.live_key(hw3, dead) != a


# -- 5. planning + plumbing --------------------------------------------------

def test_plan_batches_deterministic_coverage_and_ordering():
    items = []
    # two structural classes interleaved in grid order + one event point
    for d in (25.0, 50.0, 100.0):
        items.append(_payload(BATCH_POINTS[0], dcn_gbps=d))
        items.append(_payload(BATCH_POINTS[1], dcn_gbps=d))
    ev = dict(_payload("lm/qwen3-32b/L8/s64b2tp2pod2"), engine="event")
    items.append(ev)
    jobs = plan_batches(items, 4)
    # every position exactly once
    cover = sorted(i for _, pos in jobs for i in pos)
    assert cover == list(range(len(items)))
    # classes keep grid order internally and jobs are ordered by their
    # first position; the event point stays a single-point job
    assert all(pos == sorted(pos) for _, pos in jobs)
    assert [min(pos) for _, pos in jobs] == sorted(
        min(pos) for _, pos in jobs)
    singles = [pos for jp, pos in jobs if jp.get("kind") != "batch"]
    assert [6] in singles
    # batch jobs respect the cap and batch whole classes when they fit
    for jp, pos in jobs:
        if jp.get("kind") == "batch":
            assert 2 <= len(pos) <= 4
            assert [it["workload"] for it in jp["items"]] == \
                [items[i]["workload"] for i in pos]
    # deterministic: same input, same plan
    again = plan_batches(list(items), 4)
    assert [(jp.get("kind"), pos) for jp, pos in jobs] == \
        [(jp.get("kind"), pos) for jp, pos in again]
    with pytest.raises(ValueError):
        plan_batches(items, 1)


def test_refine_spec_batch_validation_and_env_default(monkeypatch):
    monkeypatch.delenv("REPRO_REFINE_BATCH", raising=False)
    assert RefineSpec().batch == 0
    monkeypatch.setenv("REPRO_REFINE_BATCH", "16")
    assert RefineSpec().batch == 16
    monkeypatch.delenv("REPRO_REFINE_BATCH")
    with pytest.raises(ValueError):
        RefineSpec(batch=-1)


def test_campaign_batched_equals_unbatched_with_journal_and_cache(tmp_path):
    from repro.sweep import SweepSpec
    from repro.sweep.runner import run_campaign

    def spec(batch):
        return SweepSpec(
            name="batch_mini",
            lm_grid={"arch": "qwen3-32b", "seq": [64], "batch": [2],
                     "tp": [2], "layers": [8, 16], "pod": [2]},
            preset="v5e", axes={"dcn_gbps": [50.0, 100.0]}, n_tiles=[2],
            refine=RefineSpec(mode="all", pti_ns=50_000.0, engine="fast",
                              batch=batch))

    unbatched = run_campaign(spec(0), backend="inline", use_cache=False)
    jpath = tmp_path / "journal.jsonl"
    cdir = str(tmp_path / "cache")
    batched = run_campaign(spec(8), backend="inline", cache_dir=cdir,
                           journal_path=str(jpath))
    strip = [{k: v for k, v in r.items() if k != "cached"}
             for r in batched.records]
    assert json.dumps(strip, sort_keys=True) == \
        json.dumps([{k: v for k, v in r.items() if k != "cached"}
                    for r in unbatched.records], sort_keys=True)
    # the journal saw one done event per POINT, not per batch job
    done = [json.loads(ln) for ln in jpath.read_text().splitlines()
            if '"done"' in ln]
    assert len(done) == 4
    assert len({d["key"] for d in done}) == 4
    # per-point cache entries serve an UNBATCHED rerun entirely
    rerun = run_campaign(spec(0), backend="inline", cache_dir=cdir)
    assert rerun.summary["cache_hits"] == 4
    assert rerun.summary["simulated"] == 0
