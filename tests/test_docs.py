"""Docs integrity: the CI docs-check must pass from a clean tree (no
broken intra-repo links, every src/repro package covered by the
architecture tour)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_check_docs_passes():
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs.py")],
        capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, r.stderr
    assert "docs check OK" in r.stdout


def test_architecture_and_campaigns_docs_exist():
    for name in ("ARCHITECTURE.md", "CAMPAIGNS.md"):
        p = os.path.join(REPO, "docs", name)
        assert os.path.exists(p)
        text = open(p, encoding="utf-8").read()
        assert len(text) > 2000
    camp = open(os.path.join(REPO, "docs", "CAMPAIGNS.md"),
                encoding="utf-8").read()
    # the acceptance: both new campaigns + grid fields are documented
    for needle in ("lm_decode_kv", "moe_ep_grid", "`phase`", "`kv_len`",
                   "`ep`", "resume", "spool"):
        assert needle in camp, needle
