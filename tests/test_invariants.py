"""Property-based invariants of the full-model composition (ISSUE 4).

Three families, exercised with hypothesis (or the deterministic stub
from ``tests/_hypothesis_stub.py`` in hermetic containers):

1. **Composition** — a full-model workload is EXACTLY ``layers`` copies
   of its single-layer body plus the model head: op lists, flops, and
   compiled HBM bytes equal the closed-form composition of single-layer
   results within 1e-6, and the pre-screen's reported analytic latency
   IS that closed form. The closed form itself is pinned against the
   analytic schedule of the REAL replicated graph: an upper bound
   (cross-layer prefetch overlap at the seams only shortens the
   schedule) that stays within 20% (measured gap <= 15%, worst on
   small-batch train bodies where prefetch dominates).
2. **Monotonicity** — analytic latency and compiled ``hbm_bytes`` are
   monotone non-decreasing in ``layers``, ``seq``, ``batch``, and
   ``kv_len``.
3. **Phase regime** — a decode step is strictly more HBM-bound (lower
   compiled flops/byte) than the matching prefill pass at every drawn
   (ctx, batch, tp) point.

Strategies draw from small sampled grids (not open integer ranges) so
the set of distinct task-graph shapes — and therefore XLA compilations
of the analytic scheduler — stays bounded and the suite lives in the
fast CI lane.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.vectorized import from_tasks, params_of, schedule_many_stats
from repro.graph.compiler import CompileOptions, compile_ops
from repro.graph.workloads import (lm_workload_name, model_parts,
                                   resolve_workload, workload_flops)
from repro.hw.presets import resolve_preset
from repro.sweep import RefineSpec, SweepSpec
from repro.sweep.prescreen import prescreen_cell

DENSE = get_config("qwen3-32b")
CFG = resolve_preset("v5e")
OPTS = CompileOptions(n_tiles=2)
PM = np.stack([params_of(CFG), params_of(CFG.replace(clock_ghz=0.6))])


def _analytic_ns(ops) -> np.ndarray:
    """[2] analytic makespans of one compiled op list (both PM rows)."""
    cw = compile_ops(ops, CFG, OPTS)
    mk, _ = schedule_many_stats(from_tasks(cw.tasks), PM)
    return mk


def _hbm_bytes(ops) -> float:
    return compile_ops(ops, CFG, OPTS).hbm_bytes


# -- 1. composition --------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([1, 2, 3, 5]),
       st.sampled_from([2, 4]),
       st.sampled_from(["prefill", "decode", "train"]),
       st.sampled_from([1, 2]))
def test_full_model_equals_composed_layers(layers, batch, phase, dp):
    """full == layers x body + head: op lists exactly, flops/HBM bytes
    within 1e-6, prescreen latency == the closed form, and the closed
    form bounds the real replicated graph's schedule from above within
    20% (the layer-seam overlap the fast path ignores; measured gap is
    <= 15%, worst on small-batch train bodies)."""
    name = lm_workload_name(
        "qwen3-32b", seq=0 if phase == "decode" else 64,
        kv_len=64 if phase == "decode" else 0, batch=batch * dp, tp=2,
        phase=phase, layers=layers, dp=dp, pod=2)
    full = resolve_workload(name)()
    parts = model_parts(name)
    assert parts.layers == layers
    body, head = parts.body(), parts.head()

    # exact op-list composition (names carry the layer index)
    composed = [dataclasses.replace(o, name=f"L{i}.{o.name}")
                for i in range(layers) for o in body] + head
    assert composed == full

    # flops and compiled HBM traffic compose in closed form
    f_full = workload_flops(full)
    f_comp = layers * workload_flops(body) + workload_flops(head)
    assert f_full == pytest.approx(f_comp, rel=1e-6)
    cw_full = compile_ops(full, CFG, OPTS)
    cw_body = compile_ops(body, CFG, OPTS)
    cw_head = compile_ops(head, CFG, OPTS)
    assert cw_full.total_flops == pytest.approx(
        layers * cw_body.total_flops + cw_head.total_flops, rel=1e-6)
    assert cw_full.hbm_bytes == pytest.approx(
        layers * cw_body.hbm_bytes + cw_head.hbm_bytes, rel=1e-6)

    # the pre-screen's analytic latency IS the closed-form composition
    spec = SweepSpec(name="inv", workloads=[name], preset="v5e",
                     axes={"clock_ghz": [0.94, 0.6]}, n_tiles=[2],
                     refine=RefineSpec(mode="none"))
    (cell,) = spec.cells()
    scr = prescreen_cell(cell)
    mk_body, _ = schedule_many_stats(from_tasks(cw_body.tasks), PM)
    mk_head, _ = schedule_many_stats(from_tasks(cw_head.tasks), PM)
    composed = layers * mk_body + mk_head
    np.testing.assert_allclose(scr.time_ns, composed, rtol=1e-6)
    assert scr.total_flops == pytest.approx(cw_full.total_flops, rel=1e-6)
    assert scr.hbm_bytes == pytest.approx(cw_full.hbm_bytes, rel=1e-6)

    # non-circular leg: the closed form vs the analytic schedule of the
    # REAL replicated graph. Composition is an upper bound — in the
    # list scheduler, layer i+1's prefetch DMAs queue behind layer i's,
    # so seam overlap can only shorten — and the gap (what the fast
    # path ignores) stays under 20% (measured <= 15%, worst on
    # small-batch train bodies)
    mk_full, _ = schedule_many_stats(from_tasks(cw_full.tasks), PM)
    assert np.all(mk_full <= composed * (1 + 1e-5))
    assert np.all(mk_full >= composed * 0.80)


def test_repeats_fast_path_matches_composition():
    """core.vectorized's ``repeats`` argument is the same closed form."""
    name = "lm/qwen3-32b/L6/s64b2tp1"
    parts = model_parts(name)
    arrays = from_tasks(compile_ops(parts.body(), CFG, OPTS).tasks)
    mk1, busy1 = schedule_many_stats(arrays, PM)
    mk6, busy6 = schedule_many_stats(arrays, PM, repeats=6)
    np.testing.assert_allclose(mk6, 6 * mk1, rtol=1e-9)
    np.testing.assert_allclose(busy6, 6 * busy1, rtol=1e-9)


# -- 2. monotonicity -------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.sampled_from([32, 64, 128]),
       st.sampled_from([1, 2, 4]),
       st.sampled_from([1, 2]))
def test_latency_and_hbm_monotone_in_seq_and_batch(seq, batch, tp):
    """Prefill analytic latency and compiled HBM bytes never decrease
    when seq or batch grows (everything else fixed)."""
    def layer(s, b):
        return resolve_workload(
            lm_workload_name("qwen3-32b", seq=s, batch=b, tp=tp))()

    base_t = _analytic_ns(layer(seq, batch))
    base_h = _hbm_bytes(layer(seq, batch))
    up_seq = layer(2 * seq, batch)
    up_batch = layer(seq, 2 * batch)
    for ops in (up_seq, up_batch):
        assert np.all(_analytic_ns(ops) >= base_t * (1 - 1e-9))
        assert _hbm_bytes(ops) >= base_h


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([32, 64, 128]),
       st.sampled_from([1, 2, 4]))
def test_latency_and_hbm_monotone_in_kv_len(kv_len, batch):
    """Decode analytic latency and HBM bytes never decrease in kv_len
    (the KV cache only ever grows)."""
    def step(kv):
        return resolve_workload(lm_workload_name(
            "qwen3-32b", phase="decode", kv_len=kv, batch=batch, tp=1))()

    assert np.all(_analytic_ns(step(2 * kv_len))
                  >= _analytic_ns(step(kv_len)) * (1 - 1e-9))
    assert _hbm_bytes(step(2 * kv_len)) >= _hbm_bytes(step(kv_len))


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([1, 2]),
       st.sampled_from(["prefill", "decode"]))
def test_latency_and_hbm_monotone_in_layers(layers, phase):
    """Full-model (real replicated graph, not the fast path): doubling
    the layer count never reduces analytic latency or HBM bytes."""
    def model(n):
        return resolve_workload(lm_workload_name(
            "qwen3-32b", seq=0 if phase == "decode" else 64,
            kv_len=64 if phase == "decode" else 0, batch=2, tp=1,
            phase=phase, layers=n))()

    assert np.all(_analytic_ns(model(2 * layers))
                  >= _analytic_ns(model(layers)) * (1 - 1e-9))
    assert _hbm_bytes(model(2 * layers)) >= _hbm_bytes(model(layers))


# -- 3. phase regime -------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.sampled_from([128, 512, 1024, 4096]),
       st.sampled_from([1, 4, 8]),
       st.sampled_from([1, 2, 4]))
def test_decode_strictly_more_hbm_bound_than_prefill(ctx, batch, tp):
    """At every drawn (ctx, batch, tp) point, a decode step over a
    ctx-token cache has strictly lower compiled flops/byte than the
    matching prefill pass over ctx tokens."""
    pre = compile_ops(resolve_workload(lm_workload_name(
        "qwen3-32b", seq=ctx, batch=batch, tp=tp))(), CFG, OPTS)
    dec = compile_ops(resolve_workload(lm_workload_name(
        "qwen3-32b", phase="decode", kv_len=ctx, batch=batch,
        tp=tp))(), CFG, OPTS)
    assert pre.hbm_bytes > 0 and dec.hbm_bytes > 0
    assert (dec.total_flops / dec.hbm_bytes) < \
        (pre.total_flops / pre.hbm_bytes)


@settings(max_examples=6, deadline=None)
@given(st.sampled_from([256, 1024]),
       st.sampled_from([2, 8]),
       st.sampled_from([2, 4]))
def test_decode_more_hbm_bound_at_full_model_scale(ctx, batch, layers):
    """The phase regime survives full-model composition: the composed
    decode model is still strictly more HBM-bound than the composed
    prefill model."""
    pre = compile_ops(resolve_workload(lm_workload_name(
        "qwen3-32b", seq=ctx, batch=batch, tp=1, layers=layers))(),
        CFG, OPTS)
    dec = compile_ops(resolve_workload(lm_workload_name(
        "qwen3-32b", phase="decode", kv_len=ctx, batch=batch, tp=1,
        layers=layers))(), CFG, OPTS)
    assert (dec.total_flops / dec.hbm_bytes) < \
        (pre.total_flops / pre.hbm_bytes)
