"""Tracer PTI accounting + Power-EM characterization and integration."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Tracer
from repro.hw.presets import V5E, paper_skew
from repro.power.characterization import DEFAULT_CHARS, LeakageLUT, VFCurve
from repro.power.powerem import PowerEM, build_power_tree


def test_busy_time_union():
    tr = Tracer()
    tr.emit("m", "busy", 0, 10, 1)
    tr.emit("m", "busy", 5, 15, 1)     # overlaps
    tr.emit("m", "busy", 20, 25, 1)
    assert tr.busy_time("m") == 15 + 5


def test_pti_prorata():
    tr = Tracer()
    tr.emit("m", "bytes", 0, 20, 100)  # uniform rate 5/ns
    bins = tr.pti_activity("m", "bytes", pti=8, t_end=24)
    assert bins == pytest.approx([40, 40, 20])


@given(st.lists(st.tuples(
    st.floats(0, 1e4, allow_nan=False),
    st.floats(0.1, 1e3, allow_nan=False),
    st.floats(0.1, 1e5, allow_nan=False)), min_size=1, max_size=30),
    st.floats(1.0, 1e4, allow_nan=False))
@settings(max_examples=60, deadline=None)
def test_pti_conserves_activity(samples, pti):
    """Property: PTI binning conserves total activity (Power-EM spatial +
    temporal capture loses nothing)."""
    tr = Tracer()
    total = 0.0
    for t0, dur, amount in samples:
        tr.emit("m", "ops", t0, t0 + dur, amount)
        total += amount
    bins = tr.pti_activity("m", "ops", pti=pti)
    assert sum(bins) == pytest.approx(total, rel=1e-6)


def test_leakage_lut_monotonic():
    lut = LeakageLUT()
    assert lut.lookup(25, 0.7) < lut.lookup(85, 0.7)
    assert lut.lookup(60, 0.6) < lut.lookup(60, 1.0)


def test_vf_curve_monotonic():
    vf = VFCurve()
    vs = [vf.f2v(f) for f in (0.3, 0.6, 0.94, 1.2)]
    assert vs == sorted(vs)
    assert vf.f2v(0.94, 105) > vf.f2v(0.94, 25)


def test_power_char_utilization_scaling():
    ch = DEFAULT_CHARS["mxu"]
    p0 = ch.total_w(0.94, 0.0)
    p1 = ch.total_w(0.94, 1.0)
    assert p1 > p0 > 0
    # dynamic part scales linearly in utilization
    pm = ch.total_w(0.94, 0.5)
    assert pm == pytest.approx((p0 + p1) / 2, rel=1e-6)


def test_power_super_linear_in_freq():
    """Fig 6: power grows faster than frequency (V^2 term)."""
    ch = DEFAULT_CHARS["mxu"]
    p_low = ch.dynamic_w(0.5, 1.0)
    p_high = ch.dynamic_w(1.0, 1.0)
    assert p_high / p_low > 2.0   # > linear scaling


def test_powerem_integration():
    tr = Tracer()
    cfg = V5E
    # mxu at 50% of peak MAC rate for 1us, then idle 1us
    half_rate = cfg.macs * cfg.clock_ghz * 0.5
    tr.emit("tile0.mxu", "ops", 0, 1000, half_rate * 1000)
    pem = PowerEM(cfg, n_tiles=1)
    rep = pem.analyze(tr, pti_ns=1000, t_end_ns=2000)
    u = rep.util["tile0.mxu"]
    assert u[0] == pytest.approx(0.5, rel=1e-3)
    assert u[1] == 0.0
    assert rep.series["tile0.mxu"][0] > rep.series["tile0.mxu"][1]
    assert rep.peak_w >= rep.avg_w > 0


def test_power_tree_scales_with_hw_size():
    small = build_power_tree(paper_skew())
    big = build_power_tree(V5E)

    def peak(tree):
        return sum(n.scale * n.char.total_w(0.94, 1.0) for n in tree.walk()
                   if not n.children)

    assert peak(small) < 0.25 * peak(big)
