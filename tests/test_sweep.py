"""Sweep-campaign subsystem: spec/grid, Pareto selection, cache,
runner end-to-end (analytic pre-screen vs event refinement), CLI."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.sweep import (ANALYTIC_AXES, RefineSpec, ResultCache, SweepSpec,
                         builtin_spec_names, load_builtin_spec, pareto_front,
                         run_campaign, select_points)
from repro.sweep.cache import content_key
from repro.sweep.runner import load_result, save_result


def _small_spec(**kw):
    base = dict(
        name="test_campaign",
        workloads=["mobilenet_v2"],
        preset="paper_skew",
        axes={"clock_ghz": [0.4, 0.7, 1.0], "hbm_gbps": [17.0, 34.0]},
        n_tiles=[2],
        refine=RefineSpec(mode="pareto", max_points=2),
    )
    base.update(kw)
    return SweepSpec(**base)


# -- spec / grid -----------------------------------------------------------

def test_spec_grid_and_cells():
    spec = _small_spec(n_tiles=[1, 2])
    assert spec.grid_size == 3 * 2 * 2
    cells = spec.cells()
    assert len(cells) == 2           # n_tiles is structural
    assert all(len(c.points) == 6 for c in cells)
    assert spec.analytic_axes.keys() == {"clock_ghz", "hbm_gbps"}
    assert not spec.structural_axes


def test_spec_structural_axis_splits_cells():
    spec = _small_spec(axes={"clock_ghz": [0.5, 1.0],
                             "vmem_bytes": [2 * 2**20, 16 * 2**20]})
    assert "vmem_bytes" not in ANALYTIC_AXES
    cells = spec.cells()
    assert len(cells) == 2           # one per vmem capacity
    assert all(len(c.points) == 2 for c in cells)
    # structural override lands in the cell's compile config
    assert {c.base_cfg().vmem_bytes for c in cells} == \
        {2 * 2**20, 16 * 2**20}


def test_spec_validation_errors():
    with pytest.raises(KeyError):
        _small_spec(workloads=["nope"])
    with pytest.raises(KeyError):
        _small_spec(axes={"not_a_field": [1]})
    with pytest.raises(ValueError):
        _small_spec(axes={"clock_ghz": []})
    with pytest.raises(ValueError):
        _small_spec(refine=RefineSpec(mode="bogus"))
    with pytest.raises(KeyError):
        _small_spec(preset="no-such-preset")


def test_spec_json_roundtrip():
    spec = _small_spec()
    spec2 = SweepSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2.to_dict() == spec.to_dict()
    assert [p.point_id() for c in spec2.cells() for p in c.points] == \
        [p.point_id() for c in spec.cells() for p in c.points]


def test_builtin_specs_load():
    names = builtin_spec_names()
    assert "dvfs_bw" in names
    spec = load_builtin_spec("dvfs_bw")
    assert spec.grid_size >= 100     # acceptance: >=100-point pre-screen
    assert len(spec.cells()) == 1    # ... in ONE batched XLA call


# -- pareto ----------------------------------------------------------------

def test_pareto_front_simple():
    obj = np.array([[1.0, 4.0], [2.0, 2.0], [4.0, 1.0],
                    [3.0, 3.0], [2.0, 2.0]])
    front = set(pareto_front(obj))
    assert {0, 2} <= front
    assert 3 not in front            # dominated by (2,2)


def test_select_points_modes_and_budget():
    rng = np.random.default_rng(0)
    obj = rng.random((50, 2))
    assert select_points(obj, "all") == list(range(50))
    assert select_points(obj, "none") == []
    picked = select_points(obj, "pareto", max_points=4)
    assert 0 < len(picked) <= 4
    front = sorted(pareto_front(obj), key=lambda i: obj[i, 0])
    if len(front) > 4:               # endpoints pinned under thinning
        assert front[0] in picked and front[-1] in picked
    with pytest.raises(ValueError):
        select_points(obj, "bogus")


# -- cache -----------------------------------------------------------------

def test_cache_roundtrip_and_miss(tmp_path):
    c = ResultCache(str(tmp_path / "cache"))
    key = content_key({"a": 1, "b": [2.0, 3]})
    assert content_key({"b": [2.0, 3], "a": 1}) == key  # canonical
    assert c.get(key) is None
    c.put(key, {"x": 1.5})
    assert c.get(key) == {"x": 1.5}
    assert len(c) == 1
    assert c.hits == 1 and c.misses == 1


# -- runner end-to-end -----------------------------------------------------

def test_campaign_prescreen_matches_event_engine(tmp_path):
    """Acceptance: analytic pre-screen and event refinement agree within
    the deviation bound already asserted for core/vectorized, and the
    cache returns identical records on a second run."""
    spec = _small_spec(cache_dir=str(tmp_path / "cache"))
    res = run_campaign(spec, workers=0)
    assert res.summary["grid_points"] == 6
    assert res.summary["prescreen_calls"] == 1   # one XLA call
    refined = res.refined
    assert 0 < len(refined) <= 2
    for r in refined:
        assert 0.5 < r["deviation"] < 2.0        # same bound as tier-1
        assert r["time_ns"] > 0 and r["energy_j"] > 0
        assert not r["cached"]
    # analytic proxy is present on every grid point
    assert all(r["analytic_time_ns"] > 0 and r["analytic_avg_w"] > 0
               for r in res.records)

    # second run: all refinements served from the cache, identical records
    res2 = run_campaign(spec, workers=0)
    assert res2.summary["cache_hits"] == len(refined)
    assert res2.summary["simulated"] == 0

    def strip(recs):
        return [{k: v for k, v in r.items() if k != "cached"}
                for r in recs]

    assert strip(res2.records) == strip(res.records)
    assert all(r["cached"] for r in res2.refined)


def test_campaign_monotone_in_clock(tmp_path):
    """Analytic pre-screen must preserve the DVFS trend the event engine
    shows: higher clock -> lower makespan."""
    spec = _small_spec(axes={"clock_ghz": [0.3, 0.6, 0.9, 1.2]},
                       refine=RefineSpec(mode="none"))
    res = run_campaign(spec, workers=0, use_cache=False)
    recs = sorted(res.records,
                  key=lambda r: r["overrides"]["clock_ghz"])
    times = [r["analytic_time_ns"] for r in recs]
    assert all(a > b for a, b in zip(times, times[1:]))


def test_campaign_refine_all_and_result_io(tmp_path):
    spec = _small_spec(axes={"clock_ghz": [0.5, 1.0]},
                       refine=RefineSpec(mode="all"))
    res = run_campaign(spec, workers=0, use_cache=False)
    assert len(res.refined) == 2
    assert res.best("time_ns")["overrides"]["clock_ghz"] == 1.0
    p = str(tmp_path / "campaign.json")
    save_result(res, p)
    res2 = load_result(p)
    assert res2.records == res.records
    assert res2.summary == res.summary


def test_campaign_keep_series(tmp_path):
    spec = _small_spec(axes={},
                       refine=RefineSpec(mode="all", keep_series=True,
                                         pti_ns=50_000.0))
    res = run_campaign(spec, workers=0, use_cache=False)
    (rec,) = res.refined
    assert rec["series_w"] and rec["pti_ns"] == 50_000.0
    total0 = sum(v[0] for v in rec["series_w"].values())
    assert total0 > 0


@pytest.mark.slow
def test_campaign_parallel_workers_match_inline(tmp_path):
    spec = _small_spec(refine=RefineSpec(mode="all"))
    inline = run_campaign(spec, workers=0, use_cache=False)
    par = run_campaign(spec, workers=2, use_cache=False)
    assert par.records == inline.records


@pytest.mark.slow
def test_cli_run_end_to_end(tmp_path):
    """`python -m repro.sweep run <spec>` executes a campaign and the
    artifact is a well-formed campaign record file."""
    spec_path = tmp_path / "spec.json"
    spec = _small_spec(name="cli_campaign")
    spec_path.write_text(json.dumps(spec.to_dict()))
    out = tmp_path / "out.json"
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.sweep", "run", str(spec_path),
         "--workers", "0", "--cache-dir", str(tmp_path / "cache"),
         "--out", str(out)],
        capture_output=True, text=True, timeout=300, env=env,
        cwd=str(tmp_path))
    assert r.returncode == 0, r.stderr
    assert "prescreen" in r.stdout and "grid_points,6" in r.stdout
    rec = json.loads(out.read_text())
    assert rec["summary"]["grid_points"] == 6
    assert any(x["refined"] for x in rec["records"])
    # listing builtins works too
    r2 = subprocess.run([sys.executable, "-m", "repro.sweep", "list"],
                        capture_output=True, text=True, timeout=60, env=env)
    assert r2.returncode == 0 and "dvfs_bw" in r2.stdout
