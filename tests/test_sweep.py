"""Sweep-campaign subsystem: spec/grid, Pareto selection, cache,
runner end-to-end (analytic pre-screen vs event refinement), CLI."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.sweep import (ANALYTIC_AXES, RefineSpec, ResultCache, SweepSpec,
                         builtin_spec_names, load_builtin_spec, pareto_front,
                         run_campaign, select_points)
from repro.sweep.cache import content_key
from repro.sweep.runner import load_result, save_result


def _small_spec(**kw):
    base = dict(
        name="test_campaign",
        workloads=["mobilenet_v2"],
        preset="paper_skew",
        axes={"clock_ghz": [0.4, 0.7, 1.0], "hbm_gbps": [17.0, 34.0]},
        n_tiles=[2],
        refine=RefineSpec(mode="pareto", max_points=2),
    )
    base.update(kw)
    return SweepSpec(**base)


# -- spec / grid -----------------------------------------------------------

def test_spec_grid_and_cells():
    spec = _small_spec(n_tiles=[1, 2])
    assert spec.grid_size == 3 * 2 * 2
    cells = spec.cells()
    assert len(cells) == 2           # n_tiles is structural
    assert all(len(c.points) == 6 for c in cells)
    assert spec.analytic_axes.keys() == {"clock_ghz", "hbm_gbps"}
    assert not spec.structural_axes


def test_spec_structural_axis_splits_cells():
    spec = _small_spec(axes={"clock_ghz": [0.5, 1.0],
                             "vmem_bytes": [2 * 2**20, 16 * 2**20]})
    assert "vmem_bytes" not in ANALYTIC_AXES
    cells = spec.cells()
    assert len(cells) == 2           # one per vmem capacity
    assert all(len(c.points) == 2 for c in cells)
    # structural override lands in the cell's compile config
    assert {c.base_cfg().vmem_bytes for c in cells} == \
        {2 * 2**20, 16 * 2**20}


def test_spec_validation_errors():
    with pytest.raises(KeyError):
        _small_spec(workloads=["nope"])
    with pytest.raises(KeyError):
        _small_spec(axes={"not_a_field": [1]})
    with pytest.raises(ValueError):
        _small_spec(axes={"clock_ghz": []})
    with pytest.raises(ValueError):
        _small_spec(refine=RefineSpec(mode="bogus"))
    with pytest.raises(KeyError):
        _small_spec(preset="no-such-preset")


def test_spec_json_roundtrip():
    spec = _small_spec()
    spec2 = SweepSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2.to_dict() == spec.to_dict()
    assert [p.point_id() for c in spec2.cells() for p in c.points] == \
        [p.point_id() for c in spec.cells() for p in c.points]


def test_builtin_specs_load():
    names = builtin_spec_names()
    assert "dvfs_bw" in names
    spec = load_builtin_spec("dvfs_bw")
    assert spec.grid_size >= 100     # acceptance: >=100-point pre-screen
    assert len(spec.cells()) == 1    # ... in ONE batched XLA call


# -- LM workload grid ------------------------------------------------------

def test_lm_grid_expands_workloads():
    spec = SweepSpec(name="lm_t",
                     lm_grid={"arch": "qwen3-32b", "seq": [64, 128],
                              "batch": [1], "tp": [1, 2]},
                     preset="v5e", axes={"clock_ghz": [0.5, 1.0]},
                     n_tiles=[2])
    assert spec.workloads == ["lm/qwen3-32b/s64b1tp1",
                              "lm/qwen3-32b/s64b1tp2",
                              "lm/qwen3-32b/s128b1tp1",
                              "lm/qwen3-32b/s128b1tp2"]
    assert spec.grid_size == 4 * 2
    assert len(spec.cells()) == 4    # each lm point is its own cell
    # to_dict/from_dict round-trip must not double-expand
    spec2 = SweepSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2.workloads == spec.workloads


def test_lm_grid_scalar_convenience():
    spec = SweepSpec(name="lm_s",
                     lm_grid={"arch": "qwen3-32b", "seq": 64,
                              "batch": 1, "tp": [1, 2]},
                     preset="v5e", n_tiles=[2])
    assert spec.workloads == ["lm/qwen3-32b/s64b1tp1",
                              "lm/qwen3-32b/s64b1tp2"]


def test_lm_grid_validation_errors():
    with pytest.raises(KeyError):    # unknown arch
        SweepSpec(name="x", lm_grid={"arch": "nope", "seq": [1],
                                     "batch": [1], "tp": [1]})
    with pytest.raises(KeyError):    # missing grid axes
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b", "seq": [1]})
    with pytest.raises(KeyError):    # stray key
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b", "seq": [1],
                                     "batch": [1], "tp": [1], "zz": 1})
    with pytest.raises(ValueError):  # no workloads at all
        SweepSpec(name="x", workloads=[])
    with pytest.raises(KeyError):    # malformed lm name
        SweepSpec(name="x", workloads=["lm/qwen3-32b/s64"])


def test_builtin_lm_seq_tp_is_10k_points():
    """Acceptance: the first LM campaign grids lm_layer_ops over
    seq x batch x TP with >1e4 analytic points."""
    spec = load_builtin_spec("lm_seq_tp")
    assert spec.grid_size > 10_000
    assert len(spec.workloads) == 4 * 3 * 4
    assert all(w.startswith("lm/qwen3-32b/") for w in spec.workloads)
    per_cell = spec.grid_size // (len(spec.workloads) * len(spec.n_tiles))
    assert spec.refine.max_points < per_cell      # Pareto-pruned


def test_lm_campaign_tp_collectives_end_to_end():
    """A tiny LM campaign with tensor parallelism runs through
    pre-screen AND event refinement; TP>1 adds ICI collective tasks."""
    spec = SweepSpec(name="lm_tp",
                     lm_grid={"arch": "qwen3-32b", "seq": [64],
                              "batch": [1], "tp": [1, 2]},
                     preset="v5e", axes={}, n_tiles=[2],
                     refine=RefineSpec(mode="all"))
    res = run_campaign(spec, workers=0, use_cache=False)
    assert len(res.refined) == 2
    for r in res.refined:
        assert r["time_ns"] > 0 and r["energy_j"] > 0
        assert r["analytic_time_ns"] > 0
    # TP>1 compiles Megatron-style all-reduces onto the ICI fabric
    from repro.graph.compiler import CompileOptions, compile_ops
    from repro.graph.workloads import resolve_workload
    from repro.hw.presets import resolve_preset

    cfg = resolve_preset("v5e")
    opts = CompileOptions(n_tiles=2)
    n_ici = {tp: sum(t.engine == "ici" for t in compile_ops(
        resolve_workload(f"lm/qwen3-32b/s64b1tp{tp}")(), cfg, opts).tasks)
        for tp in (1, 2)}
    assert n_ici == {1: 0, 2: 2}


def test_lm_grid_phase_kv_ep_axes():
    """lm_grid phase/kv_len/ep axes expand into decode / EP workload
    names; defaults reproduce the historical prefill-only expansion."""
    spec = SweepSpec(name="ph",
                     lm_grid={"arch": "qwen3-32b",
                              "phase": ["prefill", "decode"],
                              "seq": [64], "kv_len": [256, 512],
                              "batch": [1], "tp": [1]},
                     preset="v5e", n_tiles=[2])
    assert spec.workloads == ["lm/qwen3-32b/s64b1tp1",
                              "lm/qwen3-32b/decode/kv256b1tp1",
                              "lm/qwen3-32b/decode/kv512b1tp1"]
    # scalar convenience on phase + round-trip stability
    spec2 = SweepSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2.workloads == spec.workloads
    dec = SweepSpec(name="d",
                    lm_grid={"arch": "qwen3-32b", "phase": "decode",
                             "kv_len": 128, "batch": [2], "tp": [1]},
                    preset="v5e", n_tiles=[2])
    assert dec.workloads == ["lm/qwen3-32b/decode/kv128b2tp1"]
    ep = SweepSpec(name="e",
                   lm_grid={"arch": "qwen3-moe-30b-a3b", "seq": [64],
                            "batch": [1], "tp": [1], "ep": [1, 8]},
                   preset="v5e", n_tiles=[2])
    assert ep.workloads == ["lm/qwen3-moe-30b-a3b/s64b1tp1",
                            "lm/qwen3-moe-30b-a3b/s64b1tp1ep8"]


def test_lm_grid_phase_validation_errors():
    with pytest.raises(KeyError):    # decode without kv_len
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b",
                                     "phase": ["decode"],
                                     "batch": [1], "tp": [1]})
    with pytest.raises(KeyError):    # prefill without seq
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b",
                                     "batch": [1], "tp": [1]})
    with pytest.raises(ValueError):  # bogus phase
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b",
                                     "phase": ["bogus"], "seq": [1],
                                     "batch": [1], "tp": [1]})
    with pytest.raises(KeyError):    # ep>1 on a dense arch
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b", "seq": [1],
                                     "batch": [1], "tp": [1], "ep": [4]})
    with pytest.raises(KeyError):    # kv_len without the decode phase
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b", "seq": [512],
                                     "kv_len": [512, 4096],
                                     "batch": [1], "tp": [1]})
    with pytest.raises(KeyError):    # seq in a decode-only grid
        SweepSpec(name="x", lm_grid={"arch": "qwen3-32b",
                                     "phase": ["decode"], "seq": [512],
                                     "kv_len": [512],
                                     "batch": [1], "tp": [1]})
    with pytest.raises(ValueError):  # exactly one arch per grid
        SweepSpec(name="x", lm_grid={"arch": ["qwen3-32b",
                                              "qwen3-moe-30b-a3b"],
                                     "seq": [1], "batch": [1], "tp": [1]})


def test_lm_grid_layers_dp_pod_axes():
    """The full-model grid axes expand into L<layers>/...dp<dp>pod<pod>
    names; expansion order is batch, tp, ep, dp, layers, pod."""
    spec = SweepSpec(name="pod_t",
                     lm_grid={"arch": "qwen3-32b", "seq": [64],
                              "batch": [8], "tp": [2], "dp": [1, 2],
                              "layers": [2, 4], "pod": [2]},
                     preset="v5e", n_tiles=[2])
    assert spec.workloads == ["lm/qwen3-32b/L2/s64b8tp2pod2",
                              "lm/qwen3-32b/L4/s64b8tp2pod2",
                              "lm/qwen3-32b/L2/s64b8tp2dp2pod2",
                              "lm/qwen3-32b/L4/s64b8tp2dp2pod2"]
    # round-trip must not double-expand
    spec2 = SweepSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert spec2.workloads == spec.workloads
    # train phase rides the seq axis and the layers requirement
    tr = SweepSpec(name="tr",
                   lm_grid={"arch": "qwen3-32b", "phase": "train",
                            "seq": 64, "batch": 8, "tp": 2, "dp": [2],
                            "layers": [2]},
                   preset="v5e", n_tiles=[2])
    assert tr.workloads == ["lm/qwen3-32b/L2/train/s64b8tp2dp2"]


def test_lm_grid_pod_axes_validation_errors():
    base = {"arch": "qwen3-32b", "seq": [64], "batch": [8], "tp": [1]}
    with pytest.raises(KeyError):    # dp>1 without a layers axis
        SweepSpec(name="x", lm_grid={**base, "dp": [2]})
    with pytest.raises(KeyError):    # pod without a layers axis
        SweepSpec(name="x", lm_grid={**base, "pod": [8]})
    with pytest.raises(KeyError):    # train without a layers axis
        SweepSpec(name="x", lm_grid={**base, "phase": ["train"]})
    with pytest.raises(ValueError):  # layers must be >= 1
        SweepSpec(name="x", lm_grid={**base, "layers": [0, 2]})
    with pytest.raises(KeyError):    # global batch must divide over dp
        SweepSpec(name="x", lm_grid={**base, "batch": [3],
                                     "layers": [2], "dp": [2]})
    with pytest.raises(ValueError):  # bogus phase still rejected
        SweepSpec(name="x", lm_grid={**base, "phase": ["serve"],
                                     "layers": [2]})


def test_builtin_lm_full_pod_campaign():
    """Acceptance: lm_full_pod grids full models over layers x dp x tp
    x batch x phase with >=1e4 analytic points, Pareto-pruned."""
    spec = load_builtin_spec("lm_full_pod")
    assert spec.grid_size >= 10_000
    assert all("/L" in w for w in spec.workloads)
    assert any("dp4" in w for w in spec.workloads)
    assert any("/decode/" in w for w in spec.workloads)
    assert any("tp16" in w for w in spec.workloads)   # TP ring > pod
    assert all(w.endswith("pod8") for w in spec.workloads)
    assert spec.description
    per_cell = spec.grid_size // len(spec.cells())
    assert spec.refine.max_points < per_cell          # Pareto-pruned


def test_model_prescreen_memo_shares_parts_across_layers():
    """Cells differing only in the layers axis share one body + one
    head screen via the runner's part memo, and the analytic makespan
    is exactly linear in the layer count (closed-form replication)."""
    from repro.sweep.prescreen import prescreen_cell

    spec = SweepSpec(name="memo_t",
                     lm_grid={"arch": "qwen3-32b", "seq": [64],
                              "batch": [4], "tp": [1],
                              "layers": [1, 2, 4]},
                     preset="v5e", axes={"clock_ghz": [0.6, 0.94]},
                     n_tiles=[2], refine=RefineSpec(mode="none"))
    memo = {}
    screens = {c.workload: prescreen_cell(c, memo=memo)
               for c in spec.cells()}
    assert len(memo) == 2            # one body + one head, 3 cells
    t = {int(w.split("/L")[1].split("/")[0]): s.time_ns
         for w, s in screens.items()}
    # f32 XLA makespans: linear to within float32 resolution
    np.testing.assert_allclose(t[4] - t[2], 2 * (t[2] - t[1]), rtol=1e-5)
    f = {int(w.split("/L")[1].split("/")[0]): s.total_flops
         for w, s in screens.items()}
    assert f[4] - f[2] == pytest.approx(2 * (f[2] - f[1]), rel=1e-12)


def test_full_model_campaign_end_to_end():
    """A tiny full-model pod campaign runs through the fast-path
    pre-screen AND full-op-list event refinement; DP=2 halves the
    per-chip batch and cross-pod TP shows up in the analytic time."""
    spec = SweepSpec(name="pod_e2e",
                     lm_grid={"arch": "qwen3-32b", "phase": ["decode"],
                              "kv_len": [64], "batch": [4], "tp": [2],
                              "dp": [1, 2], "layers": [2], "pod": [2]},
                     preset="v5e", n_tiles=[2],
                     refine=RefineSpec(mode="all"))
    res = run_campaign(spec, workers=0, use_cache=False)
    assert len(res.refined) == 2
    by_wl = {r["workload"]: r for r in res.records}
    full = by_wl["lm/qwen3-32b/L2/decode/kv64b4tp2pod2"]
    half = by_wl["lm/qwen3-32b/L2/decode/kv64b4tp2dp2pod2"]
    for r in (full, half):
        assert r["refined"] and r["time_ns"] > 0 and r["energy_j"] > 0
        assert r["deviation"] > 0
    # DP=2 shards the global batch -> strictly less per-chip work
    assert half["total_flops"] < full["total_flops"]
    assert half["analytic_time_ns"] < full["analytic_time_ns"]


def test_cross_pod_collectives_run_at_dcn_speed():
    """pod placement end-to-end: with TP=2 on 1-chip pods the TP ring
    crosses pods, so cutting DCN bandwidth hurts; an in-pod ring
    ignores it (both analytically and in the compiled CollectiveSpec)."""
    from repro.core.vectorized import (from_tasks, params_of,
                                       schedule_many)
    from repro.graph.compiler import CompileOptions, compile_ops
    from repro.graph.workloads import resolve_workload
    from repro.hw.presets import resolve_preset

    cfg = resolve_preset("v5e")
    slow_dcn = cfg.replace(dcn_gbps=cfg.dcn_gbps / 100)
    pm = np.stack([params_of(cfg), params_of(slow_dcn)])
    opts = CompileOptions(n_tiles=2)

    def times(pod):
        ops = resolve_workload(f"lm/qwen3-32b/L2/s64b4tp2pod{pod}")()
        cw = compile_ops(ops, cfg, opts)
        cross = [t.payload.cross_pod for t in cw.tasks
                 if t.engine == "ici"]
        mk = schedule_many(from_tasks(cw.tasks), pm)
        return cross, mk

    cross_in, mk_in = times(2)       # TP ring fits the pod
    cross_out, mk_out = times(1)     # TP ring spans pods
    assert not any(cross_in) and all(cross_out) and cross_out
    assert mk_in[1] == pytest.approx(mk_in[0])        # DCN irrelevant
    assert mk_out[1] > mk_out[0] * 1.05               # DCN paces it


def test_builtin_decode_and_moe_campaigns_load():
    """Acceptance: lm_decode_kv grids >1e4 analytic points over both
    phases; moe_ep_grid grids EP degrees with alltoall collectives."""
    spec = load_builtin_spec("lm_decode_kv")
    assert spec.grid_size > 10_000
    assert any("/decode/kv" in w for w in spec.workloads)
    assert any("/s" in w for w in spec.workloads)
    assert spec.description
    moe = load_builtin_spec("moe_ep_grid")
    assert any(w.endswith("ep16") for w in moe.workloads)
    assert moe.description


def test_phase_campaign_decode_more_hbm_bound_end_to_end():
    """A tiny prefill+decode campaign runs through pre-screen AND event
    refinement; decode records are strictly more HBM-bound (lower
    flops/byte) than matching prefill records."""
    spec = SweepSpec(name="phase_t",
                     lm_grid={"arch": "qwen3-32b",
                              "phase": ["prefill", "decode"],
                              "seq": [256], "kv_len": [256],
                              "batch": [2], "tp": [1]},
                     preset="v5e", n_tiles=[2],
                     refine=RefineSpec(mode="all"))
    res = run_campaign(spec, workers=0, use_cache=False)
    by_wl = {r["workload"]: r for r in res.records}
    pre = by_wl["lm/qwen3-32b/s256b2tp1"]
    dec = by_wl["lm/qwen3-32b/decode/kv256b2tp1"]
    assert dec["flops_per_byte"] < pre["flops_per_byte"]
    assert dec["hbm_bytes"] > 0 and pre["hbm_bytes"] > 0
    for r in (pre, dec):
        assert r["refined"] and r["time_ns"] > 0 and r["energy_j"] > 0


def test_moe_ep_campaign_alltoall_end_to_end():
    """An EP campaign refines on the event engine: the alltoall
    collectives run on the ICI fabric and EP>1 still produces a valid
    timeline + power record."""
    spec = SweepSpec(name="ep_t",
                     lm_grid={"arch": "qwen3-moe-30b-a3b", "seq": [64],
                              "batch": [1], "tp": [1], "ep": [1, 4]},
                     preset="v5e", n_tiles=[2],
                     refine=RefineSpec(mode="all"))
    res = run_campaign(spec, workers=0, use_cache=False)
    assert len(res.refined) == 2
    for r in res.refined:
        assert r["time_ns"] > 0 and r["energy_j"] > 0
        assert 0.25 < r["deviation"] < 4.0


# -- pareto ----------------------------------------------------------------

def test_pareto_front_simple():
    obj = np.array([[1.0, 4.0], [2.0, 2.0], [4.0, 1.0],
                    [3.0, 3.0], [2.0, 2.0]])
    front = set(pareto_front(obj))
    assert {0, 2} <= front
    assert 3 not in front            # dominated by (2,2)


def test_select_points_modes_and_budget():
    rng = np.random.default_rng(0)
    obj = rng.random((50, 2))
    assert select_points(obj, "all") == list(range(50))
    assert select_points(obj, "none") == []
    picked = select_points(obj, "pareto", max_points=4)
    assert 0 < len(picked) <= 4
    front = sorted(pareto_front(obj), key=lambda i: obj[i, 0])
    if len(front) > 4:               # endpoints pinned under thinning
        assert front[0] in picked and front[-1] in picked
    with pytest.raises(ValueError):
        select_points(obj, "bogus")


# -- cache -----------------------------------------------------------------

def test_cache_roundtrip_and_miss(tmp_path):
    c = ResultCache(str(tmp_path / "cache"))
    key = content_key({"a": 1, "b": [2.0, 3]})
    assert content_key({"b": [2.0, 3], "a": 1}) == key  # canonical
    assert c.get(key) is None
    c.put(key, {"x": 1.5})
    assert c.get(key) == {"x": 1.5}
    assert len(c) == 1
    assert c.hits == 1 and c.misses == 1


def test_cache_corrupt_entry_is_miss_and_deleted(tmp_path):
    """A killed worker mid-write (non-atomic fs) leaves a truncated
    entry: get() must treat it as a miss and delete it, never raise."""
    c = ResultCache(str(tmp_path / "cache"))
    key = content_key({"a": 1})
    c.put(key, {"x": 1})
    p = c._path(key)
    with open(p, "w") as f:
        f.write('{"x": 1, "trunca')              # torn mid-write
    assert c.get(key) is None
    assert not os.path.exists(p)                 # dropped, will re-simulate
    c.put(key, {"x": 2})
    with open(p, "w") as f:
        f.write('[1, 2, 3]')                     # valid JSON, not a record
    assert c.get(key) is None
    assert not os.path.exists(p)
    c.put(key, {"x": 3})
    assert c.get(key) == {"x": 3}                # cache still functional


def test_cache_stats_prune_and_lifetime(tmp_path):
    from repro.sweep.cache import SCHEMA_VERSION

    root = str(tmp_path / "cache")
    c = ResultCache(root)
    k1, k2, k3 = (content_key({"a": i}) for i in range(3))
    c.put(k1, {"x": 1})
    c.put(k2, {"x": 2})
    with open(c.put(k3, {"x": 3}), "w") as f:
        json.dump({"x": 3}, f)                   # forge a legacy entry
    st = c.stats()
    assert st["entries"] == 3 and st["bytes"] > 0
    assert st["by_schema"][SCHEMA_VERSION] == 2
    assert st["by_schema"][None] == 1
    assert c.prune() == 1                        # drops the legacy entry
    assert len(c) == 2 and c.get(k1) == {"x": 1}

    c.get(content_key({"never": 1}))             # one miss
    c.log_stats("t")
    life = ResultCache(root).lifetime_stats()
    assert life["runs"] == 1 and life["misses"] == 1
    assert life["hits"] == 1                     # the get(k1) above
    assert life["hit_rate"] == 0.5


def test_cache_cli_stats_and_prune(tmp_path, capsys):
    from repro.sweep.__main__ import main as sweep_main

    root = str(tmp_path / "cache")
    c = ResultCache(root)
    c.put(content_key({"a": 1}), {"x": 1})
    assert sweep_main(["cache", root, "--prune"]) == 0
    out = capsys.readouterr().out
    assert "entries,1" in out and "schema_current,1" in out
    assert "pruned,0" in out                     # nothing stale yet


# -- runner end-to-end -----------------------------------------------------

def test_campaign_prescreen_matches_event_engine(tmp_path):
    """Acceptance: analytic pre-screen and event refinement agree within
    the deviation bound already asserted for core/vectorized, and the
    cache returns identical records on a second run."""
    spec = _small_spec(cache_dir=str(tmp_path / "cache"))
    res = run_campaign(spec, workers=0)
    assert res.summary["grid_points"] == 6
    assert res.summary["prescreen_calls"] == 1   # one XLA call
    refined = res.refined
    assert 0 < len(refined) <= 2
    for r in refined:
        assert 0.5 < r["deviation"] < 2.0        # same bound as tier-1
        assert r["time_ns"] > 0 and r["energy_j"] > 0
        assert not r["cached"]
    # analytic proxy is present on every grid point
    assert all(r["analytic_time_ns"] > 0 and r["analytic_avg_w"] > 0
               for r in res.records)

    # second run: all refinements served from the cache, identical records
    res2 = run_campaign(spec, workers=0)
    assert res2.summary["cache_hits"] == len(refined)
    assert res2.summary["simulated"] == 0

    def strip(recs):
        return [{k: v for k, v in r.items() if k != "cached"}
                for r in recs]

    assert strip(res2.records) == strip(res.records)
    assert all(r["cached"] for r in res2.refined)


def test_campaign_monotone_in_clock(tmp_path):
    """Analytic pre-screen must preserve the DVFS trend the event engine
    shows: higher clock -> lower makespan."""
    spec = _small_spec(axes={"clock_ghz": [0.3, 0.6, 0.9, 1.2]},
                       refine=RefineSpec(mode="none"))
    res = run_campaign(spec, workers=0, use_cache=False)
    recs = sorted(res.records,
                  key=lambda r: r["overrides"]["clock_ghz"])
    times = [r["analytic_time_ns"] for r in recs]
    assert all(a > b for a, b in zip(times, times[1:]))


def test_best_tie_break_by_grid_index():
    """Equal-metric points resolve by grid index, not iteration
    accident, so reports are stable across runs/backends."""
    from repro.sweep.runner import CampaignResult

    recs = [
        {"grid_index": 2, "refined": True, "time_ns": 5.0, "pid": "late"},
        {"grid_index": 0, "refined": True, "time_ns": 5.0, "pid": "early"},
        {"grid_index": 1, "refined": True, "time_ns": 9.0, "pid": "slow"},
    ]
    res = CampaignResult(spec={}, records=recs, summary={})
    assert res.best("time_ns")["pid"] == "early"
    # reversed record order: same winner
    res2 = CampaignResult(spec={}, records=recs[::-1], summary={})
    assert res2.best("time_ns")["pid"] == "early"
    assert CampaignResult(spec={}, records=[], summary={}).best() is None


def test_campaign_refine_all_and_result_io(tmp_path):
    spec = _small_spec(axes={"clock_ghz": [0.5, 1.0]},
                       refine=RefineSpec(mode="all"))
    res = run_campaign(spec, workers=0, use_cache=False)
    assert len(res.refined) == 2
    assert res.best("time_ns")["overrides"]["clock_ghz"] == 1.0
    assert [r["grid_index"] for r in res.records] == [0, 1]
    p = str(tmp_path / "campaign.json")
    save_result(res, p)
    res2 = load_result(p)
    assert res2.records == res.records
    assert res2.summary == res.summary


def test_campaign_keep_series(tmp_path):
    spec = _small_spec(axes={},
                       refine=RefineSpec(mode="all", keep_series=True,
                                         pti_ns=50_000.0))
    res = run_campaign(spec, workers=0, use_cache=False)
    (rec,) = res.refined
    assert rec["series_w"] and rec["pti_ns"] == 50_000.0
    total0 = sum(v[0] for v in rec["series_w"].values())
    assert total0 > 0


@pytest.mark.slow
def test_campaign_parallel_workers_match_inline(tmp_path):
    spec = _small_spec(refine=RefineSpec(mode="all"))
    inline = run_campaign(spec, workers=0, use_cache=False)
    par = run_campaign(spec, workers=2, use_cache=False)
    assert par.records == inline.records


@pytest.mark.slow
def test_cli_run_end_to_end(tmp_path):
    """`python -m repro.sweep run <spec>` executes a campaign and the
    artifact is a well-formed campaign record file."""
    spec_path = tmp_path / "spec.json"
    spec = _small_spec(name="cli_campaign")
    spec_path.write_text(json.dumps(spec.to_dict()))
    out = tmp_path / "out.json"
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.sweep", "run", str(spec_path),
         "--workers", "0", "--cache-dir", str(tmp_path / "cache"),
         "--out", str(out)],
        capture_output=True, text=True, timeout=300, env=env,
        cwd=str(tmp_path))
    assert r.returncode == 0, r.stderr
    assert "prescreen" in r.stdout and "grid_points,6" in r.stdout
    rec = json.loads(out.read_text())
    assert rec["summary"]["grid_points"] == 6
    assert any(x["refined"] for x in rec["records"])
    # listing builtins works too
    r2 = subprocess.run([sys.executable, "-m", "repro.sweep", "list"],
                        capture_output=True, text=True, timeout=60, env=env)
    assert r2.returncode == 0 and "dvfs_bw" in r2.stdout
