"""Per-arch smoke tests: reduced configs of all 10 assigned architectures
run one forward + one full train step on CPU; shapes + finiteness asserted.
Full configs are exercised only via the dry-run (ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import build_model
from repro.train.loop import init_state, make_train_step

# whole-module: every case compiles + runs a real model step (2-30s each)
pytestmark = pytest.mark.slow

ARCHS = list(REGISTRY)


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model), np.float32))
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32))
    if cfg.family == "vlm":
        batch["images"] = jnp.asarray(rng.standard_normal(
            (B, cfg.n_image_tokens, cfg.d_model), np.float32))
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h = model.forward(params, batch, for_train=False)
    B, S = 2, 32
    assert h.shape == (B, S + cfg.n_meta_tokens, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg, remat=True)
    state = init_state(model, jax.random.PRNGKey(0), dtype=jnp.float32)
    step_fn = make_train_step(model, None,
                              lr_schedule=lambda s: jnp.asarray(1e-3))
    batch = _batch(cfg)
    state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state["opt"]["step"]) == 1
    # a second step changes the loss (params actually updated)
    _, m2 = step_fn(state, batch)
    assert float(m2["loss"]) != float(metrics["loss"])


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-32b", "xlstm-125m",
                                  "hymba-1.5b", "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch):
    """Prefill + 2 decode steps == full forward logits (f32, exact-ish)."""
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0), jnp.float32)
    B, S, SMAX = 2, 20, 40
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 2),
                                    dtype=np.int32))
    batch = {"tokens": toks[:, :S]}
    lg, cache = model.prefill(params, batch, SMAX)
    lg1, cache = model.decode_step(params, cache, toks[:, S:S + 1])
    lg2, cache = model.decode_step(params, cache, toks[:, S + 1:S + 2])

    def ref(n):
        h = model.forward(params, {"tokens": toks[:, :n]}, for_train=False)
        if cfg.n_meta_tokens:
            h = h[:, cfg.n_meta_tokens:]
        return model._logits(params, h[:, -1])

    for got, n in ((lg, S), (lg1, S + 1), (lg2, S + 2)):
        want = ref(n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


def test_vlm_needs_images():
    cfg = REGISTRY["llama-3.2-vision-90b"].reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    # cross-attn gates init at 0 (llama-3.2 behavior: image influence is
    # learned); open them so the path is observable
    params["segments"][0]["cross"]["gate_attn"] = jnp.ones(
        params["segments"][0]["cross"]["gate_attn"].shape, jnp.bfloat16)
    batch = _batch(cfg)
    # changing the image tokens changes the output (cross-attn is live)
    h1 = model.forward(params, batch, for_train=False)
    batch2 = dict(batch)
    batch2["images"] = batch["images"] + 1.0
    h2 = model.forward(params, batch2, for_train=False)
    assert float(jnp.max(jnp.abs(h1.astype(jnp.float32)
                                 - h2.astype(jnp.float32)))) > 1e-3


def test_encoder_bidirectional():
    """HuBERT is not causal: flipping a late frame changes early outputs."""
    cfg = REGISTRY["hubert-xlarge"].reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h1 = model.forward(params, batch, for_train=False)
    frames2 = batch["frames"].at[:, -1].add(10.0)
    h2 = model.forward(params, {**batch, "frames": frames2},
                       for_train=False)
    delta_early = float(jnp.max(jnp.abs(
        (h1 - h2)[:, :4].astype(jnp.float32))))
    assert delta_early > 1e-4


def test_param_counts_near_nominal():
    """Analytic parameter counts are in the right ballpark for the
    name-plate sizes (within a factor ~2 — embeddings/untied heads vary)."""
    nominal = {
        "smollm-135m": 135e6, "minicpm-2b": 2.4e9, "qwen2-1.5b": 1.5e9,
        "qwen3-32b": 32e9, "qwen3-moe-30b-a3b": 30e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "xlstm-125m": 125e6,
        "hymba-1.5b": 1.5e9,
    }
    for name, n in nominal.items():
        got = REGISTRY[name].param_count()
        assert 0.45 * n < got < 2.2 * n, (name, got, n)
